"""CI gate: BENCH_*.json emission sanity.

Fails (exit 1) if the kernel/serve bench JSON artifacts are missing, have
no records, or the serving subsystem stopped delivering its measured
properties:

- k-sparse admission >= 4x analytic bank-byte reduction (N=256, k=50)
- cold admission exercised the sparse path with a >= 2x measured reduction
- WARM admission hit the profile cache and read ZERO bank bytes
- bucketed prefill occupancy >= 0.5 (pow2 padding bounds the loss)
- host syncs per decoded token < 1 (device-resident slot state)
- windowed decode no slower than the SAME RUN's per-token-sync baseline
  (the PR 1 architecture's cadence, so the gate is machine-independent);
  BENCH_STRICT=1 additionally enforces the absolute PR 1 number — for
  perf machines, not shared CI runners whose wall clock varies 2-4x
- the 8-fake-device mesh is BITWISE equal to the 1-device path (graduated
  store bytes, admission Â/B̂, decode token ids) and shards memory
  (per-device resident bytes strictly below single-device); the
  sharded-vs-single throughput floor applies under BENCH_STRICT=1 only
  (8 fake devices timeshare one CPU — wall clock there measures the
  host, not the sharding)

and the training-side lifecycle (BENCH_train.json, PR 3):

- host syncs per TRAINING step < 1 (metrics buffered on device between
  log/checkpoint boundaries)
- every pending profile is accounted for (graduated + evicted == streamed)
- the gang step retraced ZERO times across admission waves
- the graduation roundtrip is bit-exact (persisted store == trained masks)
- BENCH_STRICT=1 additionally enforces an absolute profiles-graduated/min
  floor (perf machines only, same policy as the decode floor)
"""
from __future__ import annotations

import json
import os
import sys

MIN_ADMISSION_REDUCTION = 4.0
MIN_MEASURED_REDUCTION = 2.0
MIN_PREFILL_OCCUPANCY = 0.5
MAX_SYNCS_PER_TOKEN = 1.0
MIN_VS_PER_TOKEN_BASELINE = 0.9   # windowed >= 0.9x same-run baseline
MIN_DECODE_TOKENS_PER_S = 2723.0  # PR 1 absolute, BENCH_STRICT only
MIN_SHARDED_VS_SINGLE = 0.05      # 8-fake-device tok/s floor, STRICT only
                                  # (fake devices timeshare one CPU; this
                                  # only catches catastrophic regressions)
MAX_SYNCS_PER_TRAIN_STEP = 1.0
MIN_PROFILES_PER_MIN = 300.0      # smoke-config absolute, BENCH_STRICT only


def fail(msg: str):
    print(f"check_bench: FAIL — {msg}")
    sys.exit(1)


def load(path: str) -> dict:
    if not os.path.exists(path):
        fail(f"{path} missing (bench did not emit)")
    with open(path) as f:
        data = json.load(f)
    if not data.get("records"):
        fail(f"{path} has no records")
    return data


def record(data: dict, name: str) -> dict:
    rec = next((r for r in data["records"] if r["name"] == name), None)
    if rec is None:
        fail(f"BENCH_{data['suite']}.json missing record {name!r}")
    return rec


def main():
    base = os.environ.get("BENCH_DIR", ".")
    kernels = load(os.path.join(base, "BENCH_kernels.json"))
    serve = load(os.path.join(base, "BENCH_serve.json"))
    train = load(os.path.join(base, "BENCH_train.json"))

    names = {r["name"] for r in kernels["records"]}
    for required in ("mask_aggregate_batched.pallas_interpret",
                     "fused_adapter_batched.decode.pallas_interpret"):
        if required not in names:
            fail(f"BENCH_kernels.json missing record {required!r}")

    agg = record(serve, "admission.aggregate_bytes")
    if agg["reduction"] < MIN_ADMISSION_REDUCTION:
        fail(f"admission byte reduction {agg['reduction']}x < "
             f"{MIN_ADMISSION_REDUCTION}x (bytes_dense={agg['bytes_dense']}, "
             f"bytes_sparse={agg['bytes_sparse']})")

    # the record the ENGINE wrote about the cold admission it actually ran:
    # hard masks must go k-sparse and read fewer bank bytes than dense
    adm = record(serve, "admission.batched")
    if adm.get("path") != "sparse":
        fail(f"cold admission took the {adm.get('path')!r} path — the "
             "k-sparse fast path is not being exercised")
    if adm.get("measured_reduction", 0) < MIN_MEASURED_REDUCTION:
        fail(f"measured admission reduction {adm.get('measured_reduction')}x "
             f"< {MIN_MEASURED_REDUCTION}x — sparse aggregation is reading "
             "too much of the bank")

    # warm admission: every request's profile was LRU-cached, so the wave
    # must admit without touching the bank at all
    warm = record(serve, "admission.profile_cache")
    if warm.get("path") != "cached":
        fail(f"warm admission took the {warm.get('path')!r} path — the "
             "profile cache is not being hit")
    if warm.get("bank_bytes_per_request", -1) != 0:
        fail(f"cache-hit admission read {warm.get('bank_bytes_per_request')} "
             "bank bytes/request — the hit path must read ZERO")
    if warm.get("hit_rate", 0) <= 0:
        fail("profile cache hit rate is zero")

    pre = record(serve, "prefill.batched")
    if pre.get("occupancy", 0) < MIN_PREFILL_OCCUPANCY:
        fail(f"prefill batch occupancy {pre.get('occupancy')} < "
             f"{MIN_PREFILL_OCCUPANCY} — bucketing is fragmenting waves")

    sync = record(serve, "decode.host_syncs")
    if sync.get("syncs_per_token", 1.0) >= MAX_SYNCS_PER_TOKEN:
        fail(f"{sync.get('syncs_per_token')} host syncs per decoded token — "
             "decode state is not staying device-resident")

    tp = record(serve, "decode.throughput")
    if tp.get("tokens_per_s", 0) <= 0:
        fail("BENCH_serve.json has no positive decode throughput")
    base = record(serve, "decode.throughput_per_token_sync")
    floor = MIN_VS_PER_TOKEN_BASELINE * base.get("tokens_per_s", 0)
    if tp["tokens_per_s"] < floor:
        fail(f"windowed decode {tp['tokens_per_s']} tok/s < "
             f"{MIN_VS_PER_TOKEN_BASELINE}x the same-run per-token-sync "
             f"baseline {base.get('tokens_per_s')} — device-resident slot "
             "state stopped paying for itself")
    if os.environ.get("BENCH_STRICT") and \
            tp["tokens_per_s"] < MIN_DECODE_TOKENS_PER_S:
        fail(f"decode {tp['tokens_per_s']} tok/s < PR 1 absolute baseline "
             f"{MIN_DECODE_TOKENS_PER_S} on the smoke config (BENCH_STRICT)")

    # ---- multi-device (8-fake-device mesh vs 1 device) ------------------
    par = record(serve, "sharded.parity")
    for bit in ("onboard_store_bitwise_equal", "serve_entries_bitwise_equal",
                "decode_tokens_equal"):
        if not par.get(bit):
            fail(f"sharded parity broken: {bit} is false — the mesh path "
                 "no longer reproduces the single-device results")
    shtp = record(serve, "sharded.throughput")
    single_b = shtp.get("single_bytes_per_device", {}).get("total", 0)
    shard_b = shtp.get("sharded_bytes_per_device", {}).get("total", 0)
    if not (0 < shard_b < single_b):
        fail(f"sharded per-device bytes {shard_b} not below single-device "
             f"{single_b} — the mesh is not actually sharding state")
    if os.environ.get("BENCH_STRICT") and \
            shtp.get("sharded_vs_single", 0) < MIN_SHARDED_VS_SINGLE:
        fail(f"sharded decode at {shtp.get('sharded_vs_single')}x the "
             f"single-device rate < {MIN_SHARDED_VS_SINGLE}x floor "
             "(BENCH_STRICT)")

    # ---- training lifecycle (roster / onboarding / gang-step) -----------
    tsync = record(train, "train.host_syncs")
    if tsync.get("syncs_per_step", 1.0) >= MAX_SYNCS_PER_TRAIN_STEP:
        fail(f"{tsync.get('syncs_per_step')} host syncs per TRAIN step — "
             "metrics are not staying device-resident between boundaries")
    life = record(train, "onboard.lifecycle")
    if life.get("graduated", 0) <= 0:
        fail("onboarding graduated zero profiles")
    if life.get("graduated", 0) + life.get("evicted", 0) != \
            life.get("profiles", -1):
        fail(f"onboarding lost profiles: {life.get('graduated')} graduated "
             f"+ {life.get('evicted')} evicted != {life.get('profiles')} "
             "streamed")
    if life.get("retraces", 1) != 0:
        fail(f"gang step retraced {life.get('retraces')} times across "
             f"{life.get('admission_waves')} admission waves — slot "
             "admission must not invalidate the jitted step")
    rt = record(train, "graduation.roundtrip")
    if not rt.get("ok"):
        fail("graduation roundtrip is not bit-exact: persisted store masks "
             "differ from the trained profiles'")
    if os.environ.get("BENCH_STRICT") and \
            life.get("profiles_per_min", 0) < MIN_PROFILES_PER_MIN:
        fail(f"onboarding {life.get('profiles_per_min')} profiles/min < "
             f"absolute floor {MIN_PROFILES_PER_MIN} on the smoke config "
             "(BENCH_STRICT)")

    print(f"check_bench: OK — admission reduction {agg['reduction']}x, "
          f"cache-hit admission {warm['bank_bytes_per_request']} B/req "
          f"(hit rate {warm['hit_rate']}), prefill occupancy "
          f"{pre['occupancy']}, {sync['syncs_per_token']} syncs/token, "
          f"decode {tp['tokens_per_s']} tok/s "
          f"(per-token-sync baseline {base.get('tokens_per_s')}); "
          f"{par['devices']}-device parity bitwise OK at {shard_b} B/device "
          f"(single {single_b}, {shtp['sharded_vs_single']}x tok/s); "
          f"train {tsync['syncs_per_step']} syncs/step, onboarding "
          f"{life['graduated']}/{life['profiles']} graduated @ "
          f"{life['profiles_per_min']} profiles/min, {life['retraces']} "
          "gang retraces")


if __name__ == "__main__":
    main()
