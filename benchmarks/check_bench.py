"""CI gate: BENCH_*.json emission sanity.

Fails (exit 1) if the kernel/serve bench JSON artifacts are missing, have
no records, or the serving subsystem stopped delivering its measured
properties:

- k-sparse admission >= 4x analytic bank-byte reduction (N=256, k=50)
- cold admission exercised the sparse path with a >= 2x measured reduction
- WARM admission hit the profile cache and read ZERO bank bytes
- bucketed prefill occupancy >= 0.5 (pow2 padding bounds the loss)
- host syncs per decoded token < 1 (device-resident slot state)
- windowed decode no slower than the SAME RUN's per-token-sync baseline
  (the PR 1 architecture's cadence, so the gate is machine-independent);
  BENCH_STRICT=1 additionally enforces the absolute PR 1 number — for
  perf machines, not shared CI runners whose wall clock varies 2-4x
- continuous batching (PR 7 paged engine, cb.* records): per-request token
  ids BITWISE equal to the windowed engine on the skewed workload, the
  decode step compiled exactly ONCE across admissions/preemptions/resumes,
  mean slot occupancy strictly higher and stranded slot-steps strictly
  lower than windowed; BENCH_STRICT=1 additionally enforces the >= 1.3x
  decode tok/s floor (wall clock on shared runners varies — the
  structural gates are the unconditional contract)
- self-speculative decoding (ISSUE 8, spec.* records): greedy speculative
  output BITWISE equal plain greedy per request on the normal AND the
  adversarial-profile workload, ONE compiled decode step, committed tokens
  per device step > 1, acceptance within [floor, 1), and the adversarial
  profile actually forced rejections; the spec-vs-plain tok/s floor is
  BENCH_STRICT-only (CPU toy shapes are compute-bound — verify is a
  gamma+1-token forward)
- the decode megakernel records (decode_fused.*.pallas_interpret) exist
  for every adapter route (none/bf16/int8/int4) with bitwise parity
  against the jitted jnp oracle and an activation-traffic win > 1
- the 8-fake-device mesh is BITWISE equal to the 1-device path (graduated
  store bytes, admission Â/B̂, decode token ids) and shards memory
  (per-device resident bytes strictly below single-device); the
  sharded-vs-single throughput floor applies under BENCH_STRICT=1 only
  (8 fake devices timeshare one CPU — wall clock there measures the
  host, not the sharding)

and the quantized bank (repro/quant, bank_quant=int8|int4):

- the quant kernel records exist and their byte reduction (tpu_win) > 1
- ANALYTIC at full dims: int8 k-sparse admission <= 0.30x and int4
  <= 0.20x the bf16 DENSE bank bytes per request, and <= 0.55x / 0.32x
  the bf16 SPARSE read (2x is the physical bf16->int8 payload limit; the
  fp16 scales cost the rest — the acceptance's 0.30x/0.20x constants are
  only reachable against the dense bf16 baseline)
- MEASURED on the smoke engine: the quant cold admission took the
  quant_sparse path and read <= 0.55x (int8) / 0.35x (int4) of the
  same-run bf16 cold admission's bank bytes; store-hydrated admission
  (graduated quantized Â/B̂ records) read ZERO bank bytes
- int8 end-to-end greedy decode agrees with the bf16 path on >= 99% of
  tokens; int4 must hold >= 75% (autoregressive compounding: one argmax
  flip on the random-weight smoke model diverges the rest of the
  sequence — per-STEP agreement is also gated at >= 75%)
- quantized engines are strictly lighter per device than the bf16 engine
- BENCH_STRICT=1 additionally enforces a quant-vs-none decode throughput
  floor (dequant must not cost more than it saves)

and the training-side lifecycle (BENCH_train.json, PR 3):

- host syncs per TRAINING step < 1 (metrics buffered on device between
  log/checkpoint boundaries)
- every pending profile is accounted for (graduated + evicted == streamed)
- the gang step retraced ZERO times across admission waves
- the graduation roundtrip is bit-exact (persisted store == trained masks)
- BENCH_STRICT=1 additionally enforces an absolute profiles-graduated/min
  floor (perf machines only, same policy as the decode floor)

and the chaos soak (BENCH_fault.json, PR 6 resilience layer — also
runnable standalone via `check_bench.py --fault-only`, the chaos-smoke
path):

- >= 20% of profiles injected with persistent hydration failures and
  >= 2 store records corrupted; every admission wave still completes
- degraded_requests == the count the PLAN predicts (persistent failures
  + quarantined corrupt records) — nothing more, nothing less; flaky
  (transient) hydrations recover via retry and never degrade
- no checksum-failing record is ever served; corrupt records are all
  detected and quarantined
- UNAFFECTED requests in faulted waves decode BITWISE identical to the
  no-fault run
- gang finite guard: healthy slots bitwise-unaffected by a NaN-poisoned
  slot, the poisoned slot's params/moments bitwise-untouched
- a torn (truncated) checkpoint is rejected and resume falls back to the
  last checksum-clean step
- poisoned onboarding profiles quarantine without graduating and the
  lifecycle accounting still closes

and the observability layer (BENCH_obs.json, ISSUE 10 — produced by
`make obs-smoke`, gated opportunistically here like the chaos artifact):

- obs-on decode tokens BITWISE identical to obs-off (the device
  accumulator is unconditional, so the compiled programs are the same)
- host syncs/token and decode-step jit traces EXACTLY unchanged — the
  layer's zero-extra-syncs / zero-retraces contract
- the exported Chrome trace validates and spans >= 6 categories
- TTFT / per-token decode latency / admission wait / gang-step time
  histograms populated with 0 < p50 <= p99
- zero retrace-sentinel violations; BENCH_STRICT=1 additionally enforces
  the obs-on tok/s floor

A missing BENCH_<family>.json fails with the `make` target that produces
it (run that first); `check_bench.py --summary` instead prints one
consolidated line per family from whatever artifacts exist (each with its
recorded provenance — jax version, devices, mesh, git SHA, config hash),
marking absent families with their target.
"""
from __future__ import annotations

import json
import os
import sys

MIN_ADMISSION_REDUCTION = 4.0
MIN_MEASURED_REDUCTION = 2.0
MIN_PREFILL_OCCUPANCY = 0.5
MAX_SYNCS_PER_TOKEN = 1.0
MIN_VS_PER_TOKEN_BASELINE = 0.9   # windowed >= 0.9x same-run baseline
MIN_DECODE_TOKENS_PER_S = 2723.0  # PR 1 absolute, BENCH_STRICT only
MIN_SHARDED_VS_SINGLE = 0.05      # 8-fake-device tok/s floor, STRICT only
                                  # (fake devices timeshare one CPU; this
                                  # only catches catastrophic regressions)
MIN_CB_TOK_S_RATIO = 1.3          # continuous vs windowed, STRICT only
MAX_SYNCS_PER_TRAIN_STEP = 1.0
MIN_PROFILES_PER_MIN = 300.0      # smoke-config absolute, BENCH_STRICT only

# quantized bank (bank bytes are the mandatory reduction; agreement and
# the STRICT throughput floor keep the quality/latency story honest)
QUANT_GATES = {
    "int8": {"vs_dense": 0.30, "vs_sparse": 0.55, "measured_vs_none": 0.55,
             "token_agreement": 0.99},
    "int4": {"vs_dense": 0.20, "vs_sparse": 0.32, "measured_vs_none": 0.35,
             "token_agreement": 0.75},
}
MIN_INT4_STEP_AGREEMENT = 0.75
MIN_QUANT_VS_NONE_TPS = 0.15      # BENCH_STRICT only

# chaos soak (BENCH_fault.json, PR 6): injected-failure floors the plan
# must reach for the soak to mean anything
MIN_INJECTED_FAIL_RATE = 0.20
MIN_CORRUPT_RECORDS = 2

# self-speculative decoding (spec.* records, ISSUE 8). The floor is low on
# purpose: acceptance depends on how far the adapter moves the bare PLM's
# argmax, which the random-weight smoke model only loosely controls — the
# hard gates are parity, one trace, and committed tokens/device-step.
MIN_SPEC_ACCEPTANCE = 0.05
MIN_SPEC_COMMITTED_PER_STEP = 1.0
MIN_SPEC_TOK_S_RATIO = 0.4        # BENCH_STRICT only (CPU is compute-bound)

# observability (BENCH_obs.json, ISSUE 10)
MIN_OBS_TRACE_CATEGORIES = 6
MIN_OBS_TOK_S_RATIO = 0.5         # BENCH_STRICT only

# which `make` target (re)produces each BENCH_<family>.json artifact
FAMILIES = {"kernels": "bench-smoke", "serve": "bench-smoke",
            "train": "bench-smoke", "fault": "chaos-smoke",
            "obs": "obs-smoke"}


def fail(msg: str):
    print(f"check_bench: FAIL — {msg}")
    sys.exit(1)


def family_path(family: str) -> str:
    return os.path.join(os.environ.get("BENCH_DIR", "."),
                        f"BENCH_{family}.json")


def load_family(family: str) -> dict:
    path = family_path(family)
    if not os.path.exists(path):
        fail(f"BENCH_{family}.json missing — run `make {FAMILIES[family]}` "
             f"first (looked in {os.path.dirname(path) or '.'})")
    with open(path) as f:
        data = json.load(f)
    if not data.get("records"):
        fail(f"{path} has no records — run `make {FAMILIES[family]}` again")
    return data


def record(data: dict, name: str) -> dict:
    rec = next((r for r in data["records"] if r["name"] == name), None)
    if rec is None:
        fail(f"BENCH_{data['suite']}.json missing record {name!r}")
    return rec


def check_fault(fault: dict):
    """Chaos-soak gates (BENCH_fault.json): every resilience contract the
    PR 6 layer claims, checked against what the soak actually observed."""
    chaos = record(fault, "resilience.serve_chaos")
    if chaos.get("failed_waves", 1) != 0 or not chaos.get("all_done"):
        fail(f"chaos soak dropped work: {chaos.get('failed_waves')} failed "
             f"admission waves, all_done={chaos.get('all_done')} — degraded "
             "serving must complete every wave")
    if chaos.get("injected_fail_rate", 0) < MIN_INJECTED_FAIL_RATE:
        fail(f"chaos plan injected only {chaos.get('injected_fail_rate')} "
             f"persistent hydration failures < {MIN_INJECTED_FAIL_RATE} — "
             "the soak is not stressing anything")
    if chaos.get("corrupt_records", 0) < MIN_CORRUPT_RECORDS:
        fail(f"chaos plan corrupted {chaos.get('corrupt_records')} records "
             f"< {MIN_CORRUPT_RECORDS}")
    if chaos.get("corrupt_detected") != chaos.get("corrupt_records"):
        fail(f"store crc missed corruption: {chaos.get('corrupt_detected')} "
             f"detected of {chaos.get('corrupt_records')} injected")
    if chaos.get("corrupt_served", 1) != 0:
        fail(f"{chaos.get('corrupt_served')} requests were served from a "
             "checksum-failing record — corrupt records must NEVER serve")
    exp, got = chaos.get("expected_degraded"), chaos.get("degraded_requests")
    if not exp or got != exp:
        fail(f"degraded accounting broken: plan predicts {exp} degraded "
             f"requests, engine served {got} — every persistent failure "
             "degrades, nothing else does")
    if chaos.get("flaky_degraded", 1) != 0:
        fail(f"{chaos.get('flaky_degraded')} flaky-profile requests "
             "degraded — transient hydration failures must recover via "
             "retry")
    if chaos.get("hydration_retries", 0) <= 0:
        fail("the soak recorded zero hydration retries — the backoff path "
             "is not being exercised")
    if chaos.get("quarantined_profiles", 0) < MIN_CORRUPT_RECORDS:
        fail(f"only {chaos.get('quarantined_profiles')} profiles "
             f"quarantined, expected every corrupt record's")
    if not chaos.get("unaffected_bitwise"):
        fail("UNAFFECTED requests in faulted waves decoded differently "
             "from the no-fault run — degradation must be surgical")

    gang = record(fault, "resilience.gang_guard")
    if not gang.get("healthy_bitwise"):
        fail("gang finite guard: healthy slots' params/moments are not "
             "bitwise-identical to the injection-off run")
    if not gang.get("poisoned_untouched"):
        fail("gang finite guard: the poisoned slot's params or Adam "
             "moments moved — a non-finite update leaked through")
    if gang.get("nonfinite_detected", 0) <= 0:
        fail("gang finite guard saw zero non-finite strikes despite "
             "injection — the detector is dead")

    ck = record(fault, "resilience.ckpt_fallback")
    if not ck.get("fallback_ok"):
        fail(f"checkpoint fallback broken: torn step {ck.get('torn_step')} "
             f"rejected={ck.get('torn_rejected')}, resumed from "
             f"{ck.get('resumed_step')} — resume must land on the last "
             "checksum-clean checkpoint")

    ob = record(fault, "resilience.onboard_quarantine")
    if ob.get("quarantined", 0) < 1:
        fail("poisoned onboarding quarantined zero profiles")
    if not ob.get("accounting_ok"):
        fail(f"onboarding lost profiles under poisoning: "
             f"{ob.get('graduated')} graduated + {ob.get('evicted')} "
             f"evicted + {ob.get('quarantined')} quarantined != "
             f"{ob.get('profiles')} streamed")
    if ob.get("quarantined_served", 1) != 0:
        fail("a quarantined profile reached the serving store")

    # elastic reshard record is emitted only on >= 8-device runs
    el = next((r for r in fault["records"]
               if r["name"] == "resilience.elastic"), None)
    if el is not None and not el.get("bitwise"):
        fail("surviving-mesh reshard changed state values")

    print(f"check_bench[fault]: OK — {chaos['degraded_requests']}/"
          f"{chaos['requests']} requests degraded as planned over "
          f"{chaos['waves']} waves (0 failed), "
          f"{chaos['corrupt_detected']} corrupt records caught, "
          f"{chaos['hydration_retries']} retries; gang guard bitwise OK "
          f"({gang['nonfinite_detected']} strikes), checkpoint fell back "
          f"to step {ck['resumed_step']}, onboarding quarantined "
          f"{ob['quarantined']}/{ob['profiles']}"
          + ("" if el is None else
             f"; elastic reshard bitwise on {el['devices']} devices"))


def check_obs(obs: dict):
    """Observability gates (BENCH_obs.json): obs-on must be free — bitwise
    tokens, unchanged syncs/token, unchanged jit trace counts — and the
    trace/histogram artifacts must actually carry signal."""
    par = record(obs, "obs.parity")
    if not par.get("tokens_equal"):
        fail("obs-on decode tokens != obs-off — attaching the bundle "
             "changed the compiled program (parity broken)")
    if par.get("host_syncs_on") != par.get("host_syncs_off") or \
            par.get("syncs_per_token_on") != par.get("syncs_per_token_off"):
        fail(f"obs changed host syncs: {par.get('host_syncs_off')} -> "
             f"{par.get('host_syncs_on')} "
             f"({par.get('syncs_per_token_off')} -> "
             f"{par.get('syncs_per_token_on')} syncs/token) — the layer "
             "must add ZERO syncs per token")
    if par.get("step_traces_on") != par.get("step_traces_off"):
        fail(f"obs changed decode jit traces: {par.get('step_traces_off')} "
             f"-> {par.get('step_traces_on')} — the layer must add ZERO "
             "retraces")
    tr = record(obs, "obs.trace")
    if not tr.get("valid"):
        fail("exported trace is not valid Chrome trace-event JSON")
    if tr.get("categories", 0) < MIN_OBS_TRACE_CATEGORIES:
        fail(f"trace covers {tr.get('categories')} span categories < "
             f"{MIN_OBS_TRACE_CATEGORIES} — the smoke must exercise "
             "admission/prefill/decode-window/gang-step/graduation/"
             "resilience")
    hist = record(obs, "obs.histograms")
    for prefix in ("ttft", "decode_token", "admission_wait", "gang_step"):
        cnt = hist.get(f"{prefix}_count", 0)
        p50, p99 = hist.get(f"{prefix}_p50_us", 0), \
            hist.get(f"{prefix}_p99_us", 0)
        if not cnt or not (0 < p50 <= p99):
            fail(f"{prefix} latency histogram empty or inconsistent "
                 f"(count={cnt}, p50={p50}, p99={p99})")
    sen = record(obs, "obs.sentinel")
    if sen.get("violations", 1) != 0:
        fail(f"{sen.get('violations')} retrace-sentinel violations — a "
             "hot-path fn recompiled beyond its contract")
    ov = record(obs, "obs.overhead")
    if os.environ.get("BENCH_STRICT") and \
            ov.get("ratio", 0) < MIN_OBS_TOK_S_RATIO:
        fail(f"obs-on decode at {ov.get('ratio')}x obs-off tok/s < "
             f"{MIN_OBS_TOK_S_RATIO}x floor (BENCH_STRICT)")
    print(f"check_bench[obs]: OK — parity bitwise, "
          f"{par['syncs_per_token_on']} syncs/token unchanged, "
          f"{par['step_traces_on']} decode trace(s) unchanged, "
          f"{tr['events']} trace events over {tr['categories']} "
          f"categories, {ov['ratio']}x tok/s with obs on")


def main(fault_only: bool = False):
    if fault_only:
        check_fault(load_family("fault"))
        return
    kernels = load_family("kernels")
    serve = load_family("serve")
    train = load_family("train")
    # the chaos and obs artifacts are produced by `make chaos-smoke` /
    # `make obs-smoke`, each of which runs its own mandatory gate in
    # `make verify` — here they are gated opportunistically
    # (stale-artifact safety net)
    if os.path.exists(family_path("fault")):
        check_fault(load_family("fault"))
    if os.path.exists(family_path("obs")):
        check_obs(load_family("obs"))

    names = {r["name"] for r in kernels["records"]}
    for required in ("mask_aggregate_batched.pallas_interpret",
                     "fused_adapter_batched.decode.pallas_interpret"):
        if required not in names:
            fail(f"BENCH_kernels.json missing record {required!r}")

    # decode megakernel: every adapter route present, bitwise parity vs
    # the jitted oracle, activation round-trips actually collapsed
    for route in ("none", "bf16", "int8", "int4"):
        mk = record(kernels, f"decode_fused.{route}.pallas_interpret")
        if not mk.get("parity"):
            fail(f"decode_fused.{route}: megakernel output != the jitted "
                 "jnp oracle — the fused decode step is no longer bitwise")
        if mk.get("tpu_win", 0) <= 1.0:
            fail(f"decode_fused.{route}: activation-traffic win "
                 f"{mk.get('tpu_win')}x <= 1x — the megakernel stopped "
                 "collapsing per-layer intermediate round-trips")
    for scheme in QUANT_GATES:
        for required in (f"mask_aggregate_quant_{scheme}.pallas_interpret",
                         f"fused_adapter_quant_{scheme}.decode"
                         ".pallas_interpret"):
            rec = record(kernels, required)
            if rec.get("tpu_win", 0) <= 1.0:
                fail(f"{required}: quantized bytes reduction "
                     f"{rec.get('tpu_win')}x <= 1x — the dequant-fused "
                     "kernel stopped saving HBM traffic")

    agg = record(serve, "admission.aggregate_bytes")
    if agg["reduction"] < MIN_ADMISSION_REDUCTION:
        fail(f"admission byte reduction {agg['reduction']}x < "
             f"{MIN_ADMISSION_REDUCTION}x (bytes_dense={agg['bytes_dense']}, "
             f"bytes_sparse={agg['bytes_sparse']})")

    # the record the ENGINE wrote about the cold admission it actually ran:
    # hard masks must go k-sparse and read fewer bank bytes than dense
    adm = record(serve, "admission.batched")
    if adm.get("path") != "sparse":
        fail(f"cold admission took the {adm.get('path')!r} path — the "
             "k-sparse fast path is not being exercised")
    if adm.get("measured_reduction", 0) < MIN_MEASURED_REDUCTION:
        fail(f"measured admission reduction {adm.get('measured_reduction')}x "
             f"< {MIN_MEASURED_REDUCTION}x — sparse aggregation is reading "
             "too much of the bank")

    # warm admission: every request's profile was LRU-cached, so the wave
    # must admit without touching the bank at all
    warm = record(serve, "admission.profile_cache")
    if warm.get("path") != "cached":
        fail(f"warm admission took the {warm.get('path')!r} path — the "
             "profile cache is not being hit")
    if warm.get("bank_bytes_per_request", -1) != 0:
        fail(f"cache-hit admission read {warm.get('bank_bytes_per_request')} "
             "bank bytes/request — the hit path must read ZERO")
    if warm.get("hit_rate", 0) <= 0:
        fail("profile cache hit rate is zero")

    pre = record(serve, "prefill.batched")
    if pre.get("occupancy", 0) < MIN_PREFILL_OCCUPANCY:
        fail(f"prefill batch occupancy {pre.get('occupancy')} < "
             f"{MIN_PREFILL_OCCUPANCY} — bucketing is fragmenting waves")

    sync = record(serve, "decode.host_syncs")
    if sync.get("syncs_per_token", 1.0) >= MAX_SYNCS_PER_TOKEN:
        fail(f"{sync.get('syncs_per_token')} host syncs per decoded token — "
             "decode state is not staying device-resident")

    tp = record(serve, "decode.throughput")
    if tp.get("tokens_per_s", 0) <= 0:
        fail("BENCH_serve.json has no positive decode throughput")
    base = record(serve, "decode.throughput_per_token_sync")
    floor = MIN_VS_PER_TOKEN_BASELINE * base.get("tokens_per_s", 0)
    if tp["tokens_per_s"] < floor:
        fail(f"windowed decode {tp['tokens_per_s']} tok/s < "
             f"{MIN_VS_PER_TOKEN_BASELINE}x the same-run per-token-sync "
             f"baseline {base.get('tokens_per_s')} — device-resident slot "
             "state stopped paying for itself")
    if os.environ.get("BENCH_STRICT") and \
            tp["tokens_per_s"] < MIN_DECODE_TOKENS_PER_S:
        fail(f"decode {tp['tokens_per_s']} tok/s < PR 1 absolute baseline "
             f"{MIN_DECODE_TOKENS_PER_S} on the smoke config (BENCH_STRICT)")

    # ---- quantized bank (int8/int4) -------------------------------------
    for scheme, g in QUANT_GATES.items():
        if agg.get(f"{scheme}_vs_dense", 1.0) > g["vs_dense"]:
            fail(f"analytic {scheme} sparse admission at "
                 f"{agg.get(f'{scheme}_vs_dense')}x the bf16 dense bytes "
                 f"> {g['vs_dense']}x ceiling")
        if agg.get(f"{scheme}_vs_sparse", 1.0) > g["vs_sparse"]:
            fail(f"analytic {scheme} sparse admission at "
                 f"{agg.get(f'{scheme}_vs_sparse')}x the bf16 sparse bytes "
                 f"> {g['vs_sparse']}x ceiling")
        qadm = record(serve, f"admission.quant_{scheme}")
        if qadm.get("path") != "quant_sparse":
            fail(f"{scheme} cold admission took the {qadm.get('path')!r} "
                 "path — the quantized k-sparse kernel is not being "
                 "exercised")
        got = qadm.get("bank_bytes_per_request", 0)
        ref_b = qadm.get("none_bytes_per_request", 0)
        if not (0 < got <= g["measured_vs_none"] * ref_b):
            fail(f"{scheme} admission read {got} bank B/req vs bf16 "
                 f"{ref_b} — outside (0, {g['measured_vs_none']}x] "
                 "(quantization must actually cut the measured read)")
        qstore = record(serve, f"admission.quant_store_{scheme}")
        if qstore.get("path") != "quant_store" or \
                qstore.get("bank_bytes_per_request", -1) != 0:
            fail(f"store-record {scheme} admission path="
                 f"{qstore.get('path')!r} read "
                 f"{qstore.get('bank_bytes_per_request')} B/req — "
                 "graduated quantized records must admit with ZERO bank "
                 "reads")
        qdec = record(serve, f"decode.quant_{scheme}")
        if qdec.get("token_agreement", 0) < g["token_agreement"]:
            fail(f"{scheme} greedy decode agreed on "
                 f"{qdec.get('token_agreement')} of tokens < "
                 f"{g['token_agreement']} floor")
        if scheme == "int4" and \
                qdec.get("step_agreement", 0) < MIN_INT4_STEP_AGREEMENT:
            fail(f"int4 per-step agreement {qdec.get('step_agreement')} < "
                 f"{MIN_INT4_STEP_AGREEMENT}")
        if not (0 < qdec.get("resident_bytes", 0)
                < qdec.get("none_resident_bytes", 0)):
            fail(f"{scheme} engine resident bytes "
                 f"{qdec.get('resident_bytes')} not below the bf16 "
                 f"engine's {qdec.get('none_resident_bytes')} — dropping "
                 "the bf16 bank stopped paying for itself")
        if os.environ.get("BENCH_STRICT") and \
                qdec.get("tokens_per_s", 0) < \
                MIN_QUANT_VS_NONE_TPS * qdec.get("none_tokens_per_s", 0):
            fail(f"{scheme} decode {qdec.get('tokens_per_s')} tok/s < "
                 f"{MIN_QUANT_VS_NONE_TPS}x the same-run bf16 rate "
                 f"{qdec.get('none_tokens_per_s')} (BENCH_STRICT)")

    # ---- continuous batching (paged KV + adapter-slot memory) -----------
    cbp = record(serve, "cb.parity")
    if not cbp.get("tokens_equal"):
        fail("continuous-batching tokens != windowed tokens — the paged "
             "engine must be BITWISE identical per request")
    if cbp.get("step_traces") != 1:
        fail(f"continuous decode step traced {cbp.get('step_traces')} "
             "times — admissions/preemptions/resumes must reuse ONE "
             "compiled program")
    cbo = record(serve, "cb.occupancy")
    if cbo.get("continuous", 0) <= cbo.get("windowed", 1):
        fail(f"continuous slot occupancy {cbo.get('continuous')} <= "
             f"windowed {cbo.get('windowed')} — continuous batching "
             "stopped filling freed slots mid-decode")
    if cbo.get("continuous_stranded", 1) >= cbo.get("windowed_stranded", 0):
        fail(f"continuous stranded slot-steps {cbo.get('continuous_stranded')}"
             f" >= windowed {cbo.get('windowed_stranded')} — short requests "
             "are still waiting out the wave straggler")
    cbt = record(serve, "cb.tok_s_vs_windowed")
    if cbt.get("ratio", 0) <= 0:
        fail("continuous-vs-windowed tok/s ratio is not positive")
    if os.environ.get("BENCH_STRICT") and \
            cbt.get("ratio", 0) < MIN_CB_TOK_S_RATIO:
        fail(f"continuous decode at {cbt.get('ratio')}x windowed tok/s < "
             f"{MIN_CB_TOK_S_RATIO}x floor (BENCH_STRICT)")

    # ---- self-speculative decoding (bare-PLM draft, adapted verify) -----
    spp = record(serve, "spec.parity")
    if not spp.get("tokens_equal"):
        fail("speculative greedy tokens != plain greedy tokens — "
             "draft/verify/commit must be BITWISE per request")
    if not spp.get("adversarial_tokens_equal"):
        fail("adversarial-profile speculative tokens != plain — the "
             "rejection fallback must be the verifier's own argmax")
    if spp.get("step_traces") != 1:
        fail(f"spec decode step traced {spp.get('step_traces')} times — "
             "draft+verify must stay ONE compiled program")
    spa = record(serve, "spec.acceptance")
    if spa.get("committed_per_device_step", 0) <= \
            MIN_SPEC_COMMITTED_PER_STEP:
        fail(f"spec committed {spa.get('committed_per_device_step')} "
             f"tokens/device-step <= {MIN_SPEC_COMMITTED_PER_STEP} — "
             "speculation is not amortizing decode steps")
    if not (MIN_SPEC_ACCEPTANCE <= spa.get("acceptance_rate", -1) <= 1.0):
        fail(f"spec acceptance rate {spa.get('acceptance_rate')} outside "
             f"[{MIN_SPEC_ACCEPTANCE}, 1]")
    if spa.get("adversarial_acceptance_rate", 1.0) >= 1.0:
        fail("the adversarial profile forced no rejections — the "
             "reject/fallback path is not being measured")
    spt = record(serve, "spec.tok_s_vs_plain")
    if spt.get("spec_device_steps", 1) >= spt.get("plain_device_steps", 0):
        fail(f"spec used {spt.get('spec_device_steps')} device steps >= "
             f"plain's {spt.get('plain_device_steps')} — the same tokens "
             "must take strictly fewer steps")
    if os.environ.get("BENCH_STRICT") and \
            spt.get("ratio", 0) < MIN_SPEC_TOK_S_RATIO:
        fail(f"spec decode at {spt.get('ratio')}x plain tok/s < "
             f"{MIN_SPEC_TOK_S_RATIO}x floor (BENCH_STRICT)")

    # ---- heterogeneous adapter-type bank (typed segments) ---------------
    htp = record(serve, "hetero.parity")
    if not htp.get("tokens_equal"):
        fail("hetero engine tokens != composed dense reference — "
             "cross-segment aggregation / composed apply / prefix "
             "hydration must be BITWISE per emitted token")
    if htp.get("step_traces") != 1:
        fail(f"hetero decode step traced {htp.get('step_traces')} times — "
             "typed entries must serve through ONE compiled program")
    if not htp.get("prefix_on_requests") or \
            not htp.get("prefix_off_requests"):
        fail(f"hetero workload did not exercise both prefix paths "
             f"(on={htp.get('prefix_on_requests')}, "
             f"off={htp.get('prefix_off_requests')})")
    hta = record(serve, "hetero.admission")
    if hta.get("path") != "sparse":
        fail(f"hetero cold admission took the {hta.get('path')!r} path — "
             "the unified-space k-sparse fast path is not being exercised")
    for col, v in hta.items():
        if col.startswith("record_bytes_") and v <= 0:
            fail(f"hetero admission {col} = {v} — a typed segment "
                 "contributed no record bytes")
    htk = record(serve, "hetero.kernel_parity")
    for t, ok in htk.items():
        if t != "name" and not ok:
            fail(f"hetero kernel parity broken for {t!r}: interpret != "
                 "ref on the admitted entries")

    # ---- multi-device (8-fake-device mesh vs 1 device) ------------------
    par = record(serve, "sharded.parity")
    for bit in ("onboard_store_bitwise_equal", "serve_entries_bitwise_equal",
                "decode_tokens_equal", "cb_decode_tokens_equal"):
        if not par.get(bit):
            fail(f"sharded parity broken: {bit} is false — the mesh path "
                 "no longer reproduces the single-device results")
    cbtr = par.get("cb_step_traces", {})
    if cbtr.get("sharded") != 1:
        fail(f"continuous decode step traced {cbtr.get('sharded')} times "
             "on the mesh — one compiled program must serve all devices")
    shtp = record(serve, "sharded.throughput")
    single_b = shtp.get("single_bytes_per_device", {}).get("total", 0)
    shard_b = shtp.get("sharded_bytes_per_device", {}).get("total", 0)
    if not (0 < shard_b < single_b):
        fail(f"sharded per-device bytes {shard_b} not below single-device "
             f"{single_b} — the mesh is not actually sharding state")
    if os.environ.get("BENCH_STRICT") and \
            shtp.get("sharded_vs_single", 0) < MIN_SHARDED_VS_SINGLE:
        fail(f"sharded decode at {shtp.get('sharded_vs_single')}x the "
             f"single-device rate < {MIN_SHARDED_VS_SINGLE}x floor "
             "(BENCH_STRICT)")

    # ---- training lifecycle (roster / onboarding / gang-step) -----------
    tsync = record(train, "train.host_syncs")
    if tsync.get("syncs_per_step", 1.0) >= MAX_SYNCS_PER_TRAIN_STEP:
        fail(f"{tsync.get('syncs_per_step')} host syncs per TRAIN step — "
             "metrics are not staying device-resident between boundaries")
    life = record(train, "onboard.lifecycle")
    if life.get("graduated", 0) <= 0:
        fail("onboarding graduated zero profiles")
    if life.get("graduated", 0) + life.get("evicted", 0) != \
            life.get("profiles", -1):
        fail(f"onboarding lost profiles: {life.get('graduated')} graduated "
             f"+ {life.get('evicted')} evicted != {life.get('profiles')} "
             "streamed")
    if life.get("retraces", 1) != 0:
        fail(f"gang step retraced {life.get('retraces')} times across "
             f"{life.get('admission_waves')} admission waves — slot "
             "admission must not invalidate the jitted step")
    rt = record(train, "graduation.roundtrip")
    if not rt.get("ok"):
        fail("graduation roundtrip is not bit-exact: persisted store masks "
             "differ from the trained profiles'")
    if os.environ.get("BENCH_STRICT") and \
            life.get("profiles_per_min", 0) < MIN_PROFILES_PER_MIN:
        fail(f"onboarding {life.get('profiles_per_min')} profiles/min < "
             f"absolute floor {MIN_PROFILES_PER_MIN} on the smoke config "
             "(BENCH_STRICT)")

    q8 = record(serve, "admission.quant_int8")
    q4 = record(serve, "admission.quant_int4")
    print(f"check_bench: OK — admission reduction {agg['reduction']}x "
          f"(int8 {q8['vs_none']}x / int4 {q4['vs_none']}x of bf16 sparse "
          f"bytes, int8 agreement "
          f"{record(serve, 'decode.quant_int8')['token_agreement']}), "
          f"cache-hit admission {warm['bank_bytes_per_request']} B/req "
          f"(hit rate {warm['hit_rate']}), prefill occupancy "
          f"{pre['occupancy']}, {sync['syncs_per_token']} syncs/token, "
          f"decode {tp['tokens_per_s']} tok/s "
          f"(per-token-sync baseline {base.get('tokens_per_s')}); "
          f"continuous batching bitwise OK, occupancy "
          f"{cbo['windowed']} -> {cbo['continuous']}, stranded "
          f"{cbo['windowed_stranded']} -> {cbo['continuous_stranded']}, "
          f"{cbt['ratio']}x tok/s; "
          f"{par['devices']}-device parity bitwise OK at {shard_b} B/device "
          f"(single {single_b}, {shtp['sharded_vs_single']}x tok/s); "
          f"train {tsync['syncs_per_step']} syncs/step, onboarding "
          f"{life['graduated']}/{life['profiles']} graduated @ "
          f"{life['profiles_per_min']} profiles/min, {life['retraces']} "
          f"gang retraces; speculative decode bitwise OK at "
          f"{spa['committed_per_device_step']} committed tokens/step "
          f"(acceptance {spa['acceptance_rate']}, adversarial "
          f"{spa['adversarial_acceptance_rate']}), megakernel parity "
          "bitwise on all 4 routes")


def _fmt(recs: dict, name: str, key: str, label: str):
    """One `label value` fragment, or None when the record/key is absent
    (summary mode tolerates partial artifacts)."""
    v = recs.get(name, {}).get(key)
    return None if v is None else f"{label} {v}"


def _gate_families() -> list:
    """Re-run the gates over whatever artifacts exist; returns the list of
    failing family groups (empty = all present families pass). SystemExit
    from fail() is caught per group so one failing family can't mask
    another's verdict in the summary read-out."""
    present = {f for f in FAMILIES if os.path.exists(family_path(f))}
    failures = []

    def run(label, fn):
        try:
            fn()
        except SystemExit as e:
            if e.code:
                failures.append(label)
        except Exception as exc:  # corrupt artifact == failing gate
            print(f"check_bench: FAIL — {label}: {exc}")
            failures.append(label)

    if {"kernels", "serve", "train"} <= present:
        # main() gates the three bench-smoke families together (fault and
        # obs opportunistically) — run it once, attribute to the group
        run("kernels/serve/train", main)
    else:
        # partial artifact sets stay tolerated (the absent families are
        # already marked in the read-out) — gate what exists
        if "fault" in present:
            run("fault", lambda: check_fault(load_family("fault")))
        if "obs" in present:
            run("obs", lambda: check_obs(load_family("obs")))
    return failures


def summary():
    """One consolidated line per family from whatever artifacts exist;
    absent families are marked with the `make` target that produces them.
    The read-out is ALSO a gate: any present family whose checks fail
    exits non-zero (a green summary can be trusted in CI)."""
    digests = {
        "kernels": [
            ("mask_aggregate.sparse_ref", "tpu_win", "sparse-agg win"),
            ("fused_adapter.pallas_interpret", "tpu_win", "fused-adapter"),
            ("mask_aggregate_quant_int4.pallas_interpret", "tpu_win",
             "int4-agg"),
            ("decode_fused.bf16.pallas_interpret", "tpu_win",
             "megakernel-act"),
        ],
        "serve": [
            ("admission.aggregate_bytes", "reduction", "admission"),
            ("decode.throughput", "tokens_per_s", "decode tok/s"),
            ("cb.tok_s_vs_windowed", "ratio", "cb ratio"),
            ("spec.acceptance", "committed_per_device_step",
             "spec tokens/step"),
            ("spec.acceptance", "acceptance_rate", "acceptance"),
            ("hetero.parity", "tokens_equal", "hetero parity"),
            ("hetero.admission", "bank_bytes_per_request",
             "hetero bank B/req"),
        ],
        "train": [
            ("train.host_syncs", "syncs_per_step", "syncs/step"),
            ("onboard.lifecycle", "graduated", "graduated"),
            ("onboard.lifecycle", "profiles_per_min", "profiles/min"),
        ],
        "fault": [
            ("resilience.serve_chaos", "degraded_requests", "degraded"),
            ("resilience.serve_chaos", "corrupt_detected",
             "corrupt caught"),
            ("resilience.onboard_quarantine", "quarantined", "quarantined"),
        ],
        "obs": [
            ("obs.parity", "tokens_equal", "parity"),
            ("obs.parity", "syncs_per_token_on", "syncs/token"),
            ("obs.trace", "categories", "trace cats"),
            ("obs.overhead", "ratio", "obs-on tok/s ratio"),
        ],
    }
    for family, target in FAMILIES.items():
        path = family_path(family)
        if not os.path.exists(path):
            print(f"{family:7s} — missing: run `make {target}` first")
            continue
        with open(path) as f:
            data = json.load(f)
        recs = {r["name"]: r for r in data.get("records", [])}
        parts = [p for n, k, lbl in digests[family]
                 for p in [_fmt(recs, n, k, lbl)] if p]
        body = ", ".join(parts) if parts else "no gated records"
        print(f"{family:7s} — {len(recs)} records: {body}")
        prov = data.get("provenance")
        if prov:
            mesh = prov.get("mesh_shape")
            print(f"        provenance: jax {prov.get('jax_version')}, "
                  f"{prov.get('device_count')}x "
                  f"{prov.get('device_kind')} ({prov.get('platform')}), "
                  f"mesh {mesh if mesh else '1-device'}, "
                  f"git {prov.get('git_sha') or '?'}, "
                  f"config {prov.get('config_hash', '?')}")
    failures = _gate_families()
    if failures:
        print(f"check_bench: summary gate FAILED — {', '.join(failures)}")
        sys.exit(1)


if __name__ == "__main__":
    if "--summary" in sys.argv:
        summary()
    else:
        main(fault_only="--fault-only" in sys.argv)
