"""CI gate: BENCH_*.json emission sanity.

Fails (exit 1) if the kernel/serve bench JSON artifacts are missing, have
no records, or the k-sparse admission path stopped delivering its analytic
bank-byte reduction (>= 4x at the full config's N=256, k=50)."""
from __future__ import annotations

import json
import os
import sys

MIN_ADMISSION_REDUCTION = 4.0


def fail(msg: str):
    print(f"check_bench: FAIL — {msg}")
    sys.exit(1)


def load(path: str) -> dict:
    if not os.path.exists(path):
        fail(f"{path} missing (bench did not emit)")
    with open(path) as f:
        data = json.load(f)
    if not data.get("records"):
        fail(f"{path} has no records")
    return data


def main():
    base = os.environ.get("BENCH_DIR", ".")
    kernels = load(os.path.join(base, "BENCH_kernels.json"))
    serve = load(os.path.join(base, "BENCH_serve.json"))

    names = {r["name"] for r in kernels["records"]}
    for required in ("mask_aggregate_batched.pallas_interpret",
                     "fused_adapter_batched.decode.pallas_interpret"):
        if required not in names:
            fail(f"BENCH_kernels.json missing record {required!r}")

    agg = next((r for r in serve["records"]
                if r["name"] == "admission.aggregate_bytes"), None)
    if agg is None:
        fail("BENCH_serve.json missing admission.aggregate_bytes")
    if agg["reduction"] < MIN_ADMISSION_REDUCTION:
        fail(f"admission byte reduction {agg['reduction']}x < "
             f"{MIN_ADMISSION_REDUCTION}x (bytes_dense={agg['bytes_dense']}, "
             f"bytes_sparse={agg['bytes_sparse']})")
    # the record the ENGINE wrote about the admission it actually ran: the
    # hard-mask path must have gone sparse and read fewer bank bytes than
    # the dense contraction would (ratio == N/k of the exercised config)
    adm = next((r for r in serve["records"]
                if r["name"] == "admission.batched"), None)
    if adm is None:
        fail("BENCH_serve.json missing admission.batched")
    if adm.get("path") != "sparse":
        fail(f"admission took the {adm.get('path')!r} path — the k-sparse "
             "fast path is not being exercised")
    if adm.get("measured_reduction", 0) < 2.0:
        fail(f"measured admission reduction {adm.get('measured_reduction')}x "
             "< 2x — sparse aggregation is reading too much of the bank")
    tp = next((r for r in serve["records"]
               if r["name"] == "decode.throughput"), None)
    if tp is None or tp.get("tokens_per_s", 0) <= 0:
        fail("BENCH_serve.json has no positive decode throughput")
    print(f"check_bench: OK — admission reduction {agg['reduction']}x, "
          f"decode {tp['tokens_per_s']} tok/s")


if __name__ == "__main__":
    main()
