"""Paper Tables 8/9 proxy: training step time vs N (and vs baselines).

Wall-clock on this CPU container is indicative only; the derived column
reports the analytic flops ratio — on TPU the N-scaling of x_peft cost is
dominated by the dense mask-bank aggregation (independent of tokens)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_config, emit, timeit
from repro.data import ProfileClassification
from repro.train.steps import init_train_state, make_train_step

BATCH, SEQ = 8, 24


def one(cfg, mode):
    key = jax.random.key(0)
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels, 2, seed=5)
    state = init_train_state(key, cfg, mode)
    step = jax.jit(make_train_step(cfg, mode, lr=1e-3))
    b = data.sample(0, BATCH, SEQ)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    if mode != "xpeft":
        batch["profile_ids"] = jnp.zeros(BATCH, jnp.int32)
    rng = jax.random.key(1)

    def run(state):
        s, m = step(state, batch, rng)
        return m["loss"]

    return timeit(run, state, iters=10, warmup=2)


def main():
    print("# Train-step time vs N (Tables 8/9 proxy; CPU wall-clock)")
    print("mode,N,us_per_step")
    for N in (8, 16, 32, 64):
        cfg = bench_config(N=N)
        us = one(cfg, "xpeft")
        emit(f"train_time.xpeft_N{N}", us, f"N={N}")
    for mode, m in (("head_only", "head_only"), ("single_adapter", "adapter")):
        us = one(bench_config(), m)
        emit(f"train_time.{mode}", us, "")


if __name__ == "__main__":
    main()
