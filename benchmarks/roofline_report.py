"""§Roofline report: read dry-run artifacts -> markdown + CSV tables.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

HBM_PER_CHIP = 16e9  # v5e


def load(dirname: str, variant="baseline"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(path))
        if r.get("variant", "baseline") != variant:
            continue
        rows.append(r)
    return rows


def fmt_row(r):
    if not r.get("ok"):
        return None
    rf = r["roofline"]
    mem = r["memory"]
    hbm = (mem["state_bytes_per_dev_analytic"] + mem["temp_bytes"]) / HBM_PER_CHIP
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
        "collective_s": rf["collective_s"], "dominant": rf["dominant"],
        "ratio": r["useful_flops_ratio"],
        "state_gb": mem["state_bytes_per_dev_analytic"] / 1e9,
        "temp_gb": mem["temp_bytes"] / 1e9,
        "hbm_frac": hbm,
        "compile_s": r["compile_s"],
    }


def one_liner(row):
    """The 'what would move the dominant term down' sentence."""
    d = row["dominant"]
    if d == "collective":
        return ("cut FSDP/TP re-gathers (bf16 collectives, reuse gathered "
                "weights across fwd/bwd via remat policy)")
    if d == "memory":
        return ("fuse elementwise chains / drop fp32 conversions; raise "
                "arithmetic intensity via larger per-step token blocks")
    return "already MXU-bound: improve useful-flops ratio (causal rectangle)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    rows = [fmt_row(r) for r in load(args.dir, args.variant)]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    if args.csv:
        print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
              "useful_ratio,state_gb,temp_gb,compile_s")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['mesh']},{r['compute_s']:.4f},"
                  f"{r['memory_s']:.4f},{r['collective_s']:.4f},"
                  f"{r['dominant']},{r['ratio']:.3f},{r['state_gb']:.2f},"
                  f"{r['temp_gb']:.2f},{r['compile_s']}")
        return
    print("| arch | shape | mesh | compute(s) | memory(s) | collective(s) |"
          " dominant | useful/HLO | state GB/dev | temp GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
              f"| {r['collective_s']:.4f} | **{r['dominant']}** "
              f"| {r['ratio']:.3f} | {r['state_gb']:.2f} "
              f"| {r['temp_gb']:.2f} |")


if __name__ == "__main__":
    main()
