"""Continuous-batching smoke: windowed vs paged-continuous engine on a
skewed-length workload.

The workload is deliberately adversarial to lockstep waves: every third
request decodes long (the wave straggler), the rest finish after 2 tokens.
The windowed engine strands the short requests' slots until the wave's
straggler retires; the continuous engine retires them at the next host
sync, re-admits into the freed slots mid-decode, and pages the KV cache so
a short request never holds a max-length allocation.

Both engines run the SAME requests (greedy decode, per-uid seeded
prompts), so the gates are exact:

- parity     per-request token ids BITWISE equal between the two engines
- occupancy  mean slot occupancy strictly higher for continuous, stranded
             slot-steps strictly lower
- one trace  the continuous decode step compiled exactly once across all
             admissions / preemptions / resumes
- tok/s      continuous >= 1.3x windowed (gated under BENCH_STRICT=1 only
             — shared CI runners' wall clock varies; the structural gates
             above hold unconditionally)

`run_cb_workload()` is the shared entry point: serve_bench embeds its
summary into BENCH_serve.json (gated by benchmarks/check_bench.py) and
`make cb-smoke` runs this file standalone with --check.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def skewed_requests(cfg, n_reqs: int, *, seed: int = 0, long_every: int = 3,
                    short_new: int = 2, long_new: int = 40):
    """Per-uid seeded prompts (identical across engines) with a skewed
    token-budget distribution: 1-in-`long_every` requests decode long."""
    from repro.serve.scheduler import Request
    reqs = []
    for i in range(n_reqs):
        r = np.random.default_rng(seed * 7919 + i)
        T = int(r.integers(3, 13))
        reqs.append(Request(
            uid=i, prompt=r.integers(0, cfg.vocab_size, T),
            profile_id=i % 3,
            max_new_tokens=long_new if i % long_every == 0 else short_new))
    return reqs


def run_cb_workload(arch: str = "qwen1.5-0.5b", *, max_slots: int = 3,
                    max_seq: int = 64, sync_every: int = 8,
                    page_size: int = 16, n_reqs: int = 12,
                    max_pages=None, mesh=None) -> dict:
    """Drain the same skewed workload through a windowed and a continuous
    engine (warmup pass + timed pass each) and return the comparison the
    bench records / gates are built from."""
    import jax

    from repro.configs import get_config, reduce_for_smoke
    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.models import init_lm
    from repro.serve.engine import ServeEngine

    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))

    out = {}
    for mode in ("windowed", "continuous"):
        cont = mode == "continuous"
        eng = ServeEngine(cfg, params, store, max_slots=max_slots,
                          max_seq=max_seq, sync_every=sync_every,
                          continuous=cont, page_size=page_size,
                          max_pages=max_pages if cont else None, mesh=mesh)
        # warmup drain compiles every jit variant (prefill buckets, decode,
        # scatter/insert, and — continuous — the swap/restore pair). The
        # warmup IS the timed workload (fresh request objects, same seed):
        # incremental admission reaches prefill (batch, bucket) shapes a
        # different workload would miss, and a compile inside the timed
        # drain would swamp the measurement
        eng.run_until_drained(skewed_requests(cfg, n_reqs, seed=0))
        useful0 = eng.useful_slot_steps
        stranded0 = eng.stranded_slot_steps
        steps0 = eng.slots.device_steps
        timed = skewed_requests(cfg, n_reqs, seed=0)
        t0 = time.perf_counter()
        eng.run_until_drained(timed)
        dt = time.perf_counter() - t0
        st = eng.serve_stats()
        d_steps = eng.slots.device_steps - steps0
        tokens = {r.uid: list(map(int, r.generated)) for r in timed}
        n_tok = sum(len(t) for t in tokens.values())
        out[mode] = {
            "tokens": tokens,
            "tokens_per_s": round(n_tok / dt, 1),
            "device_steps": d_steps,
            "occupancy": round((eng.useful_slot_steps - useful0)
                               / max(max_slots * d_steps, 1), 4),
            "stranded_slot_steps": eng.stranded_slot_steps - stranded0,
            "step_traces": st["step_traces"],
            "preemptions": st.get("preemptions", 0),
            "resumes": st.get("resumes", 0),
            "pages": st.get("pages"),
        }
        if cont and eng.page_alloc is not None:
            eng.page_alloc.check()
        if cont and eng.mask_alloc is not None:
            eng.mask_alloc.check()

    win, cb = out["windowed"], out["continuous"]
    return {
        "arch": arch, "requests": n_reqs, "slots": max_slots,
        "page_size": page_size,
        "tokens_equal": win["tokens"] == cb["tokens"],
        "windowed": {k: v for k, v in win.items() if k != "tokens"},
        "continuous": {k: v for k, v in cb.items() if k != "tokens"},
        "tok_s_ratio": round(cb["tokens_per_s"]
                             / max(win["tokens_per_s"], 1e-9), 3),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-pages", type=int, default=None,
                    help="shrink the page pool to force preempt/resume")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless parity + occupancy + one-trace "
                    "hold (tok/s floor only with BENCH_STRICT=1)")
    args = ap.parse_args()

    import os
    res = run_cb_workload(args.arch, n_reqs=args.requests,
                          max_pages=args.max_pages)
    print(json.dumps(res, indent=1))
    if not args.check:
        return 0
    win, cb = res["windowed"], res["continuous"]
    errs = []
    if not res["tokens_equal"]:
        errs.append("continuous tokens != windowed tokens (parity broken)")
    if args.max_pages is not None:
        # a deliberately starved pool exists to exercise preempt/resume
        # swaps (parity above must survive them); occupancy is expected
        # to DROP — preemption trades slot utilization for memory
        if cb["preemptions"] < 1 or cb["resumes"] < 1:
            errs.append(f"max_pages={args.max_pages} forced no "
                        f"preempt/resume ({cb['preemptions']}/"
                        f"{cb['resumes']}) — the swap path went untested")
    else:
        if cb["occupancy"] <= win["occupancy"]:
            errs.append(f"occupancy {cb['occupancy']} <= windowed "
                        f"{win['occupancy']}")
        if cb["stranded_slot_steps"] >= win["stranded_slot_steps"]:
            errs.append(f"stranded {cb['stranded_slot_steps']} >= windowed "
                        f"{win['stranded_slot_steps']}")
    if cb["step_traces"] != 1:
        errs.append(f"decode step traced {cb['step_traces']} times")
    if os.environ.get("BENCH_STRICT") and args.max_pages is None \
            and res["tok_s_ratio"] < 1.3:
        errs.append(f"tok/s ratio {res['tok_s_ratio']} < 1.3 "
                    "(BENCH_STRICT)")
    for e in errs:
        print(f"cb_smoke: FAIL — {e}", file=sys.stderr)
    if not errs:
        print(f"cb_smoke: OK — parity bitwise, occupancy "
              f"{win['occupancy']} -> {cb['occupancy']}, stranded "
              f"{win['stranded_slot_steps']} -> "
              f"{cb['stranded_slot_steps']}, {res['tok_s_ratio']}x tok/s, "
              f"{cb['preemptions']} preemptions / {cb['resumes']} resumes")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
