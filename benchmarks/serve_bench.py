"""Serve bench: decode throughput + admission-aggregation cost.

Measured numbers come from the CPU-runnable smoke engine (reduced
qwen1.5-family config); the analytic columns are computed at the FULL
config's X-PEFT dimensions (N=256, k=50) — they are the acceptance
numbers for the k-sparse admission path:

    dense admission reads  N·L·d·b bank bytes per request,
    sparse admission reads k·L·d·b  (ratio N/k = 5.12x at N=256, k=50).

Emits BENCH_serve.json with tokens/s and bytes-per-admission records.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from benchmarks.common import BenchWriter
from repro.configs import get_config, reduce_for_smoke


def _build_engine(cfg, n_profiles: int, max_slots: int, max_seq: int,
                  precompute: bool = True):
    import jax.numpy as jnp  # noqa: F401  (keeps jax import ordering tidy)
    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.models import init_lm
    from repro.serve.engine import ServeEngine

    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, cfg.xpeft.mask_type,
                         cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(n_profiles):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    eng = ServeEngine(cfg, params, store, max_slots=max_slots,
                      max_seq=max_seq, precompute=precompute)
    return eng


def aggregation_bytes(cfg) -> dict:
    """Analytic bank bytes read per admission (both banks), dense vs sparse."""
    xp = cfg.xpeft
    L, N, k, d, b = (cfg.num_layers, xp.num_adapters, xp.k, cfg.d_model,
                     xp.bottleneck)
    itemsize = 2 if cfg.dtype == "bfloat16" else 4
    dense = 2 * N * L * d * b * itemsize
    sparse = 2 * k * L * d * b * itemsize
    return {"N": N, "k": k, "L": L, "d": d, "b": b,
            "bytes_dense": dense, "bytes_sparse": sparse,
            "reduction": round(dense / sparse, 2)}


def main(smoke: bool = False):
    from repro.serve.engine import Request

    w = BenchWriter("serve")

    # analytic admission-aggregation bytes at the FULL config dims
    full = get_config("qwen1.5-0.5b")
    agg = aggregation_bytes(full)
    w.emit("admission.aggregate_bytes", None, **agg)

    cfg = reduce_for_smoke(full)
    max_slots = 2 if smoke else 4
    steps = 8 if smoke else 32
    n_prof = max_slots + 1
    eng = _build_engine(cfg, n_prof, max_slots, max_seq=128)

    def make_reqs(n, base=0):
        return [Request(uid=base + i, prompt=np.arange(6 + i) % cfg.vocab_size,
                        profile_id=i % n_prof, max_new_tokens=10_000)
                for i in range(n)]

    # warm up every jit variant (admission bucket, prefill buckets, decode)
    eng.admit_many(make_reqs(max_slots))
    for _ in range(2):
        eng.step()
    for slot in range(eng.n_slots):     # drain
        eng.slot_req[slot] = None

    # admission latency (batched, k-sparse aggregation + prefill); the
    # path/bytes come from the ENGINE's record of what it actually ran,
    # so check_bench gates on exercised behavior, not config arithmetic
    t0 = time.perf_counter()
    n_adm = eng.admit_many(make_reqs(max_slots, base=100))
    adm_us = (time.perf_counter() - t0) / max(n_adm, 1) * 1e6
    adm = eng.last_admission
    smoke_dense = aggregation_bytes(cfg)["bytes_dense"]
    w.emit("admission.batched", adm_us, requests=n_adm, path=adm["path"],
           bank_bytes_per_request=adm["bank_bytes_per_request"],
           measured_reduction=round(
               smoke_dense / adm["bank_bytes_per_request"], 2))

    # decode throughput with full slots
    t0 = time.perf_counter()
    toks = 0
    for _ in range(steps):
        toks += eng.step()
    dt = time.perf_counter() - t0
    w.emit("decode.throughput", dt / steps * 1e6, steps=steps,
           slots=max_slots, tokens=toks,
           tokens_per_s=round(toks / dt, 1))

    w.write()
    return w.records


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small shapes / CI smoke")
    main(smoke=p.parse_args().smoke)
