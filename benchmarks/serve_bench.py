"""Serve bench: decode throughput + admission cost across the layered
serving subsystem (scheduler / slot-state / profile-cache).

Measured numbers come from the CPU-runnable smoke engine (reduced
qwen1.5-family config); the analytic columns are computed at the FULL
config's X-PEFT dimensions (N=256, k=50). Records emitted into
BENCH_serve.json (gated by benchmarks/check_bench.py):

- admission.aggregate_bytes   analytic dense-vs-sparse bank bytes (full cfg)
- admission.batched           COLD batched admission: the k-sparse path the
                              engine actually ran + bytes it read
- admission.profile_cache     WARM admission of the same profiles: the LRU
                              hit path must read ZERO bank bytes
- prefill.batched             bucketed-prefill batch occupancy
- decode.throughput           tokens/s with full slots
- decode.host_syncs           host syncs per decoded token (< 1 with
                              sync_every > 1: device-resident decode state)
- sharded.parity              8-fake-device mesh vs 1 device: graduated
                              store bytes / admission Â/B̂ / decode tokens
                              all BITWISE equal (subprocess, see
                              benchmarks/sharded_smoke.py)
- sharded.throughput          sharded-vs-single tokens/s + analytic
                              per-device resident bytes under the mesh
- cb.parity                   continuous-batching engine vs windowed on the
                              skewed workload: per-request token ids BITWISE
                              equal, decode step compiled exactly once
- cb.occupancy                mean slot occupancy + stranded slot-steps,
                              continuous vs windowed (continuous must win)
- cb.tok_s_vs_windowed        decode tok/s ratio (>= 1.3x floor under
                              BENCH_STRICT only; structural gates above are
                              unconditional) — see benchmarks/cb_smoke.py
- spec.parity                 self-speculative greedy (bare-PLM draft,
                              adapted verify) BITWISE equal plain greedy per
                              request — normal AND adversarial-profile
                              workloads — in one compiled step
- spec.acceptance             drafted/accepted counters, acceptance rate
                              (adversarial profile must force rejections),
                              committed tokens per device step (> 1)
- spec.tok_s_vs_plain         spec-vs-plain decode tok/s + device-step
                              ratio (tok/s floor under BENCH_STRICT only:
                              CPU toy shapes are compute-bound, see
                              benchmarks/spec_smoke.py)
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from benchmarks.common import BenchWriter
from repro.configs import get_config, reduce_for_smoke
from repro.utils import pow2_bucket, pow2_count


def _build_engine(cfg, n_profiles: int, max_slots: int, max_seq: int,
                  precompute: bool = True, sync_every: int = 8,
                  store_agg: bool = False):
    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.models import init_lm
    from repro.serve.engine import ServeEngine

    xp = cfg.xpeft
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k, quant=xp.bank_quant,
                         quant_group=xp.quant_group)
    table = XP.init_profile_table(key, cfg)
    for pid in range(n_profiles):
        prof = jax.tree.map(lambda t: t[pid], table)
        agg = None
        if store_agg and xp.bank_quant != "none":
            # graduation-style quantized Â/B̂ record (quantize-on-write):
            # serving admits these with ZERO bank reads
            eff = XP.precompute_effective_adapters(params["xpeft_bank"],
                                                   prof, xp)
            agg = (eff["a_hat"], eff["b_hat"])
        store.add_profile(pid, prof, agg=agg)
    eng = ServeEngine(cfg, params, store, max_slots=max_slots,
                      max_seq=max_seq, precompute=precompute,
                      sync_every=sync_every)
    return eng


# the analytic admission byte math lives in repro.analysis.bytes (shared
# with the engine's admit stats and the quant gates in check_bench)
from repro.analysis.bytes import aggregation_bytes  # noqa: E402


def main(smoke: bool = False):
    from repro.serve.engine import Request

    # analytic admission-aggregation bytes at the FULL config dims
    full = get_config("qwen1.5-0.5b")
    w = BenchWriter("serve", cfg=full)
    agg = aggregation_bytes(full)
    w.emit("admission.aggregate_bytes", None, **agg)

    cfg = reduce_for_smoke(full)
    max_slots = 2 if smoke else 4
    steps = 24 if smoke else 32
    sync_every = 8
    n_prof = max_slots + 1
    eng = _build_engine(cfg, n_prof, max_slots, max_seq=128,
                        sync_every=sync_every)

    def make_reqs(n, base=0, max_new=10_000):
        return [Request(uid=base + i, prompt=np.arange(6 + i) % cfg.vocab_size,
                        profile_id=i % n_prof, max_new_tokens=max_new)
                for i in range(n)]

    # warm up every jit variant (admission bucket, prefill buckets, decode)
    eng.admit_many(make_reqs(max_slots))
    for _ in range(2):
        eng.step()
    eng.abort_all()

    # COLD admission latency (batched k-sparse aggregation + prefill); the
    # path/bytes come from the ENGINE's record of what it actually ran, so
    # check_bench gates on exercised behavior, not config arithmetic
    eng.profile_cache.clear()
    t0 = time.perf_counter()
    n_adm = eng.admit_many(make_reqs(max_slots, base=100))
    adm_us = (time.perf_counter() - t0) / max(n_adm, 1) * 1e6
    adm = eng.last_admission
    smoke_dense = aggregation_bytes(cfg)["bytes_dense"]
    w.emit("admission.batched", adm_us, requests=n_adm, path=adm["path"],
           cache_misses=adm["cache_misses"],
           bank_bytes_per_request=adm["bank_bytes_per_request"],
           measured_reduction=round(
               smoke_dense / adm["bank_bytes_per_request"], 2))
    eng.abort_all()

    # WARM admission: the same profiles are now LRU-cached, so the whole
    # wave admits with ZERO bank reads (the dominant multi-profile case)
    t0 = time.perf_counter()
    n_adm = eng.admit_many(make_reqs(max_slots, base=200))
    warm_us = (time.perf_counter() - t0) / max(n_adm, 1) * 1e6
    adm = eng.last_admission
    w.emit("admission.profile_cache", warm_us, requests=n_adm,
           path=adm["path"], cache_hits=adm["cache_hits"],
           bank_bytes_per_request=adm["bank_bytes_per_request"],
           hit_rate=round(adm["cache_hits"] / max(adm["requests"], 1), 4),
           lifetime_hit_rate=eng.profile_cache.stats()["hit_rate"],
           cold_us=round(adm_us, 1),
           speedup=round(adm_us / max(warm_us, 1e-9), 2))

    # bucketed batched prefill occupancy (same-bucket requests share ONE
    # jitted prefill launch; pow2 row padding is the occupancy loss)
    st = eng.serve_stats()
    wave = make_reqs(max_slots)
    buckets = sorted({pow2_bucket(len(r.prompt)) for r in wave})
    w.emit("prefill.batched", None, batches=st["prefill_batches"],
           occupancy=st["prefill_occupancy"],
           last_wave_occupancy=adm.get("prefill_occupancy", 0.0),
           wave_buckets=buckets, wave_padded_rows=pow2_count(len(wave)))

    # decode throughput with full slots (device-resident slot state;
    # host syncs amortized over sync_every-step windows)
    for _ in range(2):
        eng.step()
    eng.sync()  # flush warmup tokens so no window inherits them
    syncs0, toks0 = eng.slots.host_syncs, eng.decode_tokens

    def timed_windows(per_token: bool):
        """Best-of-3 windows (CPU timing is noisy); tokens come from the
        SYNCED count of the winning window, never step()'s host-visible
        upper bound."""
        best = None
        for _ in range(3):
            w0 = eng.decode_tokens
            t0 = time.perf_counter()
            for _ in range(steps):
                eng.step()
                if per_token:
                    eng.sync()  # PR 1-era cadence: host round-trip/token
            eng.sync()
            dt = time.perf_counter() - t0
            if best is None or dt < best[0]:
                best = (dt, eng.decode_tokens - w0)
        return best

    best_dt, toks = timed_windows(per_token=False)
    w.emit("decode.throughput", best_dt / steps * 1e6, steps=steps,
           slots=max_slots, tokens=toks,
           tokens_per_s=round(toks / best_dt, 1))
    d_syncs = eng.slots.host_syncs - syncs0
    d_toks = max(eng.decode_tokens - toks0, 1)
    w.emit("decode.host_syncs", None, sync_every=sync_every,
           window_syncs=d_syncs, window_tokens=d_toks,
           syncs_per_token=round(d_syncs / d_toks, 4))

    # same-machine, same-run baseline at the PR 1 architecture's cadence
    # (host sync after every token) — the machine-independent reference
    # check_bench gates the windowed number against. Fresh admission so
    # both measurements decode at comparable cache positions.
    eng.abort_all()
    eng.admit_many(make_reqs(max_slots, base=300))
    for _ in range(2):
        eng.step()
    eng.sync()
    base_dt, base_toks = timed_windows(per_token=True)
    w.emit("decode.throughput_per_token_sync", base_dt / steps * 1e6,
           steps=steps, slots=max_slots, tokens=base_toks,
           tokens_per_s=round(base_toks / base_dt, 1))

    # ---- quantized bank (int8/int4): measured bytes + decode parity ----
    # fresh engines on the same reduced config/seed; the bf16 reference
    # tokens come from a fresh none-engine so every path decodes the same
    # requests from a cold start
    def quant_reqs(n, max_new):
        return [Request(uid=900 + i,
                        prompt=np.arange(5 + i % 4) % cfg.vocab_size,
                        profile_id=i % n_prof, max_new_tokens=max_new)
                for i in range(n)]

    n_dec = 2 * max_slots
    ref_eng = _build_engine(cfg, n_prof, max_slots, max_seq=128,
                            sync_every=sync_every)
    cold_reqs = quant_reqs(max_slots, 2)
    ref_eng.admit_many(cold_reqs)
    ref_cold_bytes = ref_eng.last_admission["bank_bytes_per_request"]
    ref_eng.abort_all()
    dec = quant_reqs(n_dec, 16)
    t0 = time.perf_counter()
    ref_eng.run_until_drained(dec)
    ref_tps = sum(len(r.generated) for r in dec) / (time.perf_counter() - t0)
    ref_toks = [list(r.generated) for r in dec]

    for scheme in ("int8", "int4"):
        qcfg = cfg.with_xpeft(bank_quant=scheme)
        eng_q = _build_engine(qcfg, n_prof, max_slots, max_seq=128,
                              sync_every=sync_every)
        t0 = time.perf_counter()
        n_adm = eng_q.admit_many(quant_reqs(max_slots, 2))
        adm_us = (time.perf_counter() - t0) / max(n_adm, 1) * 1e6
        adm = eng_q.last_admission
        eng_q.abort_all()
        dec_q = quant_reqs(n_dec, 16)
        t0 = time.perf_counter()
        eng_q.run_until_drained(dec_q)
        tps = sum(len(r.generated) for r in dec_q) / \
            (time.perf_counter() - t0)
        toks = [list(r.generated) for r in dec_q]
        pairs = [(t, u) for s, su in zip(toks, ref_toks)
                 for t, u in zip(s, su)]
        agree = sum(t == u for t, u in pairs) / max(len(pairs), 1)
        # per-STEP agreement: first generated token of each request is an
        # independent trial (no autoregressive compounding)
        step_pairs = [(s[0], su[0]) for s, su in zip(toks, ref_toks)]
        step_agree = sum(t == u for t, u in step_pairs) / len(step_pairs)
        w.emit(f"admission.quant_{scheme}", adm_us, requests=n_adm,
               path=adm["path"], scheme=adm["scheme"],
               bank_bytes_per_request=adm["bank_bytes_per_request"],
               none_bytes_per_request=ref_cold_bytes,
               vs_none=round(adm["bank_bytes_per_request"]
                             / max(ref_cold_bytes, 1), 3))
        w.emit(f"decode.quant_{scheme}", None, tokens_per_s=round(tps, 1),
               none_tokens_per_s=round(ref_tps, 1),
               token_agreement=round(agree, 4),
               step_agreement=round(step_agree, 4),
               resident_bytes=eng_q.resident_bytes_per_device()["total"],
               none_resident_bytes=ref_eng.
               resident_bytes_per_device()["total"])

        # store-hydrated admission: graduated quantized Â/B̂ records admit
        # with ZERO bank reads (the quantize-on-write train→serve path)
        eng_s = _build_engine(qcfg, n_prof, max_slots, max_seq=128,
                              sync_every=sync_every, store_agg=True)
        eng_s.admit_many(quant_reqs(max_slots, 2))
        adm_s = eng_s.last_admission
        w.emit(f"admission.quant_store_{scheme}", None,
               path=adm_s["path"],
               bank_bytes_per_request=adm_s["bank_bytes_per_request"],
               store_hydrated=adm_s["store_hydrated_profiles"])

    # ---- continuous batching vs windowed (paged KV + slot memory) -------
    # same skewed workload through both engines; cb_smoke owns the
    # workload + comparison so `make cb-smoke` and this record agree
    from benchmarks.cb_smoke import run_cb_workload
    cb = run_cb_workload(n_reqs=12)
    win_cb, cont = cb["windowed"], cb["continuous"]
    w.emit("cb.parity", None, tokens_equal=cb["tokens_equal"],
           requests=cb["requests"], step_traces=cont["step_traces"],
           preemptions=cont["preemptions"], resumes=cont["resumes"])
    w.emit("cb.occupancy", None, windowed=win_cb["occupancy"],
           continuous=cont["occupancy"],
           windowed_stranded=win_cb["stranded_slot_steps"],
           continuous_stranded=cont["stranded_slot_steps"],
           windowed_device_steps=win_cb["device_steps"],
           continuous_device_steps=cont["device_steps"])
    w.emit("cb.tok_s_vs_windowed", None,
           windowed_tokens_per_s=win_cb["tokens_per_s"],
           continuous_tokens_per_s=cont["tokens_per_s"],
           ratio=cb["tok_s_ratio"], page_size=cb["page_size"],
           pages=cont["pages"])

    # ---- self-speculative decoding (bare-PLM draft, adapted verify) -----
    # spec_smoke owns the workloads + comparison so `make spec-smoke` and
    # these records agree; the adversarial profile forces rejections so
    # the fallback path is measured, not just the accept-everything case
    from benchmarks.spec_smoke import run_spec_workload
    sp = run_spec_workload(n_reqs=6)
    w.emit("spec.parity", None, tokens_equal=sp["tokens_equal"],
           adversarial_tokens_equal=sp["adversarial_tokens_equal"],
           requests=sp["requests"], gamma=sp["gamma"],
           step_traces=sp["spec"]["step_traces"])
    w.emit("spec.acceptance", None, gamma=sp["gamma"],
           drafted=sp["spec"]["drafted"], accepted=sp["spec"]["accepted"],
           acceptance_rate=sp["spec"]["acceptance_rate"],
           adversarial_acceptance_rate=sp["spec"]
           ["adversarial_acceptance_rate"],
           committed_per_device_step=sp["spec"]
           ["committed_per_device_step"],
           plain_committed_per_device_step=sp["plain"]
           ["committed_per_device_step"])
    w.emit("spec.tok_s_vs_plain", None,
           plain_tokens_per_s=sp["plain"]["tokens_per_s"],
           spec_tokens_per_s=sp["spec"]["tokens_per_s"],
           ratio=sp["tok_s_ratio"],
           plain_device_steps=sp["plain"]["device_steps"],
           spec_device_steps=sp["spec"]["device_steps"])

    # ---- heterogeneous adapter-type bank (typed segments, one mask space)
    # hetero_smoke owns the workload + comparison so `make hetero-smoke`
    # and these records agree; the crafted no-prefix profile keeps the
    # prefix-off admission path (buffer offset 0) measured every run
    from benchmarks.hetero_smoke import run_hetero_workload
    ht = run_hetero_workload(n_reqs=6)
    w.emit("hetero.parity", None, tokens_equal=ht["tokens_equal"],
           requests=ht["requests"], step_traces=ht["step_traces"],
           prefix_on_requests=ht["prefix_on_requests"],
           prefix_off_requests=ht["prefix_off_requests"])
    w.emit("hetero.admission", None, path=ht["admission_path"],
           bank_bytes_per_request=ht["bank_bytes_per_request"],
           **{f"record_bytes_{t}": v
              for t, v in ht["record_bytes_per_type"].items()})
    w.emit("hetero.kernel_parity", None,
           **{t: int(ok) for t, ok in ht["kernel_parity"].items()})

    # multi-device parity + throughput: subprocess (this process pinned
    # itself to 1 CPU device at first jax use; the smoke forces 8 fake
    # host devices and runs BOTH paths, so the record is self-contained)
    from benchmarks.sharded_smoke import run_subprocess
    sm = run_subprocess()
    w.emit("sharded.parity", None, devices=sm["devices"], mesh=sm["mesh"],
           onboard_store_bitwise_equal=sm["onboard_store_bitwise_equal"],
           serve_entries_bitwise_equal=sm["serve_entries_bitwise_equal"],
           decode_tokens_equal=sm["decode_tokens_equal"],
           cb_decode_tokens_equal=sm["cb_decode_tokens_equal"],
           cb_step_traces=sm["cb_step_traces"],
           gang_traces=sm["gang_traces"])
    w.emit("sharded.throughput", None,
           single_tokens_per_s=sm["single"]["tokens_per_s"],
           sharded_tokens_per_s=sm["sharded"]["tokens_per_s"],
           sharded_vs_single=sm["sharded_vs_single"],
           single_bytes_per_device=sm["single"]["resident_bytes_per_device"],
           sharded_bytes_per_device=sm["sharded"]["resident_bytes_per_device"])

    w.write()
    return w.records


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small shapes / CI smoke")
    main(smoke=p.parse_args().smoke)
