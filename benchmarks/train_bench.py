"""Train bench: gang-step cost + onboarding lifecycle throughput across the
layered training subsystem (roster / onboarding / gang-step).

Runs a full onboarding pass on the CPU-runnable paper-family smoke config
(bert + classification, the paper workload) streaming P profiles through S
roster slots, then records what the subsystem actually did. Records emitted
into BENCH_train.json (gated by benchmarks/check_bench.py):

- gang_step.time          us per jitted slot-packed gang step (S slots x m)
- train.host_syncs        host syncs per training step, counting metric
                          flushes AND lifecycle EMA/graduation fetches
                          (< 1: the host is off the per-step path)
- onboard.lifecycle       profiles graduated/evicted, admission waves,
                          gang-step retraces (must be 0), profiles/min
- graduation.roundtrip    store save/load bit-exactness of a graduated
                          profile's k-sparse masks (the train→serve loop)
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np
import jax

from benchmarks.common import BenchWriter, bench_config, timeit


def main(smoke: bool = False):
    from repro.data import ProfileClassification
    from repro.train import GraduationPolicy
    from repro.train.onboarding import build_onboarding_run

    S, m, seq = 4, 4, 16
    P = 8 if smoke else 16
    cfg = bench_config(num_labels=4, vocab=128, N=16, k=4, profiles=P)
    w = BenchWriter("train", cfg=cfg)
    policy = GraduationPolicy(min_steps=8, max_steps=20, target_acc=0.95)

    # ---- gang-step cost (jitted, steady state) ---------------------------
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=P, seed=3)
    trainer, gang = build_onboarding_run(
        cfg, data, range(P), slots=S, per_slot=m, seq_len=seq,
        policy=policy, lr=3e-2, log_every=10)
    store = trainer.scheduler.store
    batch = {k: jax.numpy.asarray(v) for k, v in trainer.loader.next().items()}
    rng = jax.random.key(9)
    us = timeit(lambda: trainer.step_fn(trainer.state, batch, rng)[1]["loss"],
                iters=10, warmup=2)
    w.emit("gang_step.time", us, slots=S, per_slot_batch=m, seq_len=seq)

    # ---- full onboarding run --------------------------------------------
    t0 = time.perf_counter()
    trainer.run_until_drained(max_steps=5_000)
    wall = time.perf_counter() - t0
    st = trainer.scheduler.stats()
    steps = max(trainer.step, 1)
    w.emit("train.host_syncs", steps=trainer.step,
           host_syncs=trainer.host_syncs,
           syncs_per_step=round(trainer.host_syncs / steps, 4),
           log_every=trainer.log_every)
    w.emit("onboard.lifecycle", wall * 1e6,
           profiles=P, graduated=st["graduated"], evicted=st["evicted"],
           admission_waves=st["admission_waves"],
           retraces=gang.trace_counter["traces"] - 1,
           profiles_per_min=round(st["graduated"] / max(wall / 60, 1e-9), 1))

    # ---- graduation roundtrip: persisted store == in-memory store --------
    fd, path = tempfile.mkstemp(suffix=".npz")
    os.close(fd)
    try:
        store.save(path)
        from repro.core.profiles import ProfileStore
        loaded = ProfileStore.load(path)
        ok = loaded.profile_ids() == store.profile_ids()
        for pid in store.profile_ids():
            a = [np.asarray(x) for x in store.sparse_indices(pid)]
            b = [np.asarray(x) for x in loaded.sparse_indices(pid)]
            ok = ok and all(np.array_equal(x, y) for x, y in zip(a, b))
    finally:
        os.remove(path)
    w.emit("graduation.roundtrip", ok=int(ok),
           profiles=len(store.profile_ids()),
           bytes_per_profile=store.bytes_per_profile())
    w.write()


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small shapes / CI smoke")
    main(smoke=p.parse_args().smoke)
