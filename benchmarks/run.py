"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus per-table headers).

  Table 1 / Fig 1   -> table1_memory       (params & bytes per profile)
  Tables 2/3        -> glue_sim            (xp vs ho vs sa ordering proxy)
  Fig 5 a/b/c       -> ablations           (N, soft/hard, tied masks, k)
  Tables 8/9        -> train_time          (step time vs N)
  kernels           -> kernel_bench        (sparse agg + fused adapter,
                                            emits BENCH_kernels.json)
  serve             -> serve_bench         (decode tok/s + admission bytes,
                                            emits BENCH_serve.json)
  train lifecycle   -> train_bench         (gang step + onboarding rate,
                                            emits BENCH_train.json)
  dry-run roofline  -> roofline_report     (reads artifacts/dryrun)
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (ablations, glue_sim, kernel_bench, serve_bench,
                            table1_memory, train_bench, train_time)
    suites = [
        ("table1_memory", table1_memory.main),
        ("kernel_bench", kernel_bench.main),
        ("serve_bench", serve_bench.main),
        ("train_bench", train_bench.main),
        ("train_time", train_time.main),
        ("ablations", ablations.main),
        ("glue_sim", glue_sim.main),
    ]
    failures = 0
    for name, fn in suites:
        print(f"\n==== {name} ====")
        t0 = time.time()
        try:
            fn()
            print(f"# {name} done in {time.time() - t0:.1f}s")
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"# {name} FAILED")
    try:
        import glob
        if glob.glob("artifacts/dryrun/*.json"):
            print("\n==== roofline_report (from artifacts/dryrun) ====")
            from benchmarks import roofline_report
            sys.argv = ["roofline_report", "--csv"]
            roofline_report.main()
    except Exception:  # noqa: BLE001
        traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
