"""Quant smoke: the zero-to-working proof of the quantized bank subsystem.

Builds bf16(none)/int8/int4 engines on the reduced config and ASSERTS the
acceptance properties end to end (exit 1 on any miss):

- quantized engines drop the fp bank from resident params and read
  <= 0.55x (int8) / 0.35x (int4) of the bf16 k-sparse admission bytes
- int8 greedy decode agrees with the bf16 path on >= 99% of tokens
- graduated quantized Â/B̂ records admit with ZERO bank reads
- per-device residency strictly shrinks

Runs in ~1 min on CPU: `make quant-smoke` (wired into `make verify` and
the ci.yml quant job). The BENCH json gates live in check_bench.py; this
script is the fast standalone probe humans and CI bisects reach for.
"""
from __future__ import annotations

import sys

import numpy as np
import jax

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request

N_PROF, SLOTS, MAX_NEW = 3, 2, 12


def build(scheme: str, store_agg: bool = False):
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b")).with_xpeft(
        bank_quant=scheme)
    xp = cfg.xpeft
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k, quant=scheme,
                         quant_group=xp.quant_group)
    table = XP.init_profile_table(key, cfg)
    for pid in range(N_PROF):
        prof = jax.tree.map(lambda t: t[pid], table)
        agg = None
        if store_agg and scheme != "none":
            eff = XP.precompute_effective_adapters(params["xpeft_bank"],
                                                   prof, xp)
            agg = (eff["a_hat"], eff["b_hat"])
        store.add_profile(pid, prof, agg=agg)
    return cfg, ServeEngine(cfg, params, store, max_slots=SLOTS,
                            max_seq=64, sync_every=4)


def decode(cfg, eng, n=4):
    reqs = [Request(uid=i, prompt=np.arange(5 + i) % cfg.vocab_size,
                    profile_id=i % N_PROF, max_new_tokens=MAX_NEW)
            for i in range(n)]
    eng.run_until_drained(reqs)
    return [list(r.generated) for r in reqs]


def check(ok: bool, msg: str):
    if not ok:
        print(f"quant_smoke: FAIL — {msg}")
        sys.exit(1)
    print(f"quant_smoke: ok — {msg}")


def main():
    cfg0, eng0 = build("none")
    base = decode(cfg0, eng0)
    check("xpeft_bank" in eng0.params and eng0.qbank is None,
          "none engine keeps the fp bank (bitwise-identical path)")
    bytes0 = None
    eng0.profile_cache.clear()
    eng0.abort_all()
    eng0.admit_many([Request(uid=50, prompt=np.arange(5), profile_id=0,
                             max_new_tokens=2)])
    bytes0 = eng0.last_admission["bank_bytes_per_request"]
    res0 = eng0.resident_bytes_per_device()["total"]

    ceilings = {"int8": 0.55, "int4": 0.35}
    floors = {"int8": 0.99, "int4": 0.75}
    for scheme in ("int8", "int4"):
        cfg, eng = build(scheme)
        toks = decode(cfg, eng)
        check("xpeft_bank" not in eng.params and eng.qbank is not None,
              f"{scheme} engine serves without the fp bank resident")
        pairs = [(t, u) for s, su in zip(toks, base) for t, u in zip(s, su)]
        agree = sum(t == u for t, u in pairs) / len(pairs)
        check(agree >= floors[scheme],
              f"{scheme} greedy decode token agreement {agree:.4f} >= "
              f"{floors[scheme]}")
        eng.profile_cache.clear()
        eng.abort_all()
        eng.admit_many([Request(uid=60, prompt=np.arange(5), profile_id=0,
                                max_new_tokens=2)])
        adm = eng.last_admission
        got = adm["bank_bytes_per_request"]
        check(adm["path"] == "quant_sparse" and
              0 < got <= ceilings[scheme] * bytes0,
              f"{scheme} admission read {got} B/req <= "
              f"{ceilings[scheme]}x bf16 ({bytes0})")
        res = eng.resident_bytes_per_device()["total"]
        check(res < res0, f"{scheme} resident {res} B < bf16 {res0} B")

        cfg_s, eng_s = build(scheme, store_agg=True)
        decode(cfg_s, eng_s, n=2)
        adm = eng_s.last_admission
        check(adm["path"] == "quant_store" and
              adm["bank_bytes_per_request"] == 0,
              f"{scheme} store-record admission read ZERO bank bytes")
    print("quant_smoke: OK")


if __name__ == "__main__":
    main()
