"""Kernel microbench: Pallas (interpret) vs jnp reference + the analytic
TPU win (HBM bytes moved) for each kernel.

Wall-clock here is CPU-interpret (not meaningful); the derived column is
the analytic HBM-traffic ratio on TPU, which is what the kernel buys.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels import ref
from repro.kernels.fused_adapter import fused_adapter
from repro.kernels.mask_aggregate import mask_aggregate


def main():
    print("# mask_aggregate: k-sparse vs dense bank aggregation")
    N, d, b, k = 256, 1024, 64, 50
    ks = jax.random.split(jax.random.key(0), 3)
    bank = jax.random.normal(ks[0], (N, d, b), jnp.bfloat16)
    idx = jax.random.permutation(ks[1], N)[:k].astype(jnp.int32)
    w = jax.random.uniform(ks[2], (k,), jnp.float32)
    dense_w = jnp.zeros((N,), jnp.float32).at[idx].set(w)

    dense_bytes = N * d * b * 2          # whole bank read
    sparse_bytes = k * d * b * 2         # k slices read
    us_ref = timeit(jax.jit(lambda: jnp.einsum(
        "n,ndb->db", dense_w, bank.astype(jnp.float32))), iters=5)
    emit("mask_aggregate.dense_ref", us_ref,
         f"hbm_bytes={dense_bytes}")
    us_sparse = timeit(jax.jit(lambda: ref.mask_aggregate_ref(bank, idx, w)),
                       iters=5)
    emit("mask_aggregate.sparse_ref", us_sparse,
         f"hbm_bytes={sparse_bytes};tpu_win={dense_bytes / sparse_bytes:.1f}x")
    us_pk = timeit(lambda: mask_aggregate(bank, idx, w, interpret=True),
                   iters=2, warmup=1)
    emit("mask_aggregate.pallas_interpret", us_pk, "semantics-check-only")

    print("# fused_adapter: fused d->b->d vs unfused")
    T, d2, b2 = 512, 1024, 64
    x = jax.random.normal(ks[0], (T, d2), jnp.bfloat16)
    a = jax.random.normal(ks[1], (d2, b2), jnp.bfloat16) * 0.02
    bb = jax.random.normal(ks[2], (b2, d2), jnp.bfloat16) * 0.02
    ls, lb = jnp.ones(b2), jnp.zeros(b2)
    unfused_bytes = (2 * T * d2 * 2          # read x twice (matmul+residual)
                     + 2 * T * b2 * 4        # h round-trip fp32
                     + 2 * T * d2 * 2)       # write y + read back
    fused_bytes = 2 * T * d2 * 2             # read x once, write y once
    us_ref = timeit(jax.jit(lambda: ref.fused_adapter_ref(x, a, bb, ls, lb)),
                    iters=5)
    emit("fused_adapter.ref", us_ref, f"hbm_bytes~{unfused_bytes}")
    us_pk = timeit(lambda: fused_adapter(x, a, bb, ls, lb, interpret=True),
                   iters=2, warmup=1)
    emit("fused_adapter.pallas_interpret", us_pk,
         f"hbm_bytes~{fused_bytes};tpu_win={unfused_bytes / fused_bytes:.1f}x")


if __name__ == "__main__":
    main()
