"""Kernel microbench: Pallas (interpret) vs jnp reference + the analytic
TPU win (HBM bytes moved) for each kernel, including the batched variants
that the serve/train hot paths dispatch to (kernels/ops.py).

Wall-clock here is CPU-interpret (not meaningful); the derived columns are
the analytic HBM-traffic numbers on TPU, which is what each kernel buys.
Emits BENCH_kernels.json (see benchmarks.common.BenchWriter).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import BenchWriter, timeit
from repro.analysis.bytes import record_bytes, row_bytes
from repro.kernels import ops, ref
from repro.kernels.fused_adapter import fused_adapter
from repro.kernels.fused_adapter_batched import fused_adapter_batched
from repro.kernels.fused_adapter_quant import fused_adapter_quant_batched
from repro.kernels.mask_aggregate import mask_aggregate, mask_aggregate_batched
from repro.kernels.mask_aggregate_quant import mask_aggregate_quant_batched
from repro.quant import schemes as QS


def _bench_mask_aggregate(w: BenchWriter, smoke: bool):
    print("# mask_aggregate: k-sparse vs dense bank aggregation")
    N, d, b, k = (64, 256, 32, 8) if smoke else (256, 1024, 64, 50)
    ks = jax.random.split(jax.random.key(0), 3)
    bank = jax.random.normal(ks[0], (N, d, b), jnp.bfloat16)
    idx = jax.random.permutation(ks[1], N)[:k].astype(jnp.int32)
    wgt = jax.random.uniform(ks[2], (k,), jnp.float32)
    dense_w = jnp.zeros((N,), jnp.float32).at[idx].set(wgt)

    dense_bytes = N * d * b * 2          # whole bank read
    sparse_bytes = k * d * b * 2         # k slices read
    us = timeit(jax.jit(lambda: jnp.einsum(
        "n,ndb->db", dense_w, bank.astype(jnp.float32))), iters=5)
    w.emit("mask_aggregate.dense_ref", us, hbm_bytes=dense_bytes)
    us = timeit(jax.jit(lambda: ref.mask_aggregate_ref(bank, idx, wgt)),
                iters=5)
    w.emit("mask_aggregate.sparse_ref", us, hbm_bytes=sparse_bytes,
           tpu_win=round(dense_bytes / sparse_bytes, 2))
    us = timeit(lambda: mask_aggregate(bank, idx, wgt, interpret=True),
                iters=2, warmup=1)
    w.emit("mask_aggregate.pallas_interpret", us, semantics_check=1)

    # batched (P profiles / layers in ONE launch — the admission shape)
    P = 2 if smoke else 4
    kb = jax.random.split(jax.random.key(1), P)
    idx_b = jnp.stack([jax.random.permutation(kk, N)[:k] for kk in kb]
                      ).astype(jnp.int32)
    w_b = jax.random.uniform(kb[0], (P, k), jnp.float32)
    us = timeit(jax.jit(lambda: ref.mask_aggregate_batched_ref(
        bank, idx_b, w_b)), iters=5)
    w.emit("mask_aggregate_batched.ref", us, P=P,
           hbm_bytes=P * sparse_bytes)
    us = timeit(lambda: mask_aggregate_batched(bank, idx_b, w_b,
                                               interpret=True),
                iters=2, warmup=1)
    w.emit("mask_aggregate_batched.pallas_interpret", us, P=P,
           hbm_bytes=P * sparse_bytes,
           tpu_win=round(dense_bytes / sparse_bytes, 2))


def _bench_fused_adapter(w: BenchWriter, smoke: bool):
    print("# fused_adapter: fused d->b->d vs unfused")
    T, d, b = (128, 256, 32) if smoke else (512, 1024, 64)
    ks = jax.random.split(jax.random.key(2), 3)
    x = jax.random.normal(ks[0], (T, d), jnp.bfloat16)
    a = jax.random.normal(ks[1], (d, b), jnp.bfloat16) * 0.02
    bb = jax.random.normal(ks[2], (b, d), jnp.bfloat16) * 0.02
    ls, lb = jnp.ones(b), jnp.zeros(b)
    unfused_bytes = (2 * T * d * 2          # read x twice (matmul+residual)
                     + 2 * T * b * 4        # h round-trip fp32
                     + 2 * T * d * 2)       # write y + read back
    fused_bytes = 2 * T * d * 2             # read x once, write y once
    us = timeit(jax.jit(lambda: ref.fused_adapter_ref(x, a, bb, ls, lb)),
                iters=5)
    w.emit("fused_adapter.ref", us, hbm_bytes=unfused_bytes)
    us = timeit(lambda: fused_adapter(x, a, bb, ls, lb, interpret=True),
                iters=2, warmup=1)
    w.emit("fused_adapter.pallas_interpret", us, hbm_bytes=fused_bytes,
           tpu_win=round(unfused_bytes / fused_bytes, 2))

    # batched: the decode-step (B rows, tiny T) and train (per-example Â/B̂)
    # shapes — one grid (B, T/block_t) launch vs a vmap of B launches
    for tag, (B, Tb) in {"decode": (8, 1),
                         "train": (4, 64 if smoke else 128)}.items():
        kb = jax.random.split(jax.random.key(3), 5)
        xb = jax.random.normal(kb[0], (B, Tb, d), jnp.bfloat16)
        ab = jax.random.normal(kb[1], (B, d, b), jnp.bfloat16) * 0.02
        bbb = jax.random.normal(kb[2], (B, b, d), jnp.bfloat16) * 0.02
        lsb = jnp.ones((B, b)), jnp.zeros((B, b))
        batch_bytes = 2 * B * Tb * d * 2 + 2 * B * d * b * 2
        unfused_b = B * (2 * Tb * d * 2 + 2 * Tb * b * 4 + 2 * Tb * d * 2) \
            + 2 * B * d * b * 2
        us = timeit(jax.jit(lambda: ref.fused_adapter_batched_ref(
            xb, ab, bbb, *lsb)), iters=5)
        w.emit(f"fused_adapter_batched.{tag}.ref", us, B=B, T=Tb,
               hbm_bytes=unfused_b)
        us = timeit(lambda: fused_adapter_batched(xb, ab, bbb, *lsb,
                                                  interpret=True),
                    iters=2, warmup=1)
        w.emit(f"fused_adapter_batched.{tag}.pallas_interpret", us, B=B,
               T=Tb, hbm_bytes=batch_bytes,
               tpu_win=round(unfused_b / batch_bytes, 2))


def _bench_quant_kernels(w: BenchWriter, smoke: bool):
    """Dequant-fused kernels: HBM bytes at the quantized row width vs the
    bf16 rows the unquantized path streams (the tpu_win column is the
    byte reduction check_bench gates)."""
    print("# quant kernels: dequant-in-register aggregation + adapter")
    N, d, b, k, P = (64, 256, 32, 8, 2) if smoke else (256, 1024, 64, 50, 4)
    bank = 0.05 * jax.random.normal(jax.random.key(5), (N, d, b),
                                    jnp.float32)
    kb = jax.random.split(jax.random.key(6), P)
    idx = jnp.stack([jax.random.permutation(kk, N)[:k] for kk in kb]
                    ).astype(jnp.int32)
    wgt = jax.random.uniform(kb[0], (P, k), jnp.float32)
    # one bank's k-sparse read: k slices of d rows, each row length b
    bf16_bytes = P * k * d * row_bytes(b, itemsize=2)
    for scheme in ("int8", "int4"):
        rec = QS.quantize(bank, scheme)
        q_bytes = P * k * d * row_bytes(b, scheme=scheme)
        us = timeit(lambda: mask_aggregate_quant_batched(
            rec["q"], rec["scale"], idx, wgt, scheme=scheme,
            interpret=True), iters=2, warmup=1)
        w.emit(f"mask_aggregate_quant_{scheme}.pallas_interpret", us, P=P,
               hbm_bytes=q_bytes,
               tpu_win=round(bf16_bytes / q_bytes, 2))

    B, d2, b2 = (8, 256, 64) if smoke else (8, 1024, 64)
    ks = jax.random.split(jax.random.key(7), 3)
    x = jax.random.normal(ks[0], (B, 1, d2), jnp.float32)
    a = jax.random.normal(ks[1], (B, d2, b2)) * 0.05
    bb = jax.random.normal(ks[2], (B, b2, d2)) * 0.02
    ls, lb = jnp.ones((B, b2)), jnp.zeros((B, b2))
    bf16_rec = record_bytes(1, d2, b2, scheme="none")
    for scheme in ("int8", "int4"):
        qa, qb = QS.quantize(a, scheme), QS.quantize(bb, scheme)
        rec_bytes = record_bytes(1, d2, b2, scheme=scheme)
        us = timeit(lambda: fused_adapter_quant_batched(
            x, qa["q"], qa["scale"], qb["q"], qb["scale"], ls, lb,
            scheme=scheme, interpret=True), iters=2, warmup=1)
        w.emit(f"fused_adapter_quant_{scheme}.decode.pallas_interpret", us,
               B=B, hbm_bytes=B * (2 * 1 * d2 * 4 + rec_bytes),
               record_bytes=rec_bytes,
               tpu_win=round(bf16_rec / rec_bytes, 2))


def _bench_decode_fused(w: BenchWriter, smoke: bool):
    """Decode megakernel (ISSUE 8): one program per layer at T=1 applying
    norm/attention/MLP AND the adapter. Weight and KV-row reads are
    identical either way, so the analytic columns count only ACTIVATION
    HBM round-trips: the composed path materializes ~12 intermediates per
    layer (ln1, qkv, rope'd q/k, probs, ctx, proj, residual, ln2, mlp
    up/act/down, adapter h/out), the megakernel reads x once and writes y
    once. Parity here is bitwise vs the jitted jnp oracle — both routes
    jitted, since eager dispatch fuses (FMA) differently."""
    print("# decode_fused: per-layer decode megakernel + adapter routes")
    from repro.configs import get_config, reduce_for_smoke
    from repro.core import xpeft as XP
    from repro.models import init_lm

    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    B, S = (4, 32) if smoke else (8, 128)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    block = jax.tree.map(lambda t: t[0], params["blocks"])
    ks = jax.random.split(jax.random.key(11), 4)
    dt = jnp.dtype(cfg.dtype)
    x = jax.random.normal(ks[0], (B, 1, cfg.d_model), dt)
    kc = jax.random.normal(ks[1], (B, S, KV, hd), dt)
    vc = jax.random.normal(ks[2], (B, S, KV, hd), dt)
    pos = (jnp.arange(B, dtype=jnp.int32) * 7) % S

    table = XP.init_profile_table(ks[3], cfg)
    prof = XP.gather_profiles(table, jnp.arange(B) % cfg.xpeft.max_profiles)
    agg = jax.vmap(lambda p: XP.precompute_effective_adapters(
        params["xpeft_bank"], p, cfg.xpeft))(prof)
    lay = {k: v[:, 0] for k, v in agg.items()}

    act = B * 1 * cfg.d_model * dt.itemsize
    unfused_act = 2 * 12 * act   # ~12 per-layer intermediate round-trips
    fused_act = 2 * act          # read x once, write y once
    for route in ("none", "bf16", "int8", "int4"):
        if route in ("none", "bf16"):
            masks_l = {} if route == "none" else lay
        else:
            qa = QS.quantize(lay["a_hat"], route,
                             group=cfg.xpeft.quant_group)
            qb = QS.quantize(lay["b_hat"], route,
                             group=cfg.xpeft.quant_group)
            masks_l = {"a_q": qa["q"], "a_scale": qa["scale"],
                       "b_q": qb["q"], "b_scale": qb["scale"],
                       "ln_scale": lay["ln_scale"],
                       "ln_bias": lay["ln_bias"]}
        kw = dict(norm=cfg.norm, qkv_bias=cfg.qkv_bias,
                  use_rope=cfg.pos == "rope", theta=cfg.rope_theta,
                  cap=cfg.logit_softcap, mlp_type=cfg.mlp_type,
                  act_name=cfg.act, adapter=route,
                  adapter_act=cfg.xpeft.adapter_activation)
        args = (x, pos, block, kc, vc, masks_l)
        ref_out = jax.jit(lambda *a: ops.decode_block_fused(
            *a, impl="ref", **kw))(*args)
        itp_out = jax.jit(lambda *a: ops.decode_block_fused(
            *a, impl="interpret", **kw))(*args)
        parity = all(
            bool(jnp.array_equal(r, i).item())
            for r, i in zip(ref_out, itp_out))
        us = timeit(lambda: ops.decode_block_fused(*args, impl="interpret",
                                                   **kw),
                    iters=2, warmup=1)
        w.emit(f"decode_fused.{route}.pallas_interpret", us, B=B, S=S,
               parity=int(parity), hbm_act_bytes=fused_act,
               tpu_win=round(unfused_act / fused_act, 2))


def main(smoke: bool = False):
    w = BenchWriter("kernels")
    _bench_mask_aggregate(w, smoke)
    _bench_fused_adapter(w, smoke)
    _bench_quant_kernels(w, smoke)
    _bench_decode_fused(w, smoke)
    w.write()
    return w.records


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small shapes / CI smoke")
    main(smoke=p.parse_args().smoke)
