"""Chaos soak: the resilience layer under a seeded FaultPlan.

Serves a multi-wave request stream against an engine whose hydration path
persistently fails for >= 20% of profiles and whose store carries >= 2
corrupted records, then proves the degradation contract end to end.
Records emitted into BENCH_fault.json (gated by benchmarks/check_bench.py):

- resilience.serve_chaos      every wave completes (failed_waves == 0);
                              degraded_requests == the count the PLAN
                              predicts (persistent failures + quarantined
                              corrupt records); no checksum-failing record
                              was ever served adapted; flaky hydrations
                              recovered via retry; unaffected requests
                              decode BITWISE identical to a no-fault run
- resilience.gang_guard       NaN-poisoned roster slot: healthy slots'
                              params AND Adam moments bitwise-equal to a
                              clean run, the poisoned slot's untouched,
                              the nonfinite counter saw every strike
- resilience.ckpt_fallback    torn-write (truncated) checkpoint: verify
                              rejects it, resume falls back to the last
                              good step
- resilience.onboard_quarantine  poisoned profiles are quarantined without
                              graduation and the lifecycle accounting
                              still closes: graduated + evicted +
                              quarantined == streamed
- resilience.elastic          (>= 8 devices only) surviving-mesh reshard
                              keeps roster values bitwise
"""
from __future__ import annotations

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import BenchWriter, bench_config
from repro.configs import get_config, reduce_for_smoke
from repro.resilience import FaultPlan, RetryPolicy

# fast, deadline-safe retries for the soak (the defaults sleep for real)
SOAK_RETRY = RetryPolicy(attempts=3, delay_s=1e-4, max_delay_s=1e-3,
                         deadline_s=10.0)


def _build_engine(cfg, n_profiles, max_slots, plan=None):
    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.models import init_lm
    from repro.serve.engine import ServeEngine

    xp = cfg.xpeft
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(n_profiles):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    eng = ServeEngine(cfg, params, store, max_slots=max_slots, max_seq=64,
                      fault_plan=plan, retry_policy=SOAK_RETRY)
    return eng, store


def serve_chaos(w: BenchWriter, smoke: bool):
    from repro.serve.engine import Request

    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    n_prof = 8 if smoke else 16
    n_reqs = 2 * n_prof
    max_slots = 2 if smoke else 4

    # plan first, so the expected-degraded set is computed from the plan
    # alone (never from observing the run): >= 20% persistent hydration
    # failures by rate draw, plus 2 corrupted records chosen OUTSIDE the
    # failing set so every degraded request has exactly one cause
    plan = FaultPlan(seed=1234, hydration_fail_rate=0.25,
                     hydration_flaky_rate=0.2)
    pids = list(range(n_prof))
    fail_set = set(plan.persistent_fail_pids(pids))
    if len(fail_set) < max(1, n_prof // 5):  # guarantee the >= 20% floor
        extra = [p for p in pids if p not in fail_set]
        need = max(1, n_prof // 5) - len(fail_set)
        plan = FaultPlan(seed=plan.seed, hydration_fail_rate=0.25,
                         hydration_flaky_rate=0.2,
                         fail_pids=tuple(extra[:need]))
        fail_set = set(plan.persistent_fail_pids(pids))
    healthy = [p for p in pids if plan.hydration_mode(p) is None]
    assert len(healthy) >= 4, "chaos plan left too few healthy profiles"
    corrupt = tuple(healthy[-2:])
    plan = FaultPlan(seed=plan.seed, hydration_fail_rate=0.25,
                     hydration_flaky_rate=0.2, fail_pids=plan.fail_pids,
                     corrupt_pids=corrupt)
    flaky_set = set(plan.flaky_hydration_pids(pids))
    degraded_pids = fail_set | set(corrupt)

    def make_reqs():
        return [Request(uid=i, prompt=np.arange(4 + i % 5) % cfg.vocab_size,
                        profile_id=i % n_prof, max_new_tokens=6)
                for i in range(n_reqs)]

    # no-fault reference: same seed/requests on an uncorrupted store
    ref_eng, _ = _build_engine(cfg, n_prof, max_slots)
    ref = make_reqs()
    ref_eng.run_until_drained(list(ref))

    eng, store = _build_engine(cfg, n_prof, max_slots, plan)
    corrupt_events = plan.corrupt_store(store)
    reqs = make_reqs()
    eng.scheduler.submit(list(reqs))
    waves = failed_waves = 0
    for _ in range(10_000):
        free = eng.free_slots()
        if free and eng.scheduler.pending():
            waves += 1
            try:
                eng.admit_many(eng.scheduler.next_batch(len(free)))
            except Exception:
                failed_waves += 1
        if not eng.active_count():
            if not eng.scheduler.pending():
                break
            continue
        eng.step()
    eng.sync()

    stats = eng.serve_stats()
    expected = sum(1 for r in reqs if r.profile_id in degraded_pids)
    unaffected_bitwise = all(
        r.generated == rr.generated for r, rr in zip(reqs, ref)
        if r.profile_id not in degraded_pids)
    # a checksum-failing record "served" = a corrupt-pid request that
    # completed NON-degraded (i.e. its adapters were actually hydrated)
    corrupt_served = sum(1 for r in reqs
                         if r.profile_id in corrupt and not r.degraded)
    flaky_degraded = sum(1 for r in reqs
                         if r.profile_id in flaky_set and r.degraded)
    w.emit("resilience.serve_chaos", None,
           requests=len(reqs), waves=waves, failed_waves=failed_waves,
           all_done=int(all(r.done for r in reqs)),
           injected_fail_rate=round(len(fail_set) / n_prof, 3),
           corrupt_records=len(corrupt_events),
           corrupt_detected=stats["store_integrity"]["corrupt_detected"],
           corrupt_served=corrupt_served,
           expected_degraded=expected,
           degraded_requests=stats["degraded_requests"],
           flaky_profiles=len(flaky_set), flaky_degraded=flaky_degraded,
           hydration_retries=stats["hydration_retries"],
           quarantined_profiles=stats["quarantined_profiles"],
           unaffected_bitwise=bool(unaffected_bitwise))


def gang_guard(w: BenchWriter):
    from repro.data import ProfileClassification
    from repro.models import init_lm
    from repro.train.roster import Roster, init_roster_state
    from repro.train.steps import make_gang_step

    cfg = bench_config()
    S, m, steps = 4, 2, 3
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=S, seed=7)
    frozen = init_lm(jax.random.key(0), cfg)

    def run(plan):
        roster = Roster(cfg, jax.random.key(2), S)
        rstate = init_roster_state(jax.random.key(1), cfg, S)
        for s in range(S):
            rstate = roster.admit(rstate, s, s)
        step = jax.jit(make_gang_step(cfg, lr=5e-2, fault_plan=plan))
        state = {"frozen": frozen, "roster": rstate}
        pids = np.repeat(np.arange(S), m)
        b = data.sample(0, S * m, 12, profile_ids=pids)
        batch = {k: jnp.asarray(np.asarray(v).reshape((S, m) + v.shape[1:]))
                 for k, v in b.items()}
        met = None
        for _ in range(steps):
            state, met = step(state, batch, jax.random.key(3))
        return jax.device_get(state["roster"]), jax.device_get(met)

    poisoned_slot = 1
    # bitwise reference = the SAME compiled program (identical plan, poison
    # window that never fires): injection on vs off, not two different HLO
    # programs whose fusion differs by a ulp
    clean, _ = run(FaultPlan(poison_slots=(poisoned_slot,),
                             poison_from_step=10 ** 9))
    faulty, met = run(FaultPlan(poison_slots=(poisoned_slot,)))

    def rows(tree, s):
        return [np.asarray(leaf[s]) for leaf in jax.tree.leaves(tree)]

    healthy_bitwise = all(
        np.array_equal(a, b)
        for s in range(S) if s != poisoned_slot
        for a, b in zip(rows(clean, s), rows(faulty, s)))
    # untouched = params frozen at admission (clean trained them away) and
    # Adam moments still exactly zero
    poisoned_untouched = (
        all(not np.array_equal(a, b) for a, b in
            zip(rows(clean["trainable"], poisoned_slot),
                rows(faulty["trainable"], poisoned_slot))) and
        all(np.all(np.asarray(leaf)[poisoned_slot] == 0.0)
            for leaf in jax.tree.leaves(faulty["opt"]["m"]) +
            jax.tree.leaves(faulty["opt"]["v"])) and
        int(faulty["opt"]["step"][poisoned_slot]) == 0)
    w.emit("resilience.gang_guard", None,
           slots=S, steps=steps, poisoned_slot=poisoned_slot,
           healthy_bitwise=bool(healthy_bitwise),
           poisoned_untouched=bool(poisoned_untouched),
           nonfinite_detected=int(faulty["nonfinite"][poisoned_slot]),
           nonfinite_metric=int(met["nonfinite_slots"]),
           loss_finite=bool(np.isfinite(met["loss"])))


def ckpt_fallback(w: BenchWriter, tmp):
    from repro.checkpoint import CheckpointManager
    from repro.resilience import CheckpointCorruptError

    state = {"w": jnp.arange(16.0), "b": jnp.ones((4,))}
    torn = 20
    mgr = CheckpointManager(str(tmp), keep_last=5,
                            fault_plan=FaultPlan(truncate_ckpt_steps=(torn,)))
    mgr.save(10, state)
    mgr.save(torn, jax.tree.map(lambda x: x + 1, state))
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    torn_rejected = False
    try:
        mgr.restore(torn, abstract)
    except CheckpointCorruptError:
        torn_rejected = True
    good = mgr.latest_good_step()
    restored = mgr.restore(good, abstract) if good is not None else None
    fallback_ok = (torn_rejected and good == 10 and restored is not None
                   and bool(np.array_equal(np.asarray(restored["w"]),
                                           np.arange(16.0))))
    w.emit("resilience.ckpt_fallback", None, torn_step=torn,
           torn_rejected=bool(torn_rejected),
           resumed_step=-1 if good is None else good,
           fallback_ok=bool(fallback_ok))


def onboard_quarantine(w: BenchWriter):
    from repro.data import ProfileClassification
    from repro.train import GraduationPolicy
    from repro.train.onboarding import build_onboarding_run

    cfg = bench_config()
    n_prof = 4
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=n_prof, seed=5)
    pol = GraduationPolicy(min_steps=3, max_steps=6, target_acc=2.0,
                           max_poison_strikes=2)
    trainer, _ = build_onboarding_run(
        cfg, data, range(n_prof), slots=2, per_slot=2, seq_len=12,
        policy=pol, lr=5e-2, log_every=3, rng=jax.random.key(1),
        fault_plan=FaultPlan(poison_slots=(0,)))
    trainer.run_until_drained(max_steps=400)
    st = trainer.scheduler.stats()
    qpids = {r["pid"] for r in trainer.scheduler.quarantined}
    w.emit("resilience.onboard_quarantine", None,
           profiles=n_prof, graduated=st["graduated"],
           evicted=st["evicted"], quarantined=st["quarantined"],
           accounting_ok=bool(st["graduated"] + st["evicted"] +
                              st["quarantined"] == n_prof),
           quarantined_served=len(
               qpids & set(trainer.scheduler.store.profile_ids())))


def elastic(w: BenchWriter):
    """Cheap reshard drill (only meaningful with >= 8 devices): values must
    survive a shrink to the surviving mesh bitwise. The full mid-onboarding
    resume drill lives in tests/test_fault.py (subprocess)."""
    if jax.device_count() < 8:
        return
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.distributed.fault import reshard_state, surviving_mesh
    from repro.launch.mesh import make_mesh_compat

    mesh8 = make_mesh_compat((4, 2), ("data", "model"))
    state = {"a": jnp.arange(64.0).reshape(8, 8),
             "b": jnp.arange(16, dtype=jnp.int32)}
    sh8 = jax.tree.map(
        lambda _: NamedSharding(mesh8, PartitionSpec("data")), state)
    on8 = reshard_state(state, sh8)
    mesh4 = surviving_mesh(("data", "model"), (4, 2), "data", 2)
    sh4 = jax.tree.map(
        lambda _: NamedSharding(mesh4, PartitionSpec("data")), state)
    on4 = reshard_state(on8, sh4)
    ok = all(np.array_equal(np.asarray(x), np.asarray(y))
             for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(on4)))
    w.emit("resilience.elastic", None, devices=jax.device_count(),
           surviving_devices=len(mesh4.devices.flatten()),
           bitwise=bool(ok))


def main(smoke: bool = False):
    import tempfile

    w = BenchWriter("fault")
    serve_chaos(w, smoke)
    gang_guard(w)
    with tempfile.TemporaryDirectory() as tmp:
        ckpt_fallback(w, tmp)
    onboard_quarantine(w)
    elastic(w)
    w.write()


if __name__ == "__main__":
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true")
    main(**vars(p.parse_args()))
