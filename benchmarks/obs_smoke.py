"""Observability smoke: attaching the obs bundle must be free.

The layer's design rule is that observability adds ZERO host syncs per
token and ZERO retraces — device instrumentation is unconditional (the
slot accumulator exists whether or not a bundle is attached), so the
compiled programs are identical and obs-on decode must be BITWISE the
same as obs-off. This smoke proves it end to end:

- parity      per-request token ids bitwise equal obs-on vs obs-off on
              the same workload (including degraded bare-PLM requests)
- zero cost   host_syncs, syncs/token and decode-step jit traces EXACTLY
              unchanged between the two runs; the on-run's retrace
              sentinel runs in `raise` mode, so any obs-induced
              recompilation kills the smoke outright
- trace       the exported Chrome-trace JSON validates and covers >= 6
              span categories (admission, prefill, decode-window,
              gang-step, graduation, resilience) — the serve pass plus a
              small onboarding run share ONE bundle
- histograms  TTFT / per-token decode latency / admission wait /
              gang-step time histograms are populated with p50/p99
- overhead    obs-on tok/s >= MIN_OBS_TOK_S_RATIO x obs-off, gated under
              BENCH_STRICT=1 only (shared-runner wall clock varies; the
              structural gates above are the unconditional contract)

Emits BENCH_obs.json (gated by benchmarks/check_bench.py) and the trace
itself as BENCH_obs_trace.json — open the latter in Perfetto. `make
obs-smoke` runs this file with --check.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

MIN_OBS_TOK_S_RATIO = 0.5         # BENCH_STRICT only
REQUIRED_CATEGORIES = 6


def workload_requests(cfg, n_reqs: int, *, seed: int = 0):
    """Per-uid seeded prompts, identical across the off/on passes.
    Profile 2 is the FaultPlan's persistent hydration failure, so its
    requests exercise the degraded bare-PLM path (identically in both
    passes — degradation is part of the workload, not of obs)."""
    from repro.serve.scheduler import Request
    reqs = []
    for i in range(n_reqs):
        r = np.random.default_rng(seed * 6733 + i)
        T = int(r.integers(3, 13))
        reqs.append(Request(uid=i, prompt=r.integers(0, cfg.vocab_size, T),
                            profile_id=i % 3, max_new_tokens=8))
    return reqs


def run_serve_pass(cfg, params, store, obs, *, n_reqs: int,
                   max_slots: int = 3, sync_every: int = 4) -> dict:
    """One engine, warmup drain + timed drain of the same workload."""
    from repro.resilience.faults import FaultPlan
    from repro.serve.engine import ServeEngine

    eng = ServeEngine(cfg, params, store, max_slots=max_slots, max_seq=64,
                      sync_every=sync_every,
                      fault_plan=FaultPlan(fail_pids=(2,)), obs=obs)
    eng.run_until_drained(workload_requests(cfg, n_reqs))  # warmup/compile
    syncs0, steps0, toks0 = (eng.slots.host_syncs, eng.slots.device_steps,
                             eng.decode_tokens)
    timed = workload_requests(cfg, n_reqs)
    t0 = time.perf_counter()
    eng.run_until_drained(timed)
    dt = time.perf_counter() - t0
    st = eng.serve_stats()
    d_toks = eng.decode_tokens - toks0
    return {
        "tokens": {r.uid: list(map(int, r.generated)) for r in timed},
        "tokens_per_s": round(d_toks / dt, 1),
        "host_syncs": eng.slots.host_syncs - syncs0,
        "device_steps": eng.slots.device_steps - steps0,
        "decode_tokens": d_toks,
        "syncs_per_token": round((eng.slots.host_syncs - syncs0)
                                 / max(d_toks, 1), 4),
        "step_traces": st["step_traces"],
        "degraded_requests": st["degraded_requests"],
    }


def run_onboarding_pass(obs) -> dict:
    """Tiny lifecycle run on the SAME bundle: gang-step window spans,
    graduation instants, and the gang retrace-sentinel watch."""
    import jax

    from repro.data import ProfileClassification
    from repro.train import GraduationPolicy
    from repro.train.onboarding import build_onboarding_run
    from benchmarks.common import bench_config

    cfg = bench_config(num_labels=4, vocab=128, N=16, k=4, profiles=4)
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=4, seed=3)
    policy = GraduationPolicy(min_steps=3, max_steps=6, target_acc=2.0)
    trainer, gang = build_onboarding_run(
        cfg, data, range(4), slots=2, per_slot=2, seq_len=8, policy=policy,
        lr=5e-2, log_every=3, rng=jax.random.key(1), obs=obs)
    trainer.run_until_drained(max_steps=200)
    st = trainer.scheduler.stats()
    return {"graduated": st["graduated"],
            "gang_traces": gang.trace_counter["traces"]}


def run_obs_workload(arch: str = "qwen1.5-0.5b", *, n_reqs: int = 9) -> dict:
    """Same serve workload obs-off then obs-on (sentinel in raise mode),
    plus an onboarding run on the on-bundle; returns the comparison plus
    the bundle's exported state."""
    import jax

    from repro import obs as OBS
    from repro.configs import get_config, reduce_for_smoke
    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.models import init_lm

    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))

    off = run_serve_pass(cfg, params, store, None, n_reqs=n_reqs)
    bundle = OBS.Observability(sentinel_mode="raise")
    on = run_serve_pass(cfg, params, store, bundle, n_reqs=n_reqs)
    onboard = run_onboarding_pass(bundle)

    trace_path = os.path.join(os.environ.get("BENCH_DIR", "."),
                              "BENCH_obs_trace.json")
    doc = bundle.tracer.export(trace_path)
    problem = OBS.validate_chrome_trace(doc)
    cats = bundle.tracer.category_counts()
    hists = bundle.metrics.snapshot()["histograms"]
    return {
        "arch": arch, "requests": n_reqs,
        "cfg": cfg, "off": off, "on": on, "onboard": onboard,
        "tokens_equal": off["tokens"] == on["tokens"],
        "trace_path": trace_path, "trace_problem": problem,
        "trace_events": len(bundle.tracer.events()),
        "trace_dropped": bundle.tracer.dropped,
        "categories": cats,
        "histograms": hists,
        "sentinel": bundle.sentinel.counts(),
        "sentinel_violations": bundle.sentinel.violations_seen,
        "tok_s_ratio": round(on["tokens_per_s"]
                             / max(off["tokens_per_s"], 1e-9), 3),
    }


def emit_bench(res: dict) -> str:
    from benchmarks.common import BenchWriter

    w = BenchWriter("obs", cfg=res["cfg"])
    off, on = res["off"], res["on"]
    w.emit("obs.parity", tokens_equal=res["tokens_equal"],
           host_syncs_off=off["host_syncs"], host_syncs_on=on["host_syncs"],
           syncs_per_token_off=off["syncs_per_token"],
           syncs_per_token_on=on["syncs_per_token"],
           step_traces_off=off["step_traces"],
           step_traces_on=on["step_traces"],
           degraded_requests=on["degraded_requests"])
    w.emit("obs.trace", valid=res["trace_problem"] is None,
           events=res["trace_events"], dropped=res["trace_dropped"],
           categories=len(res["categories"]),
           **{f"cat_{k.replace('-', '_')}": v
              for k, v in sorted(res["categories"].items())})
    h = res["histograms"]

    def pcts(name, prefix):
        s = h.get(name, {})
        return {f"{prefix}_count": s.get("count", 0),
                f"{prefix}_p50_us": s.get("p50", 0),
                f"{prefix}_p99_us": s.get("p99", 0)}

    w.emit("obs.histograms", None,
           **pcts("serve.ttft_us", "ttft"),
           **pcts("serve.decode_token_us", "decode_token"),
           **pcts("serve.admission_wait_us", "admission_wait"),
           **pcts("train.step_time_us", "gang_step"))
    w.emit("obs.overhead", tok_s_off=off["tokens_per_s"],
           tok_s_on=on["tokens_per_s"], ratio=res["tok_s_ratio"])
    w.emit("obs.sentinel", watches=len(res["sentinel"]),
           violations=res["sentinel_violations"],
           gang_traces=res["onboard"]["gang_traces"],
           graduated=res["onboard"]["graduated"])
    return w.write()


def check(res: dict) -> list:
    """Structural gates; returns the failure list (tok/s floor is
    BENCH_STRICT-only)."""
    off, on = res["off"], res["on"]
    errs = []
    if not res["tokens_equal"]:
        errs.append("obs-on decode tokens != obs-off (parity broken — "
                    "observability changed the compiled program)")
    if on["host_syncs"] != off["host_syncs"] or \
            on["syncs_per_token"] != off["syncs_per_token"]:
        errs.append(f"host syncs changed: {off['host_syncs']} -> "
                    f"{on['host_syncs']} ({off['syncs_per_token']} -> "
                    f"{on['syncs_per_token']} syncs/token) — obs must add "
                    "ZERO syncs")
    if on["step_traces"] != off["step_traces"]:
        errs.append(f"decode step traces changed: {off['step_traces']} -> "
                    f"{on['step_traces']} — obs must add ZERO retraces")
    if res["trace_problem"] is not None:
        errs.append(f"trace JSON invalid: {res['trace_problem']}")
    if len(res["categories"]) < REQUIRED_CATEGORIES:
        errs.append(f"only {sorted(res['categories'])} span categories "
                    f"< {REQUIRED_CATEGORIES}")
    if res["sentinel_violations"]:
        errs.append(f"{res['sentinel_violations']} retrace-sentinel "
                    "violations")
    if on["degraded_requests"] <= 0:
        errs.append("no degraded requests — the resilience span path went "
                    "unexercised")
    if res["onboard"]["graduated"] <= 0:
        errs.append("onboarding graduated nothing — no graduation spans")
    h = res["histograms"]
    for name in ("serve.ttft_us", "serve.decode_token_us",
                 "serve.admission_wait_us", "train.step_time_us"):
        s = h.get(name, {})
        if not s.get("count") or not (0 < s.get("p50", 0) <= s.get("p99", 0)):
            errs.append(f"histogram {name} missing/empty: {s}")
    if os.environ.get("BENCH_STRICT") and \
            res["tok_s_ratio"] < MIN_OBS_TOK_S_RATIO:
        errs.append(f"obs-on at {res['tok_s_ratio']}x obs-off tok/s < "
                    f"{MIN_OBS_TOK_S_RATIO}x floor (BENCH_STRICT)")
    return errs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless parity + zero-cost + trace gates "
                    "hold (tok/s floor only with BENCH_STRICT=1)")
    args = ap.parse_args()

    res = run_obs_workload(args.arch, n_reqs=args.requests)
    emit_bench(res)
    print(json.dumps({k: v for k, v in res.items()
                      if k not in ("cfg", "histograms")
                      and not (isinstance(v, dict) and "tokens" in v)},
                     indent=1, default=str))
    if not args.check:
        return 0
    errs = check(res)
    for e in errs:
        print(f"obs_smoke: FAIL — {e}", file=sys.stderr)
    if not errs:
        print(f"obs_smoke: OK — parity bitwise, "
              f"{res['on']['syncs_per_token']} syncs/token unchanged, "
              f"{res['on']['step_traces']} decode trace(s) unchanged, "
              f"{res['trace_events']} trace events over "
              f"{len(res['categories'])} categories, "
              f"{res['tok_s_ratio']}x tok/s with obs on")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
