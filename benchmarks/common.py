"""Shared benchmark utilities: timing, machine-readable BENCH_*.json
emission, run provenance, and the tiny paper-family config."""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke


def bench_config(num_labels=4, vocab=256, N=16, k=4, profiles=8):
    """Reduced bert-family config: CPU-trainable in seconds, same structure
    as the paper's bert-base-uncased + Pfeiffer-adapter setting."""
    return reduce_for_smoke(get_config("bert-base-xpeft")).with_(
        num_labels=num_labels, vocab_size=vocab).with_xpeft(
        num_adapters=N, k=k, max_profiles=profiles)


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


def run_provenance(cfg=None, mesh=None) -> dict:
    """Where/what a BENCH_*.json came from: numbers are only comparable
    across runs when jax version, device kind/count, mesh shape and config
    all match — record them so `check_bench.py --summary` can say so."""
    devs = jax.devices()
    prov = {
        "jax_version": jax.__version__,
        "device_count": len(devs),
        "device_kind": devs[0].device_kind if devs else "none",
        "platform": devs[0].platform if devs else "none",
        "mesh_shape": dict(mesh.shape) if mesh is not None else None,
    }
    try:
        prov["git_sha"] = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5).stdout.strip() or None
    except Exception:
        prov["git_sha"] = None
    if cfg is not None:
        prov["config_hash"] = hashlib.sha256(
            repr(cfg).encode()).hexdigest()[:12]
    return prov


class BenchWriter:
    """Collects records and writes BENCH_<suite>.json (the perf-trajectory
    artifact: each record is {"name", "us", ...derived numeric columns},
    plus a "provenance" block pinning the run environment).

    Output dir is $BENCH_DIR (default: cwd, i.e. the repo root when run via
    `python benchmarks/run.py` / `make verify`).
    """

    def __init__(self, suite: str, cfg=None, mesh=None):
        self.suite = suite
        self.records = []
        self.provenance = run_provenance(cfg, mesh)

    def emit(self, name: str, us: float | None = None, **derived):
        rec = {"name": name, **derived}
        if us is not None:
            rec["us"] = round(us, 1)
        self.records.append(rec)
        cols = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{'' if us is None else f'{us:.1f}'},{cols}")

    def write(self) -> str:
        path = os.path.join(os.environ.get("BENCH_DIR", "."),
                            f"BENCH_{self.suite}.json")
        with open(path, "w") as f:
            json.dump({"suite": self.suite, "records": self.records,
                       "provenance": self.provenance}, f, indent=1)
        print(f"# wrote {path} ({len(self.records)} records)")
        return path
