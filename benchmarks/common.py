"""Shared benchmark utilities: timing + the tiny paper-family config."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke


def bench_config(num_labels=4, vocab=256, N=16, k=4, profiles=8):
    """Reduced bert-family config: CPU-trainable in seconds, same structure
    as the paper's bert-base-uncased + Pfeiffer-adapter setting."""
    return reduce_for_smoke(get_config("bert-base-xpeft")).with_(
        num_labels=num_labels, vocab_size=vocab).with_xpeft(
        num_adapters=N, k=k, max_profiles=profiles)


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
