"""Shared benchmark utilities: timing, machine-readable BENCH_*.json
emission, and the tiny paper-family config."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke


def bench_config(num_labels=4, vocab=256, N=16, k=4, profiles=8):
    """Reduced bert-family config: CPU-trainable in seconds, same structure
    as the paper's bert-base-uncased + Pfeiffer-adapter setting."""
    return reduce_for_smoke(get_config("bert-base-xpeft")).with_(
        num_labels=num_labels, vocab_size=vocab).with_xpeft(
        num_adapters=N, k=k, max_profiles=profiles)


def timeit(fn, *args, iters=20, warmup=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")


class BenchWriter:
    """Collects records and writes BENCH_<suite>.json (the perf-trajectory
    artifact: each record is {"name", "us", ...derived numeric columns}).

    Output dir is $BENCH_DIR (default: cwd, i.e. the repo root when run via
    `python benchmarks/run.py` / `make verify`).
    """

    def __init__(self, suite: str):
        self.suite = suite
        self.records = []

    def emit(self, name: str, us: float | None = None, **derived):
        rec = {"name": name, **derived}
        if us is not None:
            rec["us"] = round(us, 1)
        self.records.append(rec)
        cols = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{'' if us is None else f'{us:.1f}'},{cols}")

    def write(self) -> str:
        path = os.path.join(os.environ.get("BENCH_DIR", "."),
                            f"BENCH_{self.suite}.json")
        with open(path, "w") as f:
            json.dump({"suite": self.suite, "records": self.records}, f,
                      indent=1)
        print(f"# wrote {path} ({len(self.records)} records)")
        return path
