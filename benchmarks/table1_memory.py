"""Paper Table 1 + Figure 1: trainable parameters and memory per profile.

MEASURED from actual pytrees (not just formulas): we instantiate the paper's
exact dims (bert-base: L=12, d=768, b=48 / b=64 variants) and count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as M
from repro.core.profiles import ProfileStore
from repro.utils import param_count
from benchmarks.common import emit

L, D = 12, 768  # bert-base-uncased


def run(figure1: bool = False):
    print("# Table 1 — trainable params & memory per profile "
          "(paper dims: L=12 d=768)")
    print("mode,N,b,trainable_params,bytes_per_profile,vs_adapter_factor")
    b = 64
    adapter_bytes = M.adapter_bytes(D, b, L)  # fp32 single adapter
    for N in (100, 200, 400):
        params = M.trainable_params_per_profile(N, b, L)
        prof = M.init_profile_params(jax.random.key(0), L, N, b)
        measured = param_count(prof)
        assert measured == params, (measured, params)
        for mode in ("hard", "soft"):
            byts = M.bytes_per_profile(N, L, mode)
            print(f"x_peft({mode}),{N},{b},{params},{byts},"
                  f"{adapter_bytes / byts:.0f}x")
    sa_params = 2 * (D * 48) * L  # paper's b=48 single adapter = 884.7K
    print(f"single_adapter,-,48,{sa_params},{M.adapter_bytes(D, 48, L)},1x")
    emit("table1.single_adapter_params", 0.0, f"count={sa_params}")
    # paper cross-checks
    assert sa_params == 884736
    assert M.bytes_per_profile(100, L, "hard") == 312      # "0.3K"
    assert M.bytes_per_profile(400, L, "hard") == 1200     # "1.2K"

    if figure1:
        print("# Figure 1 — total profile-state bytes vs #profiles")
        print("profiles,xpeft_hard,xpeft_soft,single_adapter")
        for P in (1, 10, 100, 1000, 10000, 100000):
            hard = P * M.bytes_per_profile(100, L, "hard")
            soft = P * M.bytes_per_profile(100, L, "soft")
            sa = P * M.adapter_bytes(D, 48, L)
            print(f"{P},{hard},{soft},{sa}")


def main():
    run(figure1=True)


if __name__ == "__main__":
    main()
