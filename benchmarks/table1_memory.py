"""Paper Table 1 + Figure 1: trainable parameters and memory per profile.

MEASURED from actual pytrees (not just formulas): we instantiate the paper's
exact dims (bert-base: L=12, d=768, b=48 / b=64 variants) and count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import masks as M
from repro.core.profiles import ProfileStore
from repro.utils import param_count
from benchmarks.common import emit

L, D = 12, 768  # bert-base-uncased


def run(figure1: bool = False):
    print("# Table 1 — trainable params & memory per profile "
          "(paper dims: L=12 d=768)")
    print("mode,N,b,trainable_params,bytes_per_profile,vs_adapter_factor")
    b = 64
    adapter_bytes = M.adapter_bytes(D, b, L)  # fp32 single adapter
    for N in (100, 200, 400):
        params = M.trainable_params_per_profile(N, b, L)
        prof = M.init_profile_params(jax.random.key(0), L, N, b)
        measured = param_count(prof)
        assert measured == params, (measured, params)
        for mode in ("hard", "soft"):
            byts = M.bytes_per_profile(N, L, mode)
            print(f"x_peft({mode}),{N},{b},{params},{byts},"
                  f"{adapter_bytes / byts:.0f}x")
    sa_params = 2 * (D * 48) * L  # paper's b=48 single adapter = 884.7K
    print(f"single_adapter,-,48,{sa_params},{M.adapter_bytes(D, 48, L)},1x")
    emit("table1.single_adapter_params", 0.0, f"count={sa_params}")
    # paper cross-checks
    assert sa_params == 884736
    assert M.bytes_per_profile(100, L, "hard") == 312      # "0.3K"
    assert M.bytes_per_profile(400, L, "hard") == 1200     # "1.2K"

    if figure1:
        print("# Figure 1 — total profile-state bytes vs #profiles")
        print("profiles,xpeft_hard,xpeft_soft,single_adapter")
        for P in (1, 10, 100, 1000, 10000, 100000):
            hard = P * M.bytes_per_profile(100, L, "hard")
            soft = P * M.bytes_per_profile(100, L, "soft")
            sa = P * M.adapter_bytes(D, 48, L)
            print(f"{P},{hard},{soft},{sa}")

    quantized_bank_table()


def quantized_bank_table():
    """Extend the Table-1 memory-factor story to large N: at scale the
    BANK (N·L·d·b) and the per-profile aggregated Â/B̂ records — not the
    312-byte masks — bound resident profiles per device. int8/int4
    (repro/quant) shrink both; columns are exact byte counts from the
    shared analytic helper (matches quantize_bank's true array bytes)."""
    from repro.analysis import bytes as AB

    b = 64
    print("# Quantized bank — per-profile Â/B̂ record & per-bank bytes "
          f"(d={D} b={b} L={L})")
    print("scheme,record_bytes_per_profile,bank_bytes_N100,bank_bytes_N400,"
          "vs_bf16")
    base = None
    for scheme in ("none", "int8", "int4"):
        rec = AB.record_bytes(L, D, b, scheme=scheme)
        banks = {N: N * L * AB.bank_slice_bytes(D, b, scheme=scheme,
                                                itemsize=2)
                 for N in (100, 400)}
        base = base or banks[400]
        factor = base / banks[400]
        print(f"{scheme},{rec},{banks[100]},{banks[400]},{factor:.2f}x")
        emit(f"table1.quant_{scheme}", 0.0,
             f"record={rec};bank_n400={banks[400]};factor={factor:.2f}")
    # the quantized bank must actually shrink (gate-adjacent sanity)
    assert AB.bank_slice_bytes(D, b, scheme="int4", itemsize=2) \
        < AB.bank_slice_bytes(D, b, scheme="int8", itemsize=2) \
        < AB.bank_slice_bytes(D, b, itemsize=2)


def hetero_record_table():
    """Per-profile record bytes broken out by adapter FAMILY (ISSUE 9):
    with a typed bank the resident cost of one admitted profile is no
    longer a single Â/B̂ pair — each family contributes its own aggregate
    (bottleneck/LoRA effective pairs, an IA3 scale vector, P prefix KV
    rows). Measured from an actual sparse aggregation at paper dims, in
    the fp16 the cache keeps entries in."""
    import jax

    from repro.configs import get_config
    from repro.core import adapters as A
    from repro.core import xpeft as XP
    from repro.core.xpeft import HETERO_ENTRY_KEYS

    spec = (("bottleneck", 40), ("lora", 40), ("ia3", 10), ("prefix", 10))
    cfg = get_config("bert-base-xpeft").with_xpeft(
        num_adapters=100, bank_spec=spec, prefix_tokens=8)
    xp = cfg.xpeft
    bank = A.init_hetero_bank(jax.random.key(0), L, xp, D, cfg.kv_dim,
                              jnp.float16)
    rng = np.random.default_rng(0)
    idx = jnp.asarray(np.stack([rng.choice(xp.num_adapters, size=xp.k,
                                           replace=False)
                                for _ in range(L)]))
    w = jnp.full((L, xp.k), 1.0 / xp.k, jnp.float16)
    entry = XP.precompute_effective_adapters_sparse_hetero(
        bank, idx, w, idx, w, xp)
    entry = jax.tree.map(lambda t: np.asarray(t, np.float16), entry)

    print("# Heterogeneous bank — per-profile record bytes by adapter "
          f"family (d={D} b={xp.bottleneck} L={L} "
          f"P={xp.prefix_tokens} spec={dict(spec)})")
    print("family,segment_slots,record_bytes,share")
    total = sum(int(entry[k].nbytes)
                for keys in HETERO_ENTRY_KEYS.values() for k in keys
                if k in entry)
    for t, _, cnt in xp.segments():
        byts = sum(int(entry[k].nbytes) for k in HETERO_ENTRY_KEYS[t]
                   if k in entry)
        print(f"{t},{cnt},{byts},{byts / total:.1%}")
        emit(f"table1.hetero_{t}", 0.0, f"record={byts}")
    print(f"total,{xp.num_adapters},{total},100.0%")
    # the mask stays ONE 312-byte record regardless of how many families
    # the unified index space spans — the X-PEFT scaling story is intact
    assert M.bytes_per_profile(100, L, "hard") == 312


def main():
    run(figure1=True)
    hetero_record_table()


if __name__ == "__main__":
    main()
