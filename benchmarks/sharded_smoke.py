"""8-fake-device serve + onboard smoke: bitwise parity with 1 device.

Runs the SAME engine/trainer code twice — once unsharded, once on a
(data=N/2, model=2) mesh over N forced host CPU devices (default N=8,
i.e. 4x2) — and checks:

- onboarding: the graduated `ProfileStore` records (packed mask bytes,
  fp16 LN affines) are byte-identical,
- serving:    the admission-time aggregated Â/B̂ cache entries are
              bit-identical and the decoded token ids equal,
- continuous: the paged continuous-batching engine decodes the skewed
              workload bitwise-identical on the mesh (pages shard like
              the slot axis, so the page-table gather/scatter never
              crosses a device boundary) with its step compiled once,

plus throughput and the analytic per-device resident bytes for both
paths. Prints ONE JSON line (the last stdout line) that serve_bench
embeds into BENCH_serve.json and `benchmarks/check_bench.py` gates
(parity mandatory; the sharded-vs-single throughput floor only under
BENCH_STRICT=1 — 8 fake devices on one shared CPU are slower by design).

Standalone (also how CI's multi-device job and tests/test_distributed.py
invoke it):

  PYTHONPATH=src:. python benchmarks/sharded_smoke.py --check
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_DEVICE_COUNT_FLAG = "xla_force_host_platform_device_count"


def strip_device_count_flag(flags: str) -> str:
    """Drop any --xla_force_host_platform_device_count token, keeping every
    other compiler flag (measurements must share the caller's XLA settings)."""
    return " ".join(t for t in flags.split() if _DEVICE_COUNT_FLAG not in t)


def run_subprocess(*, check: bool = False, timeout: int = 1200) -> dict:
    """Run this smoke in a fresh subprocess and return its parsed JSON
    record — the ONE entry point serve_bench and tests share (the smoke
    must force its own device count before jax initializes, so it can
    never run in an already-started jax process)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    kept = strip_device_count_flag(env.get("XLA_FLAGS", ""))
    if kept:
        env["XLA_FLAGS"] = kept
    else:
        env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, os.path.abspath(__file__)]
    if check:
        cmd.append("--check")
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd=root, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"sharded_smoke failed:\nSTDOUT:{r.stdout}\n"
                           f"STDERR:{r.stderr[-3000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="forced host device count; the mesh is "
                    "(devices/2, 2) over (data, model) and the roster/"
                    "slot count equals the data axis")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless every parity bit holds")
    args = ap.parse_args()
    if args.devices < 4 or args.devices % 2:
        ap.error("--devices must be an even number >= 4")

    # must happen before the first jax import in this process; --devices is
    # authoritative (any inherited device-count token is replaced, other
    # compiler flags carry over)
    kept = strip_device_count_flag(os.environ.get("XLA_FLAGS", ""))
    want = f"--{_DEVICE_COUNT_FLAG}={args.devices}"
    os.environ["XLA_FLAGS"] = (kept + " " + want).strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import numpy as np
    import jax

    from repro.configs import get_config, reduce_for_smoke
    from repro.core.profiles import ProfileStore
    from repro.data import MarkovLM
    from repro.launch.mesh import make_mesh_compat
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import Request
    from repro.train import GraduationPolicy
    from repro.train.onboarding import build_onboarding_run

    assert jax.device_count() >= args.devices, jax.device_count()
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    data_ax = args.devices // 2
    mesh = make_mesh_compat((data_ax, 2), ("data", "model"))
    mesh_str = f"{data_ax}x2:data,model"
    n_prof, slots = 4, data_ax

    # ---------------------------------------------------------- onboarding
    def onboard(mesh_):
        data = MarkovLM(cfg.vocab_size, n_prof, seed=1)
        policy = GraduationPolicy(min_steps=3, max_steps=5, target_acc=2.0)
        trainer, gang = build_onboarding_run(
            cfg, data, range(n_prof), slots=slots, per_slot=2, seq_len=8,
            policy=policy, lr=5e-2, seed=0, rng=jax.random.key(1),
            log_every=50, mesh=mesh_)
        trainer.run_until_drained(max_steps=200)
        assert len(trainer.scheduler.graduated) == n_prof
        return (trainer.scheduler.store, trainer.state["frozen"],
                gang.trace_counter["traces"])

    store1, frozen, traces1 = onboard(None)
    store8, _, traces8 = onboard(mesh)

    def store_records_equal(a: ProfileStore, b: ProfileStore) -> bool:
        if a.profile_ids() != b.profile_ids():
            return False
        for pid in a.profile_ids():
            ra, rb = a._rec[pid], b._rec[pid]
            if sorted(ra) != sorted(rb):
                return False
            for key in ra:
                if ra[key].dtype != rb[key].dtype or \
                        not np.array_equal(ra[key], rb[key]):
                    return False
        return True

    onboard_ok = store_records_equal(store1, store8)

    # ------------------------------------------------------------- serving
    def serve(mesh_):
        eng = ServeEngine(cfg, frozen, store1, max_slots=slots, max_seq=64,
                          sync_every=4, mesh=mesh_)
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size, size=5 + i),
                        profile_id=i % n_prof, max_new_tokens=8)
                for i in range(2 * slots)]
        eng.run_until_drained(list(reqs))  # warm up every jit variant
        rng = np.random.default_rng(0)
        reqs = [Request(uid=i,
                        prompt=rng.integers(0, cfg.vocab_size, size=5 + i),
                        profile_id=i % n_prof, max_new_tokens=8)
                for i in range(2 * slots)]
        t0 = time.perf_counter()
        eng.run_until_drained(list(reqs))
        dt = time.perf_counter() - t0
        toks = [list(map(int, r.generated)) for r in reqs]
        entries = {pid: {k: np.asarray(v) for k, v in
                         eng.profile_cache.peek(pid).items()}
                   for pid in range(n_prof)}
        n_tok = sum(len(t) for t in toks)
        return toks, entries, round(n_tok / dt, 1), \
            eng.resident_bytes_per_device()

    toks1, ent1, tps1, bytes1 = serve(None)
    toks8, ent8, tps8, bytes8 = serve(mesh)

    entries_ok = all(
        np.array_equal(ent1[pid][k], ent8[pid][k])
        for pid in ent1 for k in ent1[pid])
    tokens_ok = toks1 == toks8

    # ------------------------------------------------- continuous batching
    # the paged engine holds the same mesh-vs-single bitwise contract on
    # the skewed workload (mid-decode admission + page reuse included)
    from benchmarks.cb_smoke import skewed_requests

    def serve_cb(mesh_):
        eng = ServeEngine(cfg, frozen, store1, max_slots=slots, max_seq=64,
                          sync_every=4, continuous=True, page_size=8,
                          mesh=mesh_)
        eng.run_until_drained(skewed_requests(cfg, 2 * slots, seed=0,
                                              long_new=16))
        reqs = skewed_requests(cfg, 2 * slots, seed=0, long_new=16)
        eng.run_until_drained(reqs)
        return ({r.uid: list(map(int, r.generated)) for r in reqs},
                eng.serve_stats()["step_traces"])

    cb_toks1, cb_tr1 = serve_cb(None)
    cb_toks8, cb_tr8 = serve_cb(mesh)
    cb_tokens_ok = cb_toks1 == cb_toks8

    out = {
        "devices": args.devices,
        "mesh": mesh_str,
        "onboard_store_bitwise_equal": bool(onboard_ok),
        "serve_entries_bitwise_equal": bool(entries_ok),
        "decode_tokens_equal": bool(tokens_ok),
        "cb_decode_tokens_equal": bool(cb_tokens_ok),
        "cb_step_traces": {"single": cb_tr1, "sharded": cb_tr8},
        "gang_traces": {"single": traces1, "sharded": traces8},
        "single": {"tokens_per_s": tps1,
                   "resident_bytes_per_device": bytes1},
        "sharded": {"tokens_per_s": tps8,
                    "resident_bytes_per_device": bytes8},
        "sharded_vs_single": round(tps8 / max(tps1, 1e-9), 3),
    }
    print(json.dumps(out))
    cb_ok = cb_tokens_ok and cb_tr1 == 1 and cb_tr8 == 1
    if args.check and not (onboard_ok and entries_ok and tokens_ok
                           and cb_ok):
        print("sharded_smoke: PARITY FAILED", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
