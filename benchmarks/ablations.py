"""Paper Figure 5 ablations: (a) N and soft-vs-hard training curves,
(b) separate mask tensors M_A+M_B vs single mask, (c) top-k sweep."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench_config
from repro.data import ProfileClassification
from repro.train.steps import init_train_state, make_train_step

STEPS = 50
BATCH = 16
SEQ = 24


def curve(cfg, tie_masks=False, seed=0, lr=5e-2):
    key = jax.random.key(seed)
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=2, seed=21)
    state = init_train_state(key, cfg, "xpeft")
    base_step = make_train_step(cfg, "xpeft", lr=lr)

    def step(state, batch, rng):
        if tie_masks:  # Fig 5b: discard M_A — single mask drives both
            tr = dict(state["trainable"])
            tbl = dict(tr["table"])
            tbl["mA"] = tbl["mB"]
            tr["table"] = tbl
            state = {**state, "trainable": tr}
        return base_step(state, batch, rng)

    jstep = jax.jit(step)
    losses = []
    for i in range(STEPS):
        b = data.sample(i, BATCH, SEQ)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, m = jstep(state, batch, jax.random.key(i))
        losses.append(float(m["loss"]))
    return losses


def tail(losses, n=10):
    return float(np.mean(losses[-n:]))


def main():
    print("# Fig 5a — N sweep and soft vs hard (final-10-step mean loss)")
    print("N,mask,final_loss")
    for N in (8, 16, 32):
        for mask in ("soft", "hard"):
            cfg = bench_config(N=N).with_xpeft(mask_type=mask,
                                               k=max(2, N // 4))
            print(f"{N},{mask},{tail(curve(cfg)):.4f}")

    print("# Fig 5b — separate M_A/M_B vs single mask")
    cfg = bench_config(N=16).with_xpeft(mask_type="soft")
    print(f"separate,{tail(curve(cfg)):.4f}")
    print(f"single,{tail(curve(cfg, tie_masks=True)):.4f}")

    print("# Fig 5c — top-k sweep (hard masks, N=16)")
    print("k,final_loss")
    for k in (1, 2, 4, 8, 12):
        cfg = bench_config(N=16).with_xpeft(mask_type="hard", k=k)
        print(f"{k},{tail(curve(cfg)):.4f}")


if __name__ == "__main__":
    main()
