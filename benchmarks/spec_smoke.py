"""Self-speculative decoding smoke: plain greedy vs draft/verify/commit on
the paged continuous engine.

The draft model is the SAME frozen PLM serving every profile — the engine
swaps in the zero-adapter view (bitwise the bare PLM), so speculation costs
zero extra weight memory. The contract is exact: greedy speculative output
is BITWISE the non-speculative greedy output per request, while the decode
step still compiles exactly once and commits > 1 token per device step.

Two workloads run through both engines:

- normal     the skewed cb workload over weak random-init adapters (drafts
             mostly accepted — the speculation win case)
- adversarial every request pinned to a profile whose ln_scale/ln_bias are
             cranked so the adapted model disagrees with the bare draft at
             almost every position — acceptance collapses, rejections fire
             every round, and parity must STILL hold (the fallback token is
             the verifier's own argmax, so correctness never depends on the
             draft being good)

Gates (--check):

- parity       speculative tokens BITWISE equal plain tokens, both workloads
- one trace    the spec decode step compiled exactly once
- progress     committed tokens per device step > 1 on the normal workload,
               and strictly fewer device steps than the plain engine
- rejection    the adversarial run observed rejections (acceptance < 1) and
               accepted strictly less than the normal run
- tok/s        spec >= 0.4x plain under BENCH_STRICT=1 only: verify is a
               gamma+1-token forward, so on CPU toy shapes (compute-bound)
               speculation is a wash — the wall-clock win needs
               memory-bound decode, i.e. real accelerators

`run_spec_workload()` is the shared entry point: serve_bench embeds its
summary into BENCH_serve.json (spec.* records, gated by check_bench) and
`make spec-smoke` runs this file standalone with --check.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.cb_smoke import skewed_requests

ADVERSARIAL_PID = 3


def _adversarial_profile(table):
    """A profile whose adapter output is large enough that the adapted
    argmax disagrees with the bare PLM's at almost every position: the
    draft's worst case, forced deterministically."""
    import jax
    prof = jax.tree.map(lambda t: t[0], table)
    return {"mA": prof["mA"], "mB": prof["mB"],
            "ln_scale": 8.0 * prof["ln_scale"],
            "ln_bias": prof["ln_bias"] + 3.0}


def run_spec_workload(arch: str = "qwen1.5-0.5b", *, gamma: int = 3,
                      max_slots: int = 2, max_seq: int = 64,
                      sync_every: int = 4, page_size: int = 16,
                      n_reqs: int = 6, long_new: int = 20,
                      mesh=None) -> dict:
    """Drain the same workloads through a plain and a speculative engine
    (warmup pass + timed pass each) and return the comparison the bench
    records / gates are built from."""
    import jax

    from repro.configs import get_config, reduce_for_smoke
    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.models import init_lm
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import Request

    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    store.add_profile(ADVERSARIAL_PID, _adversarial_profile(table))

    def adversarial_requests(n=4, max_new=10):
        reqs = []
        for i in range(n):
            r = np.random.default_rng(9001 + i)
            reqs.append(Request(uid=500 + i,
                                prompt=r.integers(0, cfg.vocab_size,
                                                  int(r.integers(3, 9))),
                                profile_id=ADVERSARIAL_PID,
                                max_new_tokens=max_new))
        return reqs

    out = {}
    for mode in ("plain", "spec"):
        spec = mode == "spec"
        eng = ServeEngine(cfg.with_(spec_enable=spec, spec_gamma=gamma),
                          params, store, max_slots=max_slots,
                          max_seq=max_seq, sync_every=sync_every,
                          continuous=True, page_size=page_size, mesh=mesh)
        # warmup drain compiles the one decode step (and, spec, the
        # draft-scan/verify program); the timed pass re-runs fresh request
        # objects with the same seeds so both engines decode identically
        eng.run_until_drained(skewed_requests(cfg, n_reqs, seed=0,
                                              long_new=long_new))
        steps0, toks0 = eng.slots.device_steps, eng.decode_tokens
        timed = skewed_requests(cfg, n_reqs, seed=0, long_new=long_new)
        t0 = time.perf_counter()
        eng.run_until_drained(timed)
        dt = time.perf_counter() - t0
        adv = adversarial_requests()
        eng.run_until_drained(adv)
        st = eng.serve_stats()
        d_steps = eng.slots.device_steps - steps0
        tokens = {r.uid: list(map(int, r.generated)) for r in timed}
        n_tok = sum(len(t) for t in tokens.values())
        out[mode] = {
            "tokens": tokens,
            "adv_tokens": {r.uid: list(map(int, r.generated)) for r in adv},
            "tokens_per_s": round(n_tok / dt, 1),
            "device_steps": d_steps,
            "committed_per_device_step": round(
                (eng.decode_tokens - toks0) / max(d_steps, 1), 4),
            "step_traces": st["step_traces"],
        }
        if spec:
            sp = st["spec"]
            adv_acc = [sp["per_request_acceptance"][r.uid] for r in adv
                       if r.uid in sp["per_request_acceptance"]]
            out[mode].update(
                gamma=sp["gamma"], drafted=sp["drafted"],
                accepted=sp["accepted"],
                acceptance_rate=sp["acceptance_rate"],
                adversarial_acceptance_rate=round(
                    float(np.mean(adv_acc)) if adv_acc else 1.0, 4))
        eng.page_alloc.check()

    plain, spec = out["plain"], out["spec"]
    return {
        "arch": arch, "gamma": gamma, "requests": n_reqs,
        "slots": max_slots,
        "tokens_equal": plain["tokens"] == spec["tokens"],
        "adversarial_tokens_equal":
            plain["adv_tokens"] == spec["adv_tokens"],
        "plain": {k: v for k, v in plain.items()
                  if k not in ("tokens", "adv_tokens")},
        "spec": {k: v for k, v in spec.items()
                 if k not in ("tokens", "adv_tokens")},
        "tok_s_ratio": round(spec["tokens_per_s"]
                             / max(plain["tokens_per_s"], 1e-9), 3),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--gamma", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless parity + one-trace + progress + "
                    "forced-rejection hold (tok/s floor only with "
                    "BENCH_STRICT=1)")
    args = ap.parse_args()

    import os
    res = run_spec_workload(args.arch, gamma=args.gamma,
                            n_reqs=args.requests)
    print(json.dumps(res, indent=1))
    if not args.check:
        return 0
    plain, spec = res["plain"], res["spec"]
    errs = []
    if not res["tokens_equal"]:
        errs.append("speculative tokens != plain tokens (parity broken)")
    if not res["adversarial_tokens_equal"]:
        errs.append("adversarial-profile speculative tokens != plain — "
                    "the rejection fallback is not the verifier's argmax")
    if spec["step_traces"] != 1:
        errs.append(f"spec decode step traced {spec['step_traces']} times")
    if spec["committed_per_device_step"] <= 1.0:
        errs.append(f"committed {spec['committed_per_device_step']} "
                    "tokens/device-step <= 1 — speculation is not "
                    "amortizing steps")
    if spec["device_steps"] >= plain["device_steps"]:
        errs.append(f"spec device steps {spec['device_steps']} >= plain "
                    f"{plain['device_steps']}")
    if spec["drafted"] <= 0:
        errs.append("zero tokens drafted")
    if not (0.0 <= spec["acceptance_rate"] <= 1.0):
        errs.append(f"acceptance rate {spec['acceptance_rate']} out of "
                    "[0, 1]")
    if spec["adversarial_acceptance_rate"] >= 1.0:
        errs.append("adversarial profile forced no rejections — the "
                    "reject/fallback path went untested")
    if spec["adversarial_acceptance_rate"] >= spec["acceptance_rate"]:
        errs.append(f"adversarial acceptance "
                    f"{spec['adversarial_acceptance_rate']} not below the "
                    f"normal workload's {spec['acceptance_rate']}")
    if os.environ.get("BENCH_STRICT") and res["tok_s_ratio"] < 0.4:
        errs.append(f"spec at {res['tok_s_ratio']}x plain tok/s < 0.4x "
                    "floor (BENCH_STRICT)")
    for e in errs:
        print(f"spec_smoke: FAIL — {e}", file=sys.stderr)
    if not errs:
        print(f"spec_smoke: OK — parity bitwise (normal + adversarial), "
              f"1 trace, {spec['committed_per_device_step']} committed "
              f"tokens/device-step (device steps "
              f"{plain['device_steps']} -> {spec['device_steps']}), "
              f"acceptance {spec['acceptance_rate']} "
              f"(adversarial {spec['adversarial_acceptance_rate']}), "
              f"{res['tok_s_ratio']}x tok/s")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
