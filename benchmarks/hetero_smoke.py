"""Heterogeneous adapter-bank smoke: mixed-type profiles through the
continuous serving engine, gated against a composed dense reference.

The bank is typed — bottleneck / LoRA / IA3 / prefix segments tiling ONE
unified mask index space — and each profile's k-sparse mask selects across
segment boundaries. Admission aggregates one per-type aggregate per layer
(bottleneck/LoRA pairs, an IA3 scale vector, renormalized prefix KV rows);
decode applies them composed in one compiled program, with prefix rows
hydrated straight into the paged KV cache so the decode step never grows a
second trace.

Workload: profiles that span segments, one crafted to select NO prefix
slot (its prompt must sit at buffer position 0 — bare RoPE phases, not
just shift-equivalent) and one crafted to always select prefix slots.

Gates (--check):

- parity       engine greedy tokens BITWISE equal a from-scratch dense
               forward per emitted token, every request (cross-segment
               aggregation, composed apply, prefix hydration, per-layer
               prefix skip, per-request buffer offsets — all at once)
- one trace    the decode step compiled exactly once across the drain
- prefix split the workload exercised BOTH prefix-on and prefix-off
               admissions (cache_pos 0 and P in one prefill trace)
- sparse path  cold admission went k-sparse with > 0 bank bytes/request
- per-type kernel parity: interpret == ref bitwise on the admitted
               entries for every residual-path family present

`run_hetero_workload()` is the shared entry point: serve_bench embeds its
summary into BENCH_serve.json (hetero.* records, gated by check_bench) and
`make hetero-smoke` runs this file standalone with --check.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

BANK_SPEC = (("bottleneck", 4), ("lora", 4), ("ia3", 2), ("prefix", 2))
NO_PREFIX_PID = 3
PREFIX_PID = 4


def _hetero_cfg(arch: str):
    from repro.configs import get_config, reduce_for_smoke
    return reduce_for_smoke(get_config(arch)).with_xpeft(
        num_adapters=sum(c for _, c in BANK_SPEC), bottleneck=4, k=4,
        max_profiles=8, bank_spec=BANK_SPEC, prefix_tokens=2)


def _crafted_profiles(table, xp):
    """(no-prefix profile, all-prefix profile): logits pinned so the top-k
    selection provably avoids / includes the prefix segment."""
    import jax
    off = next(o for t, o, c in xp.segments() if t == "prefix")
    no_pfx = jax.tree.map(lambda t: np.array(t[NO_PREFIX_PID]), table)
    no_pfx["mA"][:, off:] = -30.0
    no_pfx["mB"][:, off:] = -30.0
    with_pfx = jax.tree.map(lambda t: np.array(t[PREFIX_PID]), table)
    with_pfx["mA"][:, off] = 30.0
    with_pfx["mB"][:, off + 1] = 30.0
    return no_pfx, with_pfx


def _ref_decode(params, cfg, store, pid, prompt, n):
    """From-scratch greedy reference: full dense forward per token (the
    training-path aggregation — per-segment dense weights, composed apply,
    extra_kv prefix rows)."""
    import jax.numpy as jnp

    from repro.models import forward, lm_logits
    wa, wb = store.mask_weights(pid)
    ln_s, ln_b = store.ln_affines([pid])
    masks = {"w_a": wa[None], "w_b": wb[None],
             "ln_scale": ln_s, "ln_bias": ln_b}
    seq = list(map(int, prompt))
    out = []
    for _ in range(n):
        h, _, _ = forward(params, jnp.asarray([seq]), cfg,
                          profile_masks=masks)
        nxt = int(jnp.argmax(lm_logits(params, h[:, -1:], cfg)[0, -1]))
        out.append(nxt)
        seq.append(nxt)
    return out


def _per_type_record_bytes(entry, xp):
    """Admission record bytes by adapter family, from a hydrated cache
    entry (the typed generalization of the a_hat/b_hat byte accounting)."""
    from repro.core.xpeft import HETERO_ENTRY_KEYS
    out = {}
    for t, _, _ in xp.segments():
        keys = list(HETERO_ENTRY_KEYS[t])
        if t == "prefix":
            keys.append("prefix_skip")
        out[t] = int(sum(np.asarray(entry[k]).nbytes
                         for k in keys if k in entry))
    return out


def _kernel_parity(entry, cfg):
    """interpret vs ref per residual-path family, on the entries the
    engine actually admitted. LoRA/IA3 compare BITWISE (same contraction
    order in both impls); bottleneck compares at the suite's established
    tolerance — its LN reduction order differs between the kernel and the
    jnp reference (same bound tests/test_kernels.py gates)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    x = jax.random.normal(jax.random.key(7), (2, 8, cfg.d_model),
                          jnp.float32)
    a = jnp.stack([entry["a_hat"][0]] * 2)
    b = jnp.stack([entry["b_hat"][0]] * 2)
    ls = jnp.stack([entry["ln_scale"][0]] * 2)
    lb = jnp.stack([entry["ln_bias"][0]] * 2)
    la = jnp.stack([entry["lora_a"][0]] * 2)
    lbb = jnp.stack([entry["lora_b"][0]] * 2)
    s = jnp.stack([entry["ia3_s"][0]] * 2)
    act = cfg.xpeft.adapter_activation
    pairs = {
        "bottleneck": (
            ops.fused_adapter(x, a, b, ls, lb, activation=act,
                              impl="interpret"),
            ops.fused_adapter(x, a, b, ls, lb, activation=act, impl="ref")),
        "lora": (ops.lora_adapter(x, la, lbb, impl="interpret"),
                 ops.lora_adapter(x, la, lbb, impl="ref")),
        "ia3": (ops.ia3_apply(x, s, impl="interpret"),
                ops.ia3_apply(x, s, impl="ref")),
    }
    out = {}
    for t, (i, r) in pairs.items():
        i, r = np.asarray(i, np.float32), np.asarray(r, np.float32)
        out[t] = bool((i == r).all()) if t != "bottleneck" \
            else bool(np.allclose(i, r, rtol=1e-4, atol=1e-5))
    return out


def run_hetero_workload(arch: str = "qwen1.5-0.5b", *, max_slots: int = 4,
                        max_seq: int = 64, sync_every: int = 4,
                        page_size: int = 16, n_reqs: int = 6,
                        max_new: int = 6, mesh=None) -> dict:
    """Drain a mixed-type workload through the continuous engine and
    return the comparison the bench records / gates are built from."""
    import jax

    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.models import init_lm
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import Request

    cfg = _hetero_cfg(arch)
    xp = cfg.xpeft
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         "hard", xp.k, bank_spec=xp.bank_spec)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    no_pfx, with_pfx = _crafted_profiles(table, xp)
    store.add_profile(NO_PREFIX_PID, no_pfx)
    store.add_profile(PREFIX_PID, with_pfx)

    def make_requests(base_uid):
        reqs = []
        for i in range(n_reqs):
            r = np.random.default_rng(4242 + i)
            reqs.append(Request(
                uid=base_uid + i,
                prompt=r.integers(0, cfg.vocab_size, int(r.integers(4, 9))),
                profile_id=i % 5, max_new_tokens=max_new))
        return reqs

    eng = ServeEngine(cfg, params, store, max_slots=max_slots,
                      max_seq=max_seq, sync_every=sync_every,
                      continuous=True, page_size=page_size, mesh=mesh)
    eng.run_until_drained(make_requests(0))     # warmup: compiles the step
    cold = dict(eng.last_admission or {})
    timed = make_requests(100)
    t0 = time.perf_counter()
    eng.run_until_drained(timed)
    dt = time.perf_counter() - t0
    st = eng.serve_stats()
    eng.page_alloc.check()

    mism = []
    pfx_on = pfx_off = 0
    for r in timed:
        if getattr(r, "prefix_len", 0):
            pfx_on += 1
        else:
            pfx_off += 1
        exp = _ref_decode(params, cfg, store, int(r.profile_id),
                          list(r.prompt), len(r.generated))
        if list(r.generated) != exp:
            mism.append({"uid": r.uid, "pid": int(r.profile_id),
                         "got": list(map(int, r.generated)), "want": exp})

    entry = eng.profile_cache.get(0)
    n_tok = sum(len(r.generated) for r in timed)
    return {
        "arch": arch, "bank_spec": [list(s) for s in BANK_SPEC],
        "requests": n_reqs, "slots": max_slots,
        "tokens_equal": not mism, "mismatches": mism[:3],
        "step_traces": st["step_traces"],
        "prefix_on_requests": pfx_on, "prefix_off_requests": pfx_off,
        "tokens_per_s": round(n_tok / dt, 1),
        "admission_path": cold.get("path"),
        "bank_bytes_per_request": cold.get("bank_bytes_per_request", 0),
        "record_bytes_per_type": _per_type_record_bytes(entry, xp),
        "kernel_parity": _kernel_parity(entry, cfg),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless parity + one-trace + prefix-split "
                    "+ sparse-admission + per-type kernel parity hold")
    args = ap.parse_args()

    res = run_hetero_workload(args.arch, n_reqs=args.requests)
    print(json.dumps(res, indent=1))
    if not args.check:
        return 0
    errs = []
    if not res["tokens_equal"]:
        errs.append(f"engine tokens != composed dense reference "
                    f"(first mismatches: {res['mismatches']})")
    if res["step_traces"] != 1:
        errs.append(f"hetero decode step traced {res['step_traces']} times")
    if not res["prefix_on_requests"] or not res["prefix_off_requests"]:
        errs.append(f"prefix split not exercised (on="
                    f"{res['prefix_on_requests']}, "
                    f"off={res['prefix_off_requests']})")
    if res["admission_path"] != "sparse":
        errs.append(f"cold admission took the {res['admission_path']!r} "
                    "path, expected the k-sparse fast path")
    if res["bank_bytes_per_request"] <= 0:
        errs.append("cold admission read zero bank bytes per request")
    for t, nbytes in res["record_bytes_per_type"].items():
        if nbytes <= 0:
            errs.append(f"per-type record bytes for {t!r} is {nbytes}")
    for t, ok in res["kernel_parity"].items():
        if not ok:
            errs.append(f"{t}: interpret kernel != ref (bitwise)")
    if errs:
        for e in errs:
            print(f"hetero_smoke: FAIL — {e}")
        return 1
    print("hetero_smoke: OK — parity + one trace + prefix split + "
          "sparse admission + per-type kernel parity")
    return 0


if __name__ == "__main__":
    sys.exit(main())
