"""Paper Tables 2/3 proxy (no internet): synthetic multi-task classification.

Compares the paper's three arms under EQUAL update budgets:
  x_peft (soft & hard, N sweep)  vs  head_only  vs  single_adapter
The claim being validated is the ORDERING (xp > ho, xp ~= sa), not absolute
GLUE scores. Paper numbers are quoted alongside in EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench_config, emit
from repro.data import ProfileClassification
from repro.train.steps import init_train_state, loss_for_batch, make_train_step

STEPS = 70
BATCH = 16
SEQ = 24
LR = 5e-2


def train_and_eval(cfg, mode, data, seed=0):
    key = jax.random.key(seed)
    state = init_train_state(key, cfg, mode)
    step = jax.jit(make_train_step(cfg, mode, lr=LR))
    t0 = time.perf_counter()
    for i in range(STEPS):
        b = data.sample(i, BATCH, SEQ)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if mode != "xpeft":
            batch["profile_ids"] = jnp.zeros(BATCH, jnp.int32)
        state, m = step(state, batch, jax.random.key(i))
    train_s = time.perf_counter() - t0
    # held-out eval
    accs = []
    for j in range(4):
        b = data.sample(10_000 + j, 32, SEQ)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if mode != "xpeft":
            batch["profile_ids"] = jnp.zeros(32, jnp.int32)
        _, mm = loss_for_batch(state["frozen"], state["trainable"], batch,
                               cfg, mode, jax.random.key(0), training=False)
        accs.append(float(mm["accuracy"]))
    return float(np.mean(accs)), train_s


def main():
    print("# GLUE-proxy: x_peft vs head_only vs single_adapter "
          f"(equal budget: {STEPS} steps x {BATCH})")
    print("mode,N,mask,acc,train_s")
    results = {}
    for N, mask in ((8, "soft"), (8, "hard"), (16, "soft"), (16, "hard")):
        cfg = bench_config(N=N).with_xpeft(mask_type=mask,
                                           k=max(2, N // 4))
        data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                     num_profiles=2, seed=11)
        acc, ts = train_and_eval(cfg, "xpeft", data)
        results[f"xp_{N}_{mask}"] = acc
        print(f"x_peft,{N},{mask},{acc:.3f},{ts:.1f}")
    cfg = bench_config()
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=2, seed=11)
    for mode in ("head_only", "single_adapter"):
        m = {"head_only": "head_only", "single_adapter": "adapter"}[mode]
        acc, ts = train_and_eval(cfg, m, data)
        results[mode] = acc
        print(f"{mode},-,-,{acc:.3f},{ts:.1f}")
    best_xp = max(v for k, v in results.items() if k.startswith("xp"))
    print(f"# ordering: best_xp={best_xp:.3f} "
          f"head_only={results['head_only']:.3f} "
          f"single_adapter={results['single_adapter']:.3f}")
    emit("glue_sim.best_xp_minus_head_only", 0.0,
         f"delta={best_xp - results['head_only']:.3f}")


if __name__ == "__main__":
    main()
