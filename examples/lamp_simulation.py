"""Paper Figure 4 simulation: the LaMP 'Personalized News Categorization'
experiment on the synthetic multi-profile task (no internet in env).

Three arms, same evaluation protocol as the paper:
  x_peft random — masks over a FROZEN RANDOM adapter bank (LTH/supermask)
  x_peft warm   — the first W profiles adapter-tune their own adapters
                  (the paper's warm-start accumulation); those trained
                  adapters fill bank slots and LATER profiles only train
                  masks over the warm bank
  single_adapter — one dedicated adapter per profile (upper-bound baseline)

  PYTHONPATH=src python examples/lamp_simulation.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.core import masks as M
from repro.data import ProfileClassification
from repro.train.steps import init_train_state, loss_for_batch, make_train_step
from repro.utils import merge_trees

STEPS, BATCH, SEQ = 120, 16, 24
N_PROFILES = 4
WARM = 2  # profiles that adapter-tune before the mask-only era

cfg = reduce_for_smoke(get_config("bert-base-xpeft")).with_(
    num_labels=3, vocab_size=128).with_xpeft(num_adapters=16, k=4,
                                             max_profiles=N_PROFILES)
data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                             num_profiles=N_PROFILES, seed=13)


def train(mode, state, lr=8e-2, profile=None, steps=STEPS):
    step = jax.jit(make_train_step(cfg, mode, lr=lr))
    for i in range(steps):
        pids = None if profile is None else [profile] * BATCH
        b = data.sample(i, BATCH, SEQ, profile_ids=pids)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if mode != "xpeft":
            batch["profile_ids"] = jnp.zeros(BATCH, jnp.int32)
        state, m = step(state, batch, jax.random.key(i))
    return state


def eval_profile(state, mode, pid):
    vals = []
    for j in range(3):
        b = data.sample(90_000 + j, 32, SEQ, profile_ids=[pid] * 32)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        if mode != "xpeft":
            batch["profile_ids"] = jnp.zeros(32, jnp.int32)
        _, m = loss_for_batch(state["frozen"], state["trainable"], batch,
                              cfg, mode, jax.random.key(0), training=False)
        vals.append(float(m["accuracy"]))
    return float(np.mean(vals))


# ---- single_adapter baselines (also provide the warm bank) -----------------
tuned = []
sa_accs = []
for pid in range(WARM):
    sa = init_train_state(jax.random.key(100 + pid), cfg, "adapter")
    sa = train("adapter", sa, profile=pid)
    sa_accs.append(eval_profile(sa, "adapter", pid))
    tuned.append(sa["trainable"]["bank"])  # [L, 1, d, b] / [L, 1, b, d]
print(f"single_adapter: acc={np.mean(sa_accs):.3f} over {WARM} profiles "
      f"({2 * cfg.d_model * cfg.xpeft.bottleneck * cfg.num_layers * 4:,} B "
      f"per profile)")

# ---- x_peft random: frozen random bank, masks per profile ------------------
st_rand = init_train_state(jax.random.key(0), cfg, "xpeft")
st_rand = train("xpeft", st_rand)
acc_rand = np.mean([eval_profile(st_rand, "xpeft", p)
                    for p in range(N_PROFILES)])
bytes_pp = M.bytes_per_profile(cfg.xpeft.num_adapters, cfg.num_layers, "hard")
print(f"x_peft random : acc={acc_rand:.3f}  ({bytes_pp} B/profile, "
      "bit-packed hard masks)")

# ---- x_peft warm: tuned adapters fill half the bank slots ------------------
st_warm = init_train_state(jax.random.key(3), cfg, "xpeft")
bank = st_warm["frozen"]["xpeft_bank"]
N = cfg.xpeft.num_adapters
slots_per = N // 2 // WARM
ba, bb = bank["bank_a"], bank["bank_b"]
for w, tb in enumerate(tuned):
    for s in range(slots_per):
        idx = w * slots_per + s
        key = jax.random.key(500 + idx)
        na = 0.2 * jnp.std(tb["bank_a"]) * jax.random.normal(
            key, tb["bank_a"][:, 0].shape)
        nb = 0.2 * jnp.std(tb["bank_b"]) * jax.random.normal(
            key, tb["bank_b"][:, 0].shape)
        ba = ba.at[:, idx].set((tb["bank_a"][:, 0] + na).astype(ba.dtype))
        bb = bb.at[:, idx].set((tb["bank_b"][:, 0] + nb).astype(bb.dtype))
st_warm["frozen"] = merge_trees(
    st_warm["frozen"], {"xpeft_bank": {"bank_a": ba, "bank_b": bb}})
st_warm = train("xpeft", st_warm)
acc_warm = np.mean([eval_profile(st_warm, "xpeft", p)
                    for p in range(N_PROFILES)])
print(f"x_peft warm   : acc={acc_warm:.3f}  (same {bytes_pp} B/profile; "
      f"bank warm-started from {WARM} adapter-tuned profiles)")

print("\npaper Fig.4 ordering to compare: warm >= random, both within reach "
      "of the dedicated adapter at ~1/10,000 the per-profile bytes")
