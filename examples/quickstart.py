"""Quickstart: X-PEFT in ~60 lines.

Builds a small LM, attaches a shared adapter bank, trains per-profile mask
tensors for two profiles simultaneously, and shows the byte-level profile
records the paper's 10,000x claim is about.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.core import masks as M
from repro.core.profiles import ProfileStore
from repro.data import MarkovLM
from repro.train.steps import init_train_state, make_train_step

# 1. a model config with X-PEFT enabled (reduced: runs on CPU in seconds)
cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
xp = cfg.xpeft
print(f"arch={cfg.name} L={cfg.num_layers} d={cfg.d_model} "
      f"| X-PEFT: N={xp.num_adapters} b={xp.bottleneck} k={xp.k} "
      f"masks={xp.mask_type}")

# 2. training state: frozen PLM + frozen adapter bank + per-profile masks
state = init_train_state(jax.random.key(0), cfg, mode="xpeft")
n_trainable = sum(x.size for x in jax.tree.leaves(state["trainable"]))
n_frozen = sum(x.size for x in jax.tree.leaves(state["frozen"]))
print(f"frozen params: {n_frozen:,} | trainable (ALL profiles): "
      f"{n_trainable:,}")

# 3. multi-profile training: one batch carries examples of many profiles
step = jax.jit(make_train_step(cfg, "xpeft", lr=3e-2))
data = MarkovLM(vocab_size=cfg.vocab_size, num_profiles=2, seed=0)
for i in range(20):
    b = data.sample(i, 8, 32)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    state, metrics = step(state, batch, jax.random.key(i))
    if i % 5 == 0:
        print(f"step {i:3d} loss {float(metrics['loss']):.4f}")

# 4. freeze profiles to byte-level records (the paper's headline)
store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                     "hard", xp.k)
for pid in (0, 1):
    store.add_profile(pid, jax.tree.map(lambda t: t[pid],
                                        state["trainable"]["table"]))
adapter_bytes = M.adapter_bytes(cfg.d_model, xp.bottleneck, cfg.num_layers)
print(f"per-profile storage: {store.bytes_per_profile()} B "
      f"(vs {adapter_bytes:,} B for a dedicated adapter -> "
      f"{adapter_bytes / store.bytes_per_profile():.0f}x smaller)")
