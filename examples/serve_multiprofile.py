"""Serving example: continuous batching with per-request X-PEFT profiles.

Shows the two serving paths side-by-side and checks they emit identical
tokens:
  - paper-faithful: per-step dense mask-bank aggregation
  - beyond-paper:   admission-time aggregated adapters (decode fast path)

  PYTHONPATH=src python examples/serve_multiprofile.py
"""
import time

import numpy as np
import jax

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.serve.engine import Request, ServeEngine

cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
key = jax.random.key(0)
params = init_lm(key, cfg)
xp = cfg.xpeft

store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                     "hard", xp.k)
table = XP.init_profile_table(key, cfg)
for pid in range(4):
    store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
print(f"4 profiles x {store.bytes_per_profile()} B each")

rng = np.random.default_rng(0)


def requests():
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=6 + i % 5),
                    profile_id=i % 4, max_new_tokens=8) for i in range(6)]


outs = {}
for precompute in (False, True):
    eng = ServeEngine(cfg, params, store, max_slots=3, max_seq=64,
                      precompute=precompute)
    reqs = requests()
    t0 = time.time()
    steps = eng.run_until_drained(list(reqs))
    label = "precomputed-adapters" if precompute else "paper-faithful"
    stats = eng.serve_stats()
    print(f"[{label:22s}] {steps} engine steps, {time.time() - t0:.2f}s, "
          f"cache hit rate {stats['profile_cache']['hit_rate']}, "
          f"{stats['syncs_per_token']} host syncs/token")
    outs[precompute] = [tuple(r.generated) for r in reqs]

# Parity check at the LOGIT level (greedy tokens of an untrained random
# model flip on fp-reassociation ties and then cascade, so token agreement
# is not informative; tests/test_serve.py asserts the same thing):
import jax.numpy as jnp
from repro.models import forward, lm_logits

wa, wb = store.mask_weights(0)
ln_s, ln_b = store.ln_affines([0])
toks = jnp.asarray(reqs[0].prompt[:6])[None]
dense = {"w_a": wa[None], "w_b": wb[None],
         "ln_scale": ln_s, "ln_bias": ln_b}
h1, _, _ = forward(params, toks, cfg, profile_masks=dense)
bank = params["xpeft_bank"]
pre = {"a_hat": jnp.einsum("ln,lndb->ldb", wa, bank["bank_a"].astype(
           jnp.float32))[None].astype(bank["bank_a"].dtype),
       "b_hat": jnp.einsum("ln,lnbd->lbd", wb, bank["bank_b"].astype(
           jnp.float32))[None].astype(bank["bank_b"].dtype),
       "ln_scale": dense["ln_scale"], "ln_bias": dense["ln_bias"]}
h2, _, _ = forward(params, toks, cfg, profile_masks=pre)
l1 = lm_logits(params, h1[:, -1:], cfg)
l2 = lm_logits(params, h2[:, -1:], cfg)
err = float(jnp.abs(l1 - l2).max()) / float(jnp.abs(l1).max())
print(f"decode-logit parity between paths: max rel err {err:.2e} ✓")
for i, g in enumerate(outs[True][:3]):
    print(f"  request {i}: {list(g)}")
