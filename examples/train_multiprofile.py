"""End-to-end driver: multi-profile X-PEFT fine-tuning with the full
production loop — sharded data, checkpointing, preemption handling,
straggler watchdog, resume.

Reduced preset runs a ~1M-param model for 120 steps on CPU (~2 min);
--preset paper uses bert-base dims (run on real accelerators):

  PYTHONPATH=src python examples/train_multiprofile.py
  PYTHONPATH=src python examples/train_multiprofile.py --preset paper
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.data import ProfileClassification
from repro.data.loader import ShardedLoader
from repro.distributed.fault import PreemptionHandler, StepWatchdog
from repro.train.steps import init_train_state, loss_for_batch, make_train_step
from repro.train.trainer import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--preset", default="tiny", choices=["tiny", "paper"])
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--ckpt", default="/tmp/xpeft_ck")
args = ap.parse_args()

cfg = get_config("bert-base-xpeft")
if args.preset == "tiny":
    cfg = reduce_for_smoke(cfg).with_(num_labels=4, vocab_size=256)
cfg = cfg.with_xpeft(max_profiles=16)

key = jax.random.key(0)
data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                             num_profiles=8, seed=3)
loader = ShardedLoader(data, global_batch=16, seq_len=24)
state = init_train_state(key, cfg, "xpeft")
step = jax.jit(make_train_step(cfg, "xpeft", lr=3e-2))

trainer = Trainer(step, state, loader, ckpt_dir=args.ckpt, ckpt_every=40,
                  watchdog=StepWatchdog(), preemption=PreemptionHandler(),
                  rng=jax.random.key(1), log_every=20)
if trainer.try_resume():
    print(f"[resume] continuing from step {trainer.step}")
trainer.run(args.steps)
print(f"done at step {trainer.step}; stragglers={trainer.watchdog.slow_steps}"
      f"; checkpoints={trainer.mgr.all_steps()}")

# held-out per-profile accuracy
accs = []
for j in range(4):
    b = data.sample(50_000 + j, 32, 24)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    _, m = loss_for_batch(trainer.state["frozen"], trainer.state["trainable"],
                          batch, cfg, "xpeft", jax.random.key(0),
                          training=False)
    accs.append(float(m["accuracy"]))
print(f"held-out accuracy over profiles: {np.mean(accs):.3f}")
