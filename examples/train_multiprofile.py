"""End-to-end driver: multi-profile X-PEFT fine-tuning with the full
production loop — sharded data, checkpointing, preemption handling,
straggler watchdog, resume.

Reduced preset runs a ~1M-param model for 120 steps on CPU (~2 min);
--preset paper uses bert-base dims (run on real accelerators):

  PYTHONPATH=src python examples/train_multiprofile.py
  PYTHONPATH=src python examples/train_multiprofile.py --preset paper

--onboard switches to the profile-lifecycle flow: stream P profiles
through an S-slot roster, graduating converged profiles (binarized masks +
per-profile head) into a serving ProfileStore:

  PYTHONPATH=src python examples/train_multiprofile.py --onboard
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.data import ProfileClassification
from repro.data.loader import ShardedLoader
from repro.distributed.fault import PreemptionHandler, StepWatchdog
from repro.train.steps import init_train_state, loss_for_batch, make_train_step
from repro.train.trainer import Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--preset", default="tiny", choices=["tiny", "paper"])
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--ckpt", default="/tmp/xpeft_ck")
ap.add_argument("--onboard", action="store_true",
                help="profile-lifecycle flow: stream P >> S profiles "
                     "through the roster into a ProfileStore")
ap.add_argument("--resume", action="store_true",
                help="resume --onboard from its checkpoint dir")
ap.add_argument("--profiles", type=int, default=12)
ap.add_argument("--slots", type=int, default=4)
ap.add_argument("--store-out", default="/tmp/xpeft_profiles.npz")
args = ap.parse_args()

cfg = get_config("bert-base-xpeft")
if args.preset == "tiny":
    cfg = reduce_for_smoke(cfg).with_(num_labels=4, vocab_size=256)
cfg = cfg.with_xpeft(max_profiles=16)


def run_onboarding():
    """P profiles stream through S roster slots; converged ones graduate
    into a ProfileStore the serving stack admits from directly."""
    from repro.train import GraduationPolicy
    from repro.train.onboarding import build_onboarding_run

    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=args.profiles, seed=3)
    trainer, gang = build_onboarding_run(
        cfg, data, range(args.profiles), slots=args.slots, per_slot=4,
        seq_len=24, lr=3e-2,
        policy=GraduationPolicy(min_steps=20, max_steps=60, target_acc=0.85),
        store_path=args.store_out, ckpt_dir=args.ckpt + "_onboard",
        ckpt_every=40, watchdog=StepWatchdog(),
        preemption=PreemptionHandler(), log_every=20, rng=jax.random.key(1))
    scheduler, store = trainer.scheduler, trainer.scheduler.store
    frozen = trainer.state["frozen"]
    if args.resume and trainer.try_resume():
        print(f"[resume] continuing onboarding from step {trainer.step}")
    trainer.run_until_drained(max_steps=10 * args.profiles * 60)

    st = scheduler.stats()
    print(f"done at step {trainer.step}: {st['graduated']} graduated / "
          f"{st['evicted']} evicted over {st['admission_waves']} waves; "
          f"gang-step traces={gang.trace_counter['traces']}, "
          f"host syncs/step={trainer.host_syncs / max(trainer.step, 1):.3f}")
    for g in scheduler.graduated[:6]:
        print(f"  profile {g['pid']:3d}: slot {g['slot']} steps {g['steps']}"
              f" ema_acc {g['ema_acc']:.3f}")
    store.save(args.store_out)
    print(f"store: {len(store.profile_ids())} profiles @ "
          f"{store.bytes_per_profile()} B masks "
          f"({store.total_bytes()} B total) -> {args.store_out}")

    if not store.profile_ids():
        print("no graduated profiles to evaluate")
        return
    # graduated-profile eval: hydrate masks + head back OUT of the store
    # (the exact bytes serving admits from) and score held-out data
    from repro.models import model as MDL
    accs = []
    for pid in store.profile_ids()[:4]:
        b = data.sample(90_000 + pid, 32, 24, profile_ids=[pid] * 32)
        wa, wb, ls, lb = store.batch_mask_weights([pid] * 32)
        masks = {"w_a": wa, "w_b": wb, "ln_scale": ls, "ln_bias": lb}
        hidden, _, _ = MDL.forward(frozen, jnp.asarray(b["tokens"]), cfg,
                                   profile_masks=masks)
        hw, hb = store.head(pid)
        head = {"head_w": jnp.broadcast_to(hw, (32,) + hw.shape),
                "head_b": jnp.broadcast_to(hb, (32,) + hb.shape)}
        logits = MDL.cls_logits(frozen, hidden, cfg, head)
        accs.append(float((jnp.argmax(logits, -1) ==
                           jnp.asarray(b["labels"])).mean()))
    print(f"store-hydrated held-out accuracy: {np.mean(accs):.3f}")


if args.onboard:
    run_onboarding()
    raise SystemExit(0)

key = jax.random.key(0)
data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                             num_profiles=8, seed=3)
loader = ShardedLoader(data, global_batch=16, seq_len=24)
state = init_train_state(key, cfg, "xpeft")
step = jax.jit(make_train_step(cfg, "xpeft", lr=3e-2))

trainer = Trainer(step, state, loader, ckpt_dir=args.ckpt, ckpt_every=40,
                  watchdog=StepWatchdog(), preemption=PreemptionHandler(),
                  rng=jax.random.key(1), log_every=20)
if trainer.try_resume():
    print(f"[resume] continuing from step {trainer.step}")
trainer.run(args.steps)
print(f"done at step {trainer.step}; stragglers={trainer.watchdog.slow_steps}"
      f"; checkpoints={trainer.mgr.all_steps()}")

# held-out per-profile accuracy
accs = []
for j in range(4):
    b = data.sample(50_000 + j, 32, 24)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    _, m = loss_for_batch(trainer.state["frozen"], trainer.state["trainable"],
                          batch, cfg, "xpeft", jax.random.key(0),
                          training=False)
    accs.append(float(m["accuracy"]))
print(f"held-out accuracy over profiles: {np.mean(accs):.3f}")
