# Repo verification entry points. `make verify` is what CI runs: the tier-1
# test suite (minus the documented seed-known failures below, so that NEW
# regressions fail the build) plus a kernel/serve bench smoke that gates on
# BENCH_*.json emission.

PY      := python
PP      := PYTHONPATH=src:.

# Pre-existing seed failures (multi-device emulation / dry-run cells); kept
# deselected so `make verify` is green and any NEW failure is a regression.
KNOWN_FAIL := \
  --deselect tests/test_distributed.py::test_compressed_psum_numerics \
  --deselect tests/test_distributed.py::test_pipeline_matches_single_device \
  --deselect tests/test_distributed.py::test_small_mesh_train_step_and_moe_parity \
  --deselect tests/test_distributed.py::test_elastic_reshard_smaller_mesh \
  --deselect tests/test_dryrun.py::test_dryrun_cell_single_pod \
  --deselect tests/test_dryrun.py::test_dryrun_cell_multi_pod \
  --deselect tests/test_hlo_cost.py::test_collectives_counted

.PHONY: verify test bench-smoke bench

test:
	PYTHONPATH=src $(PY) -m pytest -q $(KNOWN_FAIL)

bench-smoke:
	$(PP) $(PY) benchmarks/kernel_bench.py --smoke
	$(PP) $(PY) benchmarks/serve_bench.py --smoke
	$(PP) $(PY) benchmarks/check_bench.py

bench:
	$(PP) $(PY) benchmarks/run.py

verify: test bench-smoke
	@echo "verify: OK"
