# Repo verification entry points. `make verify` is what CI runs
# (.github/workflows/ci.yml): the FULL tier-1 test suite (the 7 seed-era
# multi-device failures were jax-version API breaks, fixed in PR 2 — no
# deselects remain) plus a kernel/serve/train bench smoke that gates on
# BENCH_*.json emission, and the onboarding smoke (--onboard through the
# launcher: roster admission, graduation, store emission).

PY      := python
PP      := PYTHONPATH=src:.

.PHONY: verify test bench-smoke onboard-smoke multidev-smoke quant-smoke \
	chaos-smoke cb-smoke spec-smoke hetero-smoke obs-smoke bench

test:
	PYTHONPATH=src $(PY) -m pytest -q

bench-smoke:
	$(PP) $(PY) benchmarks/kernel_bench.py --smoke
	$(PP) $(PY) benchmarks/serve_bench.py --smoke
	$(PP) $(PY) benchmarks/train_bench.py --smoke
	$(PP) $(PY) benchmarks/check_bench.py

onboard-smoke:
	PYTHONPATH=src $(PY) -m repro.launch.train --onboard --smoke \
		--arch qwen1.5-0.5b --profiles 6 --roster-slots 2 \
		--per-slot-batch 2 --seq 16 --graduate-min-steps 4 \
		--graduate-max-steps 10 --steps 200 \
		--store-out /tmp/onboard_smoke_store.npz

# 8-fake-device CPU mesh: serve + onboard must be BITWISE identical to the
# 1-device path (the script forces its own device-count XLA flag). Not a
# verify dep: the tier-1 suite (test_distributed) and bench-smoke
# (serve_bench -> sharded.parity gate) already run the same vehicle — this
# target is the standalone entry the CI multi-device job and humans use.
multidev-smoke:
	$(PP) $(PY) benchmarks/sharded_smoke.py --check

# quantized-bank smoke: none/int8/int4 engines end to end — admission
# byte ceilings, int8 greedy-decode agreement, zero-bank-read admission
# from graduated quantized records, per-device residency reduction. The
# BENCH json quant rows are gated by check_bench inside bench-smoke; this
# is the fast standalone probe (also a CI job).
quant-smoke:
	$(PP) $(PY) benchmarks/quant_smoke.py

# chaos soak (PR 6 resilience layer): a seeded FaultPlan injects >= 20%
# persistent hydration failures, 2 corrupted store records, NaN-poisoned
# roster slots and a torn checkpoint; check_bench --fault-only gates the
# degradation contract (every wave completes, degraded == planned, corrupt
# never served, unaffected requests bitwise, quarantine accounting closes).
# 8 forced host devices so the elastic-reshard record is emitted too.
chaos-smoke:
	$(PP) XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		$(PY) benchmarks/fault_bench.py --smoke
	$(PP) $(PY) benchmarks/check_bench.py --fault-only

# continuous-batching smoke (PR 7 paged engine): the windowed and the
# paged continuous engine drain the SAME skewed-length workload; gates are
# bitwise token parity, strictly higher slot occupancy / lower stranded
# slot-steps, and one decode trace across admissions/preemptions/resumes.
# The >= 1.3x tok/s floor applies under BENCH_STRICT=1 only (shared CI
# wall clock varies). The same numbers land in BENCH_serve.json (cb.*
# records, gated by check_bench inside bench-smoke).
cb-smoke:
	$(PP) $(PY) benchmarks/cb_smoke.py --check

# self-speculative decoding smoke (ISSUE 8): the bare PLM (zero-adapter
# view, zero extra weight memory) drafts gamma tokens per slot, the
# adapted model verifies them in ONE batched step. Gates: greedy spec
# output BITWISE equal plain greedy per request — on the normal workload
# AND with an adversarial profile that forces rejections — one compiled
# decode step, committed tokens per device step > 1, strictly fewer
# device steps than plain. The spec-vs-plain tok/s floor applies under
# BENCH_STRICT=1 only (CPU toy shapes are compute-bound; verify costs
# gamma+1 tokens of FLOPs). The same numbers land in BENCH_serve.json
# (spec.* records, gated by check_bench inside bench-smoke).
spec-smoke:
	$(PP) $(PY) benchmarks/spec_smoke.py --check

# heterogeneous adapter-bank smoke (ISSUE 9): typed segments — bottleneck /
# LoRA / IA3 / prefix — tile ONE unified mask index space; mixed-type
# profiles admit through the k-sparse fast path, prefix KV rows hydrate
# into the paged cache, and decode stays ONE compiled program. Gates:
# engine tokens BITWISE equal a composed dense reference, prefix-on AND
# prefix-off admissions both exercised, per-type record bytes positive,
# per-type interpret-vs-ref kernel parity. The same numbers land in
# BENCH_serve.json (hetero.* records, gated by check_bench inside
# bench-smoke).
hetero-smoke:
	$(PP) $(PY) benchmarks/hetero_smoke.py --check

# observability smoke (ISSUE 10): the SAME serve workload runs obs-off and
# obs-on (retrace sentinel in raise mode), plus a small onboarding run on
# the shared bundle. Gates: obs-on decode tokens BITWISE equal obs-off,
# host syncs/token and decode jit traces EXACTLY unchanged (obs adds zero
# syncs, zero retraces), the exported Chrome trace validates with >= 6
# span categories (admission / prefill / decode-window / gang-step /
# graduation / resilience), and the TTFT / decode-latency / admission-wait
# / gang-step histograms carry p50/p99. The obs-on tok/s floor applies
# under BENCH_STRICT=1 only. Emits BENCH_obs.json (obs.* records, gated by
# check_bench) and BENCH_obs_trace.json (open in Perfetto).
obs-smoke:
	$(PP) $(PY) benchmarks/obs_smoke.py --check

bench:
	$(PP) $(PY) benchmarks/run.py

verify: test bench-smoke onboard-smoke quant-smoke chaos-smoke cb-smoke \
	spec-smoke hetero-smoke obs-smoke
	@echo "verify: OK"
