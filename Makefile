# Repo verification entry points. `make verify` is what CI runs
# (.github/workflows/ci.yml): the FULL tier-1 test suite (the 7 seed-era
# multi-device failures were jax-version API breaks, fixed in PR 2 — no
# deselects remain) plus a kernel/serve bench smoke that gates on
# BENCH_*.json emission.

PY      := python
PP      := PYTHONPATH=src:.

.PHONY: verify test bench-smoke bench

test:
	PYTHONPATH=src $(PY) -m pytest -q

bench-smoke:
	$(PP) $(PY) benchmarks/kernel_bench.py --smoke
	$(PP) $(PY) benchmarks/serve_bench.py --smoke
	$(PP) $(PY) benchmarks/check_bench.py

bench:
	$(PP) $(PY) benchmarks/run.py

verify: test bench-smoke
	@echo "verify: OK"
