"""Three-term roofline model for TPU v5e (target hardware).

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

`cost_analysis()` on the SPMD-compiled module reports PER-DEVICE flops/bytes
(verified empirically), so no further division by chip count is needed.

MODEL_FLOPS ("useful" flops) is computed analytically from the config+shape:
matmul params in the forward path (attention/MLP/MoE-active/adapters/head)
plus attention score/AV flops (causal-halved, window-clipped), times the
workload factor: 4x for masks-only xpeft training (fwd + activation-grad bwd;
frozen weight grads are DCE'd), 6x for full training, 2x for inference.
MODEL_FLOPS / HLO_FLOPs exposes remat recompute, rectangle-waste in chunked
attention, and dispatch overheads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

PEAK_FLOPS = 197e12     # bf16 FLOP/s per v5e chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link (conservative single-link figure)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    t_c = flops_per_dev / PEAK_FLOPS
    t_m = bytes_per_dev / HBM_BW
    t_x = coll_bytes_per_dev / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    total = max(t_c, t_m, t_x)
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom[1], "bound_s": total,
            "compute_frac_of_bound": t_c / total if total else 0.0}


# ----------------------------------------------------------------------------
# Analytic "useful" FLOPs
# ----------------------------------------------------------------------------

def matmul_params(cfg) -> int:
    """Active matmul parameters touched per token in the forward pass."""
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    per_layer = 0
    if cfg.block_pattern == "attn":
        attn = d * H * hd + 2 * d * KV * hd + H * hd * d
        if cfg.moe:
            mlp = d * cfg.num_experts + cfg.top_k * 3 * d * ff
        else:
            mlp = (3 if cfg.mlp_type == "glu" else 2) * d * ff
        per_layer = attn + mlp
        total = L * per_layer
    elif cfg.block_pattern == "rwkv":
        tm = 5 * d * (H * hd) + (H * hd) * d + d * 64 + 64 * H * hd
        cm = 2 * d * ff + d * d
        total = L * (tm + cm)
    elif cfg.block_pattern in ("mamba", "zamba"):
        d_inner = 2 * d
        nheads = d_inner // cfg.mamba_headdim
        in_dim = 2 * d_inner + 2 * cfg.ssm_state + nheads
        total = L * (d * in_dim + d_inner * d)
        if cfg.block_pattern == "zamba":
            n_inv = L // cfg.shared_attn_every
            attn = d * H * hd + 2 * d * KV * hd + H * hd * d + 3 * d * ff
            total += n_inv * attn
    else:
        total = 0
    # X-PEFT adapter application: 2·d·b per adapted layer
    if cfg.xpeft.enabled:
        total += L * 2 * d * cfg.xpeft.bottleneck
    # LM head (tied or not, the logits matmul runs)
    total += d * cfg.vocab_size
    return int(total)


def _attn_flops_per_seq(cfg, T: int, decode_ctx: int = 0) -> float:
    """Score+AV flops for ONE sequence (forward)."""
    H, hd, L = cfg.num_heads, cfg.head_dim, cfg.num_layers
    if cfg.block_pattern == "rwkv":
        c = cfg.la_chunk
        return L * T * (2 * c * (hd + hd) + 4 * hd * hd) * cfg.num_heads / 2
    if cfg.block_pattern in ("mamba", "zamba"):
        d_inner = 2 * cfg.d_model
        nheads = d_inner // cfg.mamba_headdim
        c = cfg.la_chunk
        n, p = cfg.ssm_state, cfg.mamba_headdim
        fl = L * nheads * T * (c * (n + p) + 4 * n * p) / 2
        if cfg.block_pattern == "zamba":
            n_inv = L // cfg.shared_attn_every
            if decode_ctx:
                fl += n_inv * 4 * decode_ctx * H * hd
            else:
                fl += n_inv * 2 * T * T * H * hd  # causal-halved
        return fl
    # attention archs
    meta_global = 1.0 / cfg.global_every if cfg.attn_type == "sliding_mix" else 1.0
    if decode_ctx:  # one new token vs ctx
        per_layer_global = 4 * decode_ctx * H * hd
        per_layer_local = 4 * min(decode_ctx, cfg.sliding_window) * H * hd
    else:
        per_layer_global = 2 * T * T * H * hd          # causal-halved 4T²/2
        w = min(cfg.sliding_window, T)
        per_layer_local = 4 * T * w * H * hd / 2
    if cfg.attn_type == "sliding_mix":
        ng = cfg.num_layers // cfg.global_every
        nl = cfg.num_layers - ng
        return ng * per_layer_global + nl * per_layer_local
    return cfg.num_layers * per_layer_global


def model_flops(cfg, shape, num_devices: int, workload: str = "xpeft") -> float:
    """Per-device 'useful' FLOPs for one step of this cell."""
    Np = matmul_params(cfg)
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        factor = 4.0 if workload == "xpeft" else 6.0
        tokens = B * (T + cfg.num_prefix_tokens)
        fl = factor * Np * tokens + (factor / 2) * B * _attn_flops_per_seq(cfg, T)
    elif shape.kind == "prefill":
        tokens = B * (T + cfg.num_prefix_tokens)
        fl = 2.0 * Np * tokens + B * _attn_flops_per_seq(cfg, T)
    else:  # decode: one token per sequence against ctx = T
        fl = 2.0 * Np * B + B * _attn_flops_per_seq(cfg, 1, decode_ctx=T)
        if cfg.xpeft.enabled:
            # baseline decode re-aggregates masks against the bank each step
            xp = cfg.xpeft
            fl += 2.0 * B * cfg.num_layers * 2 * xp.num_adapters \
                * cfg.d_model * xp.bottleneck
    return fl / num_devices
