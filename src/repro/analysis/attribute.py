"""Attribute flops/bytes/collectives to HLO op sites (metadata op_name),
with while-loop trip multiplication — the 'profiler' of the dry-run world.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

from repro.analysis.hlo_cost import (HloModule, _BODY_RE, _COND_RE,
                                     _CONTRACT_RE, _first_shape_dims,
                                     _type_bytes, _type_elems, _COLLS,
                                     _FREE_OPS)

_META_RE = re.compile(r'op_name="([^"]+)"')
_CALL_RE = re.compile(
    r"(?:calls|to_apply|condition|body|called_computation)=%?([\w\.\-]+)")


def computation_multipliers(mod: HloModule) -> Dict[str, int]:
    mult = {mod.entry: 1}
    order = [mod.entry]
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        m = mult[cname]
        for o in mod.computations.get(cname, []):
            line = o["line"]
            if o["op"] == "while":
                cond = _COND_RE.search(line)
                body = _BODY_RE.search(line)
                trip = mod._trip_count(cond.group(1)) if cond else 1
                for g in ([body.group(1)] if body else []) + \
                        ([cond.group(1)] if cond else []):
                    mult[g] = mult.get(g, 0) + m * trip
                    order.append(g)
            else:
                for cm in _CALL_RE.finditer(line):
                    g = cm.group(1)
                    if g in mod.computations:
                        mult[g] = mult.get(g, 0) + m
                        order.append(g)
    return mult


def _op_cost(mod, ops, o) -> Tuple[float, float]:
    """(flops, bytes) of ONE op occurrence (fusions -> callee flops)."""
    op, t = o["op"], o["type"]
    if op in _FREE_OPS or op == "while" or op.endswith("-done"):
        return 0.0, 0.0
    base = op.replace("-start", "")
    if base in _COLLS:
        b = _type_bytes(t)
        if op.endswith("-start") and t.startswith("("):
            b //= 2
        return 0.0, float(b)
    if op == "fusion":
        cm = re.search(r"calls=%([\w\.\-]+)", o["line"])
        f = mod.cost(cm.group(1))[0] if cm else 0.0
        optypes = mod._operand_types(ops, o["rest"])
        return f, mod.fusion_bytes(cm.group(1) if cm else None, t, optypes)
    if op == "dot":
        optypes = mod._operand_types(ops, o["rest"])
        lhs = _first_shape_dims(optypes[0]) if optypes else []
        cm = _CONTRACT_RE.search(o["line"])
        contract = 1
        if cm and lhs:
            for i in cm.group(1).split(","):
                if i:
                    contract *= lhs[int(i)]
        return (2.0 * _type_elems(t) * contract,
                _type_bytes(t) + sum(_type_bytes(x) for x in optypes))
    if op in ("dynamic-update-slice", "dynamic-slice"):
        optypes = mod._operand_types(ops, o["rest"])
        moved = (_type_bytes(optypes[1]) if op == "dynamic-update-slice"
                 and len(optypes) > 1 else _type_bytes(t))
        return 0.0, 2.0 * moved
    if op in ("gather",):
        return 0.0, 2.0 * _type_bytes(t)
    if op in ("scatter",):
        optypes = mod._operand_types(ops, o["rest"])
        upd = optypes[-1] if optypes else t
        return float(_type_elems(upd)), 2.0 * _type_bytes(upd)
    if op in ("transpose", "copy"):
        return 0.0, 2.0 * _type_bytes(t)
    if op in ("reduce", "reduce-window"):
        optypes = mod._operand_types(ops, o["rest"])
        return (float(sum(_type_elems(x) for x in optypes[:1])),
                _type_bytes(t) + sum(_type_bytes(x) for x in optypes[:1]))
    return float(_type_elems(t)), 0.0


def attribute(hlo_text: str, top: int = 15, key: str = "bytes"):
    """Top sites by bytes (or flops): [(value, op_kind, op_name_meta)]."""
    mod = HloModule(hlo_text)
    mult = computation_multipliers(mod)
    sites: Dict[Tuple[str, str], float] = {}
    for cname, m in mult.items():
        ops = mod.computations.get(cname, [])
        for o in ops:
            f, b = _op_cost(mod, ops, o)
            v = b if key == "bytes" else f
            if v <= 0:
                continue
            meta = _META_RE.search(o["line"])
            name = meta.group(1)[-110:] if meta else o["name"][:60]
            k = (o["op"], name)
            sites[k] = sites.get(k, 0.0) + v * m
    out = sorted(((v, k[0], k[1]) for k, v in sites.items()), reverse=True)
    return out[:top]
