"""Parse collective traffic out of compiled (post-SPMD) HLO text.

`compiled.cost_analysis()` does not report collective bytes, so we sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute in the per-device module. Async pairs
(-start/-done) are counted once via the -start op. The module is already
SPMD-partitioned, so shapes (and therefore bytes) are PER DEVICE.
"""
from __future__ import annotations

import re
from typing import Dict

_ITEMSIZE = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute")

# `%name = TYPE op-name(` where TYPE may be a tuple
_LINE_RE = re.compile(
    r"=\s*(?P<type>\([^=]*?\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(?P<op>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"[\s(]")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _ITEMSIZE:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _ITEMSIZE[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Returns {'all-gather': bytes, ..., 'total': bytes} per device."""
    out = {k: 0 for k in _COLLS}
    counts = {k: 0 for k in _COLLS}
    for m in _LINE_RE.finditer(hlo_text):
        op = m.group("op").replace("-start", "")
        b = _shape_bytes(m.group("type"))
        # async start ops return (operand, result[, scratch]) tuples; the
        # result is roughly half the tuple bytes
        if m.group(0).find("-start") != -1 and m.group("type").startswith("("):
            b = b // 2
        out[op] += b
        counts[op] += 1
    out["total"] = sum(out[k] for k in _COLLS)
    out["counts"] = counts
    return out
