from repro.analysis.bytes import (  # noqa: F401
    admission_bank_bytes, aggregation_bytes, bank_slice_bytes, itemsize_for,
    record_bytes, row_bytes, tree_nbytes)
from repro.analysis.hlo import collective_bytes  # noqa: F401
from repro.analysis.roofline import roofline_terms, model_flops  # noqa: F401
