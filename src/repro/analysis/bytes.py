"""Analytic bank/record byte accounting — the ONE place the admission
byte math lives.

Consumers: `serve/engine.py` admit stats (what one k-sparse admission
reads), `benchmarks/serve_bench.py` (dense-vs-sparse analytic columns),
`benchmarks/table1_memory.py` (quantized per-profile / per-bank columns)
and the quant gates in `benchmarks/check_bench.py`. The quant numbers
match the TRUE array bytes `quant.schemes.quantize_bank` produces
(asserted in tests/test_analysis_bytes.py), so the analytic gates and the
engine's measured accounting can never drift apart.
"""
from __future__ import annotations

import numpy as np

from repro.quant.schemes import check_scheme, group_for


def itemsize_for(dtype: str) -> int:
    """Byte width of a model dtype string ('bfloat16', 'float32', ...)."""
    return np.dtype(np.float16 if dtype == "bfloat16" else dtype).itemsize


def row_bytes(n: int, *, scheme: str = "none", itemsize: int = 2,
              group: int = 32) -> int:
    """Bytes of ONE length-n quantized-or-not row (payload + fp16 scales).

    none: n * itemsize.  int8: n + one fp16 scale.  int4: n/2 packed +
    one fp16 scale per group_for(n, group) values."""
    check_scheme(scheme)
    if scheme == "none":
        return n * itemsize
    if scheme == "int8":
        return n + 2
    g = group_for(n, group)
    return n // 2 + 2 * (n // g)


def bank_slice_bytes(d: int, b: int, *, scheme: str = "none",
                     itemsize: int = 2, group: int = 32) -> int:
    """Bytes of one (layer, adapter) bank slice across BOTH banks: the Â
    row block [d, b] (d rows of length b) + the B̂ row block [b, d]."""
    return d * row_bytes(b, scheme=scheme, itemsize=itemsize, group=group) \
        + b * row_bytes(d, scheme=scheme, itemsize=itemsize, group=group)


def admission_bank_bytes(L: int, N: int, k: int, d: int, b: int, *,
                         dense: bool = False, scheme: str = "none",
                         itemsize: int = 2, group: int = 32) -> int:
    """Bank bytes ONE admission aggregation reads: k rows per layer on the
    sparse path (N with ``dense=True``), both banks, under ``scheme``."""
    rows = N if dense else k
    return rows * L * bank_slice_bytes(d, b, scheme=scheme,
                                       itemsize=itemsize, group=group)


def record_bytes(L: int, d: int, b: int, *, scheme: str,
                 group: int = 32) -> int:
    """Bytes of one profile's stored aggregated Â/B̂ record (+scales) —
    what the ProfileCache budgets per entry and the ProfileStore persists
    for quantized stores. scheme='none' gives the fp16 record the
    motivation cites as today's resident cost."""
    if scheme == "none":
        return 2 * 2 * L * d * b  # fp16 Â + B̂
    return L * bank_slice_bytes(d, b, scheme=scheme, group=group)


def aggregation_bytes(cfg) -> dict:
    """The serve-bench analytic record: dense vs k-sparse admission reads
    at cfg's dims, plus the quantized-sparse column for each scheme and
    the reductions the CI gates enforce."""
    xp = cfg.xpeft
    L, N, k, d, b = (cfg.num_layers, xp.num_adapters, xp.k, cfg.d_model,
                     xp.bottleneck)
    itemsize = itemsize_for(cfg.dtype)
    kw = dict(itemsize=itemsize, group=xp.quant_group)
    dense = admission_bank_bytes(L, N, k, d, b, dense=True, **kw)
    sparse = admission_bank_bytes(L, N, k, d, b, **kw)
    out = {"N": N, "k": k, "L": L, "d": d, "b": b,
           "bytes_dense": dense, "bytes_sparse": sparse,
           "reduction": round(dense / sparse, 2)}
    for scheme in ("int8", "int4"):
        q = admission_bank_bytes(L, N, k, d, b, scheme=scheme, **kw)
        out[f"bytes_sparse_{scheme}"] = q
        out[f"{scheme}_vs_sparse"] = round(q / sparse, 3)
        out[f"{scheme}_vs_dense"] = round(q / dense, 4)
    return out


def tree_nbytes(tree) -> int:
    """TRUE byte count of a pytree of arrays (shape x itemsize)."""
    import jax

    return int(sum(np.prod(x.shape) * np.dtype(x.dtype).itemsize
                   for x in jax.tree.leaves(tree)))
