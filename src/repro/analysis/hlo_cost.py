"""HLO cost analysis with while-loop trip-count multiplication.

XLA's built-in HloCostAnalysis visits each computation ONCE — a scanned
62-layer transformer reports the FLOPs of one layer (verified empirically in
this container). This module re-derives flops / bytes-accessed / collective
bytes from `compiled.as_text()`, recursively costing called computations and
multiplying while bodies by their trip counts (parsed from the loop-condition
constant, the jax.lax.scan lowering pattern).

Cost model:
- dot:        2 * prod(result_dims) * prod(lhs contracting dim sizes)
- reduce:     prod(operand dims)
- elementwise/other shaped ops: prod(result dims)
- sort:       prod * log2(prod)
- fusion:     flops of the called computation; bytes = operands + result of
              the fusion op itself (post-fusion traffic — the TPU-relevant
              number)
- while:      trip * (body + cond)
- conditional: max over branches
- collectives: result-shape bytes at the call site (x trip counts), keyed by
              kind; async -start/-done pairs counted once.

All shapes in the post-SPMD module are per-device, so every number this
module returns is per-device.
"""
from __future__ import annotations

import math
import re
from typing import Dict, List, Optional, Tuple

_ITEMSIZE = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
          "collective-permute", "ragged-all-to-all")

_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
             "after-all", "add-dependency", "copy-start", "copy-done",
             "partition-id", "replica-id", "iota", "copy"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w\.\-]+)\s*=\s*(?P<type>\([^)]*\)|[a-z0-9]+"
    r"\[[^\]]*\]\S*)\s+(?P<op>[\w\-]+)\((?P<args>.*)$")
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_BRANCH_RE = re.compile(r"(?:true_computation|false_computation|"
                        r"branch_computations=\{[^}]*\}|"
                        r"to_apply)=?%?([\w\.\-,% {}]*)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _type_bytes(t: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(t):
        if dt not in _ITEMSIZE:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _ITEMSIZE[dt]
    return total


def _type_elems(t: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(t):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def _first_shape_dims(t: str) -> List[int]:
    m = _SHAPE_RE.search(t)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[dict]] = {}
        self._parse(text)
        self._memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}
        self.entry: Optional[str] = self._entry_name(text)

    def _entry_name(self, text) -> Optional[str]:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.M)
        return m.group(1) if m else None

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            hdr = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{",
                           line)
            if hdr and not line.startswith(" "):
                cur = hdr.group(2)
                self.computations[cur] = []
                continue
            if cur is None:
                continue
            if line.startswith("}"):
                cur = None
                continue
            m = _OP_RE.match(line)
            if m:
                self.computations[cur].append({
                    "name": m.group("name"), "type": m.group("type"),
                    "op": m.group("op"), "rest": m.group("args"),
                    "line": line,
                })

    # ------------------------------------------------------------------ cost
    def _trip_count(self, cond_name: str) -> int:
        """Loop bound = the constant operand of the condition's ROOT compare
        (jax.lax.scan lowers to `while i < constant(L)`); LE gets +1."""
        ops = self.computations.get(cond_name, [])
        if not ops:
            return 1
        le = any("direction=LE" in o["line"] for o in ops)
        table = {o["name"]: o for o in ops}
        root = next((o for o in ops if o["line"].lstrip().startswith("ROOT")),
                    ops[-1])
        cands = []
        for nm in re.findall(r"%([\w\.\-]+)", root["rest"]):
            o = table.get(nm)
            if o is not None and o["op"] == "constant":
                m = _CONST_RE.search(o["line"])
                if m:
                    cands.append(int(m.group(1)))
        if not cands:  # fall back: any scalar int constant in the cond
            for o in ops:
                m = _CONST_RE.search(o["line"])
                if m and o["type"].startswith("s32[]"):
                    cands.append(int(m.group(1)))
        best = max(cands) if cands else 1
        return best + (1 if le else 0)

    def _operand_types(self, comp_ops, rest: str) -> List[str]:
        table = {o["name"]: o["type"] for o in comp_ops}
        names = re.findall(r"%([\w\.\-]+)", rest.split("),")[0])
        return [table[n] for n in names if n in table]

    def _root_op(self, comp_name: str) -> str:
        ops = self.computations.get(comp_name, [])
        for o in ops:
            if o["line"].lstrip().startswith("ROOT"):
                return o["op"]
        return ops[-1]["op"] if ops else ""

    def fusion_bytes(self, callee: Optional[str], t: str,
                     optypes: List[str]) -> float:
        """Fusion-boundary bytes. In-place-update fusions (a
        dynamic-update-slice covering the whole output, possibly wrapped in
        converts) alias their big operand on TPU: charge only the
        slice-sized operands, not the whole buffer."""
        out_b = _type_bytes(t)
        out_e = _type_elems(t)
        if callee:
            for o in self.computations.get(callee, []):
                if o["op"] == "dynamic-update-slice" \
                        and _type_elems(o["type"]) == out_e:
                    small = [_type_bytes(x) for x in optypes
                             if _type_bytes(x) < out_b / 2]
                    return 2.0 * sum(small)
        return out_b + sum(_type_bytes(x) for x in optypes)

    def cost(self, comp_name: Optional[str] = None):
        """Returns (flops, bytes, {collective_kind: bytes, 'total': ...})."""
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        self._memo[comp_name] = (0.0, 0.0, {})  # cycle guard
        flops = 0.0
        byts = 0.0
        colls: Dict[str, float] = {k: 0.0 for k in _COLLS}
        ops = self.computations.get(comp_name, [])
        for o in ops:
            op = o["op"]
            t = o["type"]
            if op == "while":
                cond = _COND_RE.search(o["line"])
                body = _BODY_RE.search(o["line"])
                trip = self._trip_count(cond.group(1)) if cond else 1
                for cname in ([body.group(1)] if body else []) + \
                        ([cond.group(1)] if cond else []):
                    f, b, c = self.cost(cname)
                    flops += trip * f
                    byts += trip * b
                    for k, v in c.items():
                        colls[k] = colls.get(k, 0.0) + trip * v
                continue
            if op == "conditional":
                branches = re.findall(r"%([\w\.\-]+)", o["line"])
                comp_branches = [b for b in branches
                                 if b in self.computations]
                if comp_branches:
                    costs = [self.cost(b) for b in comp_branches]
                    f = max(c[0] for c in costs)
                    b = max(c[1] for c in costs)
                    flops += f
                    byts += b
                    for c in costs[:1]:
                        for k, v in c[2].items():
                            colls[k] = colls.get(k, 0.0) + v
                continue
            base = op.replace("-start", "")
            if base in _COLLS:
                bb = _type_bytes(t)
                if op.endswith("-start") and t.startswith("("):
                    bb = bb // 2  # async tuple carries (operand, result)
                colls[base] = colls.get(base, 0.0) + bb
                byts += bb
                continue
            if op.endswith("-done"):
                continue
            if op in _FREE_OPS:
                continue
            if op == "fusion":
                cm = _CALLS_RE.search(o["line"])
                if cm:
                    f, _, c = self.cost(cm.group(1))
                    flops += f
                    for k, v in c.items():
                        colls[k] = colls.get(k, 0.0) + v
                optypes = self._operand_types(ops, o["rest"])
                byts += self.fusion_bytes(cm.group(1) if cm else None, t,
                                          optypes)
                continue
            if op in ("call", "custom-call", "async-start"):
                cm = re.search(r"(?:to_apply|calls|called_computation)="
                               r"%?([\w\.\-]+)", o["line"])
                if cm and cm.group(1) in self.computations:
                    f, b, c = self.cost(cm.group(1))
                    flops += f
                    byts += b
                    for k, v in c.items():
                        colls[k] = colls.get(k, 0.0) + v
                else:
                    byts += _type_bytes(t)
                continue
            if op == "dot":
                optypes = self._operand_types(ops, o["rest"])
                lhs_dims = _first_shape_dims(optypes[0]) if optypes else []
                cm = _CONTRACT_RE.search(o["line"])
                contract = 1
                if cm and lhs_dims:
                    for i in cm.group(1).split(","):
                        if i:
                            contract *= lhs_dims[int(i)]
                flops += 2.0 * _type_elems(t) * contract
                byts += _type_bytes(t) + sum(_type_bytes(x) for x in optypes)
                continue
            if op == "reduce" or op == "reduce-window":
                optypes = self._operand_types(ops, o["rest"])
                flops += float(sum(_type_elems(x) for x in optypes[:1]))
                byts += _type_bytes(t) + sum(_type_bytes(x)
                                             for x in optypes[:1])
                continue
            if op == "sort":
                n = _type_elems(t)
                flops += n * max(1.0, math.log2(max(n, 2)))
                byts += 2 * _type_bytes(t)
                continue
            if op in ("dynamic-update-slice", "dynamic-slice"):
                # in-place on TPU: traffic = the slice, not the operand
                optypes = self._operand_types(ops, o["rest"])
                moved = (_type_bytes(optypes[1]) if op == "dynamic-update-slice"
                         and len(optypes) > 1 else _type_bytes(t))
                byts += 2 * moved
                continue
            if op in ("gather", "scatter"):
                # traffic = gathered/updated elements, not the whole operand
                if op == "gather":
                    byts += 2 * _type_bytes(t)
                else:
                    optypes = self._operand_types(ops, o["rest"])
                    upd = optypes[-1] if optypes else t
                    byts += 2 * _type_bytes(upd)
                    flops += _type_elems(upd)
                continue
            if op in ("transpose", "copy"):
                byts += 2 * _type_bytes(t)
                continue
            if op in ("reshape", "broadcast", "convert", "compare", "select",
                      "and", "or", "not", "xor", "slice", "concatenate",
                      "pad", "reverse", "rev", "clamp", "sign", "negate",
                      "abs", "floor", "ceil", "round-nearest-afz",
                      "is-finite"):
                # fused-on-TPU elementwise/layout ops: flops-free-ish, no HBM
                flops += float(_type_elems(t)) * 0.0
                continue
            if op == "convolution":
                optypes = self._operand_types(ops, o["rest"])
                flops += 2.0 * _type_elems(t)
                byts += _type_bytes(t) + sum(_type_bytes(x) for x in optypes)
                continue
            # remaining elementwise math (exp, tanh, mul, add, rsqrt, rng...):
            # count flops, assume fused into neighbors for bytes
            flops += float(_type_elems(t))
        colls["total"] = sum(v for k, v in colls.items() if k in _COLLS)
        self._memo[comp_name] = (flops, byts, colls)
        return self._memo[comp_name]


def analyze(hlo_text: str) -> dict:
    mod = HloModule(hlo_text)
    flops, byts, colls = mod.cost()
    return {"flops": flops, "bytes": byts, "collectives": colls}
