"""Data pipeline: deterministic synthetic streams + multi-profile tasks +
sharded host loader."""
from repro.data.synthetic import MarkovLM, ProfileClassification  # noqa: F401
from repro.data.loader import ShardedLoader  # noqa: F401
