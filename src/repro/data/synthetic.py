"""Deterministic synthetic multi-profile data (no internet in env).

Two families, both profile-conditioned so they exercise exactly what X-PEFT
personalizes:

- MarkovLM: per-profile sparse bigram transition tables -> profile-dependent
  next-token structure. A model that adapts per profile reaches lower loss
  than any single shared model — the LM analogue of LaMP.
- ProfileClassification: per-profile random linear teachers over
  bag-of-token-features -> (tokens, label, profile_id), the GLUE/LaMP
  classification proxy used by the paper-claim benchmarks.

Everything is hash-seeded and stateless: batch(step) is reproducible from
(seed, step), which is what makes checkpoint-resume bitwise on the data side.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _rng(*parts) -> np.random.Generator:
    seed = 0x9E3779B97F4A7C15
    for p in parts:
        seed = ((seed ^ (abs(hash(int(p))) & 0xFFFFFFFFFFFFFFFF))
                * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    return np.random.default_rng(seed % (2 ** 63))


@dataclass
class MarkovLM:
    vocab_size: int
    num_profiles: int
    branch: int = 4          # candidate successors per token per profile
    seed: int = 0

    def _table(self, profile: int) -> np.ndarray:
        g = _rng(self.seed, 1, profile)
        return g.integers(0, self.vocab_size,
                          size=(self.vocab_size, self.branch))

    def sample(self, step: int, batch: int, seq_len: int,
               profile_ids=None):
        """Returns dict(tokens [B,T], labels [B,T], profile_ids [B])."""
        g = _rng(self.seed, 2, step)
        if profile_ids is None:
            profile_ids = g.integers(0, self.num_profiles, size=(batch,))
        toks = np.empty((batch, seq_len), np.int32)
        for i, pid in enumerate(np.asarray(profile_ids)):
            tbl = self._table(int(pid))
            gi = _rng(self.seed, 3, step, i)
            t = np.empty(seq_len, np.int32)
            t[0] = gi.integers(0, self.vocab_size)
            choices = gi.integers(0, self.branch, size=seq_len)
            for j in range(1, seq_len):
                t[j] = tbl[t[j - 1], choices[j]]
            toks[i] = t
        labels = np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)
        return {"tokens": toks, "labels": labels,
                "profile_ids": np.asarray(profile_ids, np.int32)}


@dataclass
class ProfileClassification:
    vocab_size: int
    num_labels: int
    num_profiles: int
    seed: int = 0

    def _teacher(self, profile: int) -> np.ndarray:
        g = _rng(self.seed, 11, profile)
        return g.normal(size=(self.vocab_size, self.num_labels))

    def sample(self, step: int, batch: int, seq_len: int, profile_ids=None):
        g = _rng(self.seed, 12, step)
        if profile_ids is None:
            profile_ids = g.integers(0, self.num_profiles, size=(batch,))
        toks = g.integers(0, self.vocab_size, size=(batch, seq_len))
        labels = np.empty((batch,), np.int32)
        for i, pid in enumerate(np.asarray(profile_ids)):
            W = self._teacher(int(pid))
            counts = np.bincount(toks[i], minlength=self.vocab_size)
            labels[i] = int(np.argmax(counts @ W))
        return {"tokens": toks.astype(np.int32), "labels": labels,
                "profile_ids": np.asarray(profile_ids, np.int32)}
