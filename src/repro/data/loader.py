"""Sharded, resumable host loader.

Every host computes its own slice of each global batch deterministically
from (seed, step, host assignment); the assignment can be re-balanced by the
straggler watchdog (distributed.fault.rebalance_assignment) without any
coordination beyond agreeing on the slow-host map.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


@dataclass
class ShardedLoader:
    source: object                 # MarkovLM / ProfileClassification-like
    global_batch: int
    seq_len: int
    host_id: int = 0
    num_hosts: int = 1
    step: int = 0
    speed_map: Dict[int, float] = field(default_factory=dict)

    def _host_range(self) -> range:
        from repro.distributed.fault import rebalance_assignment
        return rebalance_assignment(
            self.global_batch, list(range(self.num_hosts)),
            self.speed_map)[self.host_id]

    def next(self) -> dict:
        batch = self.source.sample(self.step, self.global_batch, self.seq_len)
        r = self._host_range()
        out = {k: v[r.start:r.stop] if v.shape and v.shape[0] ==
               self.global_batch else v for k, v in batch.items()}
        self.step += 1
        return out

    # -- checkpointable position ------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, s: dict):
        self.step = int(s["step"])
