"""Pytree helpers used across the framework.

Params everywhere are plain nested dicts of arrays (or ShapeDtypeStructs in
abstract mode), so these helpers are the substrate the sharding rules, the
optimizer and the checkpointer all share.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    if isinstance(k, jax.tree_util.GetAttrKey):
        return str(k.name)
    return str(k)


def tree_paths(tree) -> dict:
    """Flatten a pytree to {'a/b/c': leaf} with slash-joined string paths."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {"/".join(_key_str(k) for k in path): leaf for path, leaf in flat}


def leaf_name(path: str) -> str:
    return path.rsplit("/", 1)[-1]


def map_with_path(fn, tree):
    """tree_map where fn receives (path_str, leaf)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: fn("/".join(_key_str(k) for k in p), x), tree
    )


def map_with_paths(fn, tree, *rest):
    """Multi-tree tree_map where fn receives (path_str, leaf, *other_leaves).
    The extra trees must share `tree`'s structure (serve/pages.py maps the
    paged cache pool against the model's freshly-written dense view)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x, *r: fn("/".join(_key_str(k) for k in p), x, *r),
        tree, *rest
    )


def _leaf_size(x) -> int:
    return int(np.prod(x.shape)) if hasattr(x, "shape") else 1


def _leaf_bytes(x) -> int:
    itemsize = jnp.dtype(x.dtype).itemsize if hasattr(x, "dtype") else 4
    return _leaf_size(x) * itemsize


def param_count(tree) -> int:
    return sum(_leaf_size(x) for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    return sum(_leaf_bytes(x) for x in jax.tree_util.tree_leaves(tree))


def merge_trees(a: dict, b: dict) -> dict:
    """Recursively merge dict pytrees (b wins on conflicts at leaf level)."""
    out = dict(a)
    for k, v in b.items():
        if k in out and isinstance(out[k], dict) and isinstance(v, dict):
            out[k] = merge_trees(out[k], v)
        else:
            out[k] = v
    return out


def tree_zeros_like(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), tree)
