"""Small shared utilities: pytree helpers, padding buckets, logging, sizes."""
from repro.utils.padding import pow2_bucket, pow2_count  # noqa: F401
from repro.utils.tree import (  # noqa: F401
    tree_paths,
    leaf_name,
    param_count,
    param_bytes,
    merge_trees,
    tree_zeros_like,
    map_with_path,
    map_with_paths,
)
