"""Power-of-two padding buckets, shared by the serve scheduler and benches.

Jitted serving functions retrace per distinct shape; padding prompt lengths
and request counts to pow2 buckets bounds the number of variants at
log2(max) while wasting at most 2x pad compute.
"""
from __future__ import annotations


def pow2_bucket(n: int, floor: int = 8) -> int:
    """Length bucket: next power of two >= n, floored at `floor` (pad tokens
    are cheap, so a floor trades a little compute for fewer jit variants)."""
    b = floor
    while b < n:
        b *= 2
    return b


def pow2_count(n: int) -> int:
    """Request-count bucket: next power of two from 1 (no floor — padding
    rows cost real aggregation/prefill work, unlike pad tokens)."""
    b = 1
    while b < n:
        b *= 2
    return b
