"""Unified observability layer (ISSUE 10).

One bundle — :class:`Observability` — carries the three tools every
subsystem threads through:

- ``metrics``  (:mod:`repro.obs.metrics`): counters/gauges + exponential
  p50/p95/p99 histograms on the host; ONE device-resident accumulator in
  the slot arrays for per-token quantities, flushed only at the window
  syncs the engine already performs.
- ``tracer``   (:mod:`repro.obs.trace`): Chrome-trace-event spans
  (Perfetto-loadable) over admission / prefill / decode windows /
  preempt / spec / gang steps / graduation / resilience, in a bounded
  ring buffer.
- ``sentinel`` (:mod:`repro.obs.sentinel`): always-on retrace monitor
  over every jitted hot-path fn.

Design rule the whole layer obeys: observability must add ZERO host syncs
per token and ZERO retraces — device-side instrumentation is therefore
unconditional (compiled programs are identical with or without a bundle
attached), and host-side work happens only at sync/flush boundaries.
Engines take ``obs=None`` and fall back to :data:`NULL_OBS`, a disabled
bundle whose every call is a cheap no-op, so call sites stay unguarded.
"""
from __future__ import annotations

from repro.obs.metrics import (ExpHistogram, MetricsRegistry, StepWatchdog,
                               OBS_ACTIVE_STEPS, OBS_COLS, OBS_STRANDED_STEPS,
                               OBS_TOKENS, device_acc_init, device_acc_update)
from repro.obs.sentinel import RetraceError, RetraceSentinel
from repro.obs.trace import SpanTracer, validate_chrome_trace

__all__ = ["Observability", "NULL_OBS", "get", "MetricsRegistry",
           "ExpHistogram", "StepWatchdog", "SpanTracer", "RetraceSentinel",
           "RetraceError", "validate_chrome_trace", "device_acc_init",
           "device_acc_update", "OBS_TOKENS", "OBS_ACTIVE_STEPS",
           "OBS_STRANDED_STEPS", "OBS_COLS", "add_cli_args",
           "from_cli_args"]


class Observability:
    def __init__(self, *, enabled: bool = True, trace: bool = True,
                 trace_capacity: int = 65536, sentinel_mode: str = "log"):
        self.enabled = enabled
        self.metrics = MetricsRegistry(enabled=enabled)
        self.tracer = SpanTracer(capacity=trace_capacity,
                                 enabled=enabled and trace)
        self.sentinel = RetraceSentinel(
            mode=sentinel_mode if enabled else "off")

    def export(self, metrics_path=None, trace_path=None) -> None:
        if metrics_path:
            self.metrics.export(metrics_path)
        if trace_path:
            self.tracer.export(trace_path)

    def summary(self) -> dict:
        """Everything at once — what launchers print / dump at exit."""
        return {"metrics": self.metrics.snapshot(),
                "trace_categories": self.tracer.category_counts(),
                "trace_dropped": self.tracer.dropped,
                "retrace_watches": self.sentinel.counts()}


#: Shared disabled bundle: `obs or NULL_OBS` is the whole integration
#: contract — no call site ever branches on obs being attached.
NULL_OBS = Observability(enabled=False)


def get(obs) -> Observability:
    return obs if obs is not None else NULL_OBS


# ---------------------------------------------------------------- launchers
def add_cli_args(ap) -> None:
    """Attach the shared observability flags to an argparse parser —
    both launchers (`repro.launch.serve` / `repro.launch.train`) expose
    the same two knobs."""
    ap.add_argument("--metrics-json", default="", metavar="PATH",
                    help="write counters + p50/p95/p99 histogram snapshots "
                    "as JSON at exit (enables the obs bundle)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="write a Chrome-trace-event JSON at exit — open "
                    "in Perfetto (ui.perfetto.dev) or chrome://tracing "
                    "(enables the obs bundle)")


def from_cli_args(args):
    """Build the bundle the flags ask for, or None (engines then run on
    NULL_OBS — zero host-side obs work)."""
    if not (args.metrics_json or args.trace):
        return None
    return Observability(trace=bool(args.trace))
