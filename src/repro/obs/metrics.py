"""Metrics layer: host-side registry + the device-resident accumulator.

Two halves, one rule — the hot path never pays for observability:

- DEVICE half: every per-token quantity lives in ONE ``[n_slots, OBS_COLS]``
  int32 accumulator inside ``SlotState``'s array dict, updated by the
  already-jitted decode step and fetched in the SAME ``jax.device_get``
  the window sync already performs. Zero extra host syncs per token, zero
  extra traces (the accumulator is unconditional — the compiled program is
  identical whether an :class:`Observability` bundle is attached or not,
  which is what makes obs-on bitwise obs-off).
- HOST half: :class:`MetricsRegistry` — counters, gauges, and
  exponential-bucket histograms (:class:`ExpHistogram`) with p50/p95/p99
  snapshots. Host metrics are only touched at window/sync/flush
  boundaries, never per token.

``StepWatchdog`` (straggler scoring) moved here from
``distributed/fault.py`` — window wall-time attribution is a metric, not a
fault mechanism; ``distributed.fault`` re-exports it unchanged.
"""
from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# Device-resident accumulator: column layout shared by slots.py and the
# engine's sync-side flush. Append-only — renumbering columns would silently
# mis-label flushed metrics in any mixed-version replay.
# ----------------------------------------------------------------------------

OBS_TOKENS = 0          # tokens committed (1/step plain, c/round spec)
OBS_ACTIVE_STEPS = 1    # device steps this slot was active (occupancy num.)
OBS_STRANDED_STEPS = 2  # device steps this slot padded along inactive
OBS_COLS = 3


def device_acc_init(n_slots: int):
    """Fresh per-slot accumulator. Lives in SlotState's arrays dict, so it
    shards over the slot axis like every other per-slot leaf and passes
    through the admit/deactivate scatters untouched."""
    return jnp.zeros((n_slots, OBS_COLS), jnp.int32)


def device_acc_update(acc, was_active, committed):
    """Jit-traceable window update: one masked add per column.

    ``was_active``: [n_slots] bool, ``committed``: [n_slots] int32 tokens
    committed this step (the spec path commits a variable 1..W).
    """
    act = was_active.astype(jnp.int32)
    return (acc.at[:, OBS_TOKENS].add(committed * act)
               .at[:, OBS_ACTIVE_STEPS].add(act)
               .at[:, OBS_STRANDED_STEPS].add(1 - act))


# ----------------------------------------------------------------------------
# Exponential histograms
# ----------------------------------------------------------------------------

class ExpHistogram:
    """Fixed-base exponential-bucket histogram: O(1) record, bounded error
    percentiles, sparse storage (a dict of bucket index -> count).

    Base 2**(1/8) bounds any percentile's relative error at ~9% while a
    12-decade range still fits in ~320 live buckets — safe to leave on for
    every request forever.
    """

    BASE = 2.0 ** (1.0 / 8.0)
    _LOG_BASE = math.log(BASE)

    def __init__(self, unit: str = ""):
        self.unit = unit
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._buckets: Dict[int, int] = {}

    def record(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        # bucket i holds (BASE**(i-1), BASE**i]; non-positive values pool
        # in a single sentinel bucket below everything
        idx = (math.ceil(math.log(v) / self._LOG_BASE)
               if v > 0 else -(10 ** 6))
        self._buckets[idx] = self._buckets.get(idx, 0) + 1

    def percentile(self, q: float) -> float:
        """q in [0, 100]; returns a bucket upper bound clamped to the
        observed [min, max] (exact for the extremes)."""
        if not self.count:
            return 0.0
        target = max(1, math.ceil(self.count * q / 100.0))
        cum = 0
        for idx in sorted(self._buckets):
            cum += self._buckets[idx]
            if cum >= target:
                hi = 0.0 if idx <= -(10 ** 6) else self.BASE ** idx
                return float(min(max(hi, self.vmin), self.vmax))
        return float(self.vmax)

    def snapshot(self) -> dict:
        if not self.count:
            return {"count": 0, "unit": self.unit}
        return {"count": self.count, "unit": self.unit,
                "sum": round(self.total, 6),
                "min": round(self.vmin, 6), "max": round(self.vmax, 6),
                "mean": round(self.total / self.count, 6),
                "p50": round(self.percentile(50), 6),
                "p95": round(self.percentile(95), 6),
                "p99": round(self.percentile(99), 6)}


# ----------------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------------

class MetricsRegistry:
    """Named counters / gauges / histograms. Disabled registries keep every
    call a cheap early-return so call sites never need an `if obs:` guard
    (the engine's hot loop has none anyway — it only reports at syncs)."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, ExpHistogram] = {}

    # -- write side ---------------------------------------------------------
    def inc(self, name: str, n: float = 1) -> None:
        if not self.enabled:
            return
        self.counters[name] = self.counters.get(name, 0) + n

    def set_gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float, unit: str = "") -> None:
        if not self.enabled:
            return
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = ExpHistogram(unit)
        h.record(value)

    # -- read side ----------------------------------------------------------
    def snapshot(self) -> dict:
        return {"counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "histograms": {k: h.snapshot()
                               for k, h in sorted(self.histograms.items())}}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()


# ----------------------------------------------------------------------------
# Straggler watchdog (absorbed from distributed/fault.py — re-exported there)
# ----------------------------------------------------------------------------

@dataclass
class StepWatchdog:
    """Tracks per-step wall time; flags hosts whose steps exceed
    `deadline_factor` x the trailing-median. In a real deployment the flag
    feeds `rebalance_assignment`; here it is observable state + logs.

    An optional ``registry`` mirrors every scored step into a
    ``train.step_time_us`` histogram so the trainer gets p50/p99 gang-step
    time for free."""

    deadline_factor: float = 2.0
    window: int = 32
    clock: Callable[[], float] = time.monotonic
    registry: Optional[MetricsRegistry] = None
    _durations: List[float] = field(default_factory=list)
    _t0: Optional[float] = None
    slow_steps: int = 0

    def _observe(self, dt: float, n: int = 1) -> None:
        if self.registry is not None:
            for _ in range(n):
                self.registry.observe("train.step_time_us", dt * 1e6, "us")

    def step_start(self):
        self._t0 = self.clock()

    def step_end(self) -> bool:
        """Returns True if this step was a straggler."""
        if self._t0 is None:  # step_start never called: nothing to score
            return False
        dt = self.clock() - self._t0
        self._t0 = None
        hist = self._durations[-self.window:]
        slow = bool(hist) and dt > self.deadline_factor * float(np.median(hist))
        self._durations.append(dt)
        self._observe(dt)
        if slow:
            self.slow_steps += 1
        return slow

    def window_end(self, n_steps: int, elapsed: float) -> bool:
        """Attribute a flushed window's wall time evenly across its steps.

        With async dispatch the per-step device time is only observable at
        the sync boundary (the trainer buffers metrics between log /
        checkpoint flushes), so the watchdog scores the window's per-step
        AVERAGE against the trailing median. Returns True if the window
        straggled; `slow_steps` then counts the whole window."""
        if n_steps <= 0:
            return False
        per_step = elapsed / n_steps
        hist = self._durations[-self.window:]
        slow = bool(hist) and \
            per_step > self.deadline_factor * float(np.median(hist))
        self._durations.extend([per_step] * n_steps)
        self._observe(per_step, n_steps)
        if slow:
            self.slow_steps += n_steps
        return slow

    @property
    def median(self) -> float:
        return float(np.median(self._durations)) if self._durations else 0.0
