"""Span tracer emitting Chrome-trace-event JSON (open in Perfetto /
``chrome://tracing``).

Spans cover the host-side orchestration the aggregate counters can't
explain: admission waves, prefill buckets, decode windows, preempt/resume,
spec draft/verify rounds, gang steps, graduation, degraded/quarantine
events. Nothing here ever touches the device — a span brackets work the
host was already doing, so tracing changes no compiled program and no
sync schedule.

The ring buffer is bounded (``deque(maxlen=capacity)``): leaving the
tracer on forever costs a fixed few MB and drops the OLDEST events, never
blocks. ``dropped`` counts evictions so an exported trace says whether it
is a suffix of the run.

Each category gets its own fake thread id so Perfetto renders one lane
per subsystem; "M" metadata events name the lanes.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from contextlib import contextmanager
from typing import Dict, Optional

# Canonical categories. Emitters may use others, but these are the lanes
# the obs smoke asserts are present end-to-end.
CAT_ADMISSION = "admission"
CAT_PREFILL = "prefill"
CAT_DECODE_WINDOW = "decode-window"
CAT_PREEMPT = "preempt"
CAT_SPEC = "spec"
CAT_GANG_STEP = "gang-step"
CAT_GRADUATION = "graduation"
CAT_RESILIENCE = "resilience"


class SpanTracer:
    def __init__(self, capacity: int = 65536, enabled: bool = True,
                 clock=time.perf_counter):
        self.enabled = enabled
        self.capacity = capacity
        self.clock = clock
        self.dropped = 0
        self._events = deque(maxlen=capacity)
        self._tids: Dict[str, int] = {}
        self._pid = os.getpid()

    # ------------------------------------------------------------------ write
    def _tid(self, cat: str) -> int:
        tid = self._tids.get(cat)
        if tid is None:
            tid = self._tids[cat] = len(self._tids) + 1
        return tid

    def _emit(self, ev: dict) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    @contextmanager
    def span(self, cat: str, name: str, **args):
        """Complete-event ("X") span around a host-side block. Yields the
        args dict so the body can attach results (e.g. admitted count)."""
        if not self.enabled:
            yield args
            return
        t0 = self.clock()
        try:
            yield args
        finally:
            t1 = self.clock()
            self._emit({"name": name, "cat": cat, "ph": "X",
                        "ts": t0 * 1e6, "dur": (t1 - t0) * 1e6,
                        "pid": self._pid, "tid": self._tid(cat),
                        "args": args})

    def complete(self, cat: str, name: str, t0: float, t1: float,
                 **args) -> None:
        """Retroactive "X" span over [t0, t1] (same clock as `span`) — for
        intervals whose start predates the emit site, e.g. a decode window
        opened by the previous sync."""
        if not self.enabled:
            return
        self._emit({"name": name, "cat": cat, "ph": "X", "ts": t0 * 1e6,
                    "dur": (t1 - t0) * 1e6, "pid": self._pid,
                    "tid": self._tid(cat), "args": args})

    def instant(self, cat: str, name: str, **args) -> None:
        """Zero-duration marker ("i") for point events (degraded request,
        quarantine, retry, graduation)."""
        if not self.enabled:
            return
        self._emit({"name": name, "cat": cat, "ph": "i", "s": "t",
                    "ts": self.clock() * 1e6, "pid": self._pid,
                    "tid": self._tid(cat), "args": args})

    # ------------------------------------------------------------------- read
    def events(self) -> list:
        return list(self._events)

    def category_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self._events:
            out[ev["cat"]] = out.get(ev["cat"], 0) + 1
        return out

    def export(self, path: str) -> dict:
        """Write Chrome JSON trace format; returns the written object."""
        meta = [{"name": "thread_name", "ph": "M", "pid": self._pid,
                 "tid": tid, "args": {"name": cat}}
                for cat, tid in self._tids.items()]
        doc = {"traceEvents": meta + self.events(),
               "displayTimeUnit": "ms",
               "otherData": {"dropped_events": self.dropped}}
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc

    def reset(self) -> None:
        self._events.clear()
        self.dropped = 0


def validate_chrome_trace(doc: dict) -> Optional[str]:
    """Return None if `doc` is a loadable Chrome trace, else the problem.
    Used by the obs smoke and tests; intentionally strict about the fields
    Perfetto's importer needs."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return "missing traceEvents"
    for i, ev in enumerate(doc["traceEvents"]):
        if not isinstance(ev, dict):
            return f"event {i} not an object"
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                return f"event {i} missing {k!r}"
        if ev["ph"] in ("X", "i") and "ts" not in ev:
            return f"event {i} ({ev['ph']}) missing ts"
        if ev["ph"] == "X" and "dur" not in ev:
            return f"event {i} (X) missing dur"
    return None
