"""Always-on retrace sentinel.

PRs 2-9 each asserted "the decode step traced exactly once" inside their
own benchmark. This module promotes that per-bench assertion into a
runtime invariant: every jitted hot-path fn registers a watch, and
``check()`` — called at the sync boundaries the engine already has —
raises (or logs) the moment a fn compiles more often than its contract
allows.

Two contracts, because hot-path fns come in two shapes:

- fixed-signature fns (engine slot step, gang step): ``budget=N`` — more
  than N traces is a bug, full stop. A placement/sharding drift shows up
  here first.
- shape-polymorphic fns (admit scatter over variable wave sizes, prefill
  over bucket shapes): a new input shape legitimately compiles a new
  program, so the watch also tracks DISTINCT SHAPES seen; the invariant
  is ``traces <= distinct_shapes`` — a retrace WITHOUT a new shape means
  the inputs' placement drifted, exactly the failure the pinned
  out-shardings exist to prevent.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional


class RetraceError(RuntimeError):
    pass


class _Watch:
    __slots__ = ("count_fn", "budget", "shapes_fn")

    def __init__(self, count_fn, budget, shapes_fn):
        self.count_fn = count_fn
        self.budget = budget
        self.shapes_fn = shapes_fn


class RetraceSentinel:
    """mode: "raise" (smokes/CI), "log" (production default), "off"."""

    def __init__(self, mode: str = "log", log=None):
        assert mode in ("raise", "log", "off")
        self.mode = mode
        self.log = log or (lambda msg: print(msg, flush=True))
        self._watches: Dict[str, _Watch] = {}
        self.violations_seen = 0

    def watch(self, name: str, count_fn: Callable[[], int],
              budget: Optional[int] = None,
              shapes_fn: Optional[Callable[[], int]] = None) -> None:
        """Register a trace counter. `budget`: max allowed traces (None =
        unbounded). `shapes_fn`: distinct input shapes seen — when given,
        traces exceeding shapes is a violation even under the budget.

        A count_fn returning None means its owner is gone (watchers hold
        engines WEAKLY — the sentinel must never pin a dead engine's
        device state); the watch is dropped at the next counts()/check().
        """
        self._watches[name] = _Watch(count_fn, budget, shapes_fn)

    def _live(self):
        dead = [n for n, w in self._watches.items() if w.count_fn() is None]
        for n in dead:
            del self._watches[n]
        return self._watches

    def counts(self) -> Dict[str, dict]:
        out = {}
        for name, w in self._live().items():
            row = {"traces": int(w.count_fn()), "budget": w.budget}
            if w.shapes_fn is not None:
                row["shapes"] = int(w.shapes_fn())
            out[name] = row
        return out

    def check(self) -> list:
        """Evaluate every watch; returns the violation strings (and raises
        in "raise" mode). Cheap — a few int compares — so callers run it
        at every sync/flush boundary."""
        if self.mode == "off":
            return []
        bad = []
        for name, w in self._live().items():
            traces = int(w.count_fn())
            if w.budget is not None and traces > w.budget:
                bad.append(f"{name}: {traces} traces > budget {w.budget}")
            elif w.shapes_fn is not None:
                shapes = int(w.shapes_fn())
                if traces > shapes:
                    bad.append(f"{name}: {traces} traces for {shapes} "
                               "distinct input shapes (placement drift?)")
        if bad:
            self.violations_seen += len(bad)
            msg = "retrace sentinel: " + "; ".join(bad)
            if self.mode == "raise":
                raise RetraceError(msg)
            self.log(msg)
        return bad
