"""Mesh context for intra-model sharding hints.

Model code never sees a concrete Mesh; it calls ``hint(x, *logical_dims)``
with logical dim names and we translate to a PartitionSpec against whatever
mesh the launcher declared (or no-op on a single device / in smoke tests).
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

# logical activation dims -> mesh axis (or tuple of axes)
_DEFAULT_ACT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,          # becomes "data" under sequence parallelism
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    # context-parallel fallback: when kv_heads doesn't divide the model
    # axis (MQA / 24-head archs), the KEY/VALUE sequence dim claims it
    # instead — GSPMD lowers the softmax into flash-decode-style partial
    # max/sum + small all-reduces (hint order does the arbitration).
    "kv_seq": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "adapter_n": None,
    "bottleneck": None,
}

_state: ContextVar[Optional[dict]] = ContextVar("mesh_ctx", default=None)


@contextlib.contextmanager
def mesh_context(mesh: jax.sharding.Mesh, act_rules: Optional[dict] = None,
                 sizes: Optional[dict] = None):
    """Declare the active mesh + activation sharding rules.

    sizes: optional {axis_name: size} override (defaults from mesh.shape).
    """
    rules = dict(_DEFAULT_ACT_RULES)
    if act_rules:
        rules.update(act_rules)
    axis_sizes = dict(mesh.shape) if mesh is not None else {}
    if sizes:
        axis_sizes.update(sizes)
    tok = _state.set({"mesh": mesh, "rules": rules, "sizes": axis_sizes})
    try:
        yield
    finally:
        _state.reset(tok)


def active_mesh() -> Optional[jax.sharding.Mesh]:
    st = _state.get()
    return st["mesh"] if st else None


def axis_size(name: str) -> int:
    st = _state.get()
    if not st:
        return 1
    return int(st["sizes"].get(name, 1))


def _resolve(logical: Optional[str], dim_size: int, st) -> Optional[object]:
    if logical is None:
        return None
    axes = st["rules"].get(logical, None)
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    # keep only axes present in the mesh; require divisibility
    axes = tuple(a for a in axes if a in st["sizes"])
    if not axes:
        return None
    total = 1
    for a in axes:
        total *= st["sizes"][a]
    if total == 0 or dim_size % total != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def hint(x, *logical_dims: Optional[str]):
    """with_sharding_constraint by logical dim names; no-op without a mesh.

    len(logical_dims) must equal x.ndim; None entries stay unsharded.
    """
    st = _state.get()
    if st is None or st["mesh"] is None:
        return x
    assert len(logical_dims) == x.ndim, (logical_dims, x.shape)
    entries = []
    used = set()
    for l, s in zip(logical_dims, x.shape):
        e = _resolve(l, s, st)
        axes = e if isinstance(e, tuple) else (e,) if e else ()
        # first dim claiming a mesh axis wins; later dims keep what's left
        left = tuple(a for a in axes if a not in used)
        if left != axes:
            total = 1
            for a in left:
                total *= st["sizes"][a]
            left = left if left and s % total == 0 else ()
        used.update(left)
        e = left if len(left) > 1 else (left[0] if left else None)
        entries.append(e)
    spec = P(*entries)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(st["mesh"], spec))
