"""GPipe pipeline parallelism over a mesh axis via shard_map +
collective_permute.

The at-scale alternative to cross-pod data parallelism (DESIGN.md §5 PP):
stage s holds layers [s*L/S, (s+1)*L/S); microbatches stream through the
pipeline with a (M + S - 1)-step schedule. collective_permute is
differentiable, so jax.grad through `pipeline_apply` yields the GPipe
backward schedule for free (activations of the schedule loop are rematerialized
per-stage via jax.checkpoint on the stage body).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn, stage_params, x_micro, mesh: Mesh,
                   axis: str = "pod"):
    """Run microbatches through pipeline stages laid out on `axis`.

    stage_fn(params_one_stage, x_mb) -> y_mb  (same shape)
    stage_params: pytree stacked on a leading S dim (S = mesh.shape[axis]).
    x_micro: [M, mb, ...] microbatches.
    Returns y_micro [M, mb, ...].
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]
    steps = M + S - 1
    other_axes = tuple(a for a in mesh.axis_names if a != axis)

    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    pspec_x = P(None)  # microbatch stream replicated across stages

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(pspec_params, pspec_x),
        out_specs=pspec_x,
        check_rep=False)
    def run(params_local, xm):
        params_local = jax.tree.map(lambda a: a[0], params_local)
        idx = jax.lax.axis_index(axis)
        mb_shape = xm.shape[1:]
        body = jax.checkpoint(lambda p, x: stage_fn(p, x))

        def step(carry, t):
            send, outs = carry
            # ring-shift activations stage s -> s+1
            perm = [(i, (i + 1) % S) for i in range(S)]
            recv = jax.lax.ppermute(send, axis, perm)
            feed_idx = jnp.clip(t, 0, M - 1)
            first_in = jax.lax.dynamic_index_in_dim(xm, feed_idx, 0,
                                                    keepdims=False)
            x_in = jnp.where(idx == 0, first_in, recv)
            y = body(params_local, x_in)
            # last stage commits outputs for t >= S-1
            out_slot = jnp.clip(t - (S - 1), 0, M - 1)
            commit = (idx == S - 1) & (t >= S - 1)
            outs = jax.lax.cond(
                commit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_slot, 0),
                lambda o: o, outs)
            return (y, outs), None

        outs0 = jnp.zeros((M,) + mb_shape, xm.dtype)
        send0 = jnp.zeros(mb_shape, xm.dtype)
        (_, outs), _ = jax.lax.scan(step, (send0, outs0),
                                    jnp.arange(steps))
        # broadcast final outputs from the last stage to all stages
        outs = jax.lax.psum(
            jnp.where(idx == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return run(stage_params, x_micro)


def stack_stages(layer_params, num_stages: int):
    """[L, ...] stacked layer params -> [S, L/S, ...] per-stage stacks."""
    def re(a):
        L = a.shape[0]
        assert L % num_stages == 0, (L, num_stages)
        return a.reshape(num_stages, L // num_stages, *a.shape[1:])
    return jax.tree.map(re, layer_params)
