"""Distribution layer: sharding rules, mesh context, collectives, pipeline,
fault tolerance."""
from repro.distributed import ctx  # noqa: F401
from repro.distributed.sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    param_shardings,
    param_specs,
    to_shardings,
)
