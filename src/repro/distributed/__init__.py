"""Distribution layer: sharding rules, mesh context, collectives, pipeline,
fault tolerance."""
from repro.distributed import ctx  # noqa: F401
from repro.distributed.sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    constrain_leading,
    leading_axis_specs,
    param_shardings,
    param_specs,
    sharded_bytes_per_device,
    to_shardings,
)
