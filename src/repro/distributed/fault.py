"""Fault tolerance: step watchdog (straggler detection + data re-balance),
preemption handling, and elastic mesh resize.

On a real multi-host deployment these hooks sit in the trainer loop; every
mechanism here is host-side and unit-tested with fake clocks / subprocess
meshes (tests/test_fault.py), because the container has one host.

``StepWatchdog`` now lives in ``repro.obs.metrics`` (window wall-time
attribution is a metric, and the obs bundle mirrors it into a gang-step
time histogram) — re-exported here unchanged for every existing import
site.
"""
from __future__ import annotations

import signal
import threading
from typing import Callable, Dict, List

import numpy as np
import jax

from repro.obs.metrics import StepWatchdog  # noqa: F401  (re-export)


def rebalance_assignment(num_examples: int, hosts: List[int],
                         slow_hosts: Dict[int, float]) -> Dict[int, range]:
    """Re-split the data range across hosts, down-weighting stragglers.

    slow_hosts: {host_id: relative_speed in (0,1]} — a host at 0.5 gets half
    a share. Deterministic: every host computes the same assignment.
    """
    if not hosts:
        raise ValueError("rebalance_assignment: hosts must be non-empty")
    weights = np.array([slow_hosts.get(h, 1.0) for h in hosts], np.float64)
    # A reported speed of 0 means "barely alive", not "assign nothing at
    # the cost of a 0/0 split" — clamp to a positive floor.
    weights = np.maximum(weights, 1e-6)
    weights = weights / weights.sum()
    counts = np.floor(weights * num_examples).astype(int)
    counts[-1] += num_examples - counts.sum()
    out, lo = {}, 0
    for h, c in zip(hosts, counts):
        out[h] = range(lo, lo + int(c))
        lo += int(c)
    return out


# ----------------------------------------------------------------------------
# Preemption
# ----------------------------------------------------------------------------

class PreemptionHandler:
    """SIGTERM / SIGINT -> set flag; the trainer checkpoints and exits
    cleanly at the next step boundary.

    Chains to any previously-installed Python handler instead of silently
    replacing it (launchers commonly install their own logging/cleanup
    hooks). SIG_DFL / SIG_IGN / the default KeyboardInterrupt handler are
    NOT chained — re-raising KeyboardInterrupt would defeat the graceful
    checkpoint this handler exists to allow.
    """

    def __init__(self, sigs=(signal.SIGTERM, signal.SIGINT)):
        self._flag = threading.Event()
        self._prev: Dict[int, Callable] = {}
        for sig in (sigs if isinstance(sigs, (tuple, list)) else (sigs,)):
            try:
                prev = signal.signal(sig, self._on)
            except ValueError:
                continue  # not the main thread (tests)
            if callable(prev) and prev is not signal.default_int_handler:
                self._prev[int(sig)] = prev

    def _on(self, signum=None, frame=None):
        self._flag.set()
        prev = self._prev.get(int(signum)) if signum is not None else None
        if prev is not None:
            prev(signum, frame)

    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self):  # for tests
        self._flag.set()


# ----------------------------------------------------------------------------
# Elastic resize
# ----------------------------------------------------------------------------

def reshard_state(state, new_shardings):
    """Move a (possibly sharded) pytree onto a new mesh's shardings —
    the core of elastic shrink/grow after a node failure. Works across any
    two meshes on the same process set (jax.device_put reshards)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state, new_shardings)


def surviving_mesh(axis_names, shape, failed_fraction_axis: str,
                   new_size: int):
    """Build the post-failure mesh: the failed axis shrinks to new_size."""
    sizes = dict(zip(axis_names, shape))
    sizes[failed_fraction_axis] = new_size
    n = int(np.prod(list(sizes.values())))
    devs = jax.devices()[:n]
    return jax.sharding.Mesh(
        np.array(devs).reshape(*sizes.values()), tuple(sizes.keys()))
