"""Sharding rules: param-name-based logical axes -> PartitionSpecs.

Scheme (DESIGN.md §5):
- TP over the "model" axis: heads / kv_heads / mlp / experts / vocab / the
  adapter bank's d_model dim (row+col parallel bottleneck).
- FSDP over the "data" axis: every parameter's largest still-unsharded dim,
  when divisible and large enough (ZeRO-3 via GSPMD all-gather-on-use).
- The "pod" axis never shards parameters (cross-pod = grad reduce only).

Divisibility-aware: a logical assignment that doesn't divide the dim (e.g.
MQA kv=1 on a 16-way model axis) silently stays replicated.

`overrides` lets the §Perf hillclimb re-map individual tensors without
touching model code.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils import map_with_path

# leaf-name (+ndim disambiguation) -> logical dims for the TRAILING dims.
# Leading stack dims (layers L, profile table P) are covered implicitly:
# unmatched leading dims get None (then FSDP may claim them).
_RULES: Dict[Tuple[str, int], Tuple] = {}


def _rule(name, *logical, ndim=None):
    _RULES[(name, ndim)] = tuple(logical)


# embeddings / heads
_rule("embed", "vocab", None)
_rule("pos_embed", None, None)
_rule("lm_head", None, "vocab")
# attention (rules align to TRAILING dims; leading stack dims get None)
_rule("wq", None, "heads", None)
_rule("wk", None, "kv_heads", None)
_rule("wv", None, "kv_heads", None)
_rule("wo", "heads", None, None)
_rule("bq", "heads", None)
_rule("bk", "kv_heads", None)
_rule("bv", "kv_heads", None)
# dense mlp
_rule("wg", None, "mlp")
_rule("wu", None, "mlp")
_rule("wd", "mlp", None)
_rule("w1", None, "mlp")
_rule("w2", "mlp", None)
_rule("b1", "mlp")
_rule("b2", None)
# moe — experts over model (EP); FSDP pinned to the ff dim so the
# shard_map dispatch knows where to all-gather (models/moe.py)
_rule("router", None, None)
_rule("ew_g", "expert", None, "mlp_fsdp")
_rule("ew_u", "expert", None, "mlp_fsdp")
_rule("ew_d", "expert", "mlp_fsdp", None)
# X-PEFT adapter bank [L, N, d, b] / [L, N, b, d]: d_model TP-sharded
_rule("bank_a", "adapter_n", "tp_d", None)
_rule("bank_b", "adapter_n", None, "tp_d")
# heterogeneous bank segments: LoRA pairs share the bottleneck bank's
# layout exactly (A [L, cnt, d, r], B [L, cnt, r, d]) so they keep bank
# TP on d_model; IA3 scale vectors [L, cnt, d] and prefix KV rows
# [L, cnt, P, kv_dim] are tiny — replicate them (explicit all-None rules
# so mesh parity is a declared contract, not fsdp-matcher fallthrough)
_rule("lora_a", "adapter_n", "tp_d", None)
_rule("lora_b", "adapter_n", None, "tp_d")
_rule("ia3_v", "adapter_n", None)
_rule("prefix_k", "adapter_n", None, None)
_rule("prefix_v", "adapter_n", None, None)
# quantized bank (quant/schemes.quantize_bank): the q payloads keep the
# bf16 bank's layout (int4 packs the LAST axis, which is never the
# TP-sharded d_model dim for bank_a and stays divisibility-guarded for
# bank_b), and the fp16 scale arrays ride along on their matching dims —
# int8 scales drop the quantized axis (ndim 3), int4 group scales keep a
# trailing group axis (ndim 4)
_rule("bank_a_q", "adapter_n", "tp_d", None)
_rule("bank_b_q", "adapter_n", None, "tp_d")
_rule("bank_a_scale", "adapter_n", "tp_d", ndim=3)
_rule("bank_a_scale", "adapter_n", "tp_d", None, ndim=4)
_rule("bank_b_scale", "adapter_n", None, ndim=3)
_rule("bank_b_scale", "adapter_n", None, "tp_d", ndim=4)
# quantized LoRA segments ride the same layout as the bottleneck bank
_rule("lora_a_q", "adapter_n", "tp_d", None)
_rule("lora_b_q", "adapter_n", None, "tp_d")
_rule("lora_a_scale", "adapter_n", "tp_d", ndim=3)
_rule("lora_a_scale", "adapter_n", "tp_d", None, ndim=4)
_rule("lora_b_scale", "adapter_n", None, ndim=3)
_rule("lora_b_scale", "adapter_n", None, "tp_d", ndim=4)
# rwkv (2D projections over flattened heads)
_rule("rwr", None, "tp_d")
_rule("rwk", None, "tp_d")
_rule("rwv", None, "tp_d")
_rule("rwg", None, "tp_d")
_rule("rwo", "tp_d", None)
_rule("cw_k", None, "mlp")
_rule("cw_v", "mlp", None)
_rule("cw_r", None, None)
_rule("dec_a", None, None)
_rule("dec_b", None, "tp_d")
# mamba
_rule("in_proj", None, "tp_d")
_rule("out_proj", "tp_d", None)

_LOGICAL_TO_MESH = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "expert": "model",
    "tp_d": "model",
    "mlp_fsdp": "data",
}

FSDP_MIN_SIZE = 2 ** 16


def _lookup(name: str, ndim: int):
    """Rules align to trailing dims; any leading stack dims are padded
    with None by spec_for — so (name, None) matches every rank."""
    if (name, ndim) in _RULES:
        return _RULES[(name, ndim)]
    if (name, None) in _RULES:
        return _RULES[(name, None)]
    return None


def spec_for(path: str, shape, mesh_axes: Dict[str, int], *, fsdp: bool,
             logical_map: Optional[dict] = None,
             overrides: Optional[dict] = None) -> P:
    """Build the PartitionSpec for one parameter."""
    name = path.rsplit("/", 1)[-1]
    ndim = len(shape)
    lmap = dict(_LOGICAL_TO_MESH)
    if logical_map:
        lmap.update(logical_map)

    logical = None
    if overrides:
        for pat, val in overrides.items():
            if pat in path:
                logical = val
                break
    if logical is None:
        logical = _lookup(name, ndim)
    if logical is None:
        logical = (None,) * ndim
    # left-pad to ndim (leading stack dims unassigned)
    logical = (None,) * (ndim - len(logical)) + tuple(logical)

    assigned = []
    used_axes = set()
    for dim, lg in zip(shape, logical):
        ax = lmap.get(lg) if lg else None
        if ax and ax in mesh_axes and dim % mesh_axes[ax] == 0 \
                and ax not in used_axes:
            assigned.append(ax)
            used_axes.add(ax)
        else:
            assigned.append(None)

    if fsdp and "data" in mesh_axes and "data" not in assigned \
            and int(np.prod(shape)) >= FSDP_MIN_SIZE:
        # shard the largest remaining dim over data
        cands = [(dim, i) for i, (dim, a) in enumerate(zip(shape, assigned))
                 if a is None and dim % mesh_axes["data"] == 0]
        if cands:
            _, i = max(cands)
            assigned[i] = "data"
    return P(*assigned)


def param_specs(abstract_params, mesh: Mesh, *, fsdp: bool = True,
                logical_map: Optional[dict] = None,
                overrides: Optional[dict] = None):
    mesh_axes = dict(mesh.shape)
    mesh_axes.pop("pod", None)  # never shard params over pods
    return map_with_path(
        lambda p, x: spec_for(p, x.shape, mesh_axes, fsdp=fsdp,
                              logical_map=logical_map, overrides=overrides),
        abstract_params)


def param_shardings(abstract_params, mesh: Mesh, **kw):
    specs = param_specs(abstract_params, mesh, **kw)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------------
# Activations / batch / cache
# ----------------------------------------------------------------------------

def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_specs(abstract_batch, mesh: Mesh, global_batch: int):
    """Shard the leading batch dim of every batch leaf over pod+data; falls
    back to sequence sharding (dim 1) when batch doesn't divide (batch=1
    long-context cells)."""
    ba = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in ba]))

    def one(x):
        if x.shape and x.shape[0] % n == 0 and x.shape[0] >= n:
            return P(ba, *([None] * (len(x.shape) - 1)))
        if len(x.shape) >= 2 and x.shape[1] % n == 0:
            return P(None, ba, *([None] * (len(x.shape) - 2)))
        return P(*([None] * len(x.shape)))

    return jax.tree.map(one, abstract_batch)


def cache_specs(abstract_cache, mesh: Mesh, cfg, batch: int):
    """KV/state cache sharding: batch over data when divisible, else the
    sequence dim (sequence parallelism for batch=1 long-context); kv_heads /
    state heads over model when divisible."""
    mesh_axes = dict(mesh.shape)
    dsize = mesh_axes.get("data", 1)
    msize = mesh_axes.get("model", 1)

    def one(path, x):
        name = path.rsplit("/", 1)[-1]
        nd = len(x.shape)
        spec = [None] * nd
        # leading L (stacked layers) never sharded; batch dim = 1
        bdim = 1
        if nd >= 2 and x.shape[bdim] % dsize == 0 and x.shape[bdim] >= dsize:
            spec[bdim] = "data"
        elif name in ("k", "v", "attn_k", "attn_v") and nd >= 3 \
                and x.shape[2] % dsize == 0:
            spec[2] = "data"  # sequence-parallel KV cache (batch=1 cells)
        if name in ("k", "v", "attn_k", "attn_v") and nd >= 4:
            if x.shape[3] % msize == 0:
                spec[3] = "model"          # kv heads over TP
            elif spec[2] is None and x.shape[2] % msize == 0:
                spec[2] = "model"          # context-parallel fallback
        if name in ("wkv", "ssd") and nd >= 3 and x.shape[2] % msize == 0:
            spec[2] = "model"  # recurrent state heads
        return P(*spec)

    return map_with_path(one, abstract_cache)


def paged_cache_specs(paged_cache, mesh: Mesh, cfg, n_slots: int):
    """Sharding for the continuous engine's block-paged cache
    (`serve/pages.py`): pool leaves are ``[lead, n_pages, page, ...]`` —
    the PAGE axis sits where the dense cache's slot axis sat, so
    `cache_specs` applies verbatim (pages over "data", kv heads dim 3 over
    "model", recurrent resident leaves unchanged) and the PR-4 invariant
    "pages sharded like the slot axis" holds by construction. The page
    table shards its slot axis over "data" like every slot-packed array."""
    dsize = dict(mesh.shape).get("data", 1)
    data = cache_specs(paged_cache["data"], mesh, cfg, n_slots)
    t = paged_cache["table"].shape
    lead = "data" if t[0] % dsize == 0 and t[0] >= dsize else None
    return {"data": data, "table": P(lead, None)}


def to_shardings(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------------
# Slot-packed state (serve SlotState / mask buffers, train roster): every
# leaf carries a leading slot axis; shard it over "data" when divisible so
# per-slot work stays device-local (decode and gang-step numerics are then
# identical to the single-device path — no contraction is ever split).
# ----------------------------------------------------------------------------

def leading_axis_specs(abstract_tree, mesh: Mesh, axis: str = "data"):
    """Shard every leaf's leading dim over `axis` when divisible; replicate
    otherwise. The spec for SlotState arrays, engine mask buffers, and the
    training roster (all slot-packed on dim 0)."""
    n = dict(mesh.shape).get(axis, 1)

    def one(x):
        nd = len(x.shape)
        if nd >= 1 and n > 1 and x.shape[0] % n == 0 and x.shape[0] >= n:
            return P(axis, *([None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree.map(one, abstract_tree)


def constrain_leading(tree, mesh: Optional[Mesh], axis: str = "data"):
    """with_sharding_constraint every leaf to its leading-axis spec (no-op
    without a mesh). Used inside jitted steps to pin slot-axis sharding so
    GSPMD never migrates or splits per-slot work."""
    if mesh is None:
        return tree
    specs = leading_axis_specs(tree, mesh, axis)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)), tree, specs)


def sharded_bytes_per_device(abstract_tree, specs, mesh) -> int:
    """Analytic per-device resident bytes of a sharded pytree.

    `mesh` may be a Mesh or a plain {axis: size} mapping. This number gates
    memory planning, so malformed inputs RAISE instead of under-reporting:
    the spec tree must have exactly one PartitionSpec per leaf, each spec
    must cover its leaf's full rank, and every named axis must exist in the
    mesh. (A silent zip over mismatched flats used to drop leaves.)
    """
    sizes = dict(mesh) if isinstance(mesh, dict) else dict(mesh.shape)

    flat_x = jax.tree.leaves(abstract_tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    if len(flat_x) != len(flat_s):
        raise ValueError(
            f"specs tree has {len(flat_s)} PartitionSpecs for "
            f"{len(flat_x)} leaves — every leaf needs exactly one spec")

    total = 0
    for x, spec in zip(flat_x, flat_s):
        if not isinstance(spec, P):
            raise ValueError(f"expected PartitionSpec, got {spec!r}")
        if len(spec) != len(x.shape):
            raise ValueError(
                f"spec {spec} has {len(spec)} entries for a rank-"
                f"{len(x.shape)} leaf of shape {tuple(x.shape)} — specs "
                "must cover the full rank")
        n = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                if a not in sizes:
                    raise ValueError(
                        f"spec {spec} names mesh axis {a!r} not in "
                        f"{sorted(sizes)}")
                n *= sizes[a]
        total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize // n
    return total
