"""Distributed-optimization collectives: int8 gradient compression with
error feedback for the cross-pod (DCN) all-reduce.

Cross-pod links are the slow tier at 1000+ nodes; gradients crossing them are
quantized to int8 with a pre-agreed scale (one scalar psum) and error-feedback
accumulation so the quantization bias doesn't accumulate over steps.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x, scale):
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name: str):
    """psum(x) over `axis_name` with int8 payload (inside shard_map).

    Two collectives: a scalar pmax to agree the scale, then the int8 sum
    (accumulated in int32). Bytes on the wire: 1/4 of fp32, 1/2 of bf16.
    """
    x = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = quantize_int8(x, scale)
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return s.astype(jnp.float32) * scale


def compressed_psum_ef(x, err, axis_name: str):
    """Error-feedback variant: returns (psum_result, new_err).

    err is the per-device residual buffer carried across steps; the bias of
    quantization is re-injected next step (EF-SGD / 1-bit-Adam style).
    """
    x = x.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = quantize_int8(x, scale)
    local_repr = dequantize_int8(q, scale)
    new_err = x - local_repr
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return s.astype(jnp.float32) * scale, new_err


def tree_compressed_psum_ef(grads, errs, axis_name: str):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errs)
    outs, news = [], []
    for g, e in zip(flat_g, flat_e):
        o, ne = compressed_psum_ef(g, e, axis_name)
        outs.append(o)
        news.append(ne)
    return tdef.unflatten(outs), tdef.unflatten(news)
