"""Trainer loop driver: checkpoint hooks, straggler watchdog, preemption,
resume, and host syncs ONLY at log/checkpoint cadence.

Production posture: the loop is restartable at any step (data position is
part of the checkpoint), SIGTERM triggers checkpoint-and-exit, slow steps
are recorded and fed to the data re-balancer.

Metrics stay on DEVICE per step — the loop buffers the (async) metric trees
and fetches them in ONE device→host transfer at each sync boundary
(`log_every`, checkpoint, end of run). Straggler detection moves with it:
per-step device time is unobservable without a per-step block, so the
watchdog scores each flushed WINDOW's per-step average wall time
(`StepWatchdog.window_end`) and flags the whole window. Subclasses hook
the boundaries:

- `next_batch()`      — how a step's batch is assembled
- `on_sync(recs)`     — runs after every flush with the new host records
                        (onboarding admits/evicts/graduates here)
- `should_stop()`     — early-exit check (e.g. onboarding queue drained)
- `extra_state()` / `restore_extra()` — manifest payload for exact resume
"""
from __future__ import annotations

import time
import zipfile
from typing import Callable, List, Optional

import jax
import numpy as np

from repro import obs as OBS
from repro.checkpoint import CheckpointManager
from repro.distributed.fault import PreemptionHandler, StepWatchdog
from repro.obs import trace as TR
from repro.resilience.integrity import CheckpointCorruptError


class Trainer:
    def __init__(self, step_fn: Callable, state, loader, *,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
                 keep_last: int = 3, watchdog: Optional[StepWatchdog] = None,
                 preemption: Optional[PreemptionHandler] = None,
                 log_every: int = 10, rng=None, fault_plan=None, obs=None):
        self.step_fn = step_fn
        self.state = state
        self.loader = loader
        self.step = 0
        self.ckpt_every = ckpt_every
        self.mgr = CheckpointManager(ckpt_dir, keep_last,
                                     fault_plan=fault_plan) \
            if ckpt_dir else None
        # observability: the straggler watchdog IS the train-side metric
        # source (repro.obs.metrics absorbed it) — wiring the bundle's
        # registry in gives p50/p99 gang-step time for free, and the gang
        # step's trace counter feeds the retrace sentinel below
        self.obs = OBS.get(obs)
        if watchdog is None:
            watchdog = StepWatchdog(
                registry=self.obs.metrics if self.obs.enabled else None)
        self.watchdog = watchdog
        tc = getattr(step_fn, "trace_counter", None)
        if tc is not None:
            self.obs.sentinel.watch("train.gang_step",
                                    lambda: tc["traces"], budget=1)
        self.preemption = preemption
        self.log_every = log_every
        self.rng = rng if rng is not None else jax.random.key(0)
        self.history = []
        # buffered (step, device-metric-tree) tuples since the last flush:
        # nothing here blocks on the device
        self._pending: List[tuple] = []
        self._window_t0: Optional[float] = None
        self.host_syncs = 0

    # ------------------------------------------------------------- recovery
    def try_resume(self) -> bool:
        """Resume from the newest checkpoint that verifies — a torn or
        corrupt latest checkpoint falls back to the one before it (and so
        on), never fails the run."""
        if not self.mgr:
            return False
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)
        for latest in reversed(self.mgr.all_steps()):
            try:
                state = self.mgr.restore(latest, abstract)
            except (CheckpointCorruptError, OSError, ValueError,
                    zipfile.BadZipFile):
                continue  # torn/corrupt payload: walk back one checkpoint
            self.state = state
            man = self.mgr.manifest(latest)
            self.step = man["step"]
            self.restore_extra(man["extra"])
            return True
        return False

    def extra_state(self) -> dict:
        """Manifest payload for exact resume (subclasses extend)."""
        rng_data = np.asarray(jax.random.key_data(self.rng)).tolist()
        return {"loader": self.loader.state_dict(), "rng": rng_data}

    def restore_extra(self, extra: dict) -> None:
        self.loader.load_state_dict(extra["loader"])
        if "rng" in extra:
            self.rng = jax.random.wrap_key_data(
                jax.numpy.asarray(extra["rng"], dtype="uint32"))

    def checkpoint(self, blocking=True):
        if self.mgr:
            self.flush()  # history/manifest must reflect all taken steps
            self.mgr.save(self.step, self.state, blocking=blocking,
                          extra=self.extra_state())

    # ----------------------------------------------------------------- hooks
    def next_batch(self) -> dict:
        return {k: jax.numpy.asarray(v)
                for k, v in self.loader.next().items()}

    def on_sync(self, recs: list) -> None:
        """Called after each metric flush with the new host records."""

    def should_stop(self) -> bool:
        return False

    # ----------------------------------------------------------------- sync
    def flush(self) -> list:
        """ONE device→host transfer for every buffered step's metrics;
        appends the float records to `history` and returns them. The
        transfer drains the window's queued device work, so the elapsed
        wall time here is the window's true step time — fed to the
        watchdog as the per-step average."""
        if not self._pending:
            return []
        steps, mets = zip(*self._pending)
        self._pending = []
        host = jax.device_get(list(mets))
        self.host_syncs += 1
        slow = False
        if self._window_t0 is not None:
            now = time.perf_counter()
            slow = self.watchdog.window_end(
                len(steps), now - self._window_t0)
            # one span per flushed WINDOW (per-step device time is not
            # observable without a per-step block — same reasoning as the
            # watchdog scoring above); sentinel check rides the boundary
            self.obs.tracer.complete(TR.CAT_GANG_STEP, "gang_window",
                                     self._window_t0, now,
                                     steps=len(steps), straggler=slow)
            self.obs.metrics.inc("train.steps", len(steps))
            self._window_t0 = None
        self.obs.sentinel.check()
        recs = []
        for s, mh in zip(steps, host):
            rec = {k: float(v) for k, v in mh.items()}
            rec["step"] = s
            rec["straggler"] = slow
            recs.append(rec)
        self.history.extend(recs)
        return recs

    def sync(self) -> list:
        recs = self.flush()
        if recs:
            self.on_sync(recs)
        return recs

    # ----------------------------------------------------------------- loop
    def run(self, num_steps: int) -> list:
        for _ in range(num_steps):
            if self.preemption and self.preemption.preempted():
                self.sync()
                self.checkpoint(blocking=True)
                break
            if self.should_stop():
                break
            batch = self.next_batch()
            self.rng, sub = jax.random.split(self.rng)
            if self._window_t0 is None:
                self._window_t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch, sub)
            self.step += 1
            self._pending.append((self.step, metrics))
            if self.step % self.log_every == 0:
                recs = self.sync()
                if recs:
                    rec = recs[-1]
                    print(f"step {self.step} " +
                          " ".join(f"{k}={v:.4f}" for k, v in rec.items()
                                   if isinstance(v, float)))
            if self.mgr and self.step % self.ckpt_every == 0:
                self.sync()
                self.checkpoint(blocking=False)
        self.sync()
        if self.mgr:
            self.mgr.wait()
        return self.history
