"""Trainer loop: checkpoint hooks, straggler watchdog, preemption, resume.

Production posture: the loop is restartable at any step (data position is
part of the checkpoint), SIGTERM triggers checkpoint-and-exit, slow steps
are recorded and fed to the data re-balancer.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.distributed.fault import PreemptionHandler, StepWatchdog


class Trainer:
    def __init__(self, step_fn: Callable, state, loader, *,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 100,
                 keep_last: int = 3, watchdog: Optional[StepWatchdog] = None,
                 preemption: Optional[PreemptionHandler] = None,
                 log_every: int = 10, rng=None):
        self.step_fn = step_fn
        self.state = state
        self.loader = loader
        self.step = 0
        self.ckpt_every = ckpt_every
        self.mgr = CheckpointManager(ckpt_dir, keep_last) if ckpt_dir else None
        self.watchdog = watchdog or StepWatchdog()
        self.preemption = preemption
        self.log_every = log_every
        self.rng = rng if rng is not None else jax.random.key(0)
        self.history = []

    # ------------------------------------------------------------- recovery
    def try_resume(self) -> bool:
        if not self.mgr:
            return False
        latest = self.mgr.latest_step()
        if latest is None:
            return False
        abstract = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)
        self.state = self.mgr.restore(latest, abstract)
        man = self.mgr.manifest(latest)
        self.step = man["step"]
        self.loader.load_state_dict(man["extra"]["loader"])
        if "rng" in man["extra"]:
            self.rng = jax.random.wrap_key_data(
                jax.numpy.asarray(man["extra"]["rng"], dtype="uint32"))
        return True

    def checkpoint(self, blocking=True):
        if self.mgr:
            rng_data = np.asarray(jax.random.key_data(self.rng)).tolist()
            self.mgr.save(self.step, self.state, blocking=blocking,
                          extra={"loader": self.loader.state_dict(),
                                 "rng": rng_data})

    # ----------------------------------------------------------------- loop
    def run(self, num_steps: int) -> list:
        for _ in range(num_steps):
            if self.preemption and self.preemption.preempted():
                self.checkpoint(blocking=True)
                break
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.loader.next().items()}
            self.rng, sub = jax.random.split(self.rng)
            self.watchdog.step_start()
            self.state, metrics = self.step_fn(self.state, batch, sub)
            jax.block_until_ready(metrics["loss"])
            slow = self.watchdog.step_end()
            self.step += 1
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = self.step
            rec["straggler"] = slow
            self.history.append(rec)
            if self.step % self.log_every == 0:
                print(f"step {self.step} " +
                      " ".join(f"{k}={v:.4f}" for k, v in rec.items()
                               if isinstance(v, float)))
            if self.mgr and self.step % self.ckpt_every == 0:
                self.checkpoint(blocking=False)
        if self.mgr:
            self.mgr.wait()
        return self.history
