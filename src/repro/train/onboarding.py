"""Profile onboarding: stream P >> S profiles through the training roster
and graduate converged ones into the serving `ProfileStore`.

This is the training-side mirror of the PR-2 serving split:

- `train/roster.py`      — device-resident slot bank (the SlotState analogue)
- `RosterBatcher`        — deterministic per-slot batch assembly from any
                           profile-conditioned data source
- `OnboardingScheduler`  — host-side lifecycle: pending queue, slot→profile
                           assignment, convergence polling at sync cadence,
                           graduation (binarize masks → byte-level store
                           record) and eviction
- `OnboardingTrainer`    — Trainer subclass driving the jitted gang step;
                           all lifecycle work happens in `on_sync`, so the
                           hot loop never blocks on the host

Graduation closes the train→serve loop: the store record is written through
`ProfileStore.add_profile` (the same binarize/pack path serving admission
hydrates from), so a graduated profile is immediately admittable by
`ServeEngine` with bit-identical k-sparse masks.
"""
from __future__ import annotations

import os
from collections import deque
from dataclasses import dataclass
from typing import List, Optional

import numpy as np
import jax

from repro import obs as OBS
from repro.core.profiles import ProfileStore
from repro.obs import trace as TR
from repro.train.roster import Roster
from repro.train.trainer import Trainer


@dataclass
class GraduationPolicy:
    """When a slot's occupant is done training.

    A slot graduates once it has trained `min_steps` AND its debiased EMA
    crosses a target (`target_loss` and/or `target_acc` — either suffices).
    At `max_steps` an unconverged profile is force-graduated, or evicted
    (dropped, recorded) when `evict_at_max` is set.
    """
    min_steps: int = 30
    max_steps: int = 300
    ema_decay: float = 0.9
    target_loss: Optional[float] = None
    target_acc: Optional[float] = None
    evict_at_max: bool = False
    # poisoned-slot quarantine: a profile whose slot hits this many
    # non-finite gang steps (the in-step finite guard skipped its update)
    # is evicted WITHOUT graduating — a data/numerics problem this bad is
    # the profile's, and retraining it forever would pin a slot
    max_poison_strikes: int = 3


class RosterBatcher:
    """Assembles [S, m, ...] gang batches: row s carries slot s's profile.

    Each slot's rows are sampled with that slot's profile id; free slots get
    a placeholder id (their loss/grads are masked by the roster's `active`
    mask, and their rows occupy fixed example indices, so occupied slots'
    data streams are independent of admission activity elsewhere).
    """

    def __init__(self, source, capacity: int, per_slot: int, seq_len: int):
        self.source = source
        self.S = capacity
        self.m = per_slot
        self.seq_len = seq_len
        self.step = 0
        self.slot_pids: List[Optional[int]] = [None] * capacity

    def next(self) -> dict:
        pids = np.repeat([0 if p is None else int(p)
                          for p in self.slot_pids], self.m)
        b = self.source.sample(self.step, self.S * self.m, self.seq_len,
                               profile_ids=pids)
        self.step += 1
        return {k: np.asarray(v).reshape((self.S, self.m) + v.shape[1:])
                for k, v in b.items()}

    # -- checkpointable position ------------------------------------------
    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, s: dict) -> None:
        self.step = int(s["step"])


class OnboardingScheduler:
    """Host-side lifecycle over (roster state, store): admit pending
    profiles into free slots, poll convergence at sync cadence, graduate or
    evict. Never touches the device outside `Roster`'s jitted ops and the
    single `metrics()` fetch per poll."""

    def __init__(self, roster: Roster, store: ProfileStore,
                 policy: GraduationPolicy, pending_profiles, *,
                 bank=None, xp=None):
        self.roster = roster
        self.store = store
        self.policy = policy
        self.pending = deque(int(p) for p in pending_profiles)
        self.slot_pid: List[Optional[int]] = [None] * roster.capacity
        self.graduated: List[dict] = []
        self.evicted: List[dict] = []
        self.quarantined: List[dict] = []
        self.admission_waves = 0
        # quantized stores: graduation also freezes the profile's
        # aggregated Â/B̂ (masks x bank, computed here from the bf16/fp32
        # frozen bank — training itself never quantizes) so serving can
        # admit the profile with ZERO bank reads. `bank` is the frozen
        # params' "xpeft_bank", `xp` the XPeftConfig.
        self.bank = bank
        self.xp = xp
        # lifecycle outcomes are the onboarding trace's payload; the
        # OnboardingTrainer overwrites this with its own bundle so the
        # scheduler and trainer always share one tracer
        self.obs = OBS.NULL_OBS
        if store.quant != "none" and (bank is None or xp is None):
            raise ValueError("a quantized store needs the frozen bank and "
                             "XPeftConfig to aggregate Â/B̂ at graduation "
                             "(pass bank=/xp= or use build_onboarding_run)")

    # ------------------------------------------------------------ lifecycle
    def fill(self, rstate: dict, batcher: RosterBatcher) -> dict:
        """Admit pending profiles into every free slot (one wave)."""
        admitted = False
        for slot in range(self.roster.capacity):
            if self.slot_pid[slot] is None and self.pending:
                pid = self.pending.popleft()
                rstate = self.roster.admit(rstate, slot, pid)
                self.slot_pid[slot] = pid
                batcher.slot_pids[slot] = pid
                admitted = True
        if admitted:
            self.admission_waves += 1
        return rstate

    def poll(self, rstate: dict, batcher: RosterBatcher) -> dict:
        """Sync-cadence pass: ONE device fetch, then graduate/evict/refill."""
        met = self.roster.metrics(rstate, self.policy.ema_decay)
        pol = self.policy
        for slot, pid in enumerate(self.slot_pid):
            if pid is None:
                continue
            # strike check FIRST: a poisoned slot's slot_step freezes (the
            # finite guard skips its updates), so it would otherwise sit
            # below min_steps forever, pinning the slot
            if int(met["nonfinite"][slot]) >= pol.max_poison_strikes:
                rstate = self.quarantine(rstate, slot, met)
                batcher.slot_pids[slot] = None
                continue
            steps = int(met["slot_step"][slot])
            if steps < pol.min_steps:
                continue
            converged = (
                (pol.target_loss is not None
                 and met["ema_loss"][slot] <= pol.target_loss) or
                (pol.target_acc is not None
                 and met["ema_acc"][slot] >= pol.target_acc))
            if converged or steps >= pol.max_steps:
                if converged or not pol.evict_at_max:
                    rstate = self.graduate(rstate, slot, met)
                else:
                    rstate = self.evict(rstate, slot, met)
                batcher.slot_pids[slot] = None
        return self.fill(rstate, batcher)

    def _record(self, slot: int, met: dict) -> dict:
        return {"pid": int(self.slot_pid[slot]), "slot": int(slot),
                "steps": int(met["slot_step"][slot]),
                "ema_loss": round(float(met["ema_loss"][slot]), 6),
                "ema_acc": round(float(met["ema_acc"][slot]), 6)}

    def graduate(self, rstate: dict, slot: int, met: dict) -> dict:
        """Freeze the slot's trained row into the serving store (binarized,
        byte-level) and free the slot. Quantized stores additionally get
        the profile's aggregated Â/B̂, quantized ON WRITE (the store owns
        the scheme) — the train-side half of the quantized serving path."""
        pid = self.slot_pid[slot]
        prof = self.roster.slot_params(rstate, slot)
        agg = None
        if self.store.quant != "none" and not self.xp.is_hetero:
            # hetero banks graduate masks-only even into quantized stores:
            # the agg_* record format is the bottleneck (Â, B̂) pair, which
            # has no single-tensor analogue across mixed families —
            # admission falls back to the sparse bank-read path.
            from repro.core import xpeft as XP
            eff = XP.precompute_effective_adapters(self.bank, prof, self.xp)
            agg = (eff["a_hat"], eff["b_hat"])
        self.store.add_profile(pid, prof, agg=agg)
        rec = self._record(slot, met)
        self.graduated.append(rec)
        self.obs.tracer.instant(TR.CAT_GRADUATION, "graduate",
                                profile=int(pid), slot=int(slot),
                                steps=rec["steps"])
        self.obs.metrics.inc("train.graduated")
        rstate = self.roster.evict(rstate, slot)
        self.slot_pid[slot] = None
        return rstate

    def evict(self, rstate: dict, slot: int, met: dict) -> dict:
        """Drop an unconverged occupant without graduating it."""
        rec = self._record(slot, met)
        self.evicted.append(rec)
        self.obs.tracer.instant(TR.CAT_GRADUATION, "evict",
                                profile=rec["pid"], slot=int(slot),
                                steps=rec["steps"])
        self.obs.metrics.inc("train.evicted")
        rstate = self.roster.evict(rstate, slot)
        self.slot_pid[slot] = None
        return rstate

    def quarantine(self, rstate: dict, slot: int, met: dict) -> dict:
        """Drop a repeatedly-poisoned occupant: its slot took
        `max_poison_strikes` non-finite gang steps. The profile never
        graduates (nothing of it reaches the store) and the freed slot is
        refilled like any other."""
        rec = self._record(slot, met)
        rec["nonfinite"] = int(met["nonfinite"][slot])
        self.quarantined.append(rec)
        self.obs.tracer.instant(TR.CAT_RESILIENCE, "quarantine",
                                profile=rec["pid"], slot=int(slot),
                                nonfinite=rec["nonfinite"])
        self.obs.metrics.inc("train.quarantined")
        rstate = self.roster.evict(rstate, slot)
        self.slot_pid[slot] = None
        return rstate

    def finished(self) -> bool:
        return not self.pending and all(p is None for p in self.slot_pid)

    def stats(self) -> dict:
        return {"pending": len(self.pending),
                "in_training": sum(p is not None for p in self.slot_pid),
                "graduated": len(self.graduated),
                "evicted": len(self.evicted),
                "quarantined": len(self.quarantined),
                "admission_waves": self.admission_waves}

    # -------------------------------------------------------------- persist
    def state_dict(self) -> dict:
        return {"pending": [int(p) for p in self.pending],
                "slot_pid": [None if p is None else int(p)
                             for p in self.slot_pid],
                "graduated": list(self.graduated),
                "evicted": list(self.evicted),
                "quarantined": list(self.quarantined),
                "admission_waves": int(self.admission_waves)}

    def load_state_dict(self, s: dict) -> None:
        self.pending = deque(int(p) for p in s["pending"])
        self.slot_pid = [None if p is None else int(p)
                         for p in s["slot_pid"]]
        self.graduated = list(s["graduated"])
        self.evicted = list(s["evicted"])
        self.quarantined = list(s.get("quarantined", []))
        self.admission_waves = int(s["admission_waves"])


class OnboardingTrainer(Trainer):
    """Drives the gang step; lifecycle runs ONLY at host-sync boundaries.

    state is {"frozen": ..., "roster": ...}; `loader` is a RosterBatcher.
    The scheduler's host state (pending queue position, slot→profile
    assignment) rides in the checkpoint manifest, the roster's device state
    in the checkpoint arrays, and graduated profiles in the store file at
    `store_path` — so `--resume` restarts mid-onboarding without
    re-training anything already graduated.
    """

    def __init__(self, step_fn, state, batcher: RosterBatcher,
                 scheduler: OnboardingScheduler, *,
                 store_path: Optional[str] = None, **kw):
        super().__init__(step_fn, state, batcher, **kw)
        self.scheduler = scheduler
        self.scheduler.obs = self.obs  # one bundle across trainer+lifecycle
        self.store_path = store_path
        self.state["roster"] = scheduler.fill(self.state["roster"],
                                              self.loader)

    # ----------------------------------------------------------------- hooks
    def on_sync(self, recs: list) -> None:
        n_grad = len(self.scheduler.graduated)
        self.state["roster"] = self.scheduler.poll(self.state["roster"],
                                                   self.loader)
        # the poll's EMA fetch + each graduation's slot-row fetch are
        # device→host transfers too: count them so syncs/step reports the
        # subsystem's TOTAL host traffic, not just metric flushes
        self.host_syncs += 1 + (len(self.scheduler.graduated) - n_grad)

    def should_stop(self) -> bool:
        return self.scheduler.finished()

    # --------------------------------------------------------------- persist
    def extra_state(self) -> dict:
        extra = super().extra_state()
        extra["onboarding"] = self.scheduler.state_dict()
        return extra

    def restore_extra(self, extra: dict) -> None:
        super().restore_extra(extra)
        if "onboarding" in extra:
            self.scheduler.load_state_dict(extra["onboarding"])
            for slot in range(self.loader.S):
                self.loader.slot_pids[slot] = self.scheduler.slot_pid[slot]
        if self.store_path and os.path.exists(self.store_path):
            self.scheduler.store.merge_from(ProfileStore.load(self.store_path))

    def checkpoint(self, blocking=True):
        if self.mgr and self.store_path:
            self.scheduler.store.save(self.store_path)
        super().checkpoint(blocking=blocking)

    def run_until_drained(self, max_steps: int = 100_000) -> list:
        """Train until every pending profile has graduated (or been
        evicted); `max_steps` is the runaway backstop."""
        return self.run(max_steps)


def build_onboarding_run(cfg, source, pending, *, slots: int = 4,
                         per_slot: int = 4, seq_len: int = 16,
                         policy: Optional[GraduationPolicy] = None,
                         lr: float = 1e-3, ema_decay: float = 0.9,
                         seed: int = 0, frozen=None, store=None,
                         mesh=None, fault_plan=None, **trainer_kw):
    """Wire the whole lifecycle stack — frozen PLM, roster, gang step,
    batcher, store, scheduler, trainer — the one assembly the launcher,
    example, and bench all share. Returns (trainer, gang_step_fn); the
    un-jitted gang fn carries `.trace_counter`. Reach the pieces via
    `trainer.scheduler` (store/roster) and `trainer.state` (frozen/roster
    state).

    Pass an existing `store` to graduate into it — the RE-TRAINING flow:
    profiles already being served re-graduate in place, and any ServeEngine
    holding that store is notified so its cached aggregates invalidate.

    Pass a `mesh` to shard the gang step: the roster's slot axis (and each
    step's [S, m, ...] batch rows) go over the "data" mesh axis while the
    frozen PLM replicates, so per-slot training is device-local and the
    graduated store is bit-identical to a single-device run. Graduation
    itself always gathers the slot row to HOST numpy (`Roster.slot_params`)
    before the binarize/pack roundtrip."""
    import jax as _jax

    from repro.models import init_lm
    from repro.train.roster import init_roster_state
    from repro.train.steps import make_gang_step

    key = _jax.random.key(seed)
    kf, kr = _jax.random.split(key)
    if frozen is None:
        frozen = init_lm(kf, cfg)
    roster = Roster(cfg, _jax.random.key(seed + 2), slots, mesh=mesh)
    rstate = init_roster_state(kr, cfg, slots)
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.distributed import sharding as SH
        rstate = _jax.device_put(
            rstate, SH.to_shardings(SH.leading_axis_specs(rstate, mesh),
                                    mesh))
        frozen = _jax.device_put(frozen, NamedSharding(mesh,
                                                       PartitionSpec()))
    state = {"frozen": frozen, "roster": rstate}
    policy = policy or GraduationPolicy(ema_decay=ema_decay)
    # the step's EMA decay and the policy's debias decay must agree
    # one FaultPlan governs the whole run: gang-step grad poisoning here,
    # checkpoint truncation via the trainer's CheckpointManager below
    gang = make_gang_step(cfg, lr=lr, ema_decay=policy.ema_decay, mesh=mesh,
                          fault_plan=fault_plan)
    batcher = RosterBatcher(source, slots, per_slot, seq_len)
    xp = cfg.xpeft
    if store is None:
        store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                             xp.mask_type, xp.k,
                             quant=xp.bank_quant,
                             quant_group=xp.quant_group,
                             bank_spec=xp.bank_spec)
    scheduler = OnboardingScheduler(
        roster, store, policy, pending,
        bank=frozen["xpeft_bank"] if store.quant != "none" else None,
        xp=xp if store.quant != "none" else None)
    trainer_kw.setdefault("rng", _jax.random.key(seed + 1))
    if fault_plan is not None:
        trainer_kw.setdefault("fault_plan", fault_plan)
    trainer = OnboardingTrainer(_jax.jit(gang), state, batcher, scheduler,
                                **trainer_kw)
    return trainer, gang
