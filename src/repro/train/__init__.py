from repro.train.steps import (  # noqa: F401
    init_train_state,
    init_xpeft_trainable,
    lm_loss,
    make_gang_step,
    make_train_step,
)
from repro.train.roster import (  # noqa: F401
    Roster,
    init_roster_state,
)
from repro.train.onboarding import (  # noqa: F401
    GraduationPolicy,
    OnboardingScheduler,
    OnboardingTrainer,
    RosterBatcher,
)
from repro.train.trainer import Trainer  # noqa: F401
