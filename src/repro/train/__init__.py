from repro.train.steps import (  # noqa: F401
    init_train_state,
    init_xpeft_trainable,
    lm_loss,
    make_train_step,
)
from repro.train.trainer import Trainer  # noqa: F401
