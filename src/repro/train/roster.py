"""Device-resident roster of profile-training slots.

The training-side counterpart of `serve/slots.py`: a fixed-capacity bank of
S slots, each holding one onboarding profile's trainables (mask-table row +
optional per-profile head row), its Adam moments, and its convergence EMAs —
all packed along a leading slot axis as DEVICE arrays, gated by an
``active`` mask. `max_profiles` stops being a training-run constant:
P >> S profiles stream through the S slots.

Invariants the onboarding layer relies on:
- admission/eviction are jitted scatters taking the slot index as a traced
  scalar, so cycling profiles through slots never retraces anything — the
  gang step (train/steps.py `make_gang_step`) sees static shapes and traces
  exactly once per run;
- a freshly admitted slot is bit-identical to a from-scratch init for that
  profile: params are re-derived from `fold_in(base_key, profile_id)`,
  moments and EMAs are zeroed, per-slot Adam step restarts at 0;
- eviction only clears ``active`` (+ EMAs); parked rows are dead weight the
  gang step masks out of both grads and optimizer updates, so neighbouring
  slots' trajectories are unaffected by any admit/evict sequence;
- convergence signals (loss/accuracy EMAs, per-slot step counts) live on
  device and cross to the host in ONE transfer at `metrics()` — called at
  the trainer's sync cadence, never per step.
"""
from __future__ import annotations

from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import masks as M
from repro.distributed.sharding import constrain_leading
from repro.optim import adamw_init_rows


def init_slot_trainable(key, cfg) -> dict:
    """One slot row (no slot axis): mask-table row + optional head row."""
    k1, k2 = jax.random.split(key)
    row = {"table": M.init_profile_params(k1, cfg.num_layers,
                                          cfg.xpeft.num_adapters,
                                          cfg.xpeft.bottleneck)}
    if cfg.num_labels:
        row["heads"] = {
            "head_w": 0.02 * jax.random.normal(
                k2, (cfg.d_model, cfg.num_labels), jnp.float32),
            "head_b": jnp.zeros((cfg.num_labels,), jnp.float32),
        }
    return row


def init_roster_state(key, cfg, capacity: int) -> dict:
    """Slot-packed roster state: every leaf has leading dim S = capacity."""
    keys = jax.random.split(key, capacity)
    trainable = jax.vmap(lambda k: init_slot_trainable(k, cfg))(keys)
    return {
        "trainable": trainable,
        "opt": adamw_init_rows(trainable, capacity),
        "active": jnp.zeros((capacity,), bool),
        "slot_step": jnp.zeros((capacity,), jnp.int32),
        "ema_loss": jnp.zeros((capacity,), jnp.float32),
        "ema_acc": jnp.zeros((capacity,), jnp.float32),
        "ema_count": jnp.zeros((capacity,), jnp.int32),
        # poisoned-step counter: gang steps where this slot's loss/grads
        # came back non-finite (the update was skipped); feeds the
        # onboarding strike counter that quarantines the profile
        "nonfinite": jnp.zeros((capacity,), jnp.int32),
    }


class Roster:
    """Jitted slot lifecycle ops over a roster state pytree.

    The state itself is owned by the caller (the trainer checkpoints it as
    part of the train state); this class holds the config, the base RNG key
    profiles are deterministically initialized from, and the three jitted
    ops (`_fresh` init, `_admit` scatter, `_evict` mask-clear).

    With a `mesh`, the lifecycle ops re-pin the slot axis over "data"
    (`constrain_leading`) so admission/eviction scatters never migrate the
    roster off its gang-step sharding — the step keeps its single trace
    across waves on a mesh exactly as on one device.
    """

    def __init__(self, cfg, base_key, capacity: int, *, mesh=None):
        self.cfg = cfg
        self.capacity = capacity
        self.base_key = base_key
        self.mesh = mesh
        self._fresh = jax.jit(lambda k: init_slot_trainable(k, cfg))

        def admit_impl(state, slot, fresh):
            set_row = lambda t, r: t.at[slot].set(
                jnp.asarray(r).astype(t.dtype))
            zero_row = lambda t: t.at[slot].set(0)
            out = {
                "trainable": jax.tree.map(set_row, state["trainable"], fresh),
                "opt": {"m": jax.tree.map(zero_row, state["opt"]["m"]),
                        "v": jax.tree.map(zero_row, state["opt"]["v"]),
                        "step": state["opt"]["step"].at[slot].set(0)},
                "active": state["active"].at[slot].set(True),
                "slot_step": state["slot_step"].at[slot].set(0),
                "ema_loss": state["ema_loss"].at[slot].set(0.0),
                "ema_acc": state["ema_acc"].at[slot].set(0.0),
                "ema_count": state["ema_count"].at[slot].set(0),
                "nonfinite": state["nonfinite"].at[slot].set(0),
            }
            return constrain_leading(out, mesh)

        def evict_impl(state, slot):
            out = dict(state)
            out["active"] = state["active"].at[slot].set(False)
            return constrain_leading(out, mesh)

        self._admit = jax.jit(admit_impl)
        self._evict = jax.jit(evict_impl)

    # ------------------------------------------------------------- lifecycle
    def profile_key(self, pid: int):
        return jax.random.fold_in(self.base_key, int(pid))

    def admit(self, state: dict, slot: int, pid: int) -> dict:
        """Admit profile `pid` into `slot`: fresh deterministic init row,
        zeroed moments/EMAs. One jitted scatter; slot is a traced scalar."""
        fresh = self._fresh(self.profile_key(pid))
        return self._admit(state, jnp.int32(slot), fresh)

    def evict(self, state: dict, slot: int) -> dict:
        """Deactivate `slot`; parked rows stay in place until re-admission."""
        return self._evict(state, jnp.int32(slot))

    # ------------------------------------------------------------ host views
    def metrics(self, state: dict, ema_decay: float) -> Dict[str, np.ndarray]:
        """ONE device→host transfer of the convergence signals. EMAs are
        debiased by their update count (EMA starts at 0 on admission)."""
        active, steps, el, ea, cnt, nf = jax.device_get(
            (state["active"], state["slot_step"], state["ema_loss"],
             state["ema_acc"], state["ema_count"], state["nonfinite"]))
        debias = 1.0 - np.power(ema_decay, np.maximum(cnt, 1))
        return {"active": np.asarray(active),
                "slot_step": np.asarray(steps),
                "ema_loss": np.asarray(el) / debias,
                "ema_acc": np.asarray(ea) / debias,
                "ema_count": np.asarray(cnt),
                "nonfinite": np.asarray(nf)}

    def slot_params(self, state: dict, slot: int) -> dict:
        """Host copy of one slot's trainables, flattened to the profile
        record shape `ProfileStore.add_profile` expects (mA/mB/ln_* [+head]).
        Always gathers to HOST numpy — on a mesh the slot row is fetched off
        its data-shard, so graduation's binarize/pack roundtrip is
        bit-identical on 1 device or N."""
        row = jax.tree.map(lambda t: t[slot], state["trainable"])
        host = jax.device_get(row)
        out = {k: np.asarray(v) for k, v in host["table"].items()}
        if "heads" in host:
            out["head_w"] = np.asarray(host["heads"]["head_w"])
            out["head_b"] = np.asarray(host["heads"]["head_b"])
        return out
