"""Train steps for every fine-tuning arm of the paper + full pretrain.

Modes (paper §4 baselines, one mechanism):
- "xpeft":     trainable = per-profile mask table (+ per-profile heads for
               encoders). THE paper workload: multi-profile mask training
               against a frozen PLM + frozen shared adapter bank.
- "adapter":   single_adapter baseline — one fresh Pfeiffer adapter (+head),
               PLM frozen (bank of N=1 with fixed mask).
- "head_only": head_only baseline.
- "full":      full pretraining (framework completeness; the non-paper path).

The trainable subtree is a SEPARATE pytree from the frozen params, so frozen
weights enter grad as non-differentiated arguments and XLA drops their weight
gradients (≈1/3 of backward FLOPs saved — visible in the roofline table).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import masks as M
from repro.core import xpeft as XP
from repro.core.adapters import init_adapter_bank
from repro.models import model as MDL
from repro.optim import (adamw_init, adamw_update, adamw_update_rows,
                         clip_by_global_norm, clip_by_row_norm)
from repro.optim.adamw import _bcast_rows
from repro.utils import merge_trees


# ----------------------------------------------------------------------------
# Trainable init per mode
# ----------------------------------------------------------------------------

def init_xpeft_trainable(key, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    out = {"table": XP.init_profile_table(k1, cfg)}
    if cfg.num_labels:
        P = cfg.xpeft.max_profiles
        out["heads"] = {
            "head_w": 0.02 * jax.random.normal(
                k2, (P, cfg.d_model, cfg.num_labels), jnp.float32),
            "head_b": jnp.zeros((P, cfg.num_labels), jnp.float32),
        }
    return out


def init_adapter_trainable(key, cfg) -> dict:
    """single_adapter baseline: one adapter (bank with N=1) + LN + head."""
    k1, k2, k3 = jax.random.split(key, 3)
    xp = cfg.xpeft
    out = {
        "bank": init_adapter_bank(k1, cfg.num_layers, 1, cfg.d_model,
                                  xp.bottleneck, jnp.dtype(cfg.dtype)),
        "ln_scale": jnp.ones((cfg.num_layers, xp.bottleneck), jnp.float32),
        "ln_bias": jnp.zeros((cfg.num_layers, xp.bottleneck), jnp.float32),
    }
    if cfg.num_labels:
        out["head"] = {
            "head_w": 0.02 * jax.random.normal(
                k3, (cfg.d_model, cfg.num_labels), jnp.float32),
            "head_b": jnp.zeros((cfg.num_labels,), jnp.float32),
        }
    return out


def init_head_trainable(key, cfg) -> dict:
    return {"head": {
        "head_w": 0.02 * jax.random.normal(
            key, (cfg.d_model, cfg.num_labels), jnp.float32),
        "head_b": jnp.zeros((cfg.num_labels,), jnp.float32),
    }}


def init_trainable(key, cfg, mode: str) -> dict:
    if mode == "xpeft":
        return init_xpeft_trainable(key, cfg)
    if mode == "adapter":
        return init_adapter_trainable(key, cfg)
    if mode == "head_only":
        return init_head_trainable(key, cfg)
    raise ValueError(mode)


def init_train_state(key, cfg, mode: str = "xpeft") -> dict:
    """{"frozen", "trainable", "opt", "step"} — full training state."""
    kf, kt = jax.random.split(key)
    frozen = MDL.init_lm(kf, cfg)
    if mode == "full":
        trainable = frozen
        frozen = {}
        return {"frozen": frozen, "trainable": trainable,
                "opt": adamw_init(trainable)}
    trainable = init_trainable(kt, cfg, mode)
    return {"frozen": frozen, "trainable": trainable,
            "opt": adamw_init(trainable)}


# ----------------------------------------------------------------------------
# Losses
# ----------------------------------------------------------------------------

def lm_loss(logits, labels):
    """Mean next-token CE. logits [B,T,V] fp32, labels [B,T]."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def lm_loss_chunked(params, hidden, labels, cfg, chunk: int = 512):
    """CE without materializing [B,T,V]: scan over sequence chunks.

    At vocab 256k / seq 4k / batch 256 the full fp32 logits tensor is ~1 PB
    global; chunking bounds the live logits to [B, chunk, V/shard] and lets
    XLA re-materialize per chunk in backward (jax.checkpoint on the body).
    """
    from repro.models import model as MDL

    B, T, d = hidden.shape
    if T <= chunk or T % chunk != 0:
        return lm_loss(MDL.lm_logits(params, hidden, cfg), labels)
    n = T // chunk
    hs = hidden.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, xs):
        h, lab = xs
        logits = MDL.lm_logits(params, h, cfg)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.float32(0), (hs, ls))
    return total / (B * T)


def cls_loss(logits, labels):
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return jnp.mean(lse - gold), acc


# ----------------------------------------------------------------------------
# Forward under each mode
# ----------------------------------------------------------------------------

def _forward_mode(frozen, trainable, batch, cfg, mode, rng, training=True):
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    masks = None
    head_override = None
    params = frozen

    if mode == "xpeft":
        prof = XP.gather_profiles(trainable["table"], batch["profile_ids"])
        w_a, w_b = XP.profile_mask_weights(prof, cfg.xpeft, key=rng,
                                           training=training)
        masks = {"w_a": w_a, "w_b": w_b, "ln_scale": prof["ln_scale"],
                 "ln_bias": prof["ln_bias"]}
        if cfg.num_labels:
            head_override = jax.tree.map(
                lambda t: jnp.take(t, batch["profile_ids"], axis=0),
                trainable["heads"])
    elif mode == "adapter":
        B = tokens.shape[0]
        ones = jnp.ones((B, cfg.num_layers, 1), jnp.float32)
        masks = {"w_a": ones, "w_b": ones,
                 "ln_scale": jnp.broadcast_to(trainable["ln_scale"],
                                              (B,) + trainable["ln_scale"].shape),
                 "ln_bias": jnp.broadcast_to(trainable["ln_bias"],
                                             (B,) + trainable["ln_bias"].shape)}
        params = merge_trees(frozen, {"xpeft_bank": trainable["bank"]})
        if cfg.num_labels:
            head_override = trainable["head"]
    elif mode == "head_only":
        params = {k: v for k, v in frozen.items() if k != "xpeft_bank"}
        head_override = trainable["head"]
        cfg = cfg.with_xpeft(enabled=False)
    elif mode == "full":
        params = trainable

    hidden, _, aux = MDL.forward(params, tokens, cfg, prefix_embeds=prefix,
                                 profile_masks=masks)
    return hidden, aux, head_override, params


def loss_for_batch(frozen, trainable, batch, cfg, mode, rng, training=True):
    hidden, aux, head_override, params = _forward_mode(
        frozen, trainable, batch, cfg, mode, rng, training)
    metrics = {}
    if cfg.num_labels:  # encoder classification (paper experiments)
        if head_override is not None and head_override.get("head_w") is not None \
                and head_override["head_w"].ndim == 3:
            logits = MDL.cls_logits(params, hidden, cfg, head_override)
        elif head_override is not None:
            pooled = jnp.tanh(hidden[:, 0, :].astype(jnp.float32)
                              @ params["cls"]["pool_w"]
                              + params["cls"]["pool_b"])
            logits = pooled @ head_override["head_w"] + head_override["head_b"]
        else:
            logits = MDL.cls_logits(params, hidden, cfg)
        loss, acc = cls_loss(logits, batch["labels"])
        metrics["accuracy"] = acc
    else:  # LM next-token (seq-chunked CE: never materializes [B,T,V])
        P = 0 if batch.get("prefix_embeds") is None else \
            batch["prefix_embeds"].shape[1]
        loss = lm_loss_chunked(params, hidden[:, P:, :], batch["labels"], cfg)
    total = loss + 0.01 * aux
    metrics["loss"] = loss
    metrics["aux_loss"] = aux
    return total, metrics


# ----------------------------------------------------------------------------
# Step factory
# ----------------------------------------------------------------------------

def make_gang_step(cfg, *, lr=1e-3, weight_decay=0.0, clip_norm: float = 1.0,
                   ema_decay: float = 0.9, mesh=None, fault_plan=None):
    """Slot-packed gang step for the onboarding roster.

    One jitted update trains every ACTIVE slot on its own per-slot
    micro-batch: `batch["tokens"]` is [S, m, T] (row s belongs to slot s),
    labels [S, m] for classification or [S, m, T] for LM. Slot isolation is
    exact and bitwise:

    - the total loss is the SUM of per-slot mean losses (never normalized
      by the active count), so slot j's grads are independent of how many
      other slots are occupied;
    - grads are clipped per slot row (`clip_by_row_norm`), not globally;
    - inactive slots contribute zero loss, and `adamw_update_rows` masks
      their params AND moments, so a parked slot's trajectory is untouched
      by any admit/evict activity elsewhere.

    Finite guard (always on): a slot whose loss or grads come back
    non-finite is masked out of the update exactly like an inactive one —
    its params AND Adam moments stay bitwise-untouched, its EMAs and
    slot_step freeze, and the roster's per-slot ``nonfinite`` counter
    increments (the onboarding strike counter reads it at sync cadence to
    quarantine repeat offenders). Healthy slots are bitwise-unaffected:
    the guard reuses the same per-row masking `adamw_update_rows` already
    applies to parked slots. A `fault_plan` with `poison_slots` overwrites
    the selected slots' loss/grads with NaN AFTER `value_and_grad` — the
    chaos seam that proves the guard, off (and free) in production.

    Convergence EMAs (loss/accuracy) update on device inside the step;
    the host reads them via `Roster.metrics` at sync cadence only.
    Returns step({"frozen", "roster"}, batch, rng) -> (state, metrics),
    with a `.trace_counter` dict tests/benches use to assert the step
    traces exactly once across admission waves.

    With a `mesh`, the SLOT axis shards over the "data" mesh axis: the
    batch's [S, m, ...] rows and the roster's slot-packed leaves are
    constrained so each slot's micro-batch, grads, per-row Adam update and
    EMAs stay device-local (frozen params replicate — no contraction is
    ever split), making the sharded update bit-identical to the
    single-device one. Only the summed loss/grad-norm METRICS cross
    devices (a psum whose float error is invisible to the lifecycle).
    """
    from repro.distributed.sharding import constrain_leading

    counter = {"traces": 0}

    def step(state, batch, rng):
        counter["traces"] += 1
        frozen, rstate = state["frozen"], state["roster"]
        batch = constrain_leading(batch, mesh)
        rstate = constrain_leading(rstate, mesh)
        S, m = batch["tokens"].shape[:2]
        toks = batch["tokens"].reshape(S * m, -1)
        slot_ids = jnp.repeat(jnp.arange(S), m)
        active = rstate["active"]

        def loss_fn(trainable):
            prof = jax.tree.map(lambda t: t[slot_ids], trainable["table"])
            w_a, w_b = XP.profile_mask_weights(prof, cfg.xpeft, key=rng,
                                               training=True)
            pmasks = {"w_a": w_a, "w_b": w_b, "ln_scale": prof["ln_scale"],
                      "ln_bias": prof["ln_bias"]}
            hidden, _, _ = MDL.forward(frozen, toks, cfg,
                                       profile_masks=pmasks)
            if cfg.num_labels:
                head = jax.tree.map(lambda t: t[slot_ids],
                                    trainable["heads"])
                logits = MDL.cls_logits(frozen, hidden, cfg, head)
                labels = batch["labels"].reshape(S * m)
                lse = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(
                    logits, labels[:, None], axis=-1)[:, 0]
                per_ex = lse - gold
                slot_acc = (jnp.argmax(logits, -1) == labels) \
                    .astype(jnp.float32).reshape(S, m).mean(axis=1)
            else:
                logits = MDL.lm_logits(frozen, hidden, cfg)
                labels = batch["labels"].reshape(S * m, -1)
                lse = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(
                    logits, labels[..., None], axis=-1)[..., 0]
                per_ex = (lse - gold).mean(axis=-1)
                slot_acc = jnp.zeros((S,), jnp.float32)
            slot_loss = per_ex.reshape(S, m).mean(axis=1)
            total = jnp.sum(jnp.where(active, slot_loss, 0.0))
            return total, (slot_loss, slot_acc)

        (_, (slot_loss, slot_acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(rstate["trainable"])
        if fault_plan is not None and fault_plan.poisons_gang():
            # chaos seam, AFTER value_and_grad: healthy slots' gradient
            # computation is bitwise-unchanged by the injection
            pmask = fault_plan.gang_poison_mask(rstate["slot_step"], S)
            grads = jax.tree.map(
                lambda g: jnp.where(_bcast_rows(pmask, g), jnp.nan, g),
                grads)
            slot_loss = jnp.where(pmask, jnp.nan, slot_loss)
        # finite guard: treat a poisoned slot exactly like a parked one
        finite = jnp.isfinite(slot_loss)
        for g in jax.tree.leaves(grads):
            finite &= jnp.all(jnp.isfinite(g),
                              axis=tuple(range(1, g.ndim)))
        ok = active & finite
        grads, gnorm = clip_by_row_norm(grads, clip_norm)
        new_params, new_opt = adamw_update_rows(
            grads, rstate["opt"], rstate["trainable"], ok, lr=lr,
            weight_decay=weight_decay)
        d = ema_decay
        ema = lambda old, x: jnp.where(ok, d * old + (1 - d) * x, old)
        new_r = {
            "trainable": new_params, "opt": new_opt, "active": active,
            "slot_step": rstate["slot_step"] + ok.astype(jnp.int32),
            "ema_loss": ema(rstate["ema_loss"], slot_loss),
            "ema_acc": ema(rstate["ema_acc"], slot_acc),
            "ema_count": rstate["ema_count"] + ok.astype(jnp.int32),
            "nonfinite": rstate["nonfinite"]
            + (active & ~finite).astype(jnp.int32),
        }
        new_r = constrain_leading(new_r, mesh)
        okf = ok.astype(jnp.float32)
        n_ok = jnp.maximum(okf.sum(), 1.0)
        metrics = {"loss": jnp.where(ok, slot_loss, 0.0).sum() / n_ok,
                   "grad_norm": jnp.where(ok, gnorm, 0.0).sum() / n_ok,
                   "active_slots": active.astype(jnp.float32).sum(),
                   "nonfinite_slots":
                       (active & ~finite).astype(jnp.float32).sum()}
        if cfg.num_labels:
            metrics["accuracy"] = jnp.where(ok, slot_acc, 0.0).sum() / n_ok
        return {"frozen": frozen, "roster": new_r}, metrics

    step.trace_counter = counter
    return step


def make_train_step(cfg, mode: str = "xpeft", *, lr=1e-3, weight_decay=0.0,
                    clip_norm: float = 1.0, accum: int = 1):
    """Returns step(state, batch, rng) -> (state, metrics); jit-ready.

    Like the gang step, carries a `.trace_counter` dict (incremented once
    per jit trace — `jax.jit` copies the attribute through, sharing the
    dict) so the Trainer's retrace sentinel covers plain training too."""
    counter = {"traces": 0}

    def step(state, batch, rng):
        counter["traces"] += 1
        frozen = state["frozen"]

        def loss_fn(trainable, mb):
            return loss_for_batch(frozen, trainable, mb, cfg, mode, rng)

        if accum > 1:
            def micro(carry, mb):
                g_acc, m_acc = carry
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state["trainable"], mb)
                return (jax.tree.map(jnp.add, g_acc, g),
                        jax.tree.map(jnp.add, m_acc, m)), None
            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)
            zeros_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                                   state["trainable"])
            zeros_m = {"loss": 0.0, "aux_loss": 0.0}
            if cfg.num_labels:
                zeros_m["accuracy"] = 0.0
            (grads, metrics), _ = jax.lax.scan(micro, (zeros_g, zeros_m), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m / accum, metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["trainable"], batch)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = adamw_update(
            grads, state["opt"], state["trainable"], lr=lr,
            weight_decay=weight_decay)
        metrics["grad_norm"] = gnorm
        return {"frozen": frozen, "trainable": new_params,
                "opt": new_opt}, metrics

    step.trace_counter = counter
    return step
