"""Slot-based continuous-batching serving engine with per-request X-PEFT
profiles.

Design (DESIGN.md §2 Serve):
- Fixed slot count; every decode step advances ALL slots in one jitted call
  (inactive slots compute on pad tokens; their outputs are ignored and their
  state is overwritten at the next admission).
- Per-slot cache positions -> ragged lengths without re-batching.
- Admission hydrates the request's profile from the byte-level ProfileStore
  and (fast path) aggregates its adapters ONCE against the bank
  (`precompute=True`), so the decode loop applies two tiny matmuls per layer
  instead of a mask-bank contraction — the serving optimization the paper's
  "disable out-of-top-k gradients" remark gestures at, taken to its TPU
  conclusion.
- Prompt lengths are padded to power-of-two buckets to bound jit variants.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import model as MDL
from repro.serve.steps import greedy_next


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [T] int32
    profile_id: int
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


class ServeEngine:
    def __init__(self, cfg, params, store: ProfileStore, *, max_slots: int = 4,
                 max_seq: int = 256, precompute: bool = True):
        self.cfg = cfg
        self.params = params
        self.store = store
        self.S = max_seq
        self.n_slots = max_slots
        self.precompute = precompute and cfg.xpeft.enabled
        self.cache = MDL.init_cache(cfg, max_slots, max_seq)
        self.lengths = np.zeros(max_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.last_tok = np.zeros(max_slots, np.int32)
        xp = cfg.xpeft
        L, N, b, d = cfg.num_layers, xp.num_adapters, xp.bottleneck, cfg.d_model
        if self.precompute:
            dt = jnp.dtype(cfg.dtype)
            self.masks = {
                "a_hat": jnp.zeros((max_slots, L, d, b), dt),
                "b_hat": jnp.zeros((max_slots, L, b, d), dt),
                "ln_scale": jnp.ones((max_slots, L, b), jnp.float32),
                "ln_bias": jnp.zeros((max_slots, L, b), jnp.float32),
            }
        elif cfg.xpeft.enabled:
            self.masks = {
                "w_a": jnp.zeros((max_slots, L, N), jnp.float32),
                "w_b": jnp.zeros((max_slots, L, N), jnp.float32),
                "ln_scale": jnp.ones((max_slots, L, b), jnp.float32),
                "ln_bias": jnp.zeros((max_slots, L, b), jnp.float32),
            }
        else:
            self.masks = None
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("prompt_len",))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,),
                               static_argnames=())

    # ------------------------------------------------------------- jit impls
    def _prefill_impl(self, params, tokens, masks_row, length, *, prompt_len):
        mini = MDL.init_cache(self.cfg, 1, self.S)
        masks = None
        if masks_row is not None:
            masks = jax.tree.map(lambda a: a[None], masks_row)
        hidden, mini, _ = MDL.forward(params, tokens, self.cfg,
                                      profile_masks=masks, cache=mini,
                                      cache_pos=0)
        idx = length - 1
        logits = MDL.lm_logits(
            params, jax.lax.dynamic_slice_in_dim(hidden, idx, 1, axis=1),
            self.cfg)
        return jnp.argmax(logits[0, -1]).astype(jnp.int32), mini

    def _insert_impl(self, cache, mini, slot):
        def ins(big, small):
            # batch dim of the big cache is axis 1 for stacked caches
            return big.at[:, slot].set(small[:, 0].astype(big.dtype))
        return jax.tree.map(ins, cache, mini)

    def _decode_impl(self, params, cache, tokens, lengths, masks):
        hidden, cache, _ = MDL.forward(params, tokens[:, None], self.cfg,
                                       profile_masks=masks, cache=cache,
                                       cache_pos=lengths)
        logits = MDL.lm_logits(params, hidden, self.cfg)
        return greedy_next(logits), cache

    # ---------------------------------------------------------------- public
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def admit(self, req: Request) -> bool:
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        T = len(req.prompt)
        # recurrent-state archs can't mask pad tokens out of their state:
        # prefill exactly; attention archs pad to pow2 buckets (fewer jits)
        pad = _bucket(T) if self.cfg.block_pattern == "attn" else T
        toks = np.zeros((1, pad), np.int32)
        toks[0, :T] = req.prompt
        masks_row = None
        if self.masks is not None:
            wa, wb = self.store.mask_weights(req.profile_id)
            rec = self.store._rec[int(req.profile_id)]
            prof = {"ln_scale": jnp.asarray(rec["ln_scale"], jnp.float32),
                    "ln_bias": jnp.asarray(rec["ln_bias"], jnp.float32)}
            if self.precompute:
                bank = self.params["xpeft_bank"]
                dt = bank["bank_a"].dtype
                a_hat = jnp.einsum("ln,lndb->ldb", wa, bank["bank_a"]
                                   .astype(jnp.float32)).astype(dt)
                b_hat = jnp.einsum("ln,lnbd->lbd", wb, bank["bank_b"]
                                   .astype(jnp.float32)).astype(dt)
                masks_row = {"a_hat": a_hat, "b_hat": b_hat, **prof}
            else:
                masks_row = {"w_a": wa, "w_b": wb, **prof}
            self.masks = jax.tree.map(
                lambda buf, row: buf.at[slot].set(row.astype(buf.dtype)),
                self.masks, masks_row)
        nxt, mini = self._prefill(self.params, jnp.asarray(toks), masks_row,
                                  jnp.int32(T), prompt_len=pad)
        self.cache = self._insert(self.cache, mini, slot)
        self.slot_req[slot] = req
        self.lengths[slot] = T
        self.last_tok[slot] = int(nxt)
        req.generated.append(int(nxt))
        return True

    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        nxt, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tok),
            jnp.asarray(self.lengths), self.masks)
        nxt = np.asarray(nxt)
        for i in active:
            req = self.slot_req[i]
            self.lengths[i] += 1
            req.generated.append(int(nxt[i]))
            self.last_tok[i] = int(nxt[i])
            if len(req.generated) >= req.max_new_tokens \
                    or self.lengths[i] >= self.S - 1:
                req.done = True
                self.slot_req[i] = None
        return len(active)

    def run_until_drained(self, queue: List[Request], max_steps: int = 10_000):
        steps = 0
        while (queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            while queue and self.free_slots():
                if not self.admit(queue[0]):
                    break
                queue.pop(0)
            self.step()
            steps += 1
        return steps
