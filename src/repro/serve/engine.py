"""Slot-based continuous-batching serving engine with per-request X-PEFT
profiles.

Design (DESIGN.md §2 Serve):
- Fixed slot count; every decode step advances ALL slots in one jitted call
  (inactive slots compute on pad tokens; their outputs are ignored and their
  state is overwritten at the next admission).
- Per-slot cache positions -> ragged lengths without re-batching.
- Admission hydrates the request's profile from the byte-level ProfileStore
  and (fast path) aggregates its adapters ONCE against the bank
  (`precompute=True`), so the decode loop applies two tiny matmuls per layer
  instead of a mask-bank contraction — the serving optimization the paper's
  "disable out-of-top-k gradients" remark gestures at, taken to its TPU
  conclusion.
- Hard-mask admission is k-SPARSE: a single jitted aggregation gathers only
  the profile's top-k bank rows (k·L·d·b bank bytes instead of the dense
  einsum's N·L·d·b — 5.1x less at N=256, k=50) through
  kernels/ops.mask_aggregate_batched. Multi-request admission batches the
  aggregations of every admitted request into ONE launch (`admit_many`);
  request counts are padded to power-of-two buckets to bound jit variants.
- Prompt lengths are padded to power-of-two buckets to bound jit variants.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import model as MDL
from repro.serve.steps import greedy_next


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [T] int32
    profile_id: int
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


def _pow2(n: int) -> int:
    """Request-count bucket: next power of two from 1 (no floor — padding
    rows cost real aggregation DMA, unlike pad tokens)."""
    b = 1
    while b < n:
        b *= 2
    return b


class ServeEngine:
    def __init__(self, cfg, params, store: ProfileStore, *, max_slots: int = 4,
                 max_seq: int = 256, precompute: bool = True):
        self.cfg = cfg
        self.params = params
        self.store = store
        self.S = max_seq
        self.n_slots = max_slots
        self.precompute = precompute and cfg.xpeft.enabled
        self.cache = MDL.init_cache(cfg, max_slots, max_seq)
        self.lengths = np.zeros(max_slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        self.last_tok = np.zeros(max_slots, np.int32)
        xp = cfg.xpeft
        L, N, b, d = cfg.num_layers, xp.num_adapters, xp.bottleneck, cfg.d_model
        if self.precompute:
            dt = jnp.dtype(cfg.dtype)
            self.masks = {
                "a_hat": jnp.zeros((max_slots, L, d, b), dt),
                "b_hat": jnp.zeros((max_slots, L, b, d), dt),
                "ln_scale": jnp.ones((max_slots, L, b), jnp.float32),
                "ln_bias": jnp.zeros((max_slots, L, b), jnp.float32),
            }
        elif cfg.xpeft.enabled:
            self.masks = {
                "w_a": jnp.zeros((max_slots, L, N), jnp.float32),
                "w_b": jnp.zeros((max_slots, L, N), jnp.float32),
                "ln_scale": jnp.ones((max_slots, L, b), jnp.float32),
                "ln_bias": jnp.zeros((max_slots, L, b), jnp.float32),
            }
        else:
            self.masks = None
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("prompt_len",))
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,),
                               static_argnames=())
        # single jitted admission aggregations (padded-R bucketed); the
        # sparse path reads only k·L·d·b bank bytes per request
        self._aggregate_sparse = jax.jit(
            lambda bank, ia, wa, ib, wb:
            XP.precompute_effective_adapters_sparse(bank, ia, wa, ib, wb, xp))
        self._aggregate_dense = jax.jit(
            XP.precompute_effective_adapters_dense_batched)
        # which aggregation path the last admission took + the bank bytes it
        # actually read (from the shapes handed to the kernel) — serve_bench
        # reports these so CI gates on exercised behavior, not config math
        self.last_admission: Optional[dict] = None

    # ------------------------------------------------------------- jit impls
    def _prefill_impl(self, params, tokens, masks_row, length, *, prompt_len):
        mini = MDL.init_cache(self.cfg, 1, self.S)
        masks = None
        if masks_row is not None:
            masks = jax.tree.map(lambda a: a[None], masks_row)
        hidden, mini, _ = MDL.forward(params, tokens, self.cfg,
                                      profile_masks=masks, cache=mini,
                                      cache_pos=0)
        idx = length - 1
        logits = MDL.lm_logits(
            params, jax.lax.dynamic_slice_in_dim(hidden, idx, 1, axis=1),
            self.cfg)
        return jnp.argmax(logits[0, -1]).astype(jnp.int32), mini

    def _insert_impl(self, cache, mini, slot):
        def ins(big, small):
            # batch dim of the big cache is axis 1 for stacked caches
            return big.at[:, slot].set(small[:, 0].astype(big.dtype))
        return jax.tree.map(ins, cache, mini)

    def _decode_impl(self, params, cache, tokens, lengths, masks):
        hidden, cache, _ = MDL.forward(params, tokens[:, None], self.cfg,
                                       profile_masks=masks, cache=cache,
                                       cache_pos=lengths)
        logits = MDL.lm_logits(params, hidden, self.cfg)
        return greedy_next(logits), cache

    # ---------------------------------------------------------------- public
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _hydrate_mask_rows(self, reqs: List[Request]):
        """-> (per-request mask rows for prefill, stacked [R,...] tree for
        the slot-buffer scatter). Precompute aggregations run as ONE jitted
        batched call (k-sparse for hard masks) padded to a pow2 request
        bucket so retraces stay bounded."""
        if self.masks is None:
            return [None] * len(reqs), None
        R = len(reqs)
        recs = [self.store._rec[int(r.profile_id)] for r in reqs]
        ln_s = jnp.asarray(np.stack([r["ln_scale"] for r in recs]),
                           jnp.float32)
        ln_b = jnp.asarray(np.stack([r["ln_bias"] for r in recs]),
                           jnp.float32)
        if not self.precompute:
            was, wbs = zip(*(self.store.mask_weights(r.profile_id)
                             for r in reqs))
            stacked = {"w_a": jnp.stack(was), "w_b": jnp.stack(wbs),
                       "ln_scale": ln_s, "ln_bias": ln_b}
            rows = [jax.tree.map(lambda t: t[r], stacked) for r in range(R)]
            return rows, stacked
        bank = self.params["xpeft_bank"]
        L, N = bank["bank_a"].shape[:2]
        slice_bytes = int(np.prod(bank["bank_a"].shape[2:])
                          * 2 * bank["bank_a"].dtype.itemsize)  # Â+B̂ per row
        Rp = _pow2(R)
        if self.store.mask_type == "hard":
            # k-sparse fast path: only the top-k bank rows are read
            ia, wa, ib, wb = zip(*(self.store.sparse_indices(r.profile_id)
                                   for r in reqs))
            pad_i = np.zeros((Rp - R,) + np.asarray(ia[0]).shape, np.int32)
            pad_w = np.zeros((Rp - R,) + np.asarray(wa[0]).shape, np.float32)
            idx_a = jnp.asarray(np.concatenate([np.stack(ia), pad_i]))
            w_a = jnp.asarray(np.concatenate([np.stack(wa), pad_w]))
            idx_b = jnp.asarray(np.concatenate([np.stack(ib), pad_i]))
            w_b = jnp.asarray(np.concatenate([np.stack(wb), pad_w]))
            a_hat, b_hat = self._aggregate_sparse(bank, idx_a, w_a,
                                                  idx_b, w_b)
            k = idx_a.shape[-1]
            # bytes the kernel was actually handed, padding rows included
            self.last_admission = {"path": "sparse", "requests": R,
                                   "padded_requests": Rp,
                                   "bank_bytes_per_request":
                                   Rp * k * L * slice_bytes // R}
        else:
            # soft masks are dense by construction; jitted dense einsum
            # (reads the bank once per call, amortized over the batch)
            was, wbs = zip(*(self.store.mask_weights(r.profile_id)
                             for r in reqs))
            pad_w = np.zeros((Rp - R,) + np.asarray(was[0]).shape, np.float32)
            w_a = jnp.asarray(np.concatenate([np.stack(was), pad_w]))
            w_b = jnp.asarray(np.concatenate([np.stack(wbs), pad_w]))
            a_hat, b_hat = self._aggregate_dense(bank, w_a, w_b)
            self.last_admission = {"path": "dense", "requests": R,
                                   "padded_requests": Rp,
                                   "bank_bytes_per_request":
                                   N * L * slice_bytes // R}
        stacked = {"a_hat": a_hat[:R], "b_hat": b_hat[:R],
                   "ln_scale": ln_s, "ln_bias": ln_b}
        rows = [jax.tree.map(lambda t: t[r], stacked) for r in range(R)]
        return rows, stacked

    def admit_many(self, reqs: List[Request]) -> int:
        """Admit up to len(free_slots()) requests; one batched aggregation,
        then per-request (length-bucketed) prefill. Returns #admitted."""
        free = self.free_slots()
        reqs = reqs[:len(free)]
        if not reqs:
            return 0
        rows, stacked = self._hydrate_mask_rows(reqs)
        if stacked is not None:
            # ONE scatter into the per-slot buffers for all admitted
            # requests (not one full-buffer copy per request)
            slots = jnp.asarray(free[:len(reqs)])
            self.masks = jax.tree.map(
                lambda buf, rs: buf.at[slots].set(rs.astype(buf.dtype)),
                self.masks, stacked)
        for req, slot, masks_row in zip(reqs, free, rows):
            T = len(req.prompt)
            # recurrent-state archs can't mask pad tokens out of their state:
            # prefill exactly; attention archs pad to pow2 buckets (fewer jits)
            pad = _bucket(T) if self.cfg.block_pattern == "attn" else T
            toks = np.zeros((1, pad), np.int32)
            toks[0, :T] = req.prompt
            nxt, mini = self._prefill(self.params, jnp.asarray(toks),
                                      masks_row, jnp.int32(T), prompt_len=pad)
            self.cache = self._insert(self.cache, mini, slot)
            self.slot_req[slot] = req
            self.lengths[slot] = T
            self.last_tok[slot] = int(nxt)
            req.generated.append(int(nxt))
        return len(reqs)

    def admit(self, req: Request) -> bool:
        return self.admit_many([req]) == 1

    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        nxt, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_tok),
            jnp.asarray(self.lengths), self.masks)
        nxt = np.asarray(nxt)
        for i in active:
            req = self.slot_req[i]
            self.lengths[i] += 1
            req.generated.append(int(nxt[i]))
            self.last_tok[i] = int(nxt[i])
            if len(req.generated) >= req.max_new_tokens \
                    or self.lengths[i] >= self.S - 1:
                req.done = True
                self.slot_req[i] = None
        return len(active)

    def run_until_drained(self, queue: List[Request], max_steps: int = 10_000):
        steps = 0
        while (queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            if queue and self.free_slots():
                n = self.admit_many(queue[:len(self.free_slots())])
                del queue[:n]
            self.step()
            steps += 1
        return steps
