"""Serving engine orchestrator: scheduler + slot state + profile cache.

The engine wires four layers (DESIGN.md §2 Serve, restructured):

- `serve/scheduler.py` — request queue + admission policy: FIFO waves,
  bucket-grouped so same-length prompts share one prefill launch.
- `serve/profile_cache.py` — byte-capacity LRU of admission-time
  aggregated Â/B̂ keyed by profile_id: a hit admits with ZERO bank reads
  (the dominant case when R requests share P ≪ R profiles).
- `serve/slots.py` — device-resident decode state (`last_tok`/`lengths`/
  `active`) advanced by ONE jitted step that also decides termination on
  device; the host syncs every `sync_every` steps, not every token.
- this module — hydration + batched bucketed prefill + the public API
  (`admit_many`, `step`, `sync`, `run_until_drained`).

Admission of a wave:
1. hydrate masks: per-request profile-cache lookup; only MISSING profiles
   are aggregated against the bank — k-sparse (top-k rows only) for hard
   masks, dense einsum for soft — in one jitted call padded to a pow2
   profile-count bucket; results are cached and the wave's rows gathered.
2. ONE scatter of the stacked rows into the per-slot mask buffers.
3. batched bucketed prefill: every same-length-bucket group goes through
   ONE jitted prefill call (stacked [B, pad] batch, per-request last-token
   argmax on device), then one batched KV-cache scatter per group.
   Attention archs pad prompts to pow2 buckets; recurrent-state archs
   (rwkv/mamba/zamba) prefill at exact length (pad tokens cannot be
   masked out of a recurrent state).

The engine never touches `ProfileStore` internals — hydration goes through
the store's vectorized public API (`batch_sparse_indices`, `ln_affines`,
`batch_mask_weights`). It DOES subscribe to the store's change
notifications: re-graduating a profile (`add_profile`/`merge_from`)
invalidates its cached aggregate, so serving never pins a re-trained
profile to stale Â/B̂.

Multi-device: pass `mesh=` (see `launch/mesh.py`) and the same engine runs
under GSPMD — params via the repo sharding rules (bank d_model / heads /
vocab TP over "model"), KV cache and slot/mask buffers with their slot
axis over "data", all jitted hot-path functions pinned to those shardings.
No contraction is split along the slot axis, so admission aggregates and
per-slot decode are bit-identical to the single-device path (validated on
CPU with XLA_FLAGS=--xla_force_host_platform_device_count=8).
"""
from __future__ import annotations

import time
import weakref
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs as OBS
from repro.obs import trace as TR
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import model as MDL
from repro.resilience import (InjectedHydrationError, RecordIntegrityError,
                              RetryPolicy, retry_with_backoff)
from repro.serve import pages as PG
from repro.serve.profile_cache import ProfileCache
from repro.serve.scheduler import Request, Scheduler
from repro.serve.slots import SlotState
from repro.serve.steps import greedy_next
from repro.utils import pow2_count


def _rate(num, den, nd: int = 4) -> float:
    """Rate field for serve_stats(): 0.0 — not num/max(den,1) — when the
    denominator never ticked. A zero-decode engine must report 0 syncs per
    token, not `host_syncs` of them."""
    return round(num / den, nd) if den else 0.0


class ServeEngine:
    def __init__(self, cfg, params, store: ProfileStore, *, max_slots: int = 4,
                 max_seq: int = 256, precompute: bool = True,
                 sync_every: int = 8, cache_bytes: Optional[int] = 64 << 20,
                 mesh=None, fault_plan=None,
                 retry_policy: Optional[RetryPolicy] = None,
                 continuous: bool = False, page_size: int = 16,
                 max_pages: Optional[int] = None,
                 mask_pages: Optional[int] = None,
                 max_wait_waves: Optional[int] = None,
                 obs: Optional[OBS.Observability] = None):
        self.cfg = cfg
        self.store = store
        # observability bundle (ISSUE 10). Device-side instrumentation is
        # UNCONDITIONAL (the slot obs accumulator exists either way, so
        # compiled programs are identical with or without a bundle); the
        # bundle only turns on host-side histogram/trace/sentinel work at
        # the sync boundaries the engine already has.
        self.obs = OBS.get(obs)
        self.S = max_seq
        self.n_slots = max_slots
        self.precompute = precompute and cfg.xpeft.enabled
        self.sync_every = sync_every
        self.mesh = mesh
        # continuous batching (ISSUE 7): the KV/recurrent cache and the
        # per-slot adapter records live in block-paged pools; slots retire,
        # refill, preempt and resume at every host sync instead of decoding
        # in lockstep waves. continuous=False keeps the PR-2 windowed
        # engine bit-for-bit (the parity baseline cb_smoke gates against).
        self.continuous = continuous
        self.page_size = page_size
        # self-speculative decoding (ISSUE 8): the shared frozen PLM — the
        # zero-adapter entry, bitwise the bare PLM — drafts spec_gamma
        # tokens per slot per round; the adapted model verifies all of them
        # in ONE batched step and commits the accepted prefix plus one
        # correction/bonus token. Greedy output is bitwise identical to
        # non-speculative greedy per request; speculation only changes how
        # many device steps the same tokens take.
        self.spec = bool(cfg.spec_enable)
        self.spec_gamma = int(cfg.spec_gamma)
        if self.spec:
            if not continuous:
                raise ValueError("spec_enable requires continuous=True "
                                 "(drafting rides the paged decode path)")
            if cfg.decode_fused:
                raise ValueError(
                    "spec_enable and decode_fused are exclusive per "
                    "engine: verification runs a T=gamma+1 composed "
                    "forward, which the T=1 megakernel cannot serve")
            if cfg.block_pattern != "attn":
                raise ValueError("spec_enable requires pure-attention "
                                 "blocks (recurrent state cannot rewind "
                                 "rejected drafts)")
            if self.spec_gamma < 1:
                raise ValueError("spec_gamma must be >= 1")
        # heterogeneous adapter-type bank (cfg.xpeft.bank_spec): typed
        # cache entries / slot buffers; prefix segments additionally
        # hydrate KV rows into the cache at admission. A type-pure
        # bottleneck spec keeps every code path below bitwise-identical.
        self.hetero = bool(cfg.xpeft.enabled and cfg.xpeft.is_hetero)
        self.prefix_len = int(cfg.xpeft.prefix_tokens) \
            if (self.hetero and cfg.xpeft.has_prefix) else 0
        self._prefix_seg = next(
            ((off, cnt) for t, off, cnt in cfg.xpeft.segments()
             if t == "prefix"), None)
        if self.hetero:
            if cfg.xpeft.bank_quant != "none":
                raise ValueError(
                    "bank_quant engines do not serve heterogeneous "
                    "bank_specs (quantize_bank_hetero covers storage; "
                    "serve with bank_quant='none')")
            if self.precompute and store.mask_type != "hard":
                raise ValueError(
                    "heterogeneous precompute serving requires hard-mask "
                    "profiles (per-type k-sparse aggregation)")
        if self.prefix_len:
            if self.spec:
                raise ValueError(
                    "spec_enable cannot serve a prefix-bearing bank_spec: "
                    "bare-PLM drafts would attend the adapted prefix KV "
                    "rows resident in the shared cache")
            if cfg.block_pattern != "attn":
                raise ValueError("prefix segments require pure-attention "
                                 "blocks (KV-row hydration)")
            if not (precompute and cfg.xpeft.enabled):
                raise ValueError(
                    "per-step mask serving cannot hydrate prefix KV rows; "
                    "a prefix-bearing bank_spec requires precompute=True")
            if self.prefix_len >= max_seq - 1:
                raise ValueError("prefix_tokens must leave room for the "
                                 f"prompt (max_seq={max_seq})")
        # quantized bank (cfg.xpeft.bank_quant): the bf16/fp32 bank is
        # quantized ONCE here and DROPPED from the resident params — the
        # engine serves every admission from the int8/int4 rows (k-sparse
        # aggregation dequantizes in-register) and every decode step from
        # quantized Â/B̂ records, so per-device residency shrinks by the
        # storage factor. bank_quant="none" leaves params untouched and
        # every code path below identical to the unquantized engine.
        self.quant = cfg.xpeft.bank_quant if self.precompute else "none"
        self.qbank = None
        self._qrow_bytes = 0
        if cfg.xpeft.enabled and cfg.xpeft.bank_quant != "none" \
                and not precompute:
            # refuse rather than silently serve the unquantized bank: the
            # per-step mask path hydrates against the fp bank every step,
            # so none of bank_quant's byte/residency savings would exist
            raise ValueError("bank_quant serving requires precompute "
                             "admission (per-step mask hydration reads "
                             "the unquantized bank)")
        if self.quant != "none":
            from repro.quant import schemes as QS
            QS.check_scheme(self.quant)
            if store.mask_type != "hard":
                raise ValueError("bank_quant serving requires hard-mask "
                                 "profiles (k-sparse quantized aggregation)")
            self.qbank = QS.quantize_bank(params["xpeft_bank"], self.quant,
                                          group=cfg.xpeft.quant_group)
            params = {k: v for k, v in params.items() if k != "xpeft_bank"}
            # TRUE quantized bank bytes of one (l, n) row across both banks
            # + scales — what one k-sparse admission read actually moves
            L_, N_ = self.qbank["bank_a_q"].shape[:2]
            self._qrow_bytes = sum(
                int(np.prod(v.shape)) * np.dtype(v.dtype).itemsize
                for v in self.qbank.values()) // (L_ * N_)
        self.params = params
        # multi-device: same engine code on 1 device or an N-device mesh.
        # Params take the repo sharding rules (TP over "model": bank d_model,
        # heads, mlp, vocab — fsdp=False: serving replicates what TP doesn't
        # claim, an all-gather-on-use would sit on the decode critical path);
        # the KV/recurrent cache takes cache_specs (slots over "data",
        # kv/state heads over "model"); slot state + mask buffers shard
        # their slot axis over "data" (leading_axis_specs).
        self._specs = {}
        self._shardings = {}
        if mesh is not None:
            from repro.distributed import sharding as SH
            self._specs["params"] = SH.param_specs(params, mesh, fsdp=False)
            self._shardings["params"] = SH.to_shardings(
                self._specs["params"], mesh)
            self.params = jax.device_put(params, self._shardings["params"])
            if self.qbank is not None:
                # quantized leaves keep the bf16 bank's TP layout: bank_*_q
                # shard d_model over "model", scale arrays ride along on
                # their matching dims (rules in distributed/sharding.py)
                self._specs["qbank"] = SH.param_specs(self.qbank, mesh,
                                                      fsdp=False)
                self._shardings["qbank"] = SH.to_shardings(
                    self._specs["qbank"], mesh)
                self.qbank = jax.device_put(self.qbank,
                                            self._shardings["qbank"])
        # cache: dense [lead, n_slots, S, ...] block (windowed), or paged
        # pools + per-slot page table (continuous). Pure-recurrent archs
        # have no sequence-axis leaves — the pool degenerates away and the
        # continuous engine still gets mid-stream admission.
        self._paged = False
        self.page_alloc: Optional[PG.PageAllocator] = None
        self.mask_alloc: Optional[PG.PageAllocator] = None
        self.n_pages = 0
        if continuous:
            template = jax.eval_shape(
                lambda: MDL.init_cache(cfg, max_slots, max_seq))
            self._paged = PG.paged_seq_len(template) > 0
            if self._paged:
                if max_seq % page_size:
                    raise ValueError(f"max_seq {max_seq} must be a "
                                     f"multiple of page_size {page_size}")
                per_req = PG.pages_needed(max_seq, page_size)
                self.n_pages = (max_pages if max_pages is not None
                                else max_slots * per_req)
                if self.n_pages < per_req:
                    raise ValueError(
                        f"max_pages={self.n_pages} cannot hold one "
                        f"max-length request ({per_req} pages) — the "
                        "engine could deadlock instead of preempting")
                ncolors = 1
                if mesh is not None:
                    d = dict(mesh.shape).get("data", 1)
                    if self.n_pages % d == 0:
                        ncolors = d
                self.page_alloc = PG.PageAllocator(self.n_pages,
                                                   n_colors=ncolors)
            self.cache = PG.make_paged_cache(template, max(self.n_pages, 1),
                                             page_size, max_slots)
            self._mp = int(self.cache["table"].shape[1])
            self._sentinel = max(self.n_pages, 1)
            self._page_table_h = np.full((max_slots, self._mp),
                                         self._sentinel, np.int32)
        else:
            self.cache = MDL.init_cache(cfg, max_slots, max_seq)
        if mesh is not None:
            if continuous:
                self._specs["cache"] = SH.paged_cache_specs(
                    self.cache, mesh, cfg, max_slots)
            else:
                self._specs["cache"] = SH.cache_specs(self.cache, mesh, cfg,
                                                      max_slots)
            self._shardings["cache"] = SH.to_shardings(
                self._specs["cache"], mesh)
            self.cache = jax.device_put(self.cache, self._shardings["cache"])
        self.slot_req: List[Optional[Request]] = [None] * max_slots
        # resilience: admission probes each profile (with retry) before
        # hydration; a request whose profile can't be served degrades to
        # the bare PLM (zero-adapter masks) instead of failing its wave
        self.fault_plan = fault_plan
        self.retry_policy = retry_policy or RetryPolicy()
        self.degraded_requests = 0
        self.hydration_retries = 0
        self.slot_degraded: List[bool] = [False] * max_slots
        # continuous mode admits in small increments (1-2 freed slots), so
        # largest-bucket-first keeps prefill launches full; max_wait_waves
        # (default 4 there) stops that from starving rare lengths. The
        # windowed engine keeps strict head-first FIFO.
        if max_wait_waves is None and continuous:
            max_wait_waves = 4
        self.scheduler = Scheduler(
            cfg.block_pattern,
            policy="efficiency" if continuous else "fifo",
            max_wait_waves=max_wait_waves)
        self.profile_cache = ProfileCache(cache_bytes)
        # re-graduation hook: the store notifies every added/replaced pid,
        # so a re-trained profile can never serve a stale cached aggregate.
        # In-flight slots keep their already-scattered Â/B̂ copy until they
        # finish; the NEXT admission of the pid re-aggregates fresh.
        store.subscribe(self.invalidate_profile)
        xp = cfg.xpeft
        L, N, b, d = cfg.num_layers, xp.num_adapters, xp.bottleneck, cfg.d_model
        # continuous mode: mask records live in an ENTRY POOL (one entry =
        # one request's aggregated record, the adapter-state analogue of a
        # KV page) addressed through a per-slot table, so record capacity
        # decouples from slot count and preempted records free their entry
        mask_lead = max_slots
        if continuous:
            self.n_mask_entries = (mask_pages if mask_pages is not None
                                   else max_slots)
            if self.n_mask_entries < 1:
                raise ValueError("mask_pages must be >= 1")
            mask_lead = self.n_mask_entries
        # entry key set: what one hydrated profile entry (and the slot
        # pool, minus prefix rows) carries. Pure bottleneck keeps the
        # historical fixed tuple; hetero derives it from the bank_spec.
        self._entry_keys = ("a_hat", "b_hat", "ln_scale", "ln_bias")
        if self.hetero and self.precompute and self.quant == "none":
            keys = list(XP.hetero_entry_keys(xp))
            if self.prefix_len:
                keys.append("prefix_skip")
            self._entry_keys = tuple(keys)
        if self.precompute and self.quant != "none":
            # per-slot QUANTIZED Â/B̂ records + fp16 scales — the decode
            # step reads these and dequantizes in-register
            # (kernels/fused_adapter_quant.py via models._xpeft_apply)
            from repro.quant import schemes as QS
            aq_s, aq_dt, as_s = QS.quant_spec((mask_lead, L, d, b),
                                              self.quant,
                                              group=xp.quant_group)
            bq_s, bq_dt, bs_s = QS.quant_spec((mask_lead, L, b, d),
                                              self.quant,
                                              group=xp.quant_group)
            self.masks = {
                "a_q": jnp.zeros(aq_s, aq_dt),
                "a_scale": jnp.zeros(as_s, jnp.float16),
                "b_q": jnp.zeros(bq_s, bq_dt),
                "b_scale": jnp.zeros(bs_s, jnp.float16),
                "ln_scale": jnp.ones((mask_lead, L, b), jnp.float32),
                "ln_bias": jnp.zeros((mask_lead, L, b), jnp.float32),
            }
        elif self.precompute and self.hetero:
            # typed slot pool: one leaf per entry key the spec's families
            # need. Prefix ROWS are absent by design — they hydrate into
            # the KV cache at prefill; only the per-layer skip gate rides
            # with the decode masks.
            dt = jnp.dtype(cfg.dtype)
            shapes = {
                "a_hat": ((L, d, b), dt), "b_hat": ((L, b, d), dt),
                "ln_scale": ((L, b), jnp.float32),
                "ln_bias": ((L, b), jnp.float32),
                "lora_a": ((L, d, b), dt), "lora_b": ((L, b, d), dt),
                "ia3_s": ((L, d), dt),
                "prefix_skip": ((L,), jnp.int32),
            }
            self.masks = {}
            for key in self._entry_keys:
                if key in ("prefix_k", "prefix_v"):
                    continue
                shp, kdt = shapes[key]
                init = jnp.ones if key == "ln_scale" else jnp.zeros
                self.masks[key] = init((mask_lead,) + shp, kdt)
        elif self.precompute:
            dt = jnp.dtype(cfg.dtype)
            self.masks = {
                "a_hat": jnp.zeros((mask_lead, L, d, b), dt),
                "b_hat": jnp.zeros((mask_lead, L, b, d), dt),
                "ln_scale": jnp.ones((mask_lead, L, b), jnp.float32),
                "ln_bias": jnp.zeros((mask_lead, L, b), jnp.float32),
            }
        elif cfg.xpeft.enabled:
            self.masks = {
                "w_a": jnp.zeros((mask_lead, L, N), jnp.float32),
                "w_b": jnp.zeros((mask_lead, L, N), jnp.float32),
                "ln_scale": jnp.ones((mask_lead, L, b), jnp.float32),
                "ln_bias": jnp.zeros((mask_lead, L, b), jnp.float32),
            }
        else:
            self.masks = None
        if continuous and self.masks is not None:
            self.mask_alloc = PG.PageAllocator(self.n_mask_entries)
            self._mask_table_h = np.full((max_slots,), self.n_mask_entries,
                                         np.int32)
            self.masks = {"pool": self.masks,
                          "table": jnp.asarray(self._mask_table_h)}
        if mesh is not None and self.masks is not None:
            from repro.distributed import sharding as SH
            self._specs["masks"] = SH.leading_axis_specs(self.masks, mesh)
            self._shardings["masks"] = SH.to_shardings(
                self._specs["masks"], mesh)
            self.masks = jax.device_put(self.masks, self._shardings["masks"])
        # continuous mode decodes against a slot-indexed VIEW of the mask
        # record pool, re-gathered only when an entry table moves (host
        # syncs) — the pool is the record store that makes swap/refill a
        # table edit; the view is what the per-token step actually reads,
        # so record pooling costs the decode loop nothing
        self._masks_view = None
        if continuous and self.masks is not None:
            view = jax.tree.map(
                lambda m: jnp.zeros((max_slots,) + m.shape[1:], m.dtype),
                self.masks["pool"])
            if mesh is not None:
                self._specs["masks_view"] = SH.leading_axis_specs(view, mesh)
                self._shardings["masks_view"] = SH.to_shardings(
                    self._specs["masks_view"], mesh)
                view = jax.device_put(view, self._shardings["masks_view"])
            self._masks_view = view
        # speculative draft masks: a constant all-slot zero-adapter view
        # (identity LN) — the draft model IS the bare PLM, at zero extra
        # parameter memory (the whole point of SELF-speculation)
        self._zero_view = None
        if self.spec and self._masks_view is not None:
            zv = jax.tree.map(jnp.zeros_like, self._masks_view)
            zv["ln_scale"] = jnp.ones_like(zv["ln_scale"])
            if mesh is not None:
                zv = jax.device_put(zv, self._shardings["masks_view"])
            self._zero_view = zv

        if continuous and self.spec:
            # speculation round (still ONE jitted program): gamma bare-PLM
            # draft steps (scan over the same paged T=1 decode), then ONE
            # adapted T=gamma+1 verify forward at each slot's own offset.
            # The verify rewrites the drafts' bare KV with adapted KV
            # before attending (write-then-read inside forward), and
            # writeback_span commits the whole span to pages — positions
            # past the accepted prefix hold stale KV that the causal mask
            # hides and the next round overwrites.
            gamma, W = self.spec_gamma, self.spec_gamma + 1

            def decode_fn(params, cache, last_tok, lengths, masks, active):
                adapted = None if masks is None else masks["adapted"]
                zero = None if masks is None else masks["zero"]
                table = cache["table"]

                def draft_step(carry, _):
                    data, tok, pos = carry
                    dense = PG.dense_view(data, table, page_size)
                    hidden, dense, _ = MDL.forward(
                        params, tok[:, None], cfg, profile_masks=zero,
                        cache=dense, cache_pos=pos)
                    # near capacity a draft can point past S-1; writeback's
                    # page lookup clamps, so mask those writes out entirely
                    # (the tokens still draft — only their KV is dropped,
                    # and positions that far are never committed anyway)
                    ok = active & (pos < self.S)
                    data = PG.writeback(data, dense, table, pos, ok,
                                        page_size)
                    nxt = greedy_next(MDL.lm_logits(params, hidden, cfg))
                    return (data, nxt, pos + 1), nxt

                (data, _, _), drafts = jax.lax.scan(
                    draft_step, (cache["data"], last_tok, lengths), None,
                    length=gamma)
                drafts = jnp.moveaxis(drafts, 0, 1)          # [n, gamma]
                seq = jnp.concatenate([last_tok[:, None], drafts], axis=1)
                dense = PG.dense_view(data, table, page_size)
                hidden, dense, _ = MDL.forward(
                    params, seq, cfg, profile_masks=adapted, cache=dense,
                    cache_pos=lengths)
                data = PG.writeback_span(data, dense, table, lengths, W,
                                         active, page_size)
                logits = MDL.lm_logits(params, hidden, cfg)
                # same vocab-axis argmax as greedy_next, one per position
                toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                match = (drafts == toks[:, :gamma]).astype(jnp.int32)
                n_acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
                return toks, n_acc, {"data": data, "table": table}
        elif continuous:
            # paged decode: gather KV through the page table back to the
            # dense layout forward() already takes (bitwise-identical
            # values — junk pages only cover positions attention masks to
            # NEG_INF), then scatter the one written position back to its
            # page. All inside the ONE jitted slot step. Masks arrive as
            # the slot-indexed VIEW materialized at table-change time
            # (entry tables only move at host syncs, so gathering the
            # record pool per step would be pure overhead).
            def decode_fn(params, cache, last_tok, lengths, masks, active):
                dense = PG.dense_view(cache["data"], cache["table"],
                                      page_size)
                hidden, dense, _ = MDL.forward(params, last_tok[:, None],
                                               cfg, profile_masks=masks,
                                               cache=dense,
                                               cache_pos=lengths)
                data = PG.writeback(cache["data"], dense, cache["table"],
                                    lengths, active, page_size)
                return greedy_next(MDL.lm_logits(params, hidden, cfg)), \
                    {"data": data, "table": cache["table"]}
        else:
            def decode_fn(params, cache, last_tok, lengths, masks, active):
                hidden, cache, _ = MDL.forward(params, last_tok[:, None],
                                               cfg, profile_masks=masks,
                                               cache=cache,
                                               cache_pos=lengths)
                return greedy_next(MDL.lm_logits(params, hidden, cfg)), \
                    cache

        self.slots = SlotState(max_slots, max_seq, sync_every, decode_fn,
                               mesh=mesh,
                               cache_shardings=self._shardings.get("cache"),
                               spec_width=(self.spec_gamma + 1
                                           if self.spec else 1))
        # prefill legitimately compiles once per (bucket, batch) shape —
        # the wrapper runs per TRACE and records the shapes it saw, so the
        # retrace sentinel can tell "new bucket" from "placement drift"
        # (same shape tracing twice)
        self._prefill_traces = 0
        self._prefill_shapes = set()

        def _prefill_traced(params, tokens, masks, lengths, cache_pos=None,
                            prefix_rows=None):
            self._prefill_traces += 1
            self._prefill_shapes.add(tuple(tokens.shape))
            return self._prefill_impl(params, tokens, masks, lengths,
                                      cache_pos, prefix_rows)

        self._prefill = jax.jit(_prefill_traced)
        # the cache/mask buffers round-trip through these every wave: pin
        # their out-shardings so placement never drifts (a drift would both
        # retrace the decode step and migrate the KV cache mid-serve)
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,),
                               out_shardings=self._shardings.get("cache"))
        self._scatter_masks = jax.jit(
            lambda buf, slots, rows: jax.tree.map(
                lambda b_, r_: b_.at[slots].set(r_.astype(b_.dtype)),
                buf, rows),
            out_shardings=self._shardings.get("masks"))
        if continuous:
            csh = self._shardings.get("cache")
            dsh = csh["data"] if csh is not None else None
            self._insert_cb = jax.jit(
                lambda data, mini, slots, table: PG.insert_group(
                    data, mini, slots, table, page_size),
                donate_argnums=(0,), out_shardings=dsh)
            self._extract_cb = jax.jit(PG.extract_slot)
            self._restore_cb = jax.jit(
                PG.restore_slot, donate_argnums=(0,), out_shardings=dsh)
            if self.masks is not None:
                msh = self._shardings.get("masks")
                psh = msh["pool"] if msh is not None else None
                self._scatter_pool = jax.jit(
                    lambda pool, idx, rows: jax.tree.map(
                        lambda b_, r_: b_.at[idx].set(r_.astype(b_.dtype)),
                        pool, rows),
                    out_shardings=psh)
                self._extract_mask = jax.jit(
                    lambda pool, entry: jax.tree.map(
                        lambda m: m[entry], pool))
                self._gather_mask_view = jax.jit(
                    lambda pool, table: jax.tree.map(
                        lambda m: jnp.take(m, table, axis=0, mode="clip"),
                        pool),
                    out_shardings=self._shardings.get("masks_view"))
        # jitted admission aggregations (padded to pow2 profile counts); the
        # sparse path reads only k·L·d·b bank bytes per aggregated profile.
        # Hetero banks swap in the per-type bucketing aggregation (same
        # kernels, one launch per typed segment) returning the entry dict.
        if self.hetero:
            self._aggregate_sparse = jax.jit(
                lambda bank, ia, wa, ib, wb:
                XP.precompute_effective_adapters_sparse_hetero(
                    bank, ia, wa, ib, wb, xp))
        else:
            self._aggregate_sparse = jax.jit(
                lambda bank, ia, wa, ib, wb:
                XP.precompute_effective_adapters_sparse(
                    bank, ia, wa, ib, wb, xp))
        self._aggregate_dense = jax.jit(
            XP.precompute_effective_adapters_dense_batched)
        if self.quant != "none":
            from repro.quant import schemes as QS
            self._aggregate_sparse_quant = jax.jit(
                lambda qbank, ia, wa, ib, wb:
                XP.precompute_effective_adapters_sparse_quant(
                    qbank, ia, wa, ib, wb, xp))
            # re-quantize freshly aggregated fp32 rows into the cache/slot
            # record layout (per-row over the last axis, like the bank)
            def _requant(a_hat, b_hat):
                qa = QS.quantize(a_hat, self.quant, group=xp.quant_group)
                qb = QS.quantize(b_hat, self.quant, group=xp.quant_group)
                return {"a_q": qa["q"], "a_scale": qa["scale"],
                        "b_q": qb["q"], "b_scale": qb["scale"]}

            self._requantize = jax.jit(_requant)
        # what the last admission actually did (path, cache hits, bank bytes,
        # prefill occupancy) — serve_bench reports these so CI gates on
        # exercised behavior, not config math
        self.last_admission: Optional[dict] = None
        self.decode_tokens = 0
        # speculation accounting: drafts offered vs accepted, totals and
        # per-request (uid-keyed, so it survives preempt/resume cycles)
        self.spec_drafted = 0
        self.spec_accepted = 0
        self._spec_by_uid: dict = {}
        self.prefill_batches = 0
        self.prefill_rows = 0
        self.prefill_real = 0
        # current sync window. Windowed: sync_every capped by the UPPER
        # bound on tokens any live request can still emit (slots never
        # dead-step a full window after every request finished).
        # Continuous: capped by the LOWER bound — the host predicts the
        # first retirement exactly (greedy decode terminates on budget or
        # capacity, both host-known), so the sync lands the moment a slot
        # frees and its capacity is re-admitted immediately.
        self._window = sync_every
        # continuous-batching state: admission-order stamps (preempt the
        # youngest), the preempted-request resume queue (oldest first),
        # and the capacity accounting serve_stats reports
        self._slot_seq = [0] * max_slots
        self._admit_seq = 0
        self._resume_q: List[dict] = []
        self._backlog = False
        self._tables_dirty = True
        self._view_dirty = True
        self.preemptions = 0
        self.resumes = 0
        self.useful_slot_steps = 0
        self.stranded_slot_steps = 0
        # retrace sentinel: the per-bench "one trace" assertions of PRs
        # 2-9, promoted to a runtime invariant checked at every sync. The
        # decode step has a FIXED signature (budget 1); admit scatter and
        # prefill are shape-polymorphic, so their contract is
        # traces <= distinct input shapes.
        # Watches hold the engine WEAKLY (the store's invalidation hooks
        # set that contract): the shared NULL_OBS sentinel — or a bundle
        # outliving this engine — must not pin dead device state. A dead
        # engine's count_fn returns None and the sentinel drops the watch.
        wself = weakref.ref(self)

        def _w(get):
            return lambda: (lambda e: None if e is None else get(e))(wself())

        self.obs.sentinel.watch(
            "serve.decode_step", _w(lambda e: e.slots.step_traces), budget=1)
        self.obs.sentinel.watch(
            "serve.admit_scatter", _w(lambda e: e.slots.admit_traces),
            shapes_fn=_w(lambda e: len(e.slots.admit_shapes)))
        self.obs.sentinel.watch(
            "serve.prefill", _w(lambda e: e._prefill_traces),
            shapes_fn=_w(lambda e: len(e._prefill_shapes)))
        self._win_t0 = time.perf_counter()  # host time the window opened

    # ------------------------------------------------------------- jit impls
    def _prefill_impl(self, params, tokens, masks, lengths, cache_pos=None,
                      prefix_rows=None):
        """Batched prefill of one length bucket: tokens [B, pad], per-request
        masks [B, ...] (or None), lengths [B] -> (next_tok [B], mini cache).

        Prefix-bearing hetero specs pass ``cache_pos [B]`` (0 or P per
        request) and ``prefix_rows = (pk, pv) [B, L, P, kv]`` — the rows
        are written into the mini cache at buffer slots [0, P) BEFORE the
        forward, so the prompt attends them through the ordinary cached
        path (one trace; non-prefix requests carry zero rows at
        cache_pos 0 and never read them)."""
        B, P = tokens.shape
        mini = MDL.init_cache(self.cfg, B, self.S)
        if prefix_rows is not None:
            pk, pv = prefix_rows
            KV, hd = self.cfg.num_kv_heads, self.cfg.head_dim
            Pfx = pk.shape[2]

            def rows(x):
                x = x.reshape(x.shape[:3] + (KV, hd))   # [B, L, P, KV, hd]
                return jnp.moveaxis(x, 0, 1)            # [L, B, P, KV, hd]
            mini["k"] = mini["k"].at[:, :, :Pfx].set(
                rows(pk).astype(mini["k"].dtype))
            mini["v"] = mini["v"].at[:, :, :Pfx].set(
                rows(pv).astype(mini["v"].dtype))
        hidden, mini, _ = MDL.forward(
            params, tokens, self.cfg, profile_masks=masks, cache=mini,
            cache_pos=0 if cache_pos is None else cache_pos)
        idx = jnp.clip(lengths - 1, 0, P - 1)
        last_h = jnp.take_along_axis(hidden, idx[:, None, None], axis=1)
        logits = MDL.lm_logits(params, last_h, self.cfg)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), mini

    def _insert_impl(self, cache, mini, slots):
        B = slots.shape[0]

        def ins(big, small):
            # batch dim of stacked caches is axis 1; drop padded prefill rows
            return big.at[:, slots].set(small[:, :B].astype(big.dtype))
        return jax.tree.map(ins, cache, mini)

    # ------------------------------------------------------- paged memory
    def _push_tables(self) -> None:
        """Re-commit the host page/entry table mirrors to device with their
        PINNED shardings — a plain asarray would land on the default device
        and retrace the decode step on the next call. Mirrors are pushed
        only when dirty (every mutator sets the flag): a sync that retired
        nothing costs zero device traffic here."""
        if not self._tables_dirty:
            return
        self._tables_dirty = False
        t = jnp.asarray(self._page_table_h)
        csh = self._shardings.get("cache")
        if csh is not None:
            t = jax.device_put(t, csh["table"])
        self.cache["table"] = t
        if self.mask_alloc is not None:
            mt = jnp.asarray(self._mask_table_h)
            msh = self._shardings.get("masks")
            if msh is not None:
                mt = jax.device_put(mt, msh["table"])
            self.masks["table"] = mt

    def _slot_color(self, slot: int) -> int:
        """Data-shard index of a slot — the allocator color that keeps its
        pages on the shard that owns the slot."""
        if self.page_alloc is None or self.page_alloc.n_colors == 1:
            return 0
        return slot * self.page_alloc.n_colors // self.n_slots

    def _pages_for(self, length: int) -> int:
        return PG.pages_needed(length, self.page_size) if self._paged else 0

    def _reserve_resources(self, reqs: List[Request],
                           slots: List[int]) -> List[Request]:
        """Claim a mask entry + prompt-covering pages for each admission
        candidate; requests the pool can't hold yet go back to the FRONT of
        the scheduler queue (admission never preempts running requests —
        only page growth for already-running slots does)."""
        kept: List[Request] = []
        for k, r in enumerate(reqs):
            try:
                if self.mask_alloc is not None:
                    self.mask_alloc.alloc(1, r.uid)
                # pages must cover the hydrated prefix rows too — resolved
                # host-side from the store BEFORE hydration (a profile that
                # later degrades resolves to 0 here as well)
                need = self._pages_for(self._req_prefix_len(r)
                                       + len(r.prompt))
                if need:
                    try:
                        self.page_alloc.alloc(need, r.uid,
                                              color=self._slot_color(
                                                  slots[k]))
                    except PG.PageOOM:
                        if self.mask_alloc is not None:
                            self.mask_alloc.free_owner(r.uid)
                        raise
            except PG.PageOOM:
                self.scheduler.requeue_front(reqs[k:])
                break
            kept.append(r)
        return kept

    def _release_request(self, slot: int, req: Request) -> None:
        """Free a retired request's pages + mask entry and sentinel its
        table rows (pushed to device at the next table commit; the slot is
        already inactive on device, so its writes drop either way)."""
        if self._paged:
            self.page_alloc.free_owner(req.uid)
            self._page_table_h[slot] = self._sentinel
            self._tables_dirty = True
        if self.mask_alloc is not None:
            self.mask_alloc.free_owner(req.uid)
            self._mask_table_h[slot] = self.n_mask_entries
            self._tables_dirty = True
        # the freed slot is inactive on device, so its (stale) mask-view
        # row is never read — no view refresh on the retirement path

    def _preempt_slot(self, slot: int) -> None:
        """Swap a running request out to host (pages + mask record +
        host-reconstructible slot scalars), free its device resources, and
        queue it for resume. Swap, not recompute: the saved pages come back
        bit-identical, so a preempted request's tokens cannot drift."""
        r = self.slot_req[slot]
        rows = jax.device_get(self._extract_cb(
            self.cache["data"], jnp.asarray(self._page_table_h[slot]), slot))
        mask_row = None
        if self.mask_alloc is not None:
            entry = self.mask_alloc.pages_of(r.uid)[0]
            mask_row = jax.device_get(
                self._extract_mask(self.masks["pool"], entry))
        self._resume_q.append({
            "req": r, "rows": rows, "mask": mask_row,
            "len": self._rlen(r) + len(r.generated) - 1,
            "seq": self._slot_seq[slot],
            "degraded": self.slot_degraded[slot]})
        self._release_request(slot, r)
        hot = np.zeros((self.n_slots,), bool)
        hot[slot] = True
        self.slots.deactivate(hot)
        self.slot_req[slot] = None
        self.slot_degraded[slot] = False
        r.preemptions += 1
        self.preemptions += 1
        self.obs.tracer.instant(TR.CAT_PREEMPT, "preempt", slot=slot,
                                uid=r.uid)
        self.obs.metrics.inc("serve.preemptions")

    def _youngest_live(self, but: int) -> Optional[int]:
        """Preemption victim: the most recently admitted live slot other
        than `but` (LIFO preemption keeps the oldest work finishing)."""
        live = [(self._slot_seq[i], i)
                for i, r in enumerate(self.slot_req)
                if r is not None and i != but]
        return max(live)[1] if live else None

    def _try_resume(self) -> int:
        """Restore preempted requests (oldest first) into free slots while
        pages + entries allow. A blocked head blocks the queue — resumes
        never leapfrog, so preemption stays starvation-free."""
        n = 0
        while self._resume_q and self.free_slots():
            snap = self._resume_q[0]
            r = snap["req"]
            slot = self.free_slots()[0]
            try:
                if self.mask_alloc is not None:
                    self.mask_alloc.alloc(1, r.uid)
                need = self._pages_for(snap["len"])
                if need:
                    try:
                        self.page_alloc.alloc(
                            need, r.uid, color=self._slot_color(slot))
                    except PG.PageOOM:
                        if self.mask_alloc is not None:
                            self.mask_alloc.free_owner(r.uid)
                        raise
            except PG.PageOOM:
                break
            self._resume_q.pop(0)
            if self._paged:
                pages = self.page_alloc.pages_of(r.uid)
                self._page_table_h[slot] = self._sentinel
                self._page_table_h[slot, :len(pages)] = pages
            if self.mask_alloc is not None:
                entry = self.mask_alloc.pages_of(r.uid)[0]
                self._mask_table_h[slot] = entry
                self._view_dirty = True
            self._tables_dirty = True
            self._push_tables()
            self.cache["data"] = self._restore_cb(
                self.cache["data"],
                jax.tree.map(jnp.asarray, snap["rows"]),
                jnp.asarray(self._page_table_h[slot]), slot)
            if snap["mask"] is not None:
                row = jax.tree.map(lambda x: jnp.asarray(x)[None],
                                   snap["mask"])
                self.masks["pool"] = self._scatter_pool(
                    self.masks["pool"], jnp.asarray([entry]), row)
            self.slots.restore([slot], [r.generated[-1]], [snap["len"]],
                               [len(r.generated)], [r.max_new_tokens])
            self.slot_req[slot] = r
            self.slot_degraded[slot] = snap["degraded"]
            self._slot_seq[slot] = snap["seq"]
            self.resumes += 1
            self.obs.tracer.instant(TR.CAT_PREEMPT, "resume", slot=slot,
                                    uid=r.uid)
            self.obs.metrics.inc("serve.resumes")
            n += 1
        return n

    def _ensure_window_pages(self, window: int) -> None:
        """Grow every live slot's allocation to cover the next `window`
        decode writes, oldest slot first; on pool exhaustion the YOUNGEST
        live slot is preempted-to-pending and its pages reused. Init
        guarantees the pool holds one max-length request, so the oldest
        slot always makes progress — no deadlock, no starvation."""
        if not self._paged:
            return
        for _, i in sorted((self._slot_seq[i], i)
                           for i, r in enumerate(self.slot_req)
                           if r is not None):
            r = self.slot_req[i]
            if r is None:
                continue  # preempted by an earlier iteration
            cur = self._rlen(r) + len(r.generated) - 1
            need = PG.pages_needed(min(cur + window, self.S - 1),
                                   self.page_size)
            while need > len(self.page_alloc.pages_of(r.uid)):
                have = len(self.page_alloc.pages_of(r.uid))
                try:
                    new = self.page_alloc.alloc(need - have, r.uid,
                                                color=self._slot_color(i))
                    self._page_table_h[i, have:need] = new
                    self._tables_dirty = True
                except PG.PageOOM:
                    victim = self._youngest_live(but=i)
                    if victim is None:
                        raise  # can't happen: pool >= one full request
                    self._preempt_slot(victim)

    # ------------------------------------------------------------ resilience
    def _zero_entry(self):
        """One request's bare-PLM hydration entry: the free-slot buffer
        template (all-zero masks, identity LN). A zero adapter is the
        EXACT bare PLM — LN(0)·0 @ B̂ contributes 0 to the residual —
        so a degraded request decodes as if X-PEFT were disabled."""
        pool = self.masks["pool"] if self.continuous else self.masks
        zero = {k: jnp.zeros(v.shape[1:], v.dtype) for k, v in pool.items()}
        if "ln_scale" in zero:
            zero["ln_scale"] = jnp.ones_like(zero["ln_scale"])
        if self.prefix_len:
            # zero prefix ROWS complete the entry layout; a degraded
            # request admits with prefix_len 0 (prompt at buffer slot 0),
            # so these rows are never even written to its cache
            dt = jnp.dtype(self.cfg.dtype)
            shape = (self.cfg.num_layers, self.prefix_len, self.cfg.kv_dim)
            zero["prefix_k"] = jnp.zeros(shape, dt)
            zero["prefix_v"] = jnp.zeros(shape, dt)
        return zero

    def _rlen(self, r) -> int:
        """Device-buffer length of a request's prompt region: hydrated
        prefix rows + prompt tokens (every capacity/termination site must
        budget the prefix rows a request's cache actually holds)."""
        return getattr(r, "prefix_len", 0) + len(r.prompt)

    def _req_prefix_len(self, r) -> int:
        """Pre-hydration host-side prefix length of a request: P when its
        profile's hard masks select any prefix-segment slot, else 0 (a
        profile that never touches the prefix segment trains and serves
        at bare positions — bitwise, not just RoPE-shift-equivalent)."""
        if not self.prefix_len or getattr(r, "degraded", False):
            return 0
        try:
            ia, _, ib, _ = self.store.sparse_indices(int(r.profile_id))
        except Exception:
            return 0  # missing/corrupt record: the probe will degrade it
        off, cnt = self._prefix_seg
        ia, ib = np.asarray(ia), np.asarray(ib)
        hit = ((ia >= off) & (ia < off + cnt)).any() \
            or ((ib >= off) & (ib < off + cnt)).any()
        return self.prefix_len if hit else 0

    def _probe_profile(self, pid: int) -> bool:
        """Pre-hydration health probe for one profile, with retry.

        Transient (injected) hydration failures are retried under the
        engine's deadline-bounded backoff policy; a persistent failure, a
        quarantined/corrupt record, or a missing pid returns False — the
        caller degrades those requests to the bare PLM. `check_record`
        may legally shed a corrupt quantized agg payload here; that still
        probes True (the sparse path re-hydrates the intact masks)."""
        attempt = [0]

        def probe():
            i, attempt[0] = attempt[0], attempt[0] + 1
            if self.fault_plan is not None:
                self.fault_plan.on_hydration(pid, i)
            self.store.check_record(pid)

        def on_retry(exc, a, delay):
            self.hydration_retries += 1
            self.obs.metrics.inc("serve.hydration_retries")
            self.obs.metrics.observe("serve.hydration_retry_delay_us",
                                     delay * 1e6, "us")
            self.obs.tracer.instant(TR.CAT_RESILIENCE, "hydration_retry",
                                    profile=pid, attempt=a)

        try:
            retry_with_backoff(probe, policy=self.retry_policy,
                               retry_on=(InjectedHydrationError,),
                               seed=pid, on_retry=on_retry)
            return True
        except (InjectedHydrationError, RecordIntegrityError, KeyError):
            return False

    def _probe_wave(self, reqs: List[Request]) -> None:
        """Mark requests whose profile cannot be served as degraded
        (probed once per unique pid per wave)."""
        verdict = {}
        for r in reqs:
            pid = int(r.profile_id)
            if pid not in verdict:
                verdict[pid] = self._probe_profile(pid)
            if not verdict[pid] and not r.degraded:
                r.degraded = True
                self.degraded_requests += 1
                self.obs.metrics.inc("serve.degraded_requests")
                self.obs.tracer.instant(TR.CAT_RESILIENCE, "degraded",
                                        profile=pid, uid=r.uid)

    # ------------------------------------------------------------- hydration
    def _hydrate_stacked(self, reqs: List[Request]):
        """Stacked [R, ...] mask-row tree for an admission wave (or None).

        precompute=True: profile-cache lookups first; only missing profiles
        hit the bank, in ONE jitted aggregation padded to a pow2 count.
        precompute=False (paper-faithful): per-step mask weights hydrated
        through the store's public batch API; no cache involved.
        """
        if self.masks is None:
            return None
        R = len(reqs)
        pids = [int(r.profile_id) for r in reqs]
        if not self.precompute:
            ok_idx = [i for i, r in enumerate(reqs) if not r.degraded]
            if ok_idx:
                wa, wb, ls, lb = self.store.batch_mask_weights(
                    [pids[i] for i in ok_idx])
            zero = self._zero_entry()
            rows = [dict(zero) for _ in range(R)]
            for j, i in enumerate(ok_idx):
                rows[i] = {"w_a": wa[j], "w_b": wb[j],
                           "ln_scale": ls[j], "ln_bias": lb[j]}
            self.last_admission = {"path": "per_step", "requests": R,
                                   "cache_hits": 0,
                                   "cache_misses": len(ok_idx),
                                   "degraded": R - len(ok_idx),
                                   "bank_bytes_per_request": 0}
            return {key: jnp.stack([row[key] for row in rows])
                    for key in ("w_a", "w_b", "ln_scale", "ln_bias")}
        if self.quant != "none":
            return self._hydrate_stacked_quant(reqs, pids)

        entries = {}
        hits = misses = 0
        missing: List[int] = []  # unique uncached pids, admission order
        for pid, r in zip(pids, reqs):
            if r.degraded:
                continue  # bare-PLM entry; never cached, never aggregated
            entry = self.profile_cache.get(pid)
            if entry is not None:
                hits += 1
                entries[pid] = entry
            else:
                misses += 1
                if pid not in missing:
                    missing.append(pid)

        from repro.analysis.bytes import bank_slice_bytes
        bank = self.params["xpeft_bank"]
        L = self.cfg.num_layers
        N = self.cfg.xpeft.num_adapters
        if self.hetero:
            # average bytes of one unified-space (layer, slot) row across
            # the typed segments — what one k-sparse selection reads
            slice_bytes = sum(int(v.nbytes) for v in bank.values()) \
                // (L * N)
        else:
            d_, b_ = bank["bank_a"].shape[2], bank["bank_a"].shape[3]
            # Â+B̂ bytes per (layer, adapter) row — the shared analytic
            # helper (benchmarks consume it too, so gates can't drift)
            slice_bytes = bank_slice_bytes(
                d_, b_, itemsize=bank["bank_a"].dtype.itemsize)
        bank_bytes = 0
        aggregated = 0
        if missing:
            M = len(missing)
            Mp = pow2_count(M)
            aggregated = Mp
            if self.store.mask_type == "hard":
                # k-sparse fast path: only the top-k bank rows are read
                ia, wa, ib, wb = self.store.batch_sparse_indices(missing)
                pad_i = jnp.zeros((Mp - M,) + ia.shape[1:], ia.dtype)
                pad_w = jnp.zeros((Mp - M,) + wa.shape[1:], wa.dtype)
                agg = self._aggregate_sparse(
                    bank, jnp.concatenate([ia, pad_i]),
                    jnp.concatenate([wa, pad_w]),
                    jnp.concatenate([ib, pad_i]),
                    jnp.concatenate([wb, pad_w]))
                if not self.hetero:
                    agg = {"a_hat": agg[0], "b_hat": agg[1]}
                k = ia.shape[-1]
                path = "sparse"
                bank_bytes = Mp * k * L * slice_bytes
                ln_s, ln_b = self.store.ln_affines(missing)
                skip = on = None
                if self.prefix_len:
                    # host-side per-layer prefix gate from the SAME top-k
                    # indices the device aggregation consumed: a selected
                    # index carries weight 1/k > 0, so idx-in-segment is
                    # exactly wsum > 0
                    off, cnt = self._prefix_seg
                    ia_h, ib_h = np.asarray(ia), np.asarray(ib)
                    valid = (((ia_h >= off) & (ia_h < off + cnt)).any(-1)
                             | ((ib_h >= off) & (ib_h < off + cnt)).any(-1))
                    on = valid.any(-1)                       # [M]
                    skip = np.where(valid, 0,
                                    self.prefix_len).astype(np.int32)
            else:
                # soft masks are dense by construction; the jitted einsum
                # reads the bank once per call, amortized over the batch
                # (hetero precompute serving is hard-mask only — ctor)
                wa, wb, ln_s, ln_b = self.store.batch_mask_weights(missing)
                pad_w = jnp.zeros((Mp - M,) + wa.shape[1:], wa.dtype)
                a_hat, b_hat = self._aggregate_dense(
                    bank, jnp.concatenate([wa, pad_w]),
                    jnp.concatenate([wb, pad_w]))
                agg = {"a_hat": a_hat, "b_hat": b_hat}
                path = "dense"
                bank_bytes = N * L * slice_bytes
                skip = on = None
            for i, pid in enumerate(missing):
                entry = {}
                for key in self._entry_keys:
                    if key == "ln_scale":
                        entry[key] = ln_s[i]
                    elif key == "ln_bias":
                        entry[key] = ln_b[i]
                    elif key == "prefix_skip":
                        entry[key] = skip[i] if on[i] \
                            else np.zeros((L,), np.int32)
                    else:
                        entry[key] = agg[key][i]
                if self.prefix_len:
                    entry["prefix_on"] = np.int32(bool(on[i]))
                self.profile_cache.put(pid, entry)
                entries[pid] = entry
        else:
            path = "cached"

        if self.prefix_len:
            for pid, r in zip(pids, reqs):
                ent = None if r.degraded else entries.get(pid)
                r.prefix_len = 0 if ent is None \
                    else self.prefix_len * int(ent["prefix_on"])
        self.last_admission = {
            "path": path, "requests": R, "cache_hits": hits,
            "cache_misses": misses, "unique_profiles": len(set(pids)),
            "aggregated_profiles": aggregated,
            "degraded": sum(r.degraded for r in reqs),
            "bank_bytes_per_request": bank_bytes // R}
        zero = self._zero_entry()
        return {key: jnp.stack([zero[key] if r.degraded
                                else entries[pid][key]
                                for pid, r in zip(pids, reqs)])
                for key in self._entry_keys}

    def _hydrate_stacked_quant(self, reqs: List[Request], pids: List[int]):
        """Quantized-bank hydration: cache hits first; missing profiles
        hydrate from the store's persisted quantized Â/B̂ records when
        available (ZERO bank reads), else aggregate k-sparse against the
        quantized bank (dequant-in-register kernel) and re-quantize the
        fresh rows. Entries/slot buffers always hold the quantized record
        layout {a_q, a_scale, b_q, b_scale, ln_scale, ln_bias}."""
        R = len(reqs)
        entries = {}
        hits = misses = 0
        missing: List[int] = []  # unique uncached pids, admission order
        for pid, r in zip(pids, reqs):
            if r.degraded:
                continue  # bare-PLM entry; never cached, never aggregated
            entry = self.profile_cache.get(pid)
            if entry is not None:
                hits += 1
                entries[pid] = entry
            else:
                misses += 1
                if pid not in missing:
                    missing.append(pid)

        xp = self.cfg.xpeft
        L = self.cfg.num_layers
        bank_bytes = 0
        aggregated = 0
        store_hydrated = 0
        if missing:
            # persisted quantized records are usable only when the store's
            # scheme matches the engine's buffer layout
            rec_ok = (self.store.quant == self.quant
                      and self.store.quant_group == xp.quant_group)
            rec_pids = [p for p in missing
                        if rec_ok and self.store.has_quant_record(p)]
            agg_pids = [p for p in missing if p not in rec_pids]
            if agg_pids:
                M = len(agg_pids)
                Mp = pow2_count(M)
                aggregated = Mp
                ia, wa, ib, wb = self.store.batch_sparse_indices(agg_pids)
                pad_i = jnp.zeros((Mp - M,) + ia.shape[1:], ia.dtype)
                pad_w = jnp.zeros((Mp - M,) + wa.shape[1:], wa.dtype)
                a_hat, b_hat = self._aggregate_sparse_quant(
                    self.qbank, jnp.concatenate([ia, pad_i]),
                    jnp.concatenate([wa, pad_w]),
                    jnp.concatenate([ib, pad_i]),
                    jnp.concatenate([wb, pad_w]))
                q = self._requantize(a_hat, b_hat)
                k = ia.shape[-1]
                # TRUE quantized row bytes actually streamed from HBM
                bank_bytes = Mp * k * L * self._qrow_bytes
                ln_s, ln_b = self.store.ln_affines(agg_pids)
                for i, pid in enumerate(agg_pids):
                    entry = {"a_q": q["a_q"][i], "a_scale": q["a_scale"][i],
                             "b_q": q["b_q"][i], "b_scale": q["b_scale"][i],
                             "ln_scale": ln_s[i], "ln_bias": ln_b[i]}
                    self.profile_cache.put(pid, entry)
                    entries[pid] = entry
            if rec_pids:
                store_hydrated = len(rec_pids)
                recs = self.store.quant_records(rec_pids)
                ln_s, ln_b = self.store.ln_affines(rec_pids)
                for i, pid in enumerate(rec_pids):
                    entry = {key: recs[key][i] for key in
                             ("a_q", "a_scale", "b_q", "b_scale")}
                    entry["ln_scale"] = ln_s[i]
                    entry["ln_bias"] = ln_b[i]
                    self.profile_cache.put(pid, entry)
                    entries[pid] = entry
            if agg_pids and rec_pids:
                path = "quant_mixed"
            elif agg_pids:
                path = "quant_sparse"
            else:
                path = "quant_store"
        else:
            path = "cached"

        self.last_admission = {
            "path": path, "requests": R, "cache_hits": hits,
            "cache_misses": misses, "unique_profiles": len(set(pids)),
            "aggregated_profiles": aggregated,
            "store_hydrated_profiles": store_hydrated,
            "scheme": self.quant,
            "degraded": sum(r.degraded for r in reqs),
            "bank_bytes_per_request": bank_bytes // R}
        zero = self._zero_entry()
        return {key: jnp.stack([zero[key] if r.degraded
                                else entries[pid][key]
                                for pid, r in zip(pids, reqs)])
                for key in ("a_q", "a_scale", "b_q", "b_scale",
                            "ln_scale", "ln_bias")}

    # ---------------------------------------------------------------- public
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def active_count(self) -> int:
        """Host-visible count of occupied slots (refreshed at syncs)."""
        return sum(r is not None for r in self.slot_req)

    def admit_many(self, reqs: List[Request]) -> int:
        """Admit up to len(free_slots()) requests: one cache-aware batched
        hydration, one mask scatter, one prefill per length bucket, one
        slot-state scatter. Returns #admitted."""
        with self.obs.tracer.span(TR.CAT_ADMISSION, "admit_wave",
                                  offered=len(reqs)) as sp:
            n = self._admit_wave(reqs)
            sp["admitted"] = n
        return n

    def _admit_wave(self, reqs: List[Request]) -> int:
        t_wave = time.perf_counter()
        if self.slots.buf_fill:
            self.sync()  # flush the window before touching slot state
        resumed = 0
        if self.continuous and self._resume_q:
            resumed = self._try_resume()  # preempted work outranks fresh
        free = self.free_slots()
        if len(reqs) > len(free):
            # the caller sized the wave to the PRE-sync free count; the
            # sync/resume above may have shrunk it (resumed work outranks
            # fresh) — overflow goes back to the head, never dropped
            self.scheduler.requeue_front(reqs[len(free):])
            reqs = reqs[:len(free)]
        if self.continuous and reqs:
            reqs = self._reserve_resources(reqs, free)
        if not reqs:
            if resumed:
                self._refresh_window()  # resumed slots need window + view
            return 0
        assigned = free[:len(reqs)]
        if self.continuous:
            # commit page/entry tables BEFORE the prefill insert and mask
            # scatter — both address device memory through them
            for r, s in zip(reqs, assigned):
                if self._paged:
                    pages = self.page_alloc.pages_of(r.uid)
                    self._page_table_h[s] = self._sentinel
                    self._page_table_h[s, :len(pages)] = pages
                if self.mask_alloc is not None:
                    self._mask_table_h[s] = \
                        self.mask_alloc.pages_of(r.uid)[0]
                    self._view_dirty = True
                self._slot_seq[s] = self._admit_seq
                self._admit_seq += 1
            self._tables_dirty = True
            self._push_tables()
        if self.masks is not None:
            # health-probe every profile first (with retry): requests whose
            # profile can't be hydrated degrade to the bare PLM below,
            # never failing the wave for their healthy peers
            self._probe_wave(reqs)
        stacked = self._hydrate_stacked(reqs)
        prefix_rows = None
        if stacked is not None and self.prefix_len:
            # prefix KV rows hydrate into the cache at prefill, not into
            # the per-slot mask pool (the pool holds residual-path leaves
            # plus the per-layer skip gate)
            prefix_rows = (stacked.pop("prefix_k"), stacked.pop("prefix_v"))
        slot_of = {id(r): s for r, s in zip(reqs, assigned)}
        if stacked is not None:
            # ONE scatter into the per-slot buffers for the whole wave
            if self.continuous:
                entries = jnp.asarray(
                    [self.mask_alloc.pages_of(r.uid)[0] for r in reqs])
                self.masks["pool"] = self._scatter_pool(
                    self.masks["pool"], entries, stacked)
            else:
                self.masks = self._scatter_masks(
                    self.masks, jnp.asarray(assigned), stacked)

        idx_of = {id(r): i for i, r in enumerate(reqs)}
        groups = self.scheduler.group_by_bucket(reqs)
        next_toks = {}
        for pad, group in sorted(groups.items()):
            B = len(group)
            Bp = pow2_count(B)
            toks = np.zeros((Bp, pad), np.int32)
            lens = np.ones((Bp,), np.int32)
            for j, r in enumerate(group):
                toks[j, :len(r.prompt)] = r.prompt
                lens[j] = len(r.prompt)
            rows = None
            cpos = prows = None
            if stacked is not None:
                sel = jnp.asarray([idx_of[id(r)] for r in group]
                                  + [0] * (Bp - B))
                rows = jax.tree.map(lambda t: t[sel], stacked)
                if prefix_rows is not None:
                    # vector write offset: prompt lands at buffer P for
                    # prefix-on requests, 0 otherwise (one trace; pad rows
                    # use offset 0 and are dropped at insert)
                    cpos = jnp.asarray([r.prefix_len for r in group]
                                       + [0] * (Bp - B), jnp.int32)
                    prows = tuple(t[sel] for t in prefix_rows)
            with self.obs.tracer.span(TR.CAT_PREFILL, f"prefill[{pad}]",
                                      bucket=pad, rows=Bp, real=B):
                nxt, mini = self._prefill(self.params, jnp.asarray(toks),
                                          rows, jnp.asarray(lens), cpos,
                                          prows)
                gslots = jnp.asarray([slot_of[id(r)] for r in group])
                if self.continuous:
                    self.cache["data"] = self._insert_cb(
                        self.cache["data"], mini, gslots,
                        self.cache["table"])
                else:
                    self.cache = self._insert(self.cache, mini, gslots)
                nxt_h = np.asarray(nxt[:B])
            for j, r in enumerate(group):
                next_toks[id(r)] = int(nxt_h[j])
            self.prefill_batches += 1
            self.prefill_rows += Bp
            self.prefill_real += B
        if self.last_admission is not None:
            self.last_admission["prefill_batches"] = len(groups)
            self.last_admission["prefill_occupancy"] = round(
                len(reqs) / max(sum(pow2_count(len(g))
                                    for g in groups.values()), 1), 3)

        if self.obs.enabled:
            # first token exists as of the prefill above: TTFT + admission
            # wait for every submitted-through-the-scheduler request
            # (t_submit=0 means the caller bypassed submit(); skip)
            now = time.perf_counter()
            for r in reqs:
                t_sub = getattr(r, "t_submit", 0.0)
                if t_sub:
                    self.obs.metrics.observe("serve.ttft_us",
                                             (now - t_sub) * 1e6, "us")
                    self.obs.metrics.observe("serve.admission_wait_us",
                                             (t_wave - t_sub) * 1e6, "us")

        # slot lengths INCLUDE the hydrated prefix rows: the slot length is
        # the KV-buffer write position, and decode queries take their RoPE
        # position from it, so prefix-on requests continue at P + prompt
        lens_all = [self._rlen(r) for r in reqs]
        toks_all = [next_toks[id(r)] for r in reqs]
        self.slots.admit(assigned, toks_all, lens_all,
                         [r.max_new_tokens for r in reqs])
        for r, slot in zip(reqs, assigned):
            r.generated.append(next_toks[id(r)])
            if r.max_new_tokens <= 1 or self._rlen(r) >= self.S - 1:
                r.done = True  # budget spent by the prefill token
                if self.continuous:
                    self._release_request(slot, r)
            else:
                self.slot_req[slot] = r
                self.slot_degraded[slot] = r.degraded
        self._refresh_window()
        return len(reqs)

    def admit(self, req: Request) -> bool:
        return self.admit_many([req]) == 1

    def step(self) -> int:
        """One device decode step for all slots. Host state refreshes only
        at the `sync_every` cadence; returns the host-visible active count
        as of the last sync (an upper bound on live slots)."""
        active = self.active_count()
        if not active:
            return 0
        masks = self._masks_view if self.continuous else self.masks
        if self.spec and masks is not None:
            masks = {"adapted": masks, "zero": self._zero_view}
        self.cache = self.slots.step(self.params, self.cache, masks)
        if self.slots.buf_fill >= self._window:
            self.sync()
        return active

    def sync(self) -> int:
        """Force a device→host sync: distribute the window's tokens to
        their requests, mark finished requests done, free their slots (and,
        continuous mode, their pages/entries — then resume preempted work
        into the freed capacity). Returns the number of still-active
        slots."""
        s = self.slots.sync()
        if s.fill:
            # capacity accounting: an occupied slot that emitted fewer
            # tokens than the window stepped idled the difference
            # (stranded between finish and refill); an EMPTY slot strands
            # the whole window whenever work was waiting for it
            for i, req in enumerate(self.slot_req):
                c = int(s.counts[i])
                self.useful_slot_steps += c
                if req is not None:
                    # spec rounds commit up to W tokens per step, so only
                    # fully idle rounds count as stranded (max keeps the
                    # non-spec arithmetic untouched: c <= fill there)
                    self.stranded_slot_steps += max(s.fill - c, 0)
                elif self._backlog:
                    self.stranded_slot_steps += s.fill
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            c = int(s.counts[i])
            if c:
                toks = s.tokens[i, :c]
                assert (toks >= 0).all(), "non-contiguous slot activity"
                req.generated.extend(int(t) for t in toks)
                self.decode_tokens += c
            if s.drafted is not None and int(s.drafted[i]):
                d, a = int(s.drafted[i]), int(s.accepted[i])
                self.spec_drafted += d
                self.spec_accepted += a
                rec = self._spec_by_uid.setdefault(req.uid, [0, 0])
                rec[0] += d
                rec[1] += a
            if not s.active[i]:
                req.done = True
                self.slot_req[i] = None
                self.slot_degraded[i] = False
                if self.continuous:
                    self._release_request(i, req)
        self._flush_obs(s)
        if self.continuous and self._resume_q:
            self._try_resume()
        self._refresh_window()
        return self.active_count()

    def _flush_obs(self, s) -> None:
        """Observability flush at the sync boundary — the ONLY place decode
        metrics touch the host, and only on data the sync's single
        device_get already moved (s.obs is the device accumulator's window
        delta). Zero extra syncs per token by construction."""
        now = time.perf_counter()
        if s.fill and self.obs.enabled:
            acc = s.obs
            toks = int(acc[:, OBS.OBS_TOKENS].sum())
            m = self.obs.metrics
            m.inc("serve.decode_tokens", toks)
            m.inc("serve.device_steps", s.fill)
            m.inc("serve.active_slot_steps",
                  int(acc[:, OBS.OBS_ACTIVE_STEPS].sum()))
            m.inc("serve.stranded_slot_steps",
                  int(acc[:, OBS.OBS_STRANDED_STEPS].sum()))
            elapsed = now - self._win_t0
            if toks:
                # mean host-side per-token latency over this window (the
                # finest granularity observable without per-token syncs)
                m.observe("serve.decode_token_us", elapsed / toks * 1e6,
                          "us")
            m.observe("serve.queue_depth", self.scheduler.pending(), "reqs")
            m.set_gauge("serve.queue_depth_now", self.scheduler.pending())
            self.obs.tracer.complete(TR.CAT_DECODE_WINDOW, "decode_window",
                                     self._win_t0, now, steps=s.fill,
                                     tokens=toks)
            if s.drafted is not None:
                d, a = int(s.drafted.sum()), int(s.accepted.sum())
                if d:
                    m.inc("serve.spec_drafted", d)
                    m.inc("serve.spec_accepted", a)
                    m.observe("serve.spec_accept_rate", a / d, "ratio")
                    self.obs.tracer.instant(TR.CAT_SPEC, "spec_window",
                                            drafted=d, accepted=a,
                                            rounds=s.fill)
        self.obs.sentinel.check()
        self._win_t0 = now

    def _refresh_window(self) -> None:
        # device capacity stop is lengths >= S-1 post-increment with
        # lengths = prompt + generated - 1, so a slot can still emit
        # S - prompt - generated tokens (not S-1 - ...). Windowed mode
        # bounds the window by the MAX remaining (don't dead-step after
        # everyone finished); continuous mode by the MIN remaining — greedy
        # decode retires deterministically, so the sync lands exactly when
        # the first slot frees and its capacity turns over immediately.
        remaining = [min(r.max_new_tokens - len(r.generated),
                         self.S - self._rlen(r) - len(r.generated))
                     for r in self.slot_req if r is not None]
        if self.continuous:
            bound = min(remaining) if remaining else self.sync_every
        else:
            bound = max(remaining) if remaining else self.sync_every
        # spec mode windows count ROUNDS (up to W tokens each): the first
        # retirement can land after as few as ceil(bound / W) rounds, so
        # the sync bound shrinks accordingly (an early sync just costs one
        # host round-trip; a late one would strand the freed slot)
        W = self.spec_gamma + 1 if self.spec else 1
        self._window = max(1, min(self.sync_every, -(-bound // W)))
        if self.continuous:
            # page growth must cover every position the window can WRITE —
            # rounds x W tokens (draft + verify spans), not rounds tokens
            self._ensure_window_pages(self._window * W)
            self._push_tables()
            if self.masks is not None and self._view_dirty:
                self._view_dirty = False
                self._masks_view = self._gather_mask_view(
                    self.masks["pool"], self.masks["table"])
        self._backlog = bool(self.scheduler.pending() or self._resume_q)

    def submit(self, reqs) -> None:
        """Queue requests with the scheduler (admitted as slots free up)."""
        self.scheduler.submit(reqs)

    def invalidate_profile(self, pid: int) -> bool:
        """Drop a profile's cached Â/B̂ — REQUIRED after re-training updates
        its masks in the store (cache entries are keyed by pid alone, so a
        stale entry would otherwise keep serving the old adapters forever).
        The engine subscribes this hook to its store at construction, so
        `ProfileStore.add_profile` / `merge_from` (the graduation and
        resume-merge paths) invalidate automatically. Already-admitted
        slots finish on their scattered copy of the OLD masks; the next
        admission of the pid re-aggregates from the updated store."""
        return self.profile_cache.invalidate(pid)

    def abort_all(self) -> None:
        """Abort every in-flight request (tokens already decoded are kept);
        slots become free, caches/masks are left to be overwritten."""
        if self.slots.buf_fill:
            self.sync()
        self.slots.deactivate_all()
        for i, req in enumerate(self.slot_req):
            if req is not None:
                req.done = True
                self.slot_req[i] = None
                if self.continuous:
                    self._release_request(i, req)
            self.slot_degraded[i] = False
        for snap in self._resume_q:
            snap["req"].done = True  # preempted work aborts too
        self._resume_q.clear()
        self._refresh_window()

    def run_until_drained(self, queue: Optional[List[Request]] = None,
                          max_steps: int = 10_000) -> int:
        """Serve until the queue and all slots are empty. Admission happens
        whenever the host view shows free slots (i.e. after syncs)."""
        if queue:
            self.scheduler.submit(list(queue))
        steps = 0
        while steps < max_steps:
            if self._resume_q and self.free_slots() \
                    and self.slots.buf_fill == 0:
                # window boundary only: slot restore requires a synced
                # window (slots/pages can only have freed at a sync anyway)
                if self._try_resume():
                    self._refresh_window()
            free = self.free_slots()
            if free and self.scheduler.pending():
                self.admit_many(self.scheduler.next_batch(len(free)))
            if not self.active_count():
                if not self.scheduler.pending() and not self._resume_q:
                    break
                continue  # admission freed nothing; next wave will
            self.step()
            steps += 1
        if self.slots.buf_fill:
            self.sync()
        return steps

    def resident_bytes_per_device(self) -> dict:
        """Analytic per-device resident bytes of the engine's device state
        (params / KV cache / mask buffers) under the active sharding —
        identical to total bytes on a single device. serve_bench emits this
        so memory planning tracks the mesh, not the global shapes."""
        from repro.analysis.bytes import tree_nbytes
        from repro.distributed.sharding import sharded_bytes_per_device
        trees = {"params": self.params, "cache": self.cache}
        if self.qbank is not None:
            trees["qbank"] = self.qbank
        if self.masks is not None:
            trees["masks"] = self.masks
        out = {}
        for name, tree in trees.items():
            if self.mesh is None:
                out[name] = tree_nbytes(tree)
            else:
                out[name] = sharded_bytes_per_device(
                    tree, self._specs[name], self.mesh)
        out["total"] = sum(out.values())
        return out

    def reset_stats(self) -> None:
        """Zero every accounting counter PRs 2-9 accumulated piecemeal in
        __init__ (decode/prefill/spec/preempt/resilience, scheduler, the
        profile cache's hit/miss/byte counters, page allocators, host
        syncs) in ONE call — e.g. to measure steady state after warmup.
        Deliberately untouched: in-flight requests, caches/pools, and the
        compile-cache trace counters (`step_traces` etc.), which count
        compilations, not events in a measurement window."""
        self.decode_tokens = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self._spec_by_uid.clear()
        self.prefill_batches = 0
        self.prefill_rows = 0
        self.prefill_real = 0
        self.preemptions = 0
        self.resumes = 0
        self.useful_slot_steps = 0
        self.stranded_slot_steps = 0
        self.degraded_requests = 0
        self.hydration_retries = 0
        self.last_admission = None
        self.slots.reset_counters()
        self.scheduler.reset_stats()
        self.profile_cache.reset_stats()
        if self.page_alloc is not None:
            self.page_alloc.reset_stats()
        if self.mask_alloc is not None:
            self.mask_alloc.reset_stats()
        self.obs.metrics.reset()

    def serve_stats(self) -> dict:
        """Counters the bench reports (and operators can scrape)."""
        out = {
            "mode": "continuous" if self.continuous else "windowed",
            "devices": 1 if self.mesh is None else self.mesh.size,
            "bank_quant": self.quant,
            # capacity accounting: slot_occupancy = share of slot-steps
            # that emitted a token; stranded_slot_steps = active-capable
            # slot-steps idled between a finish and the refill (the number
            # continuous batching exists to drive to ~0)
            "useful_slot_steps": self.useful_slot_steps,
            "stranded_slot_steps": self.stranded_slot_steps,
            "slot_occupancy": _rate(
                self.useful_slot_steps,
                self.n_slots * self.slots.device_steps),
            "step_traces": self.slots.step_traces,
            "resident_bytes_per_device": self.resident_bytes_per_device(),
            "host_syncs": self.slots.host_syncs,
            "device_steps": self.slots.device_steps,
            "decode_tokens": self.decode_tokens,
            # committed tokens vs device decode steps: equal for plain
            # decode, committed > steps is the speculation win
            "committed_tokens": self.decode_tokens,
            "committed_per_device_step": _rate(self.decode_tokens,
                                               self.slots.device_steps),
            "syncs_per_token": _rate(self.slots.host_syncs,
                                     self.decode_tokens),
            "sync_every": self.sync_every,
            "prefill_batches": self.prefill_batches,
            "prefill_occupancy": _rate(self.prefill_real,
                                       self.prefill_rows),
            "profile_cache": self.profile_cache.stats(),
            "scheduler": self.scheduler.stats(),
            # resilience surface: how often serving fell back to the bare
            # PLM, how hard hydration had to retry, and what the store has
            # quarantined — the operator's first look under chaos
            "degraded_requests": self.degraded_requests,
            "degraded_slots": sum(self.slot_degraded),
            "hydration_retries": self.hydration_retries,
            "quarantined_profiles": len(self.store.quarantined_ids()),
            "store_integrity": self.store.integrity_stats(),
        }
        if self.spec:
            out["spec"] = {
                "gamma": self.spec_gamma,
                "drafted": self.spec_drafted,
                "accepted": self.spec_accepted,
                "acceptance_rate": _rate(self.spec_accepted,
                                         self.spec_drafted),
                "committed_per_device_step": _rate(
                    self.decode_tokens, self.slots.device_steps),
                # per-request acceptance (uid-keyed; survives preemption)
                "per_request_acceptance": {
                    uid: _rate(a, d)
                    for uid, (d, a) in sorted(self._spec_by_uid.items())},
            }
        if self.continuous:
            out["preemptions"] = self.preemptions
            out["resumes"] = self.resumes
            out["resume_pending"] = len(self._resume_q)
            out["page_size"] = self.page_size
            if self.page_alloc is not None:
                out["pages"] = self.page_alloc.stats()
            if self.mask_alloc is not None:
                out["mask_entries"] = self.mask_alloc.stats()
        return out
