from repro.serve.engine import ServeEngine, Request  # noqa: F401
from repro.serve.steps import make_prefill_step, make_decode_step  # noqa: F401
