from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.scheduler import Request, Scheduler  # noqa: F401
from repro.serve.slots import SlotState, SlotSync  # noqa: F401
from repro.serve.profile_cache import ProfileCache  # noqa: F401
from repro.serve.steps import make_prefill_step, make_decode_step  # noqa: F401
from repro.serve.pages import (  # noqa: F401
    PageAllocator, PageOOM, pages_needed, paged_seq_len,
    make_paged_cache, dense_view,
)
