"""Block-paged memory pool for the continuous-batching serving engine.

The windowed engine allocates its KV/recurrent cache as one dense
``[lead, n_slots, S, ...]`` block — slot count times max length, whether a
request needs it or not. The continuous engine instead backs every
sequence-axis cache leaf with a pool of fixed-size PAGES
(``[lead, n_pages, page_size, ...]``) plus a per-slot page table, vLLM
style, and applies the same idea to the per-slot adapter state (one mask
"page" = one slot's aggregated Â/B̂ record). Slot count is then decoupled
from max-length allocation: a request holds exactly
``ceil(len/page_size)`` pages, pages free the moment the request retires,
and the scheduler preempts-to-pending when the pool runs dry.

Two layers live here:

- ``PageAllocator`` — HOST bookkeeping: per-color free lists (colors map
  pages to data-mesh shards so a slot's pages stay on its shard), owner
  tracking that makes double-booking structurally impossible, OOM raised
  BEFORE any state mutates, and ``compact()`` for pool-shrink remaps.
- pure jit-friendly DEVICE helpers — ``dense_view`` (page-table gather
  back to the dense ``[lead, B, S, ...]`` layout the model's attention
  already understands, so paged decode is BITWISE identical to the dense
  cache), ``writeback`` (scatter the one decode-written position back to
  its page, dropped for slots whose pages may since be re-owned),
  ``insert_group`` (batched prefill insert), and the per-slot
  extract/restore pair used by preempt/resume swaps.

The sentinel page index is ``n_pages`` (one past the pool): gathers clamp
it to a junk page that attention masks out (positions >= kv_valid), and
scatters use ``mode="drop"`` so a sentinel write never lands — a freed
slot can never corrupt a page it no longer owns.

Recurrent archs (rwkv/mamba) have NO sequence-axis leaves — their state is
O(1) per slot and stays slot-resident. All helpers degenerate gracefully
(the page table is a [n_slots, 1] sentinel column, dense_view is the
identity), so the continuous engine runs unchanged on them: it gets the
mid-stream admission and mask-entry pooling wins without KV paging.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.utils import map_with_path, map_with_paths

# cache leaves with a sequence axis (dim 2 of [lead, B, S, ...]) — the same
# name convention distributed/sharding.cache_specs keys on. Everything else
# (recurrent conv/ssd/wkv state, token-shift carries) has no length axis
# and stays slot-resident.
PAGED_LEAVES = ("k", "v", "attn_k", "attn_v")


def leaf_is_paged(path: str) -> bool:
    return path.rsplit("/", 1)[-1] in PAGED_LEAVES


class PageOOM(RuntimeError):
    """The pool cannot satisfy an allocation. Raised BEFORE any allocator
    state mutates, so a failed alloc never leaks or double-books pages —
    the engine's response is preempt-to-pending (or deferring admission),
    never a corrupted table."""


class PageAllocator:
    """Host-side free-list allocator over ``n_pages`` fixed-size pages.

    ``n_colors`` partitions the pool into contiguous color classes (color
    of page p = ``p * n_colors // n_pages``). ``alloc(color=...)`` prefers
    pages of the caller's color — the engine colors slots by their
    data-mesh shard so a slot's pages land on the shard that owns the
    slot — and falls back to any free page (correctness never depends on
    affinity). Every page tracks its owner; freeing a page you don't own,
    double-freeing, or double-booking raises instead of corrupting.
    """

    def __init__(self, n_pages: int, *, n_colors: int = 1):
        if n_pages <= 0:
            raise ValueError(f"n_pages must be positive, got {n_pages}")
        if not (1 <= n_colors <= n_pages):
            raise ValueError(f"n_colors {n_colors} not in [1, {n_pages}]")
        self.n_pages = n_pages
        self.n_colors = n_colors
        self._owner: Dict[int, object] = {}           # page -> owner
        self._pages_of: Dict[object, List[int]] = {}  # owner -> pages
        # LIFO free stacks per color: recently freed pages are re-used
        # first (their lines are warm)
        self._free: List[List[int]] = [[] for _ in range(n_colors)]
        for p in range(n_pages - 1, -1, -1):
            self._free[self.color_of(p)].append(p)
        self.allocs = 0
        self.frees = 0
        self.oom_events = 0
        self.high_water = 0

    # ------------------------------------------------------------------ query
    def color_of(self, page: int) -> int:
        return page * self.n_colors // self.n_pages

    def used(self) -> int:
        return len(self._owner)

    def free_count(self) -> int:
        return self.n_pages - len(self._owner)

    def owner_of(self, page: int):
        return self._owner.get(page)

    def pages_of(self, owner) -> List[int]:
        return list(self._pages_of.get(owner, ()))

    def owners(self) -> List:
        return list(self._pages_of)

    # ------------------------------------------------------------ alloc/free
    def alloc(self, n: int, owner, *, color: int = 0) -> List[int]:
        """Allocate ``n`` pages for ``owner`` (color-preferring). Raises
        ``PageOOM`` — with the allocator untouched — if fewer than ``n``
        pages are free."""
        if n < 0:
            raise ValueError(f"alloc of {n} pages")
        if n > self.free_count():
            self.oom_events += 1
            raise PageOOM(f"need {n} pages, {self.free_count()} free "
                          f"of {self.n_pages}")
        got: List[int] = []
        order = [color % self.n_colors] + \
            [c for c in range(self.n_colors) if c != color % self.n_colors]
        for c in order:
            while self._free[c] and len(got) < n:
                got.append(self._free[c].pop())
            if len(got) == n:
                break
        assert len(got) == n, "free_count said yes but stacks were short"
        for p in got:
            assert p not in self._owner, f"double-booked page {p}"
            self._owner[p] = owner
        self._pages_of.setdefault(owner, []).extend(got)
        self.allocs += n
        self.high_water = max(self.high_water, self.used())
        return got

    def free(self, pages: List[int], owner) -> None:
        """Return ``pages`` to the pool; every page must belong to
        ``owner`` (ownership is validated BEFORE any page is freed)."""
        for p in pages:
            if self._owner.get(p) != owner:
                raise ValueError(
                    f"page {p} owned by {self._owner.get(p)!r}, "
                    f"not {owner!r} (double free / foreign free)")
        own = self._pages_of.get(owner, [])
        for p in pages:
            del self._owner[p]
            own.remove(p)
            self._free[self.color_of(p)].append(p)
        if owner in self._pages_of and not self._pages_of[owner]:
            del self._pages_of[owner]
        self.frees += len(pages)

    def free_owner(self, owner) -> List[int]:
        """Free every page ``owner`` holds; returns the freed list."""
        pages = self.pages_of(owner)
        if pages:
            self.free(pages, owner)
        return pages

    # -------------------------------------------------------------- compact
    def compact(self) -> Dict[int, int]:
        """Re-pack live pages onto the lowest indices (owner assignment and
        per-owner page ORDER preserved) and rebuild the free lists above
        them. Returns the ``{old_page: new_page}`` remap for the device
        side (`apply_remap`) and any page tables. Used by pool shrinks /
        elastic resizes; an identity remap comes back when already packed."""
        live = sorted(self._owner)
        remap = {old: new for new, old in enumerate(live)}
        self._owner = {remap[p]: o for p, o in self._owner.items()}
        self._pages_of = {o: [remap[p] for p in ps]
                          for o, ps in self._pages_of.items()}
        self._free = [[] for _ in range(self.n_colors)]
        for p in range(self.n_pages - 1, len(live) - 1, -1):
            self._free[self.color_of(p)].append(p)
        return remap

    def check(self) -> None:
        """Invariant audit (tests): owned ∪ free is exactly the pool, with
        no page in both and no duplicates anywhere."""
        free_flat = [p for stack in self._free for p in stack]
        assert len(free_flat) == len(set(free_flat)), "duplicate free page"
        owned = set(self._owner)
        assert not (owned & set(free_flat)), "page both owned and free"
        assert owned | set(free_flat) == set(range(self.n_pages)), \
            "pages leaked from the pool"
        by_owner = [p for ps in self._pages_of.values() for p in ps]
        assert sorted(by_owner) == sorted(owned), "owner index out of sync"

    def reset_stats(self) -> None:
        """Zero the flow counters (engine.reset_stats()); ownership and
        free lists are untouched. `high_water` restarts from the CURRENT
        occupancy — live pages are real occupancy, not history."""
        self.allocs = 0
        self.frees = 0
        self.oom_events = 0
        self.high_water = self.used()

    def stats(self) -> dict:
        return {"n_pages": self.n_pages, "used": self.used(),
                "free": self.free_count(), "high_water": self.high_water,
                "allocs": self.allocs, "frees": self.frees,
                "oom_events": self.oom_events}


# ----------------------------------------------------------------------------
# Device-side helpers (pure functions; call them inside jit)
# ----------------------------------------------------------------------------

def pages_needed(upto_len: int, page_size: int) -> int:
    """Pages covering write positions 0..upto_len-1."""
    return -(-int(upto_len) // page_size)


def paged_seq_len(cache_template) -> int:
    """The (single) sequence length of the template's paged leaves, or 0
    when the arch has none (pure recurrent state)."""
    found = set()
    map_with_path(lambda p, x: found.add(x.shape[2])
                  if leaf_is_paged(p) else None, cache_template)
    assert len(found) <= 1, f"mixed sequence lengths {found}"
    return found.pop() if found else 0


def make_paged_cache(cache_template, n_pages: int, page_size: int,
                     n_slots: int) -> dict:
    """Build the paged cache from a dense-cache template (arrays or
    ShapeDtypeStructs): paged leaves ``[lead, B, S, ...]`` become
    ``[lead, n_pages, page, ...]`` pools, resident leaves keep their dense
    shapes with B = n_slots, plus the sentinel-filled page table. Returns
    ``{"data": tree, "table": [n_slots, S/page] int32}``."""
    S = paged_seq_len(cache_template)
    assert S % page_size == 0, (S, page_size)

    def one(path, leaf):
        if leaf_is_paged(path):
            return jnp.zeros((leaf.shape[0], n_pages, page_size)
                             + tuple(leaf.shape[3:]), leaf.dtype)
        return jnp.zeros(leaf.shape, leaf.dtype)

    mp = max(S // page_size, 1)
    table = jnp.full((n_slots, mp), n_pages, jnp.int32)
    return {"data": map_with_path(one, cache_template), "table": table}


def dense_view(data, table, page_size: int):
    """Gather the paged leaves back to the dense ``[lead, B, S, ...]``
    layout through the page table (sentinel entries clamp to a junk page
    that attention masks out — every junk position is >= kv_valid).
    Resident leaves pass through, so the result is exactly the cache tree
    ``models.forward`` already takes: paged decode stays ONE compiled
    program with bitwise-dense numerics."""
    B, mp = table.shape

    def one(path, leaf):
        if not leaf_is_paged(path):
            return leaf
        v = jnp.take(leaf, table, axis=1, mode="clip")
        return v.reshape((leaf.shape[0], B, mp * page_size)
                         + tuple(leaf.shape[3:]))

    return map_with_path(one, data)


def writeback(data, dense_new, table, lengths, active, page_size: int):
    """Scatter the ONE decode-written position (``lengths[b]``) of every
    paged leaf back into its page; resident leaves take the model's new
    value wholesale. Inactive slots route to the sentinel index and are
    DROPPED — their pad-compute write must never land in a page that may
    since belong to another slot (a retired slot's table row is already
    sentinel, so this is belt and braces)."""
    B = table.shape[0]
    pidx_owned = table[jnp.arange(B), lengths // page_size]
    off = lengths % page_size

    def one(path, pool, new):
        if not leaf_is_paged(path):
            return new
        idx = lengths.reshape((1, B) + (1,) * (new.ndim - 2))
        row = jnp.take_along_axis(new, idx, axis=2)
        row = jnp.squeeze(row, axis=2).astype(pool.dtype)
        pidx = jnp.where(active, pidx_owned, jnp.int32(pool.shape[1]))
        return pool.at[:, pidx, off].set(row, mode="drop")

    return map_with_paths(one, data, dense_new)


def writeback_span(data, dense_new, table, lengths, span: int, active,
                   page_size: int):
    """Scatter ``span`` consecutive written positions per slot
    (``lengths[b] .. lengths[b]+span-1``) back into their pages — the
    speculative round's writeback: one draft+verify round writes gamma+1
    positions at once. Positions past the slot's allocated pages (or past
    S) route to the sentinel and are DROPPED; only positions the engine
    can later COMMIT are guaranteed page-backed (the window sizing does
    that), so a dropped overhang write only costs acceptance, never
    correctness — the next round rewrites those positions anyway."""
    B, mp = table.shape
    pos = lengths[:, None] + jnp.arange(span)             # [B, span]
    page_of = pos // page_size
    in_range = active[:, None] & (page_of < mp)
    pidx_owned = jnp.take_along_axis(table, jnp.clip(page_of, 0, mp - 1),
                                     axis=1)              # [B, span]
    off = pos % page_size

    def one(path, pool, new):
        if not leaf_is_paged(path):
            return new
        idx = pos.reshape((1, B, span) + (1,) * (new.ndim - 3))
        rows = jnp.take_along_axis(new, jnp.clip(idx, 0, new.shape[2] - 1),
                                   axis=2).astype(pool.dtype)
        pidx = jnp.where(in_range, pidx_owned, jnp.int32(pool.shape[1]))
        return pool.at[:, pidx, off].set(rows, mode="drop")

    return map_with_paths(one, data, dense_new)


def insert_group(data, mini, slots, table, page_size: int):
    """Batched prefill insert for one length-bucket group: the stacked
    mini-cache ``[lead, Bp, S, ...]`` chunks into pages and scatters
    through the group's table rows (chunks addressed by sentinel entries —
    pages past a request's current allocation — are dropped; decode fills
    them lazily as the sequence grows). Resident leaves scatter by slot
    index, exactly like the dense engine's insert."""
    B = slots.shape[0]
    pidx = table[slots]                                   # [B, mp]
    mp = pidx.shape[1]

    def one(path, big, small):
        if not leaf_is_paged(path):
            return big.at[:, slots].set(small[:, :B].astype(big.dtype))
        lead, rest = big.shape[0], tuple(big.shape[3:])
        rows = small[:, :B].reshape((lead, B, mp, page_size) + rest)
        return big.at[:, pidx].set(rows.astype(big.dtype), mode="drop")

    return map_with_paths(one, data, mini)


def extract_slot(data, table_row, slot):
    """Gather ONE slot's cache for a preempt-to-host swap: paged leaves as
    ``[lead, mp, page, ...]`` page rows (sentinel entries clamp to junk the
    resume's sentinel-drop then ignores), resident leaves as their
    ``[lead, ...]`` slice."""
    def one(path, leaf):
        if leaf_is_paged(path):
            return jnp.take(leaf, table_row, axis=1, mode="clip")
        return leaf[:, slot]

    return map_with_path(one, data)


def restore_slot(data, rows, table_row, slot):
    """Scatter a preempted slot's swapped cache back in (the resume half of
    ``extract_slot``; sentinel table entries drop their padded rows). The
    new table_row need not equal the one extracted from — pages are
    position-addressed through the table, never by identity."""
    def one(path, big, saved):
        if leaf_is_paged(path):
            return big.at[:, table_row].set(saved.astype(big.dtype),
                                            mode="drop")
        return big.at[:, slot].set(saved.astype(big.dtype))

    return map_with_paths(one, data, rows)


def apply_remap(data, table_h: np.ndarray, remap: Dict[int, int],
                n_pages: int):
    """Apply an allocator ``compact()`` remap to the device pools and the
    HOST page-table mirror: page contents move to their new indices (a
    gather by the inverse permutation), table entries follow through a
    lookup table, sentinels stay sentinel. Returns (data, new_table_h)."""
    perm = np.arange(n_pages)
    for old, new in remap.items():
        perm[old] = new
    inv = np.empty_like(perm)
    inv[perm] = np.arange(n_pages)

    def one(path, leaf):
        if leaf_is_paged(path):
            return jnp.take(leaf, jnp.asarray(inv), axis=1)
        return leaf

    lut = np.concatenate([perm, [n_pages]]).astype(table_h.dtype)
    return map_with_path(one, data), lut[table_h]
