"""Serving steps: prefill (forward + KV cache build) and decode (one token
against the cache). These are the functions the dry-run lowers for the
`prefill_*` / `decode_*` / `long_*` shape cells.

Per-request X-PEFT personalization rides in `profile_masks`; the decode hot
path can instead take admission-time aggregated adapters ("a_hat"/"b_hat"),
removing mask-bank aggregation from the critical path (DESIGN.md §3.4 —
measured in the §Perf hillclimb).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as MDL


def make_prefill_step(cfg):
    def prefill(params, tokens, cache, profile_masks=None,
                prefix_embeds=None):
        hidden, cache, _ = MDL.forward(
            params, tokens, cfg, prefix_embeds=prefix_embeds,
            profile_masks=profile_masks, cache=cache, cache_pos=0)
        logits = MDL.lm_logits(params, hidden[:, -1:, :], cfg)
        return logits, cache
    return prefill


def make_decode_step(cfg):
    def decode(params, tokens, cache, cache_pos, profile_masks=None):
        """tokens [B,1]; cache_pos scalar int32 (current lengths assumed
        uniform; the engine passes per-slot masking via positions)."""
        hidden, cache, _ = MDL.forward(
            params, tokens, cfg, profile_masks=profile_masks,
            cache=cache, cache_pos=cache_pos)
        logits = MDL.lm_logits(params, hidden, cfg)
        return logits, cache
    return decode


def greedy_next(logits):
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
