"""Device-resident decode state for the serving slot batch.

`SlotState` owns everything the per-token loop touches — ``last_tok``,
``lengths``, ``active``, generation counters, and a token ring buffer — as
DEVICE arrays, and advances all of it in ONE jitted step that also decides
per-slot termination on device. The host only sees the state at an explicit
``sync()``: one device→host transfer every ``sync_every`` steps instead of
a round-trip per token, so steady-state decode never blocks on Python.

Invariants the engine relies on:
- activity is contiguous within a sync window: a slot admitted at window
  position 0 emits tokens at buffer positions 0..c-1 and then goes (and
  stays) inactive, so the sync can hand exactly ``n_gen`` deltas of tokens
  to the request without per-step bookkeeping;
- admission/restore must be preceded by a sync (the engine flushes the
  window before touching slot state), so buffers always start a window
  clean;
- the step traces exactly ONCE (``step_traces``): every mutator pins its
  out-shardings, so no admit/retire/preempt cycle can drift a placement
  and recompile the decode program mid-serve.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.obs.metrics import device_acc_init, device_acc_update


def _admit_scatter(arrays, slots, last_toks, lengths, n_gens, max_news,
                   actives):
    """One batched scatter of an admission (or resume) wave into the slot
    arrays. n_gens is 1 for fresh admissions (the prefill token) and the
    already-generated count when restoring a preempted request. Extra
    (speculation) keys pass through, with the per-slot acceptance counters
    reset for the admitted slots."""
    out = dict(arrays)
    out.update({"last_tok": arrays["last_tok"].at[slots].set(last_toks),
                "lengths": arrays["lengths"].at[slots].set(lengths),
                "active": arrays["active"].at[slots].set(actives),
                "n_gen": arrays["n_gen"].at[slots].set(n_gens),
                "max_new": arrays["max_new"].at[slots].set(max_news)})
    if "drafted" in arrays:
        z = jnp.zeros_like(last_toks)
        out["drafted"] = arrays["drafted"].at[slots].set(z)
        out["accepted"] = arrays["accepted"].at[slots].set(z)
    return out


def _deactivate_scatter(arrays, mask):
    """Clear `active` for the masked slots (preemption; fixed [S] shape)."""
    out = dict(arrays)
    out["active"] = arrays["active"] & ~mask
    return out


class SlotSync(NamedTuple):
    """Host view of slot state at a sync point."""
    tokens: np.ndarray       # [n_slots, <=sync_every*W] int32, -1 padded
    counts: np.ndarray       # [n_slots] tokens emitted since last sync
    lengths: np.ndarray      # [n_slots] int32
    active: np.ndarray       # [n_slots] bool
    fill: int                # device steps this window took (stranding calc)
    drafted: Optional[np.ndarray] = None   # [n_slots] spec drafts this window
    accepted: Optional[np.ndarray] = None  # [n_slots] accepted drafts
    obs: Optional[np.ndarray] = None       # [n_slots, OBS_COLS] window deltas


class SlotState:
    """Slot decode state + the single jitted step advancing it.

    decode_fn(params, cache, last_tok [S], lengths [S], masks, active [S])
    -> (next_tok [S], cache) is the model-side half the engine provides
    (`active` lets a paged cache drop writes from slots whose pages were
    re-owned; the dense engine ignores it).

    With a `mesh`, the slot axis shards over the "data" mesh axis
    (`distributed.sharding.leading_axis_specs`) and the jitted step pins
    its out-shardings (slot arrays + the model cache via
    `cache_shardings`), so the same step serves 1 device or an N-device
    GSPMD mesh without retracing — and, because no contraction is ever
    split along the slot axis, with per-slot numerics identical to the
    single-device path.
    """

    def __init__(self, n_slots: int, max_seq: int, sync_every: int,
                 decode_fn: Callable, *, mesh=None, cache_shardings=None,
                 spec_width: int = 1):
        assert sync_every >= 1
        assert spec_width >= 1
        self.n_slots = n_slots
        self.S = max_seq
        self.sync_every = sync_every
        self.spec_width = spec_width  # gamma+1 (speculative), 1 = plain
        self.mesh = mesh
        spec = spec_width > 1
        self.last_tok = jnp.zeros((n_slots,), jnp.int32)
        self.lengths = jnp.zeros((n_slots,), jnp.int32)
        self.active = jnp.zeros((n_slots,), bool)
        self.n_gen = jnp.zeros((n_slots,), jnp.int32)
        self.max_new = jnp.zeros((n_slots,), jnp.int32)
        # speculative rounds commit a VARIABLE 1..W tokens per slot per
        # step: the buffer holds the worst case and tokens pack densely
        # from buf_len (the -1 padding moves to the tail, so the sync-side
        # contract — counts[i] tokens then padding — is unchanged)
        self.tok_buf = jnp.full((n_slots, sync_every * spec_width), -1,
                                jnp.int32)
        self.buf_len = jnp.zeros((n_slots,), jnp.int32) if spec else None
        self.drafted = jnp.zeros((n_slots,), jnp.int32) if spec else None
        self.accepted = jnp.zeros((n_slots,), jnp.int32) if spec else None
        # UNCONDITIONAL per-slot obs accumulator (repro.obs.metrics column
        # layout): updated inside the jitted step, fetched by the SAME
        # sync() device_get as the tokens. Always present so the compiled
        # program is identical whether observability is consumed or not.
        self.obs_acc = device_acc_init(n_slots)
        self.buf_fill = 0            # host: steps since last sync
        self._prev_n_gen = np.zeros((n_slots,), np.int32)  # host mirror
        self._prev_drafted = np.zeros((n_slots,), np.int32)
        self._prev_accepted = np.zeros((n_slots,), np.int32)
        self.host_syncs = 0
        self.device_steps = 0
        self.step_traces = 0         # times the decode step (re)compiled
        # multi-device: slot axis over "data" (per-slot decode stays
        # device-local), arrays committed once and every jitted update
        # pinned to the same shardings so the step never retraces on a
        # placement change across admit/sync/step cycles
        self.arr_shardings = None
        if mesh is not None:
            from repro.distributed import sharding as SH
            specs = SH.leading_axis_specs(self._arrays(), mesh)
            self.arr_shardings = SH.to_shardings(specs, mesh)
            self._set_arrays(jax.device_put(self._arrays(),
                                            self.arr_shardings))
        # immutable templates reused by sync()/deactivate_all() so resets
        # keep the committed sharding (a fresh jnp.full would land on the
        # default device and force a retrace)
        self._empty_buf = self.tok_buf
        self._all_inactive = self.active
        self._zero_counts = self.buf_len
        self._zero_obs = self.obs_acc

        def step_impl(params, cache, masks, arrays, step_idx):
            self.step_traces += 1    # python side effect: runs per TRACE
            nxt, cache = decode_fn(params, cache, arrays["last_tok"],
                                   arrays["lengths"], masks,
                                   arrays["active"])
            was_active = arrays["active"]
            lengths = arrays["lengths"] + was_active.astype(jnp.int32)
            n_gen = arrays["n_gen"] + was_active.astype(jnp.int32)
            last_tok = jnp.where(was_active, nxt, arrays["last_tok"])
            # on-device termination: token budget or sequence capacity
            done = (n_gen >= arrays["max_new"]) | (lengths >= self.S - 1)
            tok_buf = arrays["tok_buf"].at[:, step_idx].set(
                jnp.where(was_active, nxt, -1))
            obs = device_acc_update(arrays["obs"], was_active,
                                    jnp.ones_like(n_gen))
            return cache, {"last_tok": last_tok, "lengths": lengths,
                           "active": was_active & ~done, "n_gen": n_gen,
                           "max_new": arrays["max_new"], "tok_buf": tok_buf,
                           "obs": obs}

        def spec_step_impl(params, cache, masks, arrays, step_idx):
            """One SPECULATION ROUND for all slots: decode_fn drafts W-1
            tokens with the bare PLM, verifies with the adapted model, and
            returns (toks [n, W] — the adapted model's token at every
            position — and n_acc [n], the accepted-draft prefix length).
            Commit c = min(n_acc+1, budget/capacity) tokens: the accepted
            prefix plus either the correction token at the first mismatch
            or the verify bonus token, so greedy output is bitwise the
            non-speculative sequence. Tokens pack densely at buf_len."""
            self.step_traces += 1    # python side effect: runs per TRACE
            del step_idx             # spec rounds index by buf_len instead
            W = self.spec_width
            toks, n_acc, cache = decode_fn(params, cache,
                                           arrays["last_tok"],
                                           arrays["lengths"], masks,
                                           arrays["active"])
            was_active = arrays["active"]
            cap = jnp.minimum(arrays["max_new"] - arrays["n_gen"],
                              (self.S - 1) - arrays["lengths"])
            c = jnp.where(was_active,
                          jnp.clip(jnp.minimum(n_acc + 1, cap), 1, W), 0)
            lengths = arrays["lengths"] + c
            n_gen = arrays["n_gen"] + c
            sel = jnp.clip(c - 1, 0, W - 1)
            new_last = jnp.take_along_axis(toks, sel[:, None], axis=1)[:, 0]
            last_tok = jnp.where(was_active, new_last, arrays["last_tok"])
            done = (n_gen >= arrays["max_new"]) | (lengths >= self.S - 1)
            # packed scatter: row i gets toks[i, :c] at buf_len[i]...; the
            # uncommitted tail routes to an out-of-range column and drops
            col = arrays["buf_len"][:, None] + jnp.arange(W)[None, :]
            ok = was_active[:, None] & (jnp.arange(W)[None, :] < c[:, None])
            col = jnp.where(ok, col, self.sync_every * W)
            tok_buf = arrays["tok_buf"].at[
                jnp.arange(self.n_slots)[:, None], col].set(toks,
                                                            mode="drop")
            # acceptance stats: every round drafts W-1; committed drafts
            # are c-1 (the final commit is the correction/bonus token)
            drafted = arrays["drafted"] + \
                (W - 1) * was_active.astype(jnp.int32)
            accepted = arrays["accepted"] + jnp.maximum(c - 1, 0)
            return cache, {"last_tok": last_tok, "lengths": lengths,
                           "active": was_active & ~done, "n_gen": n_gen,
                           "max_new": arrays["max_new"], "tok_buf": tok_buf,
                           "buf_len": arrays["buf_len"] + c,
                           "drafted": drafted, "accepted": accepted,
                           "obs": device_acc_update(arrays["obs"],
                                                    was_active, c)}

        if spec:
            step_impl = spec_step_impl

        # Admit scatter is shape-polymorphic (one compile per wave size),
        # so the retrace sentinel contract is traces <= distinct shapes:
        # the wrapper runs per TRACE (jit only re-enters python to trace),
        # and a repeat trace of an already-seen wave size means the
        # inputs' placement drifted. The engine watches both counters.
        self.admit_traces = 0
        self.admit_shapes = set()

        def admit_impl(arrays, slots, *rest):
            self.admit_traces += 1
            self.admit_shapes.add(int(slots.shape[0]))
            return _admit_scatter(arrays, slots, *rest)

        if mesh is not None:
            self._step = jax.jit(
                step_impl, out_shardings=(cache_shardings,
                                          self.arr_shardings))
            self._admit_scatter = jax.jit(
                admit_impl, out_shardings=self.arr_shardings)
            self._deactivate = jax.jit(
                _deactivate_scatter, out_shardings=self.arr_shardings)
        else:
            self._step = jax.jit(step_impl)
            self._admit_scatter = jax.jit(admit_impl)
            self._deactivate = jax.jit(_deactivate_scatter)

    # ----------------------------------------------------------------- device
    def _arrays(self) -> dict:
        out = {"last_tok": self.last_tok, "lengths": self.lengths,
               "active": self.active, "n_gen": self.n_gen,
               "max_new": self.max_new, "tok_buf": self.tok_buf,
               "obs": self.obs_acc}
        if self.spec_width > 1:
            out.update({"buf_len": self.buf_len, "drafted": self.drafted,
                        "accepted": self.accepted})
        return out

    def _set_arrays(self, arrays: dict) -> None:
        self.last_tok = arrays["last_tok"]
        self.lengths = arrays["lengths"]
        self.active = arrays["active"]
        self.n_gen = arrays["n_gen"]
        self.max_new = arrays["max_new"]
        self.tok_buf = arrays["tok_buf"]
        self.obs_acc = arrays["obs"]
        if self.spec_width > 1:
            self.buf_len = arrays["buf_len"]
            self.drafted = arrays["drafted"]
            self.accepted = arrays["accepted"]

    def step(self, params, cache, masks):
        """One decode step for ALL slots (inactive ones pad-compute);
        returns the updated model cache. No host transfer happens here."""
        assert self.buf_fill < self.sync_every, "sync() before stepping more"
        cache, arrays = self._step(params, cache, masks, self._arrays(),
                                   self.buf_fill)
        self._set_arrays(arrays)
        self.buf_fill += 1
        self.device_steps += 1
        return cache

    def restore(self, slots, last_toks, lengths, n_gens, max_news) -> None:
        """Scatter requests into the slot arrays with explicit generation
        counters — fresh admissions (n_gen=1, the prefill token) and
        preempt-resumes (n_gen = tokens already emitted) share this one
        jitted update. A request whose budget or sequence capacity is
        already spent never becomes active."""
        assert self.buf_fill == 0, "engine must sync() before admission"
        slots_h = np.asarray(slots, np.int32)
        lengths_h = np.asarray(lengths, np.int32)
        n_gens_h = np.asarray(n_gens, np.int32)
        max_news_h = np.asarray(max_news, np.int32)
        actives_h = (n_gens_h < max_news_h) & (lengths_h < self.S - 1)
        arrays = self._admit_scatter(
            self._arrays(), jnp.asarray(slots_h),
            jnp.asarray(np.asarray(last_toks, np.int32)),
            jnp.asarray(lengths_h), jnp.asarray(n_gens_h),
            jnp.asarray(max_news_h), jnp.asarray(actives_h))
        self._set_arrays(arrays)
        self._prev_n_gen[slots_h] = n_gens_h
        if self.spec_width > 1:
            # _admit_scatter zeroed the device counters for these slots
            self._prev_drafted[slots_h] = 0
            self._prev_accepted[slots_h] = 0

    def admit(self, slots, last_toks, lengths, max_news) -> None:
        """Scatter freshly prefilled requests into the slot arrays (one
        jitted update for the whole admission batch). The prefill's first
        generated token counts toward ``max_new`` (n_gen starts at 1); a
        request whose budget is exhausted by that token (or whose prompt
        already fills the sequence) never becomes active."""
        self.restore(slots, last_toks, lengths,
                     np.ones((len(np.asarray(slots)),), np.int32), max_news)

    def deactivate(self, mask) -> None:
        """Mark the masked slots inactive on device (preemption; the engine
        syncs first so no window tokens are in flight)."""
        assert self.buf_fill == 0, "sync() before deactivating"
        self._set_arrays(self._deactivate(self._arrays(),
                                          jnp.asarray(mask, bool)))

    def deactivate_all(self) -> None:
        """Mark every slot inactive on device (abort; engine syncs first)."""
        assert self.buf_fill == 0, "sync() before deactivating"
        self.active = self._all_inactive

    # ------------------------------------------------------------------- host
    def reset_counters(self) -> None:
        """Zero the host-side rate counters (engine.reset_stats()). The
        trace counters (`step_traces`, `admit_traces`/`admit_shapes`) are
        deliberately NOT reset — they are compile-cache facts the retrace
        sentinel watches, not per-window rates."""
        self.host_syncs = 0
        self.device_steps = 0

    def sync(self) -> SlotSync:
        """ONE device→host transfer of the window's tokens + slot status;
        resets the window. The engine distributes tokens to requests. In
        spec mode the window holds up to fill*W packed tokens per slot and
        the acceptance counters come back as per-window deltas."""
        fill = self.buf_fill
        W = self.spec_width
        width = fill * W
        if W > 1:
            (tok_buf, lengths, active, n_gen, drafted,
             accepted, obs) = jax.device_get(
                (self.tok_buf[:, :width], self.lengths, self.active,
                 self.n_gen, self.drafted, self.accepted, self.obs_acc))
            d_drafted = np.asarray(drafted) - self._prev_drafted
            d_accepted = np.asarray(accepted) - self._prev_accepted
            self._prev_drafted = np.asarray(drafted).copy()
            self._prev_accepted = np.asarray(accepted).copy()
            if fill:
                self.buf_len = self._zero_counts
        else:
            tok_buf, lengths, active, n_gen, obs = jax.device_get(
                (self.tok_buf[:, :width], self.lengths, self.active,
                 self.n_gen, self.obs_acc))
            d_drafted = d_accepted = None
        counts = np.asarray(n_gen) - self._prev_n_gen
        self._prev_n_gen = np.asarray(n_gen).copy()
        if fill:
            self.tok_buf = self._empty_buf
            # the accumulator resets each window (template keeps the
            # committed sharding), so the fetched values ARE the deltas
            self.obs_acc = self._zero_obs
        self.buf_fill = 0
        self.host_syncs += 1
        return SlotSync(np.asarray(tok_buf), counts, np.asarray(lengths),
                        np.asarray(active), fill, d_drafted, d_accepted,
                        np.asarray(obs))
