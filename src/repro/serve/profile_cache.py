"""LRU cache of admission-time aggregated profile adapters.

The extreme multi-profile regime is R requests over P ≪ R profiles: most
admissions re-request a profile the engine has already aggregated. Caching
the aggregated Â/B̂ (plus the adapter-LN affine) keyed by ``profile_id``
makes the repeat admission a pure gather — ZERO bank bytes read — and the
entry is exactly the decode-hot-path representation, so a hit feeds the
slot-buffer scatter directly.

Capacity is budgeted in BYTES, not entries: an entry is 2·L·d·b values of
bank dtype plus the [L, b] affines, so the operator knob maps directly to
device memory (`ServeEngine(cache_bytes=...)`). Eviction is LRU.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional


def entry_nbytes(entry: dict) -> int:
    """TRUE bytes of an entry's arrays. Quantized entries ({a_q, a_scale,
    b_q, b_scale, ...} under bank_quant) are budgeted at their int8 /
    packed-int4 payload + fp16 scale widths — size x itemsize IS the
    quantized record size, so the same byte knob holds 2x (int8) / ~3.6x
    (int4) more resident profiles with no accounting change."""
    return sum(int(v.size) * int(v.dtype.itemsize) for v in entry.values())


class ProfileCache:
    """LRU of {"a_hat", "b_hat", "ln_scale", "ln_bias"} device-array trees.

    capacity_bytes=None means unbounded; capacity_bytes=0 disables caching
    (every get misses, puts are dropped) — the paper-faithful baseline.
    """

    def __init__(self, capacity_bytes: Optional[int] = 64 << 20):
        self.capacity = capacity_bytes
        self._entries: "OrderedDict[int, dict]" = OrderedDict()
        self._sizes: Dict[int, int] = {}
        self.bytes_used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.rejects = 0        # over-capacity puts dropped (never cached)
        self.invalidations = 0  # entries dropped by re-training/graduation

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, pid) -> bool:
        return int(pid) in self._entries

    def get(self, pid: int) -> Optional[dict]:
        entry = self._entries.get(int(pid))
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end(int(pid))
        self.hits += 1
        return entry

    def peek(self, pid: int) -> Optional[dict]:
        """get() without touching LRU order or hit/miss counters."""
        return self._entries.get(int(pid))

    def put(self, pid: int, entry: dict) -> None:
        pid = int(pid)
        size = entry_nbytes(entry)
        if self.capacity is not None and size > self.capacity:
            # larger than the whole budget; don't thrash the cache — but a
            # silent drop made hit-rates incomparable across runs, so count
            self.rejects += 1
            return
        if pid in self._entries:
            self.bytes_used -= self._sizes.pop(pid)
            del self._entries[pid]
        self._entries[pid] = entry
        self._sizes[pid] = size
        self.bytes_used += size
        while (self.capacity is not None and self.bytes_used > self.capacity
               and len(self._entries) > 1):
            old_pid, _ = self._entries.popitem(last=False)
            self.bytes_used -= self._sizes.pop(old_pid)
            self.evictions += 1

    def invalidate(self, pid: int) -> bool:
        """Drop a profile (e.g. after re-training updated its masks)."""
        pid = int(pid)
        if pid not in self._entries:
            return False
        del self._entries[pid]
        self.bytes_used -= self._sizes.pop(pid)
        self.invalidations += 1
        return True

    def clear(self) -> None:
        """Drop every entry AND reset all counters — a cleared cache starts
        a fresh, comparable measurement window (hit-rates in
        BENCH_serve.json used to drift across clear() boundaries)."""
        self._entries.clear()
        self._sizes.clear()
        self.bytes_used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.rejects = 0
        self.invalidations = 0

    def reset_stats(self) -> None:
        """Reset the flow counters ONLY (engine.reset_stats()): entries and
        resident bytes survive — a warm cache after a counter reset should
        report warm hit-rates, not lose its contents like clear() does."""
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.rejects = 0
        self.invalidations = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {"entries": len(self._entries), "bytes": self.bytes_used,
                "capacity_bytes": self.capacity, "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "rejects": self.rejects,
                "invalidations": self.invalidations,
                "hit_rate": round(self.hit_rate, 4)}
