"""Request queue + admission policy for the serving engine.

The scheduler decides WHICH queued requests enter the engine when slots
free up; the engine then prefills each same-bucket group in ONE jitted
call. Two policies:

- ``fifo`` (default, the windowed engine's behavior): the oldest request
  always leads the wave, and the rest of the wave is filled with other
  requests from the SAME length bucket first — same-bucket requests share
  a prefill launch, so grouping maximizes prefill-batch occupancy without
  reordering past the head (a request can only be overtaken by same-wave
  peers, never delayed past the wave its bucket leads).
- ``efficiency`` (the continuous engine's default): the LARGEST bucket in
  the look-ahead window leads, so the small incremental admissions of
  continuous batching (often 1-2 freed slots at a time) still fill their
  prefill launches. Pure largest-first can starve a rare-length request
  indefinitely under a steady flood of a common length — ``max_wait_waves``
  is the age-based promotion valve: any request passed over that many
  waves preempts the policy and leads the next wave unconditionally.

Length buckets: attention archs pad prompts to pow2 buckets (pad tokens
are masked out of the KV range); recurrent-state archs (rwkv/mamba/zamba)
cannot mask pad tokens out of their state, so their bucket is the EXACT
prompt length — only identical-length prompts share a prefill. Exact
buckets are also why promotion matters most there: a one-off prompt
length is a bucket of size 1 that largest-first never picks.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.utils import pow2_bucket


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [T] int32
    profile_id: int
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False
    # Set by the engine when the profile could not be hydrated (persistent
    # failure / integrity quarantine): the request was served by the bare
    # PLM (zero-adapter masks) instead of failing the wave.
    degraded: bool = False
    # admission waves this request was eligible for but passed over
    # (drives max_wait_waves promotion)
    waits: int = 0
    # times the continuous engine swapped this request out to free pages
    preemptions: int = 0
    # host clock (time.perf_counter) at submit(); 0.0 until submitted.
    # Feeds the engine's TTFT / admission-wait histograms — requests
    # admitted without going through submit() simply aren't timed.
    t_submit: float = 0.0


class Scheduler:
    """Bounded-bucket admission queue.

    `window_mult` bounds how far past the head the bucket-grouping looks:
    an admission wave considers at most window_mult * n_free queued
    requests, so matching stays O(window), and a deep queue cannot starve
    its own head. `max_wait_waves=None` disables promotion (safe for
    "fifo", where head-first already bounds overtaking).
    """

    def __init__(self, block_pattern: str = "attn", *, floor: int = 8,
                 window_mult: int = 4, policy: str = "fifo",
                 max_wait_waves: Optional[int] = None):
        if policy not in ("fifo", "efficiency"):
            raise ValueError(f"unknown policy {policy!r}")
        self.exact_length = block_pattern != "attn"
        self.floor = floor
        self.window_mult = window_mult
        self.policy = policy
        self.max_wait_waves = max_wait_waves
        self._queue: "deque[Request]" = deque()
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_promoted = 0
        self.n_requeued = 0

    def __len__(self) -> int:
        return len(self._queue)

    def pending(self) -> int:
        return len(self._queue)

    def submit(self, reqs) -> None:
        if isinstance(reqs, Request):
            reqs = [reqs]
        now = time.perf_counter()
        for r in reqs:
            if not r.t_submit:  # re-submits keep their original arrival
                r.t_submit = now
        self._queue.extend(reqs)
        self.n_submitted += len(reqs)

    def requeue_front(self, reqs: List[Request]) -> None:
        """Return already-popped requests to the HEAD of the queue in their
        original order (the continuous engine's page pool declined them;
        they must not lose their place)."""
        for r in reversed(list(reqs)):
            self._queue.appendleft(r)
        self.n_requeued += len(reqs)

    def bucket_of(self, req: Request) -> int:
        """Padded prompt length this request prefills at."""
        T = len(req.prompt)
        return T if self.exact_length else pow2_bucket(T, self.floor)

    def _pick_lead(self, window: List[Request]) -> Request:
        """The request whose bucket the next prefill group forms around.
        Overdue requests (waits >= max_wait_waves) override either policy,
        oldest first — the anti-starvation guarantee."""
        if self.max_wait_waves is not None:
            for r in window:
                if r.waits >= self.max_wait_waves:
                    self.n_promoted += 1
                    return r
        if self.policy == "fifo":
            return window[0]
        # efficiency: largest bucket in the window leads; ties go to the
        # bucket whose oldest member is oldest (stable — window is FIFO)
        counts: Dict[int, int] = {}
        for r in window:
            counts[self.bucket_of(r)] = counts.get(self.bucket_of(r), 0) + 1
        best = max(counts.values())
        for r in window:
            if counts[self.bucket_of(r)] == best:
                return r

    def next_batch(self, n_free: int) -> List[Request]:
        """Pop up to n_free requests for admission, bucket-grouped. Every
        window member passed over ages by one wait (fuel for promotion)."""
        if n_free <= 0 or not self._queue:
            return []
        window = list(self._queue)[:self.window_mult * n_free]
        picked: List[Request] = []
        remaining = window
        while remaining and len(picked) < n_free:
            lead_bucket = self.bucket_of(self._pick_lead(remaining))
            same = [r for r in remaining
                    if self.bucket_of(r) == lead_bucket]
            take = same[:n_free - len(picked)]
            picked.extend(take)
            taken = set(id(r) for r in take)
            remaining = [r for r in remaining if id(r) not in taken]
        picked_ids = set(id(r) for r in picked)
        for r in window:
            if id(r) not in picked_ids:
                r.waits += 1
        self._queue = deque(r for r in self._queue
                            if id(r) not in picked_ids)
        self.n_admitted += len(picked)
        return picked

    def group_by_bucket(self, reqs: List[Request]) -> Dict[int, List[Request]]:
        """Admission-wave requests -> {padded_len: [reqs]} prefill groups."""
        groups: Dict[int, List[Request]] = {}
        for r in reqs:
            groups.setdefault(self.bucket_of(r), []).append(r)
        return groups

    def reset_stats(self) -> None:
        """Zero the flow counters (engine.reset_stats()); queued requests
        keep their place and their submit timestamps."""
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_promoted = 0
        self.n_requeued = 0

    def stats(self) -> dict:
        return {"pending": len(self._queue),
                "submitted": self.n_submitted,
                "admitted": self.n_admitted,
                "policy": self.policy,
                "promoted": self.n_promoted,
                "requeued": self.n_requeued}
