"""Request queue + admission policy for the serving engine.

The scheduler decides WHICH queued requests enter the engine when slots
free up; the engine then prefills each same-bucket group in ONE jitted
call. Policy: FIFO overall (the oldest request is always admitted), but
the rest of the admission wave is filled with other requests from the SAME
length bucket first — same-bucket requests share a prefill launch, so
grouping them maximizes prefill-batch occupancy without starving anyone
(a request can only be overtaken by same-wave peers, never delayed past
the wave its bucket leads).

Length buckets: attention archs pad prompts to pow2 buckets (pad tokens
are masked out of the KV range); recurrent-state archs (rwkv/mamba/zamba)
cannot mask pad tokens out of their state, so their bucket is the EXACT
prompt length — only identical-length prompts share a prefill.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.utils import pow2_bucket


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [T] int32
    profile_id: int
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False
    # Set by the engine when the profile could not be hydrated (persistent
    # failure / integrity quarantine): the request was served by the bare
    # PLM (zero-adapter masks) instead of failing the wave.
    degraded: bool = False


class Scheduler:
    """Bounded-bucket FIFO admission queue.

    `window_mult` bounds how far past the head the bucket-grouping looks:
    an admission wave considers at most window_mult * n_free queued
    requests, so matching stays O(window), and a deep queue cannot starve
    its own head.
    """

    def __init__(self, block_pattern: str = "attn", *, floor: int = 8,
                 window_mult: int = 4):
        self.exact_length = block_pattern != "attn"
        self.floor = floor
        self.window_mult = window_mult
        self._queue: "deque[Request]" = deque()
        self.n_submitted = 0
        self.n_admitted = 0

    def __len__(self) -> int:
        return len(self._queue)

    def pending(self) -> int:
        return len(self._queue)

    def submit(self, reqs) -> None:
        if isinstance(reqs, Request):
            reqs = [reqs]
        self._queue.extend(reqs)
        self.n_submitted += len(reqs)

    def bucket_of(self, req: Request) -> int:
        """Padded prompt length this request prefills at."""
        T = len(req.prompt)
        return T if self.exact_length else pow2_bucket(T, self.floor)

    def next_batch(self, n_free: int) -> List[Request]:
        """Pop up to n_free requests for admission, bucket-grouped FIFO."""
        if n_free <= 0 or not self._queue:
            return []
        window = list(self._queue)[:self.window_mult * n_free]
        picked: List[Request] = []
        remaining = window
        while remaining and len(picked) < n_free:
            lead_bucket = self.bucket_of(remaining[0])
            same = [r for r in remaining
                    if self.bucket_of(r) == lead_bucket]
            take = same[:n_free - len(picked)]
            picked.extend(take)
            taken = set(id(r) for r in take)
            remaining = [r for r in remaining if id(r) not in taken]
        picked_ids = set(id(r) for r in picked)
        self._queue = deque(r for r in self._queue
                            if id(r) not in picked_ids)
        self.n_admitted += len(picked)
        return picked

    def group_by_bucket(self, reqs: List[Request]) -> Dict[int, List[Request]]:
        """Admission-wave requests -> {padded_len: [reqs]} prefill groups."""
        groups: Dict[int, List[Request]] = {}
        for r in reqs:
            groups.setdefault(self.bucket_of(r), []).append(r)
        return groups

    def stats(self) -> dict:
        return {"pending": len(self._queue),
                "submitted": self.n_submitted,
                "admitted": self.n_admitted}
