"""AdamW + schedules, from scratch (paper: AdamW, lr=1e-5, linear decay).

Optimizer state mirrors the param pytree (m, v in fp32) so the sharding
specs of the params apply verbatim to the state — FSDP shards optimizer
state for free.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


def linear_decay_schedule(base_lr: float, total_steps: int,
                          warmup_steps: int = 0) -> Callable:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(warmup_steps, 1))
        frac = jnp.clip((total_steps - step)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        return base_lr * jnp.where(step < warmup_steps, warm, frac)
    return sched


def adamw_init(params) -> dict:
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_init_rows(params, num_rows: int) -> dict:
    """Row-packed optimizer state for slot-axis tables (train roster).

    Moments mirror the ``[S, ...]`` param tables exactly (the slot axis is
    just axis 0 of every leaf), but ``step`` is PER ROW so bias correction
    restarts from zero when a slot is evicted and re-admitted for a new
    profile — a freshly admitted profile must not inherit the previous
    occupant's Adam schedule position.
    """
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((num_rows,), jnp.int32)}


def _bcast_rows(x, like):
    """Broadcast a per-row [S] vector over a [S, ...] leaf."""
    return x.reshape((x.shape[0],) + (1,) * (like.ndim - 1))


def clip_by_row_norm(grads, max_norm: float):
    """Per-row global-norm clip over slot-packed grads (axis 0 = slot).

    Each row is clipped against its OWN norm across all leaves, so one
    slot's gradient spike never rescales another slot's update — the
    isolation property the roster gang step relies on (a global clip would
    couple slot trajectories through the shared norm).
    """
    sq = [jnp.sum(jnp.square(g.astype(jnp.float32)),
                  axis=tuple(range(1, g.ndim)))
          for g in jax.tree.leaves(grads)]
    gn = jnp.sqrt(sum(sq))                                   # [S]
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    clipped = jax.tree.map(
        lambda g: g * _bcast_rows(scale, g).astype(g.dtype), grads)
    return clipped, gn


def adamw_update_rows(grads, opt_state, params, active, *, lr, b1=0.9,
                      b2=0.999, eps=1e-8, weight_decay=0.0):
    """Slot-packed AdamW: every leaf is [S, ...]; ``active`` is a [S] bool.

    Rows where ``active`` is False keep params AND moments bit-identical —
    a zero grad through plain Adam would still decay m/v and advance bias
    correction, silently perturbing a parked slot. Per-row ``step`` only
    advances for active rows.
    """
    step = opt_state["step"] + active.astype(jnp.int32)
    lr_t = lr(step) if callable(lr) else lr

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        # inactive rows have step 0; clamp so the debias denom never hits
        # zero (their values are discarded by the where below anyway)
        s = _bcast_rows(jnp.maximum(step, 1).astype(jnp.float32), g)
        mhat = m_new / (1 - b1 ** s)
        vhat = v_new / (1 - b2 ** s)
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        lt = _bcast_rows(lr_t, g) if getattr(lr_t, "ndim", 0) else lr_t
        p_new = (p.astype(jnp.float32) - lt * delta).astype(p.dtype)
        a = _bcast_rows(active, g)
        return (jnp.where(a, p_new, p), jnp.where(a, m_new, m),
                jnp.where(a, v_new, v))

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def adamw_update(grads, opt_state, params, *, lr, b1=0.9, b2=0.999,
                 eps=1e-8, weight_decay=0.0):
    """Returns (new_params, new_opt_state). lr may be a schedule or scalar."""
    step = opt_state["step"] + 1
    lr_t = lr(step) if callable(lr) else lr

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            delta = delta + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr_t * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
