"""Optimizer substrate (no optax in env — built from scratch)."""
from repro.optim.adamw import (  # noqa: F401
    adamw_init,
    adamw_init_rows,
    adamw_update,
    adamw_update_rows,
    clip_by_global_norm,
    clip_by_row_norm,
    linear_decay_schedule,
)
