"""Optimizer substrate (no optax in env — built from scratch)."""
from repro.optim.adamw import (  # noqa: F401
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    linear_decay_schedule,
)
