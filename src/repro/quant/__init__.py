"""Quantized adapter-bank subsystem: int8/int4 schemes for the bank and
stored Â/B̂ records, shared by the Pallas dequant-fused kernels
(kernels/mask_aggregate_quant.py, kernels/fused_adapter_quant.py), the
serving engine, the profile store, and the byte-accounting helpers
(analysis/bytes.py). Select with ``XPeftConfig.bank_quant``."""
from repro.quant.schemes import (  # noqa: F401
    SCHEMES, check_scheme, dequant_block, dequantize, group_for, pack_int4,
    quant_spec, quantize, quantize_bank, quantize_int4, quantize_int8,
    unpack_int4)
