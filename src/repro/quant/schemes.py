"""Quantization schemes for the adapter bank and stored Â/B̂ rows.

At production scale the BANK bounds everything: every k-sparse admission
reads k·L·d·b bank bytes and every device holds the full bank plus the
aggregated Â/B̂ records the profile cache serves from. Two schemes shrink
both, selected by ``XPeftConfig.bank_quant``:

- ``int8`` — symmetric per-row (last axis) int8, one fp16 scale per row:
  ``q = clip(round(x / s), ±127)`` with ``s = absmax/127``. 2x fewer bytes
  than bf16 (4x vs fp32) at ~0.4% relative error on adapter-scale values.
- ``int4`` — group-wise packed int4: the last axis is split into groups of
  ``group_for(n, group)`` values sharing one fp16 scale (``s = absmax/7``),
  two values per byte. ~3.6x fewer bytes than bf16.

Packing is PLANAR, not interleaved: byte ``i`` carries element ``i`` in its
low nibble and element ``i + n/2`` in its high nibble, so in-register
unpacking is two shifts + one concatenate — no lane interleave, which keeps
the dequant epilogue cheap inside the Pallas kernels (they import
``dequant_block`` so kernel, interpret, and jnp-ref backends share the
EXACT op sequence and stay bit-identical).

Everything here is pure jnp (host numpy arrays welcome) and jit-safe; the
quantize side runs at engine construction / admission / graduation, never
on the decode hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SCHEMES = ("none", "int8", "int4")
INT4_BIAS = 8  # nibbles store q + 8 in [1, 15]; 0 encodes a zero-scale group


def check_scheme(scheme: str) -> str:
    if scheme not in SCHEMES:
        raise ValueError(f"bank_quant {scheme!r}; expected one of {SCHEMES}")
    return scheme


def group_for(n: int, group: int = 32) -> int:
    """Largest divisor of ``n`` that is <= ``group`` (int4 group size).

    The configured group is an upper bound: reduced smoke configs have
    b=4-wide rows where a 32-wide group cannot fit. n itself must be even
    (two nibbles per byte); groups may be any divisor — packing is planar
    over the whole axis and independent of the grouping."""
    if n % 2:
        raise ValueError(f"int4 needs an even last axis, got {n}")
    g = min(group, n)
    while n % g:
        g -= 1
    return max(g, 2)


# ----------------------------------------------------------------------------
# int8: symmetric per-row, fp16 scale
# ----------------------------------------------------------------------------

def quantize_int8(x) -> dict:
    """x [..., n] float -> {"q": int8 [..., n], "scale": fp16 [...]}.

    The scale is rounded to fp16 BEFORE quantizing, so dequantization is
    the exact inverse of the grid actually used (roundtrip error stays
    <= scale/2 + the clip tail, never the fp16 rounding of the scale)."""
    x = jnp.asarray(x, jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=-1)
    scale = (absmax / 127.0).astype(jnp.float16)
    s32 = scale.astype(jnp.float32)[..., None]
    q = jnp.where(s32 > 0, jnp.round(x / jnp.where(s32 > 0, s32, 1.0)), 0.0)
    return {"q": jnp.clip(q, -127, 127).astype(jnp.int8), "scale": scale}


# ----------------------------------------------------------------------------
# int4: group-wise along the last axis, planar-packed two values per byte
# ----------------------------------------------------------------------------

def pack_int4(q):
    """int [..., n] in [-8, 7] -> uint8 [..., n/2] (planar: low nibble =
    first half of the axis, high nibble = second half, biased +8)."""
    n = q.shape[-1]
    b = (q + INT4_BIAS).astype(jnp.uint8)
    return b[..., : n // 2] | (b[..., n // 2:] << 4)


def unpack_int4(packed):
    """uint8 [..., n/2] -> int32 [..., n] in [-8, 7] (planar layout)."""
    lo = (packed & 0xF).astype(jnp.int32) - INT4_BIAS
    hi = (packed >> 4).astype(jnp.int32) - INT4_BIAS
    return jnp.concatenate([lo, hi], axis=-1)


def quantize_int4(x, *, group: int = 32) -> dict:
    """x [..., n] float -> {"q": uint8 [..., n/2], "scale": fp16 [..., n/g]}
    with g = group_for(n, group); values in [-7, 7] (symmetric)."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[-1]
    g = group_for(n, group)
    xg = x.reshape(x.shape[:-1] + (n // g, g))
    scale = (jnp.max(jnp.abs(xg), axis=-1) / 7.0).astype(jnp.float16)
    s32 = scale.astype(jnp.float32)[..., None]
    q = jnp.where(s32 > 0, jnp.round(xg / jnp.where(s32 > 0, s32, 1.0)), 0.0)
    q = jnp.clip(q, -7, 7).astype(jnp.int32).reshape(x.shape)
    return {"q": pack_int4(q), "scale": scale}


# ----------------------------------------------------------------------------
# shared dequant epilogue (kernels + refs import THIS, never reimplement)
# ----------------------------------------------------------------------------

def dequant_block(q, scale, scheme: str):
    """Dequantize one block to fp32. int8: q [..., n] with scale [...];
    int4: packed q [..., n/2] with scale [..., n/g]. The op sequence here
    is the single source of truth for every backend (Pallas compiled,
    Pallas interpret, jnp ref), which is what makes them bit-identical."""
    if scheme == "int8":
        return q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]
    if scheme == "int4":
        vals = unpack_int4(q).astype(jnp.float32)
        groups = scale.shape[-1]
        n = vals.shape[-1]
        vg = vals.reshape(vals.shape[:-1] + (groups, n // groups))
        vg = vg * scale.astype(jnp.float32)[..., None]
        return vg.reshape(vals.shape)
    raise ValueError(f"dequant_block: scheme {scheme!r}")


def quantize(x, scheme: str, *, group: int = 32) -> dict:
    check_scheme(scheme)
    if scheme == "int8":
        return quantize_int8(x)
    if scheme == "int4":
        return quantize_int4(x, group=group)
    raise ValueError("quantize: scheme 'none' has no quantized form")


def dequantize(rec: dict, scheme: str):
    return dequant_block(rec["q"], rec["scale"], scheme)


def quant_spec(shape, scheme: str, *, group: int = 32):
    """(q_shape, q_dtype, scale_shape) for a float tensor of ``shape``
    quantized along its last axis — how the engine sizes its per-slot
    quantized mask buffers without materializing a dummy row."""
    check_scheme(scheme)
    n = shape[-1]
    if scheme == "int8":
        return shape, jnp.int8, shape[:-1]
    g = group_for(n, group)
    return shape[:-1] + (n // 2,), jnp.uint8, shape[:-1] + (n // g,)


# ----------------------------------------------------------------------------
# bank-level helpers
# ----------------------------------------------------------------------------

def quantize_bank(bank: dict, scheme: str, *, group: int = 32) -> dict:
    """{"bank_a": [L,N,d,b], "bank_b": [L,N,b,d]} -> flat quantized tree
    {"bank_a_q", "bank_a_scale", "bank_b_q", "bank_b_scale"}.

    Flat names (not nested dicts) so the GSPMD sharding rules can address
    each leaf: bank_*_q keep the bf16 bank's d_model TP sharding, scales
    ride along (distributed/sharding.py)."""
    check_scheme(scheme)
    qa = quantize(bank["bank_a"], scheme, group=group)
    qb = quantize(bank["bank_b"], scheme, group=group)
    return {"bank_a_q": qa["q"], "bank_a_scale": qa["scale"],
            "bank_b_q": qb["q"], "bank_b_scale": qb["scale"]}


def quantize_bank_hetero(bank: dict, scheme: str, *, group: int = 32) -> dict:
    """Heterogeneous-bank quantization: matmul-family segments (bottleneck
    bank_a/bank_b, LoRA lora_a/lora_b) get the full int8/int4 treatment —
    their error is averaged away inside a d-wide contraction. IA3 scale
    deltas and prefix KV rows are stored fp16 instead: both are consumed
    ELEMENTWISE (a multiplicative gate / raw attention rows), so per-entry
    quantization noise lands directly on activations with nothing to
    average over — and at [L, cnt, d] / [L, cnt, P, kv] they are a
    rounding error of the bank's footprint anyway."""
    check_scheme(scheme)
    out = {}
    for name in ("bank_a", "bank_b", "lora_a", "lora_b"):
        if name in bank:
            q = quantize(bank[name], scheme, group=group)
            out[f"{name}_q"] = q["q"]
            out[f"{name}_scale"] = q["scale"]
    for name in ("ia3_v", "prefix_k", "prefix_v"):
        if name in bank:
            out[name] = bank[name].astype(jnp.float16)
    return out
