"""Feed-forward blocks: GLU (SwiGLU/GeGLU) and vanilla (BERT-style)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.models.common import activation, dense_init


def init_mlp(key, cfg, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "glu":
        return {
            "wg": dense_init(ks[0], (d, ff), d, dtype),
            "wu": dense_init(ks[1], (d, ff), d, dtype),
            "wd": dense_init(ks[2], (ff, d), ff, dtype),
        }
    return {
        "w1": dense_init(ks[0], (d, ff), d, dtype),
        "b1": jnp.zeros((ff,), jnp.float32),
        "w2": dense_init(ks[1], (ff, d), ff, dtype),
        "b2": jnp.zeros((d,), jnp.float32),
    }


def mlp_apply(params, x, cfg):
    act = activation(cfg.act)
    if cfg.mlp_type == "glu":
        g = jnp.einsum("btd,df->btf", x, params["wg"])
        u = jnp.einsum("btd,df->btf", x, params["wu"])
        h = act(g) * u
        h = ctx.hint(h, "batch", None, "mlp")
        return jnp.einsum("btf,fd->btd", h, params["wd"])
    h = jnp.einsum("btd,df->btf", x, params["w1"]) + params["b1"].astype(x.dtype)
    h = act(h)
    h = ctx.hint(h, "batch", None, "mlp")
    return jnp.einsum("btf,fd->btd", h, params["w2"]) + params["b2"].astype(x.dtype)
