"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Faithful-in-structure implementation: static token-shift mixing coefficients
(mu) per projection, LoRA-parameterized data-dependent per-channel decay
(w = -exp(w0 + tanh(x @ dec_a) @ dec_b)), per-head bonus u, head-wise
normalization, silu output gate, and squared-ReLU channel-mix. The wkv engine
is the shared chunked GLA (linear_attn.py).

Decode state per layer: (tm_last [B,d], cm_last [B,d], wkv [B,H,dk,dk]).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rmsnorm
from repro.models.linear_attn import gla_chunked, gla_decode_step

DECAY_LORA = 64


def init_rwkv_block(key, cfg, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    H, hd = cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 12)
    p = {
        # time-mix
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),  # r,k,v,w,g shift mixes
        "rwr": dense_init(ks[0], (d, H * hd), d, dtype),
        "rwk": dense_init(ks[1], (d, H * hd), d, dtype),
        "rwv": dense_init(ks[2], (d, H * hd), d, dtype),
        "rwg": dense_init(ks[3], (d, H * hd), d, dtype),
        "rwo": dense_init(ks[4], (H * hd, d), H * hd, dtype),
        "w0": jnp.full((H, hd), -1.0, jnp.float32),   # base log decay rate
        "dec_a": dense_init(ks[5], (d, DECAY_LORA), d, jnp.float32),
        "dec_b": 0.01 * jax.random.normal(ks[6], (DECAY_LORA, H * hd), jnp.float32),
        "u": 0.5 * jax.random.normal(ks[7], (H, hd), jnp.float32),
        "ln_x_scale": jnp.ones((H, hd), jnp.float32),
        # channel-mix
        "cmu": 0.5 * jnp.ones((2, d), jnp.float32),   # k,r shift mixes
        "cw_k": dense_init(ks[8], (d, ff), d, dtype),
        "cw_v": dense_init(ks[9], (ff, d), ff, dtype),
        "cw_r": dense_init(ks[10], (d, d), d, dtype),
    }
    return p


def init_rwkv_state(batch, cfg, dtype=jnp.float32):
    H, hd = cfg.num_heads, cfg.head_dim
    return {
        "tm_last": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_last": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }


def _shift(x, last):
    """Token shift: x[t-1] with `last` at t=0. x [B,T,d], last [B,d]."""
    prev = jnp.concatenate([last[:, None, :].astype(x.dtype), x[:, :-1]], 1)
    return prev


def _decay(p, xw):
    raw = p["w0"].reshape(-1) + jnp.tanh(
        xw.astype(jnp.float32) @ p["dec_a"]) @ p["dec_b"]
    return -jnp.exp(raw)  # log-decay <= 0, data-dependent (Finch)


def _headwise_norm(o, scale):
    # per-head RMS norm over head_dim (stand-in for RWKV's GroupNorm)
    var = jnp.mean(jnp.square(o.astype(jnp.float32)), -1, keepdims=True)
    return (o * jax.lax.rsqrt(var + 1e-6) * scale).astype(o.dtype)


def time_mix(p, x, cfg, state=None):
    """x [B,T,d] -> (y, new_state{tm_last, wkv})."""
    B, T, d = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    last = state["tm_last"] if state is not None else jnp.zeros((B, d), x.dtype)
    prev = _shift(x, last)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + (prev - x) * mu[i] for i in range(5))

    r = (xr @ p["rwr"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    k = (xk @ p["rwk"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    v = (xv @ p["rwv"]).reshape(B, T, H, hd).transpose(0, 2, 1, 3)
    g = xg @ p["rwg"]
    lw = _decay(p, xw).reshape(B, T, H, hd).transpose(0, 2, 1, 3)

    wkv0 = state["wkv"] if state is not None else None
    if T == 1 and state is not None:
        o, wkv = gla_decode_step(r[:, :, 0], k[:, :, 0], v[:, :, 0],
                                 lw[:, :, 0], wkv0, bonus=p["u"])
        o = o[:, :, None, :]
    else:
        chunk = min(cfg.la_chunk, T)
        o, wkv = gla_chunked(r, k, v, lw, chunk=chunk, bonus=p["u"],
                             state=wkv0)
    o = _headwise_norm(o, p["ln_x_scale"][:, None, :])
    o = o.transpose(0, 2, 1, 3).reshape(B, T, H * hd)
    y = (o * jax.nn.silu(g)) @ p["rwo"]
    return y, {"tm_last": x[:, -1, :], "wkv": wkv}


def channel_mix(p, x, state=None):
    B, T, d = x.shape
    last = state["cm_last"] if state is not None else jnp.zeros((B, d), x.dtype)
    prev = _shift(x, last)
    cmu = p["cmu"].astype(x.dtype)
    xk = x + (prev - x) * cmu[0]
    xr = x + (prev - x) * cmu[1]
    kk = jnp.square(jax.nn.relu(xk @ p["cw_k"]))
    y = jax.nn.sigmoid(xr @ p["cw_r"]) * (kk @ p["cw_v"])
    return y, {"cm_last": x[:, -1, :]}


def rwkv_block(p, x, cfg, norms, state=None):
    """Full pre-norm RWKV6 block. norms: {"n1","n2"} rmsnorm params."""
    h, st_tm = time_mix(p, rmsnorm(x, norms["n1"]["scale"]), cfg, state)
    x = x + h
    h, st_cm = channel_mix(p, rmsnorm(x, norms["n2"]["scale"]), state)
    x = x + h
    new_state = {**st_tm, **st_cm}
    return x, new_state
