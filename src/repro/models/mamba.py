"""Mamba2 (SSD) block on the shared chunked-GLA engine.

Structure follows the Mamba2 reference: fused in_proj -> (z, x, B, C, dt),
causal depthwise conv over (x, B, C), scalar-per-head decay
a_t = -exp(A_log) * softplus(dt + dt_bias), SSD recurrence, D skip, gated
RMSNorm, out_proj. ngroups=1 (B/C shared across heads).

Decode state per layer: conv tail [B, K-1, conv_dim] + ssd state
[B, H, n, p] (n = ssm_state, p = head dim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.linear_attn import gla_chunked, gla_decode_step

CONV_K = 4


def dims(cfg):
    d_inner = 2 * cfg.d_model
    nheads = d_inner // cfg.mamba_headdim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, nheads, conv_dim


def init_mamba_block(key, cfg, dtype) -> dict:
    d = cfg.d_model
    d_inner, nheads, conv_dim = dims(cfg)
    n = cfg.ssm_state
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * n + nheads
    return {
        "in_proj": dense_init(ks[0], (d, in_dim), d, dtype),
        "conv_w": 0.1 * jax.random.normal(ks[1], (CONV_K, conv_dim), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "gn_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[2], (d_inner, d), d_inner, dtype),
    }


def init_mamba_state(batch, cfg, dtype=jnp.float32):
    d_inner, nheads, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, conv_dim), dtype),
        "ssd": jnp.zeros((batch, nheads, cfg.ssm_state, cfg.mamba_headdim),
                         jnp.float32),
    }


def _causal_conv(u, w, b, tail=None):
    """Depthwise causal conv. u [B,T,C], w [K,C]; tail [B,K-1,C] carryover."""
    B, T, C = u.shape
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, K - 1, C), u.dtype)
    ext = jnp.concatenate([tail.astype(u.dtype), u], axis=1)   # [B, T+K-1, C]
    out = sum(ext[:, i:i + T, :] * w[i].astype(u.dtype) for i in range(K))
    out = out + b.astype(u.dtype)
    new_tail = ext[:, -(K - 1):, :]
    return out, new_tail


def mamba_block(p, x, cfg, norms, state=None):
    """Pre-norm Mamba2 block: x [B,T,d] -> (x', new_state)."""
    from repro.models.common import rmsnorm

    B, T, d = x.shape
    d_inner, nheads, conv_dim = dims(cfg)
    n, hp = cfg.ssm_state, cfg.mamba_headdim

    h = rmsnorm(x, norms["n1"]["scale"])
    zxbcdt = h @ p["in_proj"]
    z, xc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)

    tail = state["conv"] if state is not None else None
    xc, new_tail = _causal_conv(xc, p["conv_w"], p["conv_b"], tail)
    xc = jax.nn.silu(xc)
    xs, Bm, Cm = jnp.split(xc, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # [B,T,H]
    lw_h = -jnp.exp(p["A_log"]) * dt                                # [B,T,H] <=0

    v = xs.reshape(B, T, nheads, hp) * dt[..., None].astype(xs.dtype)
    v = v.transpose(0, 2, 1, 3)                                     # [B,H,T,p]
    q = jnp.broadcast_to(Cm[:, None], (B, nheads, T, n))
    k = jnp.broadcast_to(Bm[:, None], (B, nheads, T, n))
    lw = jnp.broadcast_to(lw_h.transpose(0, 2, 1)[..., None],
                          (B, nheads, T, n))

    ssd0 = state["ssd"] if state is not None else None
    if T == 1 and state is not None:
        o, ssd = gla_decode_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                 lw[:, :, 0], ssd0)
        o = o[:, :, None, :]
    else:
        chunk = min(cfg.la_chunk, T)
        o, ssd = gla_chunked(q, k, v, lw, chunk=chunk, state=ssd0)

    y = o + p["D"][None, :, None, None].astype(o.dtype) * v
    y = y.transpose(0, 2, 1, 3).reshape(B, T, d_inner)
    # gated RMSNorm (Mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype) * p["gn_scale"].astype(x.dtype)
    out = y @ p["out_proj"]
    new_state = {"conv": new_tail, "ssd": ssd}
    return x + out, new_state
