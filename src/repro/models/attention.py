"""Attention: MHA/GQA/MQA with RoPE, sliding-window/global mix, KV cache.

Long sequences use a chunked online-softmax (flash-style) path in pure JAX —
lax.scan over query chunks with an inner scan over key chunks — so [T,S]
logits never materialize. Causal chunk pairs above the diagonal are computed
masked (rectangle); the §Perf log treats removing that waste as a hillclimb.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.models.common import apply_rope, dense_init, softcap

NEG_INF = -2.0e38


def init_attention(key, cfg, dtype) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), d, dtype),
        "wk": dense_init(ks[1], (d, KV, hd), d, dtype),
        "wv": dense_init(ks[2], (d, KV, hd), d, dtype),
        "wo": dense_init(ks[3], (H, hd, d), H * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((KV, hd), jnp.float32)
        p["bv"] = jnp.zeros((KV, hd), jnp.float32)
    return p


def _mask(q_pos, k_pos, *, causal, window, kv_valid, front_skip=None,
          k_idx=None):
    """q_pos [B,Tq], k_pos [S] or [B,S], kv_valid [B] -> bool [B,Tq,S].

    ``front_skip [B]`` masks the first ``front_skip[b]`` key BUFFER slots —
    per-example gating of learned prefix KV rows concatenated at the
    front (an example whose profile selects no prefix slot must attend
    EXACTLY the bare sequence, not P zero rows diluting the softmax).
    When k_pos is per-example [B,S] (prefix path: positions differ per
    example), ``k_idx [S]`` carries the buffer-slot index that kv_valid
    and front_skip gate on; positional masks use k_pos."""
    qp = q_pos[:, :, None]
    kp = k_pos[None, None, :] if k_pos.ndim == 1 else k_pos[:, None, :]
    ki = kp if k_idx is None else k_idx[None, None, :]
    m = ki < jnp.reshape(kv_valid, (-1, 1, 1))
    if front_skip is not None:
        m = m & (ki >= jnp.reshape(front_skip, (-1, 1, 1)))
    if causal:
        m = m & (kp <= qp)
    if window is not None:
        m = m & (qp - kp < window)
    return m


def _sdpa_dense(q, k, v, mask, scale, cap):
    """q [B,KV,G,Tq,hd], k/v [B,KV,S,hd], mask [B,Tq,S]."""
    logits = jnp.einsum("bkgth,bksh->bkgts", q, k,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, cap)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bksh->bkgth", w.astype(v.dtype), v)
    return out


def _sdpa_chunked(q, k, v, q_pos, k_pos, *, causal, window, kv_valid, scale,
                  cap, q_chunk, k_chunk):
    """Flash-style online softmax over key chunks, scanned over query chunks."""
    B, KV, G, Tq, hd = q.shape
    S = k.shape[2]
    nq, nk = Tq // q_chunk, S // k_chunk
    dv = v.shape[-1]

    qs = q.reshape(B, KV, G, nq, q_chunk, hd).transpose(3, 0, 1, 2, 4, 5)
    # NOTE: re-pinning q-seq CP on the chunk dim here was measured WORSE
    # (dbrx cp_qseq 40.9 -> 45.4s; §Perf it.7 refuted) — GSPMD handles the
    # [T]->[nq,qc] reshape better than an explicit re-constraint.
    qps = q_pos.reshape(B, nq, q_chunk).transpose(1, 0, 2)
    ks = k.reshape(B, KV, nk, k_chunk, hd).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(B, KV, nk, k_chunk, dv).transpose(2, 0, 1, 3, 4)
    ks = ctx.hint(ks, None, "batch", "kv_heads", "kv_seq", None)
    vs = ctx.hint(vs, None, "batch", "kv_heads", "kv_seq", None)
    kps = k_pos.reshape(nk, k_chunk)

    def q_step(_, qc):
        qi, qpi = qc

        def k_step(carry, kc):
            m_run, l_run, acc = carry
            ki, vi, kpi = kc
            logits = jnp.einsum("bkgth,bksh->bkgts", qi, ki,
                                preferred_element_type=jnp.float32) * scale
            logits = softcap(logits, cap)
            msk = _mask(qpi, kpi, causal=causal, window=window,
                        kv_valid=kv_valid)
            logits = jnp.where(msk[:, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgts,bksh->bkgth", p.astype(vi.dtype), vi).astype(jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, KV, G, q_chunk, dv), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(k_step, init, (ks, vs, kps))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out.astype(v.dtype)

    _, outs = jax.lax.scan(q_step, None, (qs, qps))
    # outs: [nq, B, KV, G, q_chunk, hd] -> [B, KV, G, Tq, hd]
    return outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, KV, G, Tq, dv)


def attention(params, x, *, positions, cfg, cache=None, cache_pos=None,
              is_global=True, q_chunk=512, k_chunk=1024, extra_kv=None,
              front_skip=None):
    """x [B,T,d] -> (y [B,T,d], new_cache).

    cache: {"k","v": [B, S, KV, hd]} functional KV cache; cache_pos: scalar
    write offset. Without a cache, keys=queries (self-attention).

    front_skip: optional [B] int32 — mask the first ``front_skip[b]`` KEY
    buffer slots in the cached path (serving over hydrated prefix KV rows:
    a layer whose profile selected no prefix slot holds zero rows at
    [0, P) that must not dilute the softmax). The no-cache prefix path
    sets this internally from ``extra_kv``'s pvalid.

    extra_kv: optional ``(pk [B,P,KV,hd], pv [B,P,KV,hd], pvalid [B])`` —
    learned PREFIX KV rows (stored post-RoPE; concatenated un-rotated at
    the front of the no-cache key/value sequence). The caller passes
    ``positions`` already offset by P so prefix rows sit at positions
    [0, P) and the prompt starts at P; ``pvalid=False`` examples mask the
    prefix region out entirely (bitwise the bare sequence). Serving never
    uses this — the engine hydrates prefix rows straight into the KV
    cache before prefill, so cached decode stays one compiled program.
    """
    B, T, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KV

    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # TP arbitration: head-shard Q only when the KV heads shard too —
    # otherwise Q-heads and KV-seq would claim the model axis differently
    # and GSPMD bounces activations every layer (dbrx/qwen3-moe: 7.5x
    # collective blowup, see EXPERIMENTS.md §Perf it.4). With
    # non-divisible KV, context-parallel K/V carries the TP instead.
    msize = ctx.axis_size("model")
    if msize <= 1 or KV % msize == 0:
        q = ctx.hint(q, "batch", None, "heads", None)
    else:
        # non-divisible KV: q-seq CP if the launcher enabled the "q_seq"
        # rule (no-op otherwise; K/V-seq CP carries the TP by default)
        q = ctx.hint(q, "batch", "q_seq", None, None)

    k_idx = None
    if cache is not None:
        if jnp.ndim(cache_pos) == 0:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
        else:
            # per-slot positions (continuous batching / speculative verify):
            # scatter T consecutive steps at each slot's own offset; writes
            # past S fall off the end and are dropped (the engine masks
            # those slots via kv_valid and never commits their tokens)
            idx = cache_pos[:, None] + jnp.arange(T)
            ck = cache["k"].at[jnp.arange(B)[:, None], idx].set(
                k.astype(cache["k"].dtype), mode="drop")
            cv = cache["v"].at[jnp.arange(B)[:, None], idx].set(
                v.astype(cache["v"].dtype), mode="drop")
        new_cache = {"k": ck, "v": cv}
        # quantized caches (e.g. f8) cast back to compute dtype on read
        keys, vals = ck.astype(k.dtype), cv.astype(v.dtype)
        S = ck.shape[1]
        kv_valid = jnp.broadcast_to(cache_pos + T, (B,))
        k_pos = jnp.arange(S)
    elif extra_kv is not None:
        pk, pv, pvalid = extra_kv
        P = pk.shape[1]
        new_cache = None
        keys = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        vals = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        S = P + T
        kv_valid = jnp.full((B,), P + T, jnp.int32)
        # per-example key positions: prefix rows at [0, P), self keys at
        # the example's own (possibly unshifted) query positions
        k_pos = jnp.concatenate([
            jnp.broadcast_to(
                jnp.arange(P, dtype=positions.dtype)[None], (B, P)),
            positions], axis=1)
        k_idx = jnp.arange(P + T)
        front_skip = jnp.where(pvalid, 0, P).astype(jnp.int32)
    else:
        new_cache = None
        keys, vals = k, v
        S = T
        kv_valid = jnp.full((B,), T, jnp.int32)
        k_pos = jnp.arange(T)

    keys = keys.transpose(0, 2, 1, 3)   # [B, KV, S, hd]
    vals = vals.transpose(0, 2, 1, 3)
    # TP arbitration: kv_heads claims the model axis when divisible, else
    # the sequence dim does (context-parallel attention; ctx rule "kv_seq")
    keys = ctx.hint(keys, "batch", "kv_heads", "kv_seq", None)
    vals = ctx.hint(vals, "batch", "kv_heads", "kv_seq", None)
    qg = q.reshape(B, T, KV, G, hd).transpose(0, 2, 3, 1, 4)  # [B,KV,G,T,hd]

    window = None
    if cfg.attn_type == "sliding_mix":
        # traced per-layer flag: global layers get an "infinite" window
        window = jnp.where(is_global, jnp.int32(2**30),
                           jnp.int32(cfg.sliding_window))

    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    use_chunked = (T > q_chunk) and (T % q_chunk == 0) and (S % k_chunk == 0)
    if use_chunked and front_skip is None:
        out = _sdpa_chunked(qg, keys, vals, positions, k_pos,
                            causal=cfg.causal, window=window,
                            kv_valid=kv_valid, scale=scale,
                            cap=cfg.logit_softcap,
                            q_chunk=q_chunk, k_chunk=k_chunk)
    else:
        msk = _mask(positions, k_pos, causal=cfg.causal, window=window,
                    kv_valid=kv_valid, front_skip=front_skip, k_idx=k_idx)
        out = _sdpa_dense(qg, keys, vals, msk, scale, cfg.logit_softcap)

    out = out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd)
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"])
    return y, new_cache
