"""Chunked gated linear attention — the shared engine for RWKV6 (Finch,
per-channel data-dependent decay) and Mamba2 (SSD, per-head scalar decay).

Recurrence per head (state S in R^{dk x dv}):
    S_t = diag(exp(lw_t)) . S_{t-1} + k_t v_t^T
    o_t = q_t^T S_t                      (+ optional RWKV bonus-u diag term)

The chunked form runs intra-chunk attention as dense MXU matmuls and carries
the state across chunks with a lax.scan — O(T * c * d) compute, O(1) state:
this is what makes long_500k a decode-able cell for the SSM/hybrid archs.

Numerics (secondary chunking): naive factoring of exp(cum_i - cum_j) into
exp(cum_i) * exp(-cum_j) overflows fp32 for strong decays, so intra-chunk
scores are computed over sub-tiles of SUBTILE tokens where every factor is
bounded by exp(SUBTILE * |lw|_max):

    exp(cum_i - cum_j) = exp(cum_i - B_a) * exp(B_a - B_b) * exp(B_b - cum_j)

with B_x the exclusive cum at sub-tile x's start; the first and third factors
are bounded per sub-tile and the middle one is <= 1 (carried per channel into
the score einsum). All inter-chunk factors are naturally <= 1.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

LW_MIN = -5.0      # per-step log-decay clamp (decay >= e^-5 ~ 0.0067)
SUBTILE = 16


def clamp_lw(lw):
    return jnp.clip(lw, LW_MIN, -1e-6)


def _intra_chunk(qc, kc, vc, cum, lwc, bonus):
    """Strictly-causal (or inclusive) intra-chunk attention with sub-tiling.

    qc,kc: [..., c, dk]; vc: [..., c, dv]; cum: inclusive cumsum of lw.
    Returns o_intra [..., c, dv].
    """
    c, dk = qc.shape[-2], qc.shape[-1]
    s = min(SUBTILE, c)
    A = c // s
    lead = qc.shape[:-2]

    # Query-side exponent: plain GLA includes the current token's decay in
    # the product (∏_{j+1..i}); RWKV's bonus form excludes it (∏_{j+1..i-1}).
    q_cum = cum - lwc if bonus is not None else cum

    # exclusive cumsum at each position, and B_a = exclusive cum at each
    # sub-tile's first position: [..., A, dk]
    excl = cum - lwc
    Bt = excl.reshape(*lead, A, s, dk)[..., :, 0, :]

    q2 = qc.reshape(*lead, A, s, dk)
    k2 = kc.reshape(*lead, A, s, dk)
    v2 = vc.reshape(*lead, A, s, vc.shape[-1])
    qcum2 = q_cum.reshape(*lead, A, s, dk)
    cum2 = cum.reshape(*lead, A, s, dk)

    qloc = q2 * jnp.exp(qcum2 - Bt[..., :, None, :])       # <= 1 (or e^{|lw|})
    kloc = k2 * jnp.exp(Bt[..., :, None, :] - cum2)        # <= e^{s*L}
    D = jnp.exp(Bt[..., :, None, :] - Bt[..., None, :, :])  # [.., A, A, dk] <=1 for a>=b

    scores = jnp.einsum("...aid,...abd,...bjd->...abij", qloc, D, kloc)
    ii = jnp.arange(c)
    strict = bonus is not None
    causal = (ii[:, None] > ii[None, :]) if strict else (ii[:, None] >= ii[None, :])
    causal = causal.reshape(A, s, A, s).transpose(0, 2, 1, 3)  # [A,A,s,s]
    scores = jnp.where(causal, scores, 0.0)
    o = jnp.einsum("...abij,...bjv->...aiv", scores, v2)
    o = o.reshape(*lead, c, vc.shape[-1])
    if bonus is not None:
        coeff = jnp.einsum("...ik,...ik->...i", qc * bonus, kc)
        o = o + coeff[..., None] * vc
    return o


def gla_chunked(q, k, v, lw, *, chunk: int, bonus: Optional[jnp.ndarray] = None,
                state: Optional[jnp.ndarray] = None):
    """q,k: [B,H,T,dk]; v: [B,H,T,dv]; lw: [B,H,T,dk] log-decay (<=0).

    bonus: [H, dk] RWKV "u" — replaces the current-token diagonal term.
    Returns (o [B,H,T,dv], final_state [B,H,dk,dv]).
    """
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, T)
    while T % chunk:  # ragged T (serving prefill): largest divisor wins
        chunk -= 1
    G = T // chunk
    f32 = jnp.float32

    lw = clamp_lw(lw.astype(f32))
    q_, k_, v_ = (a.astype(f32) for a in (q, k, v))
    rs = lambda a: a.reshape(B, H, G, chunk, a.shape[-1])
    qc, kc, vc, lwc = rs(q_), rs(k_), rs(v_), rs(lw)
    cum = jnp.cumsum(lwc, axis=-2)                     # [B,H,G,c,dk]
    total = cum[..., -1, :]                            # [B,H,G,dk]

    bonus_f = bonus.astype(f32) if bonus is not None else None
    o_intra = _intra_chunk(
        qc, kc, vc, cum, lwc,
        bonus_f[None, :, None, None, :] if bonus_f is not None else None)

    # inter-chunk: queries decayed from chunk start (exclusive for bonus form)
    q_cum = cum - lwc if bonus is not None else cum
    qd = qc * jnp.exp(q_cum)                           # <= 1
    kt = kc * jnp.exp(total[..., None, :] - cum)       # <= 1

    def step(s, xs):
        qd_g, kt_g, v_g, tot_g = xs
        o_inter = jnp.einsum("bhik,bhkv->bhiv", qd_g, s)
        s_new = s * jnp.exp(tot_g)[..., None] + jnp.einsum(
            "bhjk,bhjv->bhkv", kt_g, v_g)
        return s_new, o_inter

    if state is None:
        state = jnp.zeros((B, H, dk, dv), f32)
    xs = (qd.transpose(2, 0, 1, 3, 4), kt.transpose(2, 0, 1, 3, 4),
          vc.transpose(2, 0, 1, 3, 4), total.transpose(2, 0, 1, 3))
    state_f, o_inter = jax.lax.scan(step, state.astype(f32), xs)
    o_inter = o_inter.transpose(1, 2, 0, 3, 4)         # [B,H,G,c,dv]

    o = (o_intra + o_inter).reshape(B, H, T, dv)
    return o.astype(v.dtype), state_f


def gla_decode_step(q, k, v, lw, state, *, bonus: Optional[jnp.ndarray] = None):
    """Single-token recurrent step. q,k: [B,H,dk]; v: [B,H,dv];
    lw: [B,H,dk]; state: [B,H,dk,dv]. Returns (o [B,H,dv], new_state)."""
    f32 = jnp.float32
    q_, k_, v_ = (a.astype(f32) for a in (q, k, v))
    lw = clamp_lw(lw.astype(f32))
    decay = jnp.exp(lw)[..., None]                     # [B,H,dk,1]
    kv = k_[..., :, None] * v_[..., None, :]           # [B,H,dk,dv]
    if bonus is None:
        s_new = state * decay + kv
        o = jnp.einsum("bhk,bhkv->bhv", q_, s_new)
    else:
        o = jnp.einsum("bhk,bhkv->bhv", q_,
                       state + bonus.astype(f32)[None, :, :, None] * kv)
        s_new = state * decay + kv
    return o.astype(v.dtype), s_new
