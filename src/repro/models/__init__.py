"""Model substrate: composable blocks + the unified scan-over-layers LM."""
from repro.models.model import (  # noqa: F401
    init_lm,
    init_cache,
    forward,
    lm_logits,
    cls_logits,
    layer_meta,
)
