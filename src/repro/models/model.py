"""The unified LM: scan-over-layers transformer substrate for every assigned
arch (dense / MoE / RWKV6 / Mamba2 / Zamba2-hybrid / encoder) with X-PEFT
adapter-bank hooks on every block's residual stream.

Params are plain dict pytrees; layers are stacked on a leading L axis and run
under jax.lax.scan (compact HLO => compilable 132B-param dry-runs on CPU).
Abstract init for the dry-run comes from jax.eval_shape(init_lm, ...).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import xpeft as XP
from repro.core.adapters import init_adapter_bank, init_hetero_bank
from repro.distributed import ctx
from repro.models import attention as ATT
from repro.models import mamba as MB
from repro.models import mlp as MLP
from repro.models import moe as MOE
from repro.models import rwkv as RK
from repro.models.common import init_norm, norm_apply, dense_init, softcap


# ----------------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------------

def _init_stack(key, n, init_one):
    return jax.vmap(init_one)(jax.random.split(key, n))


def _init_attn_block(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    block = {
        "attn": ATT.init_attention(k1, cfg, dtype),
        "n1": init_norm(cfg.norm, cfg.d_model),
        "n2": init_norm(cfg.norm, cfg.d_model),
    }
    if cfg.moe:
        block["moe"] = MOE.init_moe(k2, cfg, dtype)
    else:
        block["mlp"] = MLP.init_mlp(k2, cfg, dtype)
    return block


def _init_block(key, cfg, dtype):
    if cfg.block_pattern == "rwkv":
        return {"rwkv": RK.init_rwkv_block(key, cfg, dtype),
                "n1": init_norm("rmsnorm", cfg.d_model),
                "n2": init_norm("rmsnorm", cfg.d_model)}
    if cfg.block_pattern in ("mamba", "zamba"):
        return {"mamba": MB.init_mamba_block(key, cfg, dtype),
                "n1": init_norm("rmsnorm", cfg.d_model)}
    return _init_attn_block(key, cfg, dtype)


def init_lm(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model),
                            cfg.d_model, dtype),
        "blocks": _init_stack(keys[1], cfg.num_layers,
                              lambda k: _init_block(k, cfg, dtype)),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if cfg.pos == "learned":
        params["pos_embed"] = dense_init(keys[2], (cfg.max_seq_len, cfg.d_model),
                                         cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[3], (cfg.d_model, cfg.vocab_size),
                                       cfg.d_model, dtype)
    if cfg.block_pattern == "zamba":
        shared_cfg = cfg.with_(attn_type="full")
        params["shared_attn"] = _init_attn_block(keys[4], shared_cfg, dtype)
    if cfg.num_labels:
        params["cls"] = {
            "pool_w": dense_init(keys[5], (cfg.d_model, cfg.d_model),
                                 cfg.d_model, jnp.float32),
            "pool_b": jnp.zeros((cfg.d_model,), jnp.float32),
            "head_w": dense_init(keys[6], (cfg.d_model, cfg.num_labels),
                                 cfg.d_model, jnp.float32),
            "head_b": jnp.zeros((cfg.num_labels,), jnp.float32),
        }
    if cfg.xpeft.enabled:
        if cfg.xpeft.is_hetero:
            params["xpeft_bank"] = init_hetero_bank(
                keys[7], cfg.num_layers, cfg.xpeft, cfg.d_model, cfg.kv_dim,
                dtype)
        else:
            params["xpeft_bank"] = init_adapter_bank(
                keys[7], cfg.num_layers, cfg.xpeft.num_adapters, cfg.d_model,
                cfg.xpeft.bottleneck, dtype)
    return params


def layer_meta(cfg) -> np.ndarray:
    """Static per-layer flags: is_global (gemma3 5:1 local:global)."""
    if cfg.attn_type == "sliding_mix":
        return np.array([(l % cfg.global_every) == cfg.global_every - 1
                         for l in range(cfg.num_layers)])
    return np.ones((cfg.num_layers,), bool)


# ----------------------------------------------------------------------------
# KV / recurrent cache
# ----------------------------------------------------------------------------

def init_cache(cfg, batch: int, seq: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.cache_dtype or cfg.dtype)
    L = cfg.num_layers
    if cfg.block_pattern == "rwkv":
        st = RK.init_rwkv_state(batch, cfg, dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), st)
    if cfg.block_pattern == "mamba":
        st = MB.init_mamba_state(batch, cfg, dtype)
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), st)
    if cfg.block_pattern == "zamba":
        st = MB.init_mamba_state(batch, cfg, dtype)
        cache = jax.tree.map(lambda x: jnp.broadcast_to(x, (L,) + x.shape), st)
        n_inv = cfg.num_layers // cfg.shared_attn_every
        cache = dict(cache)
        cache["attn_k"] = jnp.zeros(
            (n_inv, batch, seq, cfg.num_kv_heads, cfg.head_dim), dtype)
        cache["attn_v"] = jnp.zeros_like(cache["attn_k"])
        return cache
    return {
        "k": jnp.zeros((L, batch, seq, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, seq, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


# ----------------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------------

def _xpeft_apply(x, bank_l, masks_l, cfg):
    if masks_l is None or not cfg.xpeft.enabled:
        return x
    if "a_q" in masks_l:
        # QUANTIZED aggregated adapters (bank_quant serving): per-example
        # int8 / packed-int4 Â/B̂ + fp16 scales, dequantized in-register by
        # the dequant-fused kernel — the record never widens in HBM.
        from repro.kernels import ops
        return ops.fused_adapter_quant(
            x, masks_l["a_q"], masks_l["a_scale"],
            masks_l["b_q"], masks_l["b_scale"],
            masks_l["ln_scale"], masks_l["ln_bias"],
            scheme=cfg.xpeft.bank_quant,
            activation=cfg.xpeft.adapter_activation,
            impl=cfg.xpeft.kernel_impl)
    if "a_hat" in masks_l or "lora_a" in masks_l or "ia3_s" in masks_l:
        # admission-time aggregated adapters (serving fast path): per-example
        # Â [B,d,b] / B̂ [B,b,d] already contracted against the bank. Routed
        # through the kernel dispatch layer — on TPU one batched Pallas
        # launch keeps the [T,b] intermediate in VMEM (no HBM round-trip).
        # Heterogeneous entries compose in the fixed per-layer order
        # bottleneck -> LoRA -> IA3 (prefix rows live in the KV cache, not
        # here); a type-pure entry carries only a_hat/b_hat and this is
        # exactly the historical single fused_adapter call.
        from repro.kernels import ops
        if "a_hat" in masks_l:
            x = ops.fused_adapter(x, masks_l["a_hat"], masks_l["b_hat"],
                                  masks_l["ln_scale"], masks_l["ln_bias"],
                                  activation=cfg.xpeft.adapter_activation,
                                  impl=cfg.xpeft.kernel_impl)
        if "lora_a" in masks_l:
            x = ops.lora_adapter(x, masks_l["lora_a"], masks_l["lora_b"],
                                 impl=cfg.xpeft.kernel_impl)
        if "ia3_s" in masks_l:
            x = ops.ia3_apply(x, masks_l["ia3_s"],
                              impl=cfg.xpeft.kernel_impl)
        return x
    if "w_a" not in masks_l:
        # serving entries with no residual-path leaves (e.g. a prefix-only
        # bank_spec: prefix_skip rides to attention, nothing applies here)
        return x
    if "idx_a" in masks_l:
        # k-sparse hard-mask aggregation: gather only the k selected
        # adapters (N/k cheaper than the dense contraction; the jnp twin of
        # kernels/mask_aggregate.py)
        return XP.apply_xpeft_layer_sparse(
            x, bank_l, masks_l["idx_a"], masks_l["w_a"],
            masks_l["idx_b"], masks_l["w_b"],
            masks_l["ln_scale"][..., None, :],
            masks_l["ln_bias"][..., None, :], cfg.xpeft)
    if cfg.xpeft.is_hetero:
        # dense unified-space weights over a typed bank (training / soft
        # masks): per-segment aggregation + bottleneck -> LoRA -> IA3
        # composition; prefix KV rows were threaded into attention by the
        # scan body before this point.
        return XP.apply_xpeft_layer_hetero(
            x, bank_l, masks_l["w_a"], masks_l["w_b"],
            masks_l["ln_scale"][..., None, :],
            masks_l["ln_bias"][..., None, :], cfg.xpeft)
    return XP.apply_xpeft_layer(x, bank_l, masks_l["w_a"], masks_l["w_b"],
                                masks_l["ln_scale"][..., None, :],
                                masks_l["ln_bias"][..., None, :], cfg.xpeft)


def _decode_fused_route(cfg, masks, use_cache: bool, Tt: int):
    """Static eligibility of the decode megakernel: returns the adapter
    route ("none" | "bf16" | "int8" | "int4") or None for the composed
    path. Only the T=1 cached full-attention decode step qualifies; the
    on-the-fly mask routes (w_a / idx_a) keep the composed path — the
    megakernel fuses admission-time aggregated records only."""
    if not (cfg.decode_fused and use_cache and Tt == 1
            and cfg.block_pattern == "attn" and not cfg.moe
            and cfg.attn_type == "full" and cfg.causal):
        return None
    if masks is None or not cfg.xpeft.enabled:
        return "none"
    if any(key in masks for key in ("lora_a", "lora_b", "ia3_s",
                                    "prefix_skip")):
        return None  # heterogeneous entries take the composed per-type path
    if "a_q" in masks:
        return cfg.xpeft.bank_quant \
            if cfg.xpeft.bank_quant in ("int8", "int4") else None
    if "a_hat" in masks:
        return "bf16"
    return None


def _decode_fused_apply(block, x, masks_l, cfg, *, positions, cache_l,
                        cache_pos, route):
    """Megakernel step: one program for norm/attn/MLP/adapter, then the
    K/V row scatter OUTSIDE the kernel (same semantics as attention.py's
    cache update, so paged sentinel-drop writeback is unchanged)."""
    from repro.kernels import ops
    B = x.shape[0]
    y, k_rows, v_rows = ops.decode_block_fused(
        x, positions[:, 0], block, cache_l["k"], cache_l["v"], masks_l,
        norm=cfg.norm, qkv_bias=cfg.qkv_bias, use_rope=cfg.pos == "rope",
        theta=cfg.rope_theta, cap=cfg.logit_softcap, mlp_type=cfg.mlp_type,
        act_name=cfg.act, adapter=route,
        adapter_act=cfg.xpeft.adapter_activation,
        impl=cfg.xpeft.kernel_impl)
    if jnp.ndim(cache_pos) == 0:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache_l["k"], k_rows[:, None], cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache_l["v"], v_rows[:, None], cache_pos, axis=1)
    else:
        ck = cache_l["k"].at[jnp.arange(B), cache_pos].set(
            k_rows, mode="drop")
        cv = cache_l["v"].at[jnp.arange(B), cache_pos].set(
            v_rows, mode="drop")
    return y, {"k": ck, "v": cv}


def _attn_block_apply(block, x, cfg, *, positions, cache_l, cache_pos,
                      is_global, extra_kv=None, front_skip=None):
    h = norm_apply(x, block["n1"], cfg.norm)
    h, new_cache = ATT.attention(block["attn"], h, positions=positions,
                                 cfg=cfg, cache=cache_l, cache_pos=cache_pos,
                                 is_global=is_global, extra_kv=extra_kv,
                                 front_skip=front_skip)
    x = x + h
    h = norm_apply(x, block["n2"], cfg.norm)
    if cfg.moe:
        h, aux = MOE.moe_apply(block["moe"], h, cfg)
    else:
        h, aux = MLP.mlp_apply(block["mlp"], h, cfg), jnp.float32(0)
    x = x + h
    return x, new_cache, aux


def _make_body(cfg, positions, cache_pos, use_cache, fused_route=None):
    """Scan body over stacked layers for uniform-block archs."""

    def body(x, xs):
        block, bank_l, masks_l, is_global, cache_l = xs
        if not use_cache:
            cache_l = None
        if fused_route is not None:
            # decode megakernel: attention/MLP AND the adapter in one
            # program per layer (adapter already applied — skip
            # _xpeft_apply below)
            x, new_cache = _decode_fused_apply(
                block, x, masks_l, cfg, positions=positions,
                cache_l=cache_l, cache_pos=cache_pos, route=fused_route)
            x = ctx.hint(x, "batch", "seq", "embed")
            return x, (new_cache, jnp.float32(0))
        if cfg.block_pattern == "rwkv":
            x, new_cache = RK.rwkv_block(
                block["rwkv"], x, cfg,
                {"n1": block["n1"], "n2": block["n2"]}, cache_l)
            aux = jnp.float32(0)
        elif cfg.block_pattern in ("mamba", "zamba"):
            x, new_cache = MB.mamba_block(block["mamba"], x, cfg,
                                          {"n1": block["n1"]}, cache_l)
            aux = jnp.float32(0)
        else:
            extra_kv = None
            front_skip = None
            if (masks_l is not None and cfg.xpeft.enabled
                    and cfg.xpeft.is_hetero and not use_cache
                    and "w_a" in masks_l):
                # dense training path over a prefix-bearing bank: this
                # layer's per-example prefix KV rows ride into attention
                # as un-rotated front rows (None when the spec has no
                # prefix segment). The cached/serving path instead
                # hydrates prefix rows into the KV cache at admission.
                extra_kv = XP.prefix_rows_dense_layer(
                    bank_l, masks_l["w_a"], masks_l["w_b"], cfg.xpeft,
                    cfg.num_kv_heads, cfg.head_dim)
            if (use_cache and masks_l is not None
                    and "prefix_skip" in masks_l):
                # serving over hydrated prefix KV rows: per-example,
                # per-layer gate — a layer whose masks selected no prefix
                # slot holds zero rows at [0, P) and must not attend them
                # (matches the training path's extra_kv pvalid gating)
                front_skip = masks_l["prefix_skip"]
            x, new_cache, aux = _attn_block_apply(
                block, x, cfg, positions=positions, cache_l=cache_l,
                cache_pos=cache_pos, is_global=is_global, extra_kv=extra_kv,
                front_skip=front_skip)
        x = _xpeft_apply(x, bank_l, masks_l, cfg)
        # re-pin the residual stream each layer (Megatron-SP: under
        # act_rules {"seq": "model"} the scan carry — and therefore the
        # remat-saved layer inputs — stay sequence-sharded over TP)
        x = ctx.hint(x, "batch", "seq", "embed")
        if new_cache is None:
            new_cache = jnp.float32(0)
        return x, (new_cache, aux)

    return body


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params, tokens, cfg, *, prefix_embeds=None, profile_masks=None,
            cache=None, cache_pos=0, positions=None):
    """tokens [B,T] -> (hidden [B,T',d], new_cache, aux_loss).

    profile_masks: {"w_a","w_b": [B,L,N], "ln_scale","ln_bias": [B,L,b]}
    (per-example hydrated mask weights), or None.
    cache: stacked cache pytree from init_cache; cache_pos: write offset.
    """
    B, T = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    Tt = x.shape[1]
    if positions is None:
        if jnp.ndim(cache_pos) == 0:
            positions = cache_pos + jnp.arange(Tt, dtype=jnp.int32)[None, :]
            positions = jnp.broadcast_to(positions, (B, Tt))
        else:  # per-slot decode positions
            positions = cache_pos[:, None] + jnp.arange(Tt, dtype=jnp.int32)
        if (cache is None and profile_masks is not None
                and cfg.xpeft.enabled and cfg.xpeft.has_prefix
                and "w_a" in profile_masks):
            # prefix-bearing dense training path: prefix KV rows occupy
            # positions [0, P), so the prompt's RoPE phase starts at P —
            # matching serving, where prefill writes the prompt at
            # cache_pos = P behind the hydrated prefix rows. Per-example:
            # a profile whose masks never touch the prefix segment keeps
            # bare positions (RoPE is only *relatively* shift-invariant,
            # so a blanket offset would break bitwise zero-mask == bare).
            wsum = jnp.zeros((B,), jnp.float32)
            for typ, off, cnt in cfg.xpeft.segments():
                if typ != "prefix":
                    continue
                seg_a = profile_masks["w_a"][:, :, off:off + cnt]
                seg_b = profile_masks["w_b"][:, :, off:off + cnt]
                wsum = wsum + seg_a.sum((1, 2)) + seg_b.sum((1, 2))
            offs = jnp.where(wsum > 0, jnp.int32(cfg.xpeft.prefix_tokens), 0)
            positions = positions + offs[:, None]
    if cfg.pos == "learned":
        if jnp.ndim(cache_pos) == 0:
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], cache_pos, Tt, axis=0)[None]
        else:
            x = x + jnp.take(params["pos_embed"], positions, axis=0)
    x = ctx.hint(x, "batch", "seq", "embed")

    use_cache = cache is not None
    bank = params.get("xpeft_bank")
    if bank is None:
        bank = jnp.zeros((cfg.num_layers,), jnp.float32)  # dummy scanned leaf
    masks = None
    if profile_masks is not None:
        # [B, L, ...] -> [L, B, ...] for scan
        masks = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), profile_masks)
    meta = jnp.asarray(layer_meta(cfg))

    if cfg.block_pattern == "zamba":
        return _forward_zamba(params, x, cfg, positions, cache, cache_pos,
                              bank, masks, meta)

    fused_route = _decode_fused_route(cfg, masks, use_cache, Tt)
    body = _remat(_make_body(cfg, positions, cache_pos, use_cache,
                             fused_route), cfg)
    dummy_cache = cache if use_cache else jnp.zeros((cfg.num_layers,), jnp.float32)
    xs = (params["blocks"], bank, masks, meta, dummy_cache)
    x, (new_cache, auxs) = jax.lax.scan(body, x, xs)
    x = norm_apply(x, params["final_norm"], cfg.norm)
    return x, (new_cache if use_cache else None), jnp.mean(auxs)


def _forward_zamba(params, x, cfg, positions, cache, cache_pos, bank, masks,
                   meta):
    """Zamba2: groups of mamba layers with a SHARED attention block between.

    38 layers, shared_attn_every=6 -> 6 shared-block invocations, each with
    its own KV cache slice (cache["attn_k"][g]).
    """
    use_cache = cache is not None
    E = cfg.shared_attn_every
    n_inv = cfg.num_layers // E
    body = _remat(_make_body(cfg, positions, cache_pos, use_cache), cfg)

    def slice_tree(tree, lo, n):
        return jax.tree.map(lambda a: a[lo:lo + n], tree)

    mamba_cache = None
    if use_cache:
        mamba_cache = {k: v for k, v in cache.items()
                       if k not in ("attn_k", "attn_v")}
    new_mamba, new_ak, new_av, auxs = [], [], [], []
    shared_cfg = cfg.with_(attn_type="full", moe=False)
    bounds = [(g * E, E) for g in range(n_inv)]
    rem = cfg.num_layers - n_inv * E
    if rem:
        bounds.append((n_inv * E, rem))
    for gi, (lo, n) in enumerate(bounds):
        xs = (slice_tree(params["blocks"], lo, n), slice_tree(bank, lo, n),
              slice_tree(masks, lo, n) if masks is not None else None,
              meta[lo:lo + n],
              slice_tree(mamba_cache, lo, n) if use_cache else
              jnp.zeros((n,), jnp.float32))
        x, (nc, aux) = jax.lax.scan(body, x, xs)
        if use_cache:
            new_mamba.append(nc)
        auxs.append(aux)
        if gi < n_inv:
            attn_cache_l = None
            if use_cache:
                attn_cache_l = {"k": cache["attn_k"][gi],
                                "v": cache["attn_v"][gi]}
            x, ac, _ = _attn_block_apply(
                params["shared_attn"], x, shared_cfg, positions=positions,
                cache_l=attn_cache_l, cache_pos=cache_pos, is_global=True)
            if use_cache:
                new_ak.append(ac["k"])
                new_av.append(ac["v"])
    new_cache = None
    if use_cache:
        new_cache = jax.tree.map(lambda *a: jnp.concatenate(a, 0), *new_mamba)
        new_cache["attn_k"] = jnp.stack(new_ak)
        new_cache["attn_v"] = jnp.stack(new_av)
    x = norm_apply(x, params["final_norm"], cfg.norm)
    return x, new_cache, jnp.mean(jnp.concatenate(
        [jnp.atleast_1d(a) for a in auxs]))


# ----------------------------------------------------------------------------
# Heads
# ----------------------------------------------------------------------------

def lm_logits(params, hidden, cfg):
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", hidden, params["embed"])
    else:
        logits = jnp.einsum("btd,dv->btv", hidden, params["lm_head"])
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return ctx.hint(logits, "batch", "seq", "vocab")


def cls_logits(params, hidden, cfg, head_override=None):
    """Encoder classification: pooled [CLS] -> labels. head_override lets
    per-profile heads (X-PEFT trainables) replace the shared head."""
    pooled = jnp.tanh(hidden[:, 0, :].astype(jnp.float32)
                      @ params["cls"]["pool_w"] + params["cls"]["pool_b"])
    head = head_override if head_override is not None else params["cls"]
    if head is params["cls"]:
        return pooled @ head["head_w"] + head["head_b"]
    # per-example heads: [B, d, C] / [B, C]
    return jnp.einsum("bd,bdc->bc", pooled, head["head_w"]) + head["head_b"]
