"""Shared model primitives: norms, RoPE, activations, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis_size, dtype=jnp.bfloat16):
    """Fan-in normal init (truncated-normal-free for speed)."""
    w = jax.random.normal(key, shape, jnp.float32) / jnp.sqrt(in_axis_size)
    return w.astype(dtype)


def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_apply(x, params, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


def init_norm(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "sqrelu": lambda x: jnp.square(jax.nn.relu(x)),
            "identity": lambda x: x}[name]


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: [B, T, H, hd]; positions: [B, T] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [B, T, hd/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits
