"""Mixture-of-Experts with sort-based capacity dispatch (dropless-ish).

FLOP-honest dispatch (DESIGN.md §5 EP): instead of the GShard one-hot
dispatch einsum — whose [T,E,C] contraction doubles HLO FLOPs — tokens are
argsorted by expert id, scattered into an [E, C, d] buffer (overflow dropped,
capacity_factor-controlled), run through batched expert GEMMs, and
scatter-added back with their router weights. Experts shard over the `model`
mesh axis (expert parallelism); GSPMD inserts the dispatch collectives.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed import ctx
from repro.models.common import activation, dense_init


def init_moe(key, cfg, dtype) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), d, jnp.float32),
        "ew_g": dense_init(ks[1], (E, d, ff), d, dtype),
        "ew_u": dense_init(ks[2], (E, d, ff), d, dtype),
        "ew_d": dense_init(ks[3], (E, ff, d), ff, dtype),
    }


def capacity(tokens: int, cfg) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(cfg.top_k, min(tokens, c))


def moe_apply(params, x, cfg):
    """x: [B, T, d] -> ([B, T, d], aux). Dispatches to the shard_map EP path
    under a distributed mesh, else the local jnp path (smoke tests)."""
    from repro.distributed import ctx
    mesh = ctx.active_mesh()
    if (mesh is not None and "model" in mesh.shape
            and cfg.num_experts % mesh.shape["model"] == 0
            and cfg.moe_impl == "sort"):
        return _moe_shard_map(params, x, cfg, mesh)
    return _moe_local(params, x, cfg)


def _moe_local(params, x, cfg):
    B, T, d = x.shape
    x2 = x.reshape(B * T, d)
    n = B * T
    E, k = cfg.num_experts, cfg.top_k
    act = activation(cfg.act)

    gates = jnp.einsum("td,de->te", x2.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(gates, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                    # [n, k]
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    if cfg.moe_impl == "dense":
        # Reference path (smoke tests / tiny E): compute every expert.
        g = jnp.einsum("td,edf->tef", x2, params["ew_g"])
        u = jnp.einsum("td,edf->tef", x2, params["ew_u"])
        y_all = jnp.einsum("tef,efd->ted", act(g) * u, params["ew_d"])
        comb = jnp.zeros((n, E), jnp.float32).at[
            jnp.arange(n)[:, None], topi].add(topw)
        y = jnp.einsum("te,ted->td", comb.astype(y_all.dtype), y_all)
        return y.reshape(B, T, d), aux_loss(probs, topi, E)

    C = capacity(n, cfg)
    eids = topi.reshape(-1)                                  # [n*k]
    tids = jnp.repeat(jnp.arange(n), k)
    wts = topw.reshape(-1)

    order = jnp.argsort(eids)                                # stable
    se, st, sw = eids[order], tids[order], wts[order]
    starts = jnp.searchsorted(se, jnp.arange(E))
    pos = jnp.arange(n * k) - starts[se]                     # rank in expert
    # out-of-capacity rows get an out-of-range index -> dropped by the scatter
    pos = jnp.where(pos < C, pos, C + 1)

    buf = jnp.zeros((E, C, d), x2.dtype)
    buf = buf.at[se, pos].set(x2[st], mode="drop")
    buf = ctx.hint(buf, "expert", None, None)

    g = jnp.einsum("ecd,edf->ecf", buf, params["ew_g"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["ew_u"])
    h = act(g) * u
    h = ctx.hint(h, "expert", None, "mlp")
    y = jnp.einsum("ecf,efd->ecd", h, params["ew_d"])

    contrib = y.at[se, jnp.minimum(pos, C - 1)].get(mode="fill", fill_value=0)
    contrib = contrib * (pos < C)[:, None] * sw[:, None].astype(y.dtype)
    out = jnp.zeros((n, d), y.dtype).at[st].add(contrib)
    return out.reshape(B, T, d), aux_loss(probs, topi, E)


def aux_loss(probs, topi, E):
    """Switch-style load-balance loss: E * sum(f_e * p_e)."""
    hot = jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32)
    f = hot.mean(0)
    p = probs.mean(0)
    return E * jnp.sum(f * p)


# ----------------------------------------------------------------------------
# Expert-parallel shard_map path (DESIGN.md §5 EP)
# ----------------------------------------------------------------------------
# Key observation: activations are batch-sharded over (pod, data) and
# REPLICATED over "model", while experts are sharded over "model". So every
# model peer already holds the tokens it needs: dispatch is a purely LOCAL
# sort/scatter onto the peer's expert slice, followed by ONE psum("model") to
# combine expert outputs — no all-to-all, no GSPMD scatter replication
# (which blew per-device temp memory up 20x; see EXPERIMENTS.md §Perf).
# FSDP-sharded expert weights are explicitly all-gathered over "data" first
# (pinned to the ff dim by the sharding rules).

def _moe_shard_map(params, x, cfg, mesh):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    ba = tuple(a for a in ("pod", "data") if a in mesh.shape)
    msize = mesh.shape["model"]
    dsize = mesh.shape.get("data", 1)
    E_loc = cfg.num_experts // msize
    ff = cfg.d_ff
    ff_fsdp = dsize if (ff % dsize == 0 and
                        cfg.num_experts * cfg.d_model * ff >= 2 ** 16) else 1

    x_spec = P(ba if x.shape[0] % max(1, np.prod([mesh.shape[a] for a in ba])) == 0
               else None, None, None)
    wg_spec = P("model", None, "data" if ff_fsdp > 1 else None)
    wd_spec = P("model", "data" if ff_fsdp > 1 else None, None)

    def inner(router, ew_g, ew_u, ew_d, x_loc):
        if ff_fsdp > 1:
            ew_g = jax.lax.all_gather(ew_g, "data", axis=2, tiled=True)
            ew_u = jax.lax.all_gather(ew_u, "data", axis=2, tiled=True)
            ew_d = jax.lax.all_gather(ew_d, "data", axis=1, tiled=True)
        B, T, d = x_loc.shape
        n = B * T
        k = cfg.top_k
        act = activation(cfg.act)
        x2 = x_loc.reshape(n, d)

        gates = jnp.einsum("td,de->te", x2.astype(jnp.float32), router)
        probs = jax.nn.softmax(gates, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

        j = jax.lax.axis_index("model")
        local = (topi >= j * E_loc) & (topi < (j + 1) * E_loc)
        eids = jnp.where(local, topi - j * E_loc, E_loc).reshape(-1)
        tids = jnp.repeat(jnp.arange(n), k)
        wts = (topw * local).reshape(-1)

        C = capacity(n, cfg)
        order = jnp.argsort(eids)
        se, st, sw = eids[order], tids[order], wts[order]
        starts = jnp.searchsorted(se, jnp.arange(E_loc + 1))
        pos = jnp.arange(n * k) - starts[jnp.minimum(se, E_loc)]
        pos = jnp.where((pos < C) & (se < E_loc), pos, C + 1)

        buf = jnp.zeros((E_loc, C, d), x2.dtype)
        buf = buf.at[se, pos].set(x2[st], mode="drop")
        g = jnp.einsum("ecd,edf->ecf", buf, ew_g)
        u = jnp.einsum("ecd,edf->ecf", buf, ew_u)
        y = jnp.einsum("ecf,efd->ecd", act(g) * u, ew_d)

        contrib = y.at[jnp.minimum(se, E_loc - 1),
                       jnp.minimum(pos, C - 1)].get(mode="fill", fill_value=0)
        contrib = contrib * ((pos < C)[:, None] * sw[:, None]).astype(y.dtype)
        out = jnp.zeros((n, d), y.dtype).at[st].add(contrib)
        out = jax.lax.psum(out, "model")
        aux = aux_loss(probs, topi, cfg.num_experts)
        aux = jax.lax.pmean(aux, ba) if ba else aux
        return out.reshape(B, T, d), aux

    fn = shard_map(inner, mesh=mesh,
                   in_specs=(P(None, None), wg_spec, wg_spec, wd_spec, x_spec),
                   out_specs=(x_spec, P()),
                   check_rep=False)
    return fn(params["router"], params["ew_g"], params["ew_u"],
              params["ew_d"], x)
