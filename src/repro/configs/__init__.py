"""Arch registry: importing this package registers every config."""
from repro.configs.base import (  # noqa: F401
    LM_SHAPES,
    LONG_CONTEXT_ARCHS,
    ModelConfig,
    ShapeConfig,
    XPeftConfig,
    get_config,
    get_shape,
    list_archs,
    reduce_for_smoke,
    shapes_for,
)

# per-arch modules (registration side effects)
from repro.configs import (  # noqa: F401
    bert_base_xpeft,
    dbrx_132b,
    deepseek_7b,
    gemma3_27b,
    gemma_2b,
    llava_next_34b,
    musicgen_medium,
    qwen15_05b,
    qwen3_moe_30b,
    rwkv6_7b,
    zamba2_12b,
)

ASSIGNED_ARCHS = (
    "gemma-2b",
    "deepseek-7b",
    "gemma3-27b",
    "qwen1.5-0.5b",
    "dbrx-132b",
    "qwen3-moe-30b-a3b",
    "rwkv6-7b",
    "musicgen-medium",
    "zamba2-1.2b",
    "llava-next-34b",
)
