"""bert-base-xpeft [encoder] — the PAPER's own configuration.

bert-base-uncased: 12L d=768 12H d_ff=3072 vocab=30522, learned positions,
LayerNorm, vanilla GeLU FFN, classification head. X-PEFT defaults match the
paper: Pfeiffer r=16 -> bottleneck b=48, N adapters, k=50 hard masks.
"""
from repro.configs.base import ModelConfig, register


@register
def bert_base_xpeft() -> ModelConfig:
    cfg = ModelConfig(
        name="bert-base-xpeft",
        family="encoder",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=30522,
        causal=False,
        pos="learned",
        max_seq_len=512,
        norm="layernorm",
        act="gelu",
        mlp_type="vanilla",
        num_labels=15,           # LaMP news categories
    )
    return cfg.with_xpeft(num_adapters=100, bottleneck=48, k=50,
                          mask_type="hard", max_profiles=512)
