"""gemma3-27b [dense]: 62L d=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.

5:1 local:global sliding-window mix, 128k+ context, head_dim=128 (HF config —
not d_model/num_heads). [hf:google/gemma-3-*; unverified]
"""
from repro.configs.base import ModelConfig, register


@register
def gemma3_27b() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        attn_type="sliding_mix",
        sliding_window=1024,
        global_every=6,          # 5 local : 1 global
        act="gelu",
        mlp_type="glu",
        embed_scale=True,
        tie_embeddings=True,
        rope_theta=1000000.0,
    )
