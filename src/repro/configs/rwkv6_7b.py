"""rwkv6-7b [ssm]: 32L d=4096 attention-free d_ff=14336 vocab=65536.

Finch: data-dependent per-channel decay. head_size=64 -> 64 heads.
[arXiv:2404.05892; hf]
"""
from repro.configs.base import ModelConfig, register


@register
def rwkv6_7b() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,            # d_model / head_size(64)
        num_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        attn_type="none",
        block_pattern="rwkv",
        pos="none",
        act="sqrelu",
        la_chunk=128,
    )
