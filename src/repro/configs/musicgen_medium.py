"""musicgen-medium [audio]: 48L d=1536 24H (kv=24) d_ff=6144 vocab=2048.

Decoder-only over EnCodec tokens; the EnCodec/text-conditioning frontend is a
STUB — input_specs() provides precomputed conditioning frame embeddings as a
prefix. [arXiv:2306.05284; hf]
"""
from repro.configs.base import ModelConfig, register


@register
def musicgen_medium() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        act="gelu",
        mlp_type="glu",
        frontend="audio_frames",
        num_prefix_tokens=64,    # precomputed conditioning frames
    )
