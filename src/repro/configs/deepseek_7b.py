"""deepseek-7b [dense]: 30L d=4096 32H (kv=32) d_ff=11008 vocab=102400.

Llama-architecture (SwiGLU, RoPE, RMSNorm). [arXiv:2401.02954; hf]
"""
from repro.configs.base import ModelConfig, register


@register
def deepseek_7b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        family="dense",
        num_layers=30,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=11008,
        vocab_size=102400,
        act="silu",
        mlp_type="glu",
        rope_theta=10000.0,
    )
