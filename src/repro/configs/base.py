"""Config system: model / X-PEFT / shape / run configs and the arch registry.

Every assigned architecture is a `ModelConfig` built in its own module under
``repro.configs``; ``get_config(name)`` resolves it, and
``reduce_for_smoke(cfg)`` derives the CPU-runnable reduced config of the same
family used by the per-arch smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


# Adapter families a bank segment can hold (heterogeneous banks): the
# mask index space is ONE contiguous [0, N) range partitioned into typed
# segments; a profile's k-sparse mask selects across families and
# aggregation produces one per-type aggregate per layer.
ADAPTER_TYPES = ("bottleneck", "lora", "ia3", "prefix")

MASK_TYPES = ("soft", "hard")
AGGREGATES = ("dense", "sparse")
BANK_QUANTS = ("none", "int8", "int4")


@dataclass(frozen=True)
class XPeftConfig:
    """The paper's technique as a first-class feature of the framework."""

    enabled: bool = True
    num_adapters: int = 256          # N — size of the shared adapter bank
    bottleneck: int = 64             # b — adapter bottleneck dim
    k: int = 50                      # top-k for hard masks
    mask_type: str = "hard"          # "soft" | "hard"
    tau: float = 1.0                 # gumbel-softmax temperature
    nu: float = 1.0                  # gumbel noise level
    adapter_activation: str = "gelu"  # "gelu" | "identity" (literal paper form)
    # "dense": masks @ bank einsum (soft or ST-hard training path)
    # "sparse": k-sparse gather-sum (inference / frozen-index training)
    aggregate: str = "dense"
    # kernel backend for adapter application/aggregation hot paths
    # (kernels/ops.py): "auto" = compiled Pallas on TPU, jnp ref elsewhere;
    # "pallas" | "interpret" | "ref" force a backend.
    kernel_impl: str = "auto"
    # serving-side bank/record quantization (repro/quant): "none" keeps the
    # bf16/fp32 bank bitwise-identical to the unquantized path; "int8" is
    # symmetric per-row with fp16 scales; "int4" is group-wise packed.
    # Training always stays bf16/fp32 — only the serve hot paths (k-sparse
    # admission aggregation, decode) read quantized rows, dequantized
    # in-register by the kernels in kernels/*_quant.py.
    bank_quant: str = "none"         # "none" | "int8" | "int4"
    quant_group: int = 32            # int4 group-size upper bound (per row)
    max_profiles: int = 1024         # rows in the per-profile mask table
    # Heterogeneous bank layout: ((type, count), ...) partitioning the N
    # mask indices into typed segments in order. () means the type-pure
    # bottleneck bank — the historical layout, bitwise-identical to the
    # pre-hetero code paths. LoRA pairs share the bottleneck rank (b) so
    # the k-sparse aggregation kernels are reused row-for-row; IA3 rows
    # are d-vector scale DELTAS (selected sum s, applied as x * (1 + s));
    # prefix rows are `prefix_tokens` learned post-RoPE KV positions.
    bank_spec: Tuple[Tuple[str, int], ...] = ()
    prefix_tokens: int = 4           # virtual KV tokens per prefix slot

    def __post_init__(self):
        # normalize bank_spec (lists from JSON/kwargs -> hashable tuples)
        spec = tuple((str(t), int(c)) for t, c in self.bank_spec)
        object.__setattr__(self, "bank_spec", spec)
        if self.mask_type not in MASK_TYPES:
            raise ValueError(
                f"mask_type {self.mask_type!r} not in {MASK_TYPES}")
        if self.aggregate not in AGGREGATES:
            raise ValueError(
                f"aggregate {self.aggregate!r} not in {AGGREGATES}")
        if self.bank_quant not in BANK_QUANTS:
            raise ValueError(
                f"bank_quant {self.bank_quant!r} not in {BANK_QUANTS}")
        if self.k > self.num_adapters:
            raise ValueError(
                f"k={self.k} > num_adapters={self.num_adapters}: a hard "
                "mask cannot select more rows than the bank holds")
        for t, c in spec:
            if t not in ADAPTER_TYPES:
                raise ValueError(
                    f"bank_spec type {t!r} not in {ADAPTER_TYPES}")
            if c <= 0:
                raise ValueError(f"bank_spec count {c} for {t!r} must be "
                                 "positive")
        if spec and sum(c for _, c in spec) != self.num_adapters:
            raise ValueError(
                f"bank_spec counts {[c for _, c in spec]} sum to "
                f"{sum(c for _, c in spec)} != num_adapters="
                f"{self.num_adapters} — segments must tile the mask "
                "index space exactly")

    def segments(self) -> Tuple[Tuple[str, int, int], ...]:
        """((type, offset, count), ...) over the unified [0, N) index
        space; the empty bank_spec resolves to one bottleneck segment."""
        spec = self.bank_spec or (("bottleneck", self.num_adapters),)
        out, off = [], 0
        for t, c in spec:
            out.append((t, off, c))
            off += c
        return tuple(out)

    @property
    def is_hetero(self) -> bool:
        """True iff any non-bottleneck segment exists — every hetero code
        path is gated on this so type-pure configs keep the exact
        (bitwise) historical code paths."""
        return any(t != "bottleneck" for t, _ in self.bank_spec)

    @property
    def has_prefix(self) -> bool:
        return any(t == "prefix" for t, _ in self.bank_spec)

    def segment_counts(self) -> dict:
        """{type: total count} over the resolved segments."""
        out = {}
        for t, _, c in self.segments():
            out[t] = out.get(t, 0) + c
        return out


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|ssm|hybrid|audio|vlm|encoder
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    attn_type: str = "full"          # "full" | "sliding_mix" | "none"
    sliding_window: int = 1024
    global_every: int = 6            # gemma3: 1 global layer per this many
    qkv_bias: bool = False
    causal: bool = True
    pos: str = "rope"                # "rope" | "learned" | "none"
    rope_theta: float = 10000.0
    max_seq_len: int = 524288
    logit_softcap: float = 0.0

    # mlp
    act: str = "silu"                # glu gate activation (silu=SwiGLU, gelu=GeGLU)
    mlp_type: str = "glu"            # "glu" | "vanilla"

    # moe
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_impl: str = "sort"           # "sort" | "dense"

    # ssm / hybrid
    block_pattern: str = "attn"      # "attn" | "rwkv" | "mamba" | "zamba"
    ssm_state: int = 64
    mamba_headdim: int = 64
    shared_attn_every: int = 6       # zamba2 shared attention cadence
    la_chunk: int = 128              # chunked linear-attention chunk length

    # modality frontend (stub: embeddings arrive precomputed via input_specs)
    frontend: str = "none"           # "none" | "audio_frames" | "vision_patches"
    num_prefix_tokens: int = 0

    # decode fast path (serve): `decode_fused` routes T=1 cached decode
    # through the per-layer megakernel (kernels/decode_fused.py — norm,
    # attention, MLP and the X-PEFT adapter in ONE program per layer,
    # backend picked by xpeft.kernel_impl); `spec_enable` turns on
    # self-speculative decoding in the continuous engine: the bare PLM
    # (zero-adapter masks, bitwise the frozen model) drafts `spec_gamma`
    # tokens per slot and the adapted model verifies them in one
    # prefill-shaped step. The two are exclusive per engine: the verify
    # forward runs at T=gamma+1 where the megakernel does not apply, so
    # mixing them would break the spec-vs-nonspec bitwise parity gate.
    decode_fused: bool = False
    spec_enable: bool = False
    spec_gamma: int = 3              # draft tokens per speculation round

    # misc
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm"
    cache_dtype: str = ""            # KV cache dtype ("" = model dtype);
                                     # e.g. "float8_e4m3fn" halves cache BW
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma multiplies embeddings by sqrt(d)
    dtype: str = "bfloat16"
    remat: str = "full"              # "none" | "full" | "dots"
    num_labels: int = 0              # classification head width (encoder/paper)

    xpeft: XPeftConfig = field(default_factory=XPeftConfig)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def with_xpeft(self, **kw) -> "ModelConfig":
        return replace(self, xpeft=replace(self.xpeft, **kw))


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


# The LM shape set assigned to every arch in the pool.
LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

# Archs allowed to run long_500k (sub-quadratic long-context decode); the
# rest skip it per DESIGN.md §4. gemma3 qualifies via 5:1 sliding windows,
# rwkv6 via O(1) state, zamba2 as the hybrid.
LONG_CONTEXT_ARCHS = frozenset({"rwkv6-7b", "zamba2-1.2b", "gemma3-27b"})


# the paper's own training shape (bert-base + GLUE: seq 128, batch 64)
PAPER_SHAPE = ShapeConfig("paper_128", 128, 64, "train")


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES + (PAPER_SHAPE,):
        if s.name == name:
            return s
    raise KeyError(name)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The shape cells this arch actually runs (skips documented in DESIGN.md)."""
    out = []
    for s in LM_SHAPES:
        if s.kind == "decode" and cfg.family == "encoder":
            continue  # encoder-only: no decode step
        if s.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
            continue  # pure full-attention: quadratic-context skip
        out.append(s)
    return tuple(out)


# ----------------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------------
_REGISTRY = {}


def register(fn):
    """Decorator: register a zero-arg config builder under its cfg.name."""
    cfg = fn()
    _REGISTRY[cfg.name] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (triggers per-arch module imports)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs():
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests.

    Keeps the structural features (GQA ratio, GLU type, MoE routing, block
    pattern, sliding mix, prefix frontend) and shrinks every dimension.
    """
    kv = max(1, min(cfg.num_kv_heads, 2 if cfg.num_kv_heads < cfg.num_heads else 4))
    heads = 4
    if cfg.num_kv_heads == cfg.num_heads:
        kv = heads
    elif cfg.num_kv_heads == 1:
        kv = 1
    else:
        kv = 2
    small = cfg.with_(
        num_layers=4 if cfg.block_pattern == "zamba" else 2,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=96 if not cfg.moe else 32,
        vocab_size=512,
        num_experts=min(cfg.num_experts, 8) if cfg.moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.moe else 0,
        sliding_window=8,
        global_every=2,
        shared_attn_every=2,
        ssm_state=8,
        mamba_headdim=8,
        la_chunk=8,
        num_prefix_tokens=4 if cfg.num_prefix_tokens else 0,
        max_seq_len=256,
        remat="none",
        dtype="float32",
    )
    return small.with_xpeft(num_adapters=8, bottleneck=4, k=2, max_profiles=8)
