"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4) per-expert d_ff=768
vocab=151936, MoE 128 experts top-8. head_dim=128 (HF config).
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.configs.base import ModelConfig, register


@register
def qwen3_moe_30b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        moe=True,
        num_experts=128,
        top_k=8,
        act="silu",
        mlp_type="glu",
        rope_theta=1000000.0,
    )
