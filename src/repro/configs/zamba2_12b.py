"""zamba2-1.2b [hybrid]: 38L d=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64. Mamba2 backbone + shared attention block (applied every 6th
layer with shared weights; the shared block carries the d_ff=8192 MLP).
[arXiv:2411.15242; hf]
"""
from repro.configs.base import ModelConfig, register


@register
def zamba2_12b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        block_pattern="zamba",
        ssm_state=64,
        mamba_headdim=64,
        shared_attn_every=6,
        pos="rope",
        act="gelu",
        mlp_type="glu",
        la_chunk=128,
    )
