"""qwen1.5-0.5b [dense]: 24L d=1024 16H (kv=16) d_ff=2816 vocab=151936.

QKV bias enabled. [hf:Qwen/Qwen1.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig, register


@register
def qwen15_05b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=2816,
        vocab_size=151936,
        qkv_bias=True,
        act="silu",
        mlp_type="glu",
        rope_theta=1000000.0,
    )
