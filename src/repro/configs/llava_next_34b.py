"""llava-next-34b [vlm]: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Backbone only; the anyres vision tower is a STUB — input_specs() provides
precomputed patch embeddings as a prefix. [hf:llava-hf/...; unverified]
"""
from repro.configs.base import ModelConfig, register


@register
def llava_next_34b() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        num_layers=60,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab_size=64000,
        act="silu",
        mlp_type="glu",
        frontend="vision_patches",
        num_prefix_tokens=128,   # anyres patch embeddings (stub)
        rope_theta=5000000.0,
    )
