"""gemma-2b [dense]: 18L d=2048 8H MQA(kv=1) d_ff=16384 vocab=256000.

GeGLU, head_dim=256, MQA. [arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig, register


@register
def gemma_2b() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        act="gelu",              # GeGLU
        mlp_type="glu",
        embed_scale=True,
        tie_embeddings=True,     # gemma ties lm_head to embeddings
        rope_theta=10000.0,
    )
