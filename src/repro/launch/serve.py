"""Serving launcher: continuous-batching multi-profile inference demo on
the layered engine (scheduler / slot-state / profile-cache).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --requests 8 --slots 4 --sync-every 8

Multi-device (same engine code, GSPMD-sharded; on CPU validate with
XLA_FLAGS=--xla_force_host_platform_device_count=8):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.serve --smoke --mesh 4x2:data,model
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--profiles", type=int, default=4)
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode steps between host syncs (device-resident "
                    "slot state; 1 = paper-era per-token round trips)")
    ap.add_argument("--cache-mb", type=int, default=64,
                    help="profile-cache capacity in MiB (0 disables)")
    ap.add_argument("--no-precompute", action="store_true",
                    help="paper-faithful per-step mask aggregation")
    ap.add_argument("--mesh", default="",
                    help="e.g. 4x2:data,model — GSPMD-shard the engine "
                    "(slots over data, bank d_model/heads/vocab TP over "
                    "model)")
    from repro import obs as OBS
    OBS.add_cli_args(ap)  # --metrics-json PATH, --trace PATH
    args = ap.parse_args()

    from repro.configs import get_config, reduce_for_smoke
    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.launch.mesh import parse_mesh
    from repro.models import init_lm
    from repro.serve.engine import Request, ServeEngine

    mesh = parse_mesh(args.mesh)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    key = jax.random.key(0)
    params = init_lm(key, cfg)

    xp = cfg.xpeft
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(args.profiles):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    print(f"profiles: {args.profiles} x {store.bytes_per_profile()} B each "
          f"(masks, byte-level)")

    obs = OBS.from_cli_args(args)
    eng = ServeEngine(cfg, params, store, max_slots=args.slots,
                      max_seq=args.max_seq,
                      precompute=not args.no_precompute,
                      sync_every=args.sync_every,
                      cache_bytes=args.cache_mb << 20, mesh=mesh, obs=obs)
    if mesh is not None:
        rb = eng.resident_bytes_per_device()
        print(f"mesh {dict(mesh.shape)}: {rb['total']} resident B/device "
              f"(params {rb['params']}, cache {rb['cache']})")
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=rng.integers(4, 17)),
                    profile_id=i % args.profiles,
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    steps = eng.run_until_drained(list(reqs))
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests / {toks} tokens in {steps} engine "
          f"steps, {dt:.1f}s ({toks / dt:.1f} tok/s)")
    st = eng.serve_stats()
    print(f"profile cache: hit rate {st['profile_cache']['hit_rate']}, "
          f"{st['profile_cache']['entries']} entries / "
          f"{st['profile_cache']['bytes']} B; "
          f"prefill occupancy {st['prefill_occupancy']} over "
          f"{st['prefill_batches']} batches; "
          f"{st['syncs_per_token']} host syncs/token "
          f"(sync_every={st['sync_every']})")
    for r in reqs[:3]:
        print(f"  req {r.uid} (profile {r.profile_id}): {r.generated}")
    if obs is not None:
        obs.export(args.metrics_json or None, args.trace or None)
        cats = obs.tracer.category_counts()
        print(f"obs: {sum(cats.values())} trace events {cats}; "
              f"retrace watches {obs.sentinel.counts()}")


if __name__ == "__main__":
    main()
