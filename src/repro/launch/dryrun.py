"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: abstract params
(jax.eval_shape — no allocation), production shardings, GSPMD compile, then
memory_analysis() (fits?) + cost_analysis() (FLOPs/bytes) + collective-bytes
parsing for the §Roofline table.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --arch dbrx-132b --shape train_4k \
      --mesh single --variant remat_dots
"""
# The VERY FIRST lines, before any jax-importing module: the dry-run (and
# only the dry-run) needs 512 placeholder devices.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import numpy as np       # noqa: E402
import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis import model_flops, roofline_terms  # noqa: E402
from repro.analysis.hlo_cost import analyze as hlo_analyze  # noqa: E402
from repro.configs import (ASSIGNED_ARCHS, get_config, get_shape,  # noqa: E402
                           shapes_for)
from repro.distributed import ctx  # noqa: E402
from repro.distributed.sharding import (batch_specs, cache_specs,  # noqa: E402
                                        param_specs,
                                        sharded_bytes_per_device,
                                        to_shardings)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as MDL  # noqa: E402
from repro.serve.steps import make_decode_step, make_prefill_step  # noqa: E402
from repro.train.steps import init_train_state, make_train_step  # noqa: E402


# ----------------------------------------------------------------------------
# Variants (the §Perf hillclimb levers — baseline first)
# ----------------------------------------------------------------------------
VARIANTS = {
    "baseline": {},
    "remat_dots": {"cfg": {"remat": "dots"}},
    "remat_none": {"cfg": {"remat": "none"}},
    "no_fsdp": {"fsdp": False},
    "precomputed_adapters": {"precomputed": True},   # decode fast path
    "sparse_k_agg": {"sparse_agg": True},            # k-sparse decode agg
    "soft_masks": {"xpeft": {"mask_type": "soft"}},
    "bank_n_shard": {"overrides": {"bank_a": ("tp_n", None, None),
                                   "bank_b": ("tp_n", None, None)},
                     "logical_map": {"tp_n": "model"}},
    "seq_sp": {"seq_sp": True},  # Megatron-SP residuals (refuted; §Perf)
    # q-seq context parallelism: shard Q's sequence over model and gather
    # K/V per layer (ring-attention-like) instead of per-tile AV reduces
    "cp_qseq": {"act_rules": {"q_seq": "model", "kv_seq": None}},
    # round-2 combos + quantized KV cache
    "cp_qseq_remat_dots": {"act_rules": {"q_seq": "model", "kv_seq": None},
                           "cfg": {"remat": "dots"}},
    "kv_f8": {"cfg": {"cache_dtype": "float8_e4m3fn"}},
    "precomputed_kv_f8": {"precomputed": True,
                          "cfg": {"cache_dtype": "float8_e4m3fn"}},
    # pure FSDP: drop tensor parallelism entirely, shard batch over ALL
    # axes; weights gathered per layer (ZeRO-3). Viable when per-layer
    # weights fit VMEM-adjacent HBM transients (not for 132B dbrx).
    "no_tp": {
        "logical_map": {"vocab": None, "heads": None, "kv_heads": None,
                        "mlp": None, "expert": None, "tp_d": None,
                        "mlp_fsdp": "data"},
        "act_rules": {"batch": ("pod", "data", "model"), "heads": None,
                      "kv_heads": None, "kv_seq": None, "mlp": None,
                      "vocab": None, "expert": None},
    },
    "no_tp_remat_dots": {
        "cfg": {"remat": "dots"},
        "logical_map": {"vocab": None, "heads": None, "kv_heads": None,
                        "mlp": None, "expert": None, "tp_d": None,
                        "mlp_fsdp": "data"},
        "act_rules": {"batch": ("pod", "data", "model"), "heads": None,
                      "kv_heads": None, "kv_seq": None, "mlp": None,
                      "vocab": None, "expert": None},
    },
}


def input_specs(cfg, shape):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, T = shape.global_batch, shape.seq_len
    xp = cfg.xpeft
    L, N, b, d = cfg.num_layers, xp.num_adapters, xp.bottleneck, cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        lab = sds((B,), i32) if cfg.num_labels else sds((B, T), i32)
        batch = {"tokens": sds((B, T), i32), "labels": lab,
                 "profile_ids": sds((B,), i32)}
        if cfg.num_prefix_tokens:
            batch["prefix_embeds"] = sds((B, cfg.num_prefix_tokens, d), dt)
        return batch
    masks = {"w_a": sds((B, L, N), f32), "w_b": sds((B, L, N), f32),
             "ln_scale": sds((B, L, b), f32), "ln_bias": sds((B, L, b), f32)}
    if shape.kind == "prefill":
        out = {"tokens": sds((B, T), i32), "masks": masks}
        if cfg.num_prefix_tokens:
            out["prefix_embeds"] = sds((B, cfg.num_prefix_tokens, d), dt)
        return out
    return {"tokens": sds((B, 1), i32), "cache_pos": sds((), i32),
            "masks": masks}


def _precomputed_masks(cfg, B):
    xp = cfg.xpeft
    L, b, d = cfg.num_layers, xp.bottleneck, cfg.d_model
    dt, f32 = jnp.dtype(cfg.dtype), jnp.float32
    sds = jax.ShapeDtypeStruct
    return {"a_hat": sds((B, L, d, b), dt), "b_hat": sds((B, L, b, d), dt),
            "ln_scale": sds((B, L, b), f32), "ln_bias": sds((B, L, b), f32)}


def _sparse_masks(cfg, B):
    xp = cfg.xpeft
    L, b, k = cfg.num_layers, xp.bottleneck, xp.k
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct
    return {"idx_a": sds((B, L, k), i32), "w_a": sds((B, L, k), f32),
            "idx_b": sds((B, L, k), i32), "w_b": sds((B, L, k), f32),
            "ln_scale": sds((B, L, b), f32), "ln_bias": sds((B, L, b), f32)}


def _mask_shardings(masks_abs, mesh):
    """Per-request masks: batch over (pod,data); a_hat/b_hat d over model."""
    def one(path, x):
        nd = len(x.shape)
        ba = tuple(a for a in ("pod", "data") if a in mesh.shape)
        n = int(np.prod([mesh.shape[a] for a in ba]))
        first = ba if x.shape[0] % n == 0 and x.shape[0] >= n else None
        spec = [first] + [None] * (nd - 1)
        name = path.rsplit("/", 1)[-1]
        if name == "a_hat" and x.shape[2] % mesh.shape.get("model", 1) == 0:
            spec[2] = "model"
        if name == "b_hat" and x.shape[3] % mesh.shape.get("model", 1) == 0:
            spec[3] = "model"
        return P(*spec)
    from repro.utils import map_with_path
    return map_with_path(one, masks_abs)


# ----------------------------------------------------------------------------
# Cell lowering
# ----------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline", xpeft_n: int = 256):
    vspec = VARIANTS[variant]
    cfg = get_config(arch)
    if cfg.name != "bert-base-xpeft":
        cfg = cfg.with_xpeft(num_adapters=xpeft_n, bottleneck=64)
    if "cfg" in vspec:
        cfg = cfg.with_(**vspec["cfg"])
    if "xpeft" in vspec:
        cfg = cfg.with_xpeft(**vspec["xpeft"])
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ndev = int(np.prod(list(mesh.shape.values())))
    fsdp = vspec.get("fsdp", True)
    sh_kw = {k: vspec[k] for k in ("overrides", "logical_map") if k in vspec}

    act_rules = {}
    if shape.global_batch == 1:
        # batch=1 long-context: sequence parallelism over the data axis —
        # the KV/seq hints must agree with the cache specs
        act_rules = {"seq": "data", "kv_seq": ("data", "model"),
                     "batch": None}
    if "act_rules" in vspec:
        act_rules.update(vspec["act_rules"])
    elif vspec.get("seq_sp") and shape.kind == "train" \
            and shape.seq_len % 16 == 0:
        # Megatron-SP residual sharding — kept as a VARIANT: measured on
        # this GSPMD version it increased collective volume 6x (hypothesis
        # refuted; see EXPERIMENTS.md §Perf).
        act_rules = {"seq": "model"}

    t0 = time.time()
    with ctx.mesh_context(mesh, act_rules=act_rules):
        if shape.kind == "train":
            lowered, state_bytes = _lower_train(cfg, shape, mesh, fsdp, sh_kw)
        else:
            lowered, state_bytes = _lower_serve(cfg, shape, mesh, fsdp, vspec,
                                                sh_kw)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis() or {}
    if isinstance(xla_cost, (list, tuple)):  # older jax: [per-device dict]
        xla_cost = xla_cost[0] if xla_cost else {}
    # XLA's HloCostAnalysis counts while bodies ONCE (verified); re-derive
    # flops/bytes/collectives with trip-count multiplication from the HLO.
    an = hlo_analyze(compiled.as_text())
    flops = an["flops"]
    acc_bytes = an["bytes"]
    colls = an["collectives"]
    terms = roofline_terms(flops, acc_bytes, colls["total"])
    mflops = model_flops(cfg, shape, ndev, workload="xpeft")

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant, "ok": True,
        "compile_s": round(compile_s, 2),
        "num_devices": ndev,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "state_bytes_per_dev_analytic": int(state_bytes),
        },
        "flops_per_dev": flops,
        "bytes_per_dev": acc_bytes,
        "collective_bytes_per_dev": colls["total"],
        "collectives": {k: int(v) for k, v in colls.items()},
        "xla_cost_flops_unscaled": float(xla_cost.get("flops", 0.0)),
        "roofline": terms,
        "model_flops_per_dev": mflops,
        "useful_flops_ratio": (mflops / flops) if flops else 0.0,
    }


def _lower_train(cfg, shape, mesh, fsdp, sh_kw):
    state_abs = jax.eval_shape(
        lambda k: init_train_state(k, cfg, "xpeft"), jax.random.key(0))
    batch_abs = input_specs(cfg, shape)
    key_abs = jax.eval_shape(lambda: jax.random.key(0))

    state_specs = param_specs(state_abs, mesh, fsdp=fsdp, **sh_kw)
    state_sh = to_shardings(state_specs, mesh)
    batch_sh = to_shardings(
        batch_specs(batch_abs, mesh, shape.global_batch), mesh)
    key_sh = NamedSharding(mesh, P())
    state_bytes = sharded_bytes_per_device(state_abs, state_specs, mesh)

    step = make_train_step(cfg, "xpeft", lr=1e-5)
    jitted = jax.jit(step, in_shardings=(state_sh, batch_sh, key_sh),
                     out_shardings=(state_sh, None),
                     donate_argnums=(0,))
    return jitted.lower(state_abs, batch_abs, key_abs), state_bytes


def _lower_serve(cfg, shape, mesh, fsdp, vspec, sh_kw):
    params_abs = jax.eval_shape(lambda k: MDL.init_lm(k, cfg),
                                jax.random.key(0))
    params_specs_ = param_specs(params_abs, mesh, fsdp=fsdp, **sh_kw)
    params_sh = to_shardings(params_specs_, mesh)
    state_bytes = sharded_bytes_per_device(params_abs, params_specs_, mesh)
    B, S = shape.global_batch, shape.seq_len
    S_cache = S + (cfg.num_prefix_tokens or 0)  # prefix lives in the cache
    inp = input_specs(cfg, shape)
    if vspec.get("precomputed"):
        inp["masks"] = _precomputed_masks(cfg, B)
    elif vspec.get("sparse_agg"):
        inp["masks"] = _sparse_masks(cfg, B)
    masks_sh = to_shardings(_mask_shardings(inp["masks"], mesh), mesh)
    ba = tuple(a for a in ("pod", "data") if a in mesh.shape)
    nb = int(np.prod([mesh.shape[a] for a in ba]))
    tok_spec = P(ba) if B % nb == 0 and B >= nb else P(None)
    tok_sh = NamedSharding(mesh, P(*tok_spec, None))

    if shape.kind == "prefill":
        cache_abs = jax.eval_shape(lambda: MDL.init_cache(cfg, B, S_cache))
        cache_specs_ = cache_specs(cache_abs, mesh, cfg, B)
        cache_sh = to_shardings(cache_specs_, mesh)
        state_bytes += sharded_bytes_per_device(cache_abs, cache_specs_, mesh)
        prefill = make_prefill_step(cfg)

        def cell(params, tokens, cache, masks, prefix=None):
            logits, cache = prefill(params, tokens, cache,
                                    profile_masks=masks,
                                    prefix_embeds=prefix)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

        args = [params_abs, inp["tokens"], cache_abs, inp["masks"]]
        in_sh = [params_sh, tok_sh, cache_sh, masks_sh]
        if "prefix_embeds" in inp:
            args.append(inp["prefix_embeds"])
            in_sh.append(NamedSharding(mesh, P(*tok_spec, None, None)))
        jitted = jax.jit(
            cell, in_shardings=tuple(in_sh),
            out_shardings=(NamedSharding(mesh, P(*tok_spec)), cache_sh),
            donate_argnums=(2,))
        return jitted.lower(*args), state_bytes

    # decode
    cache_abs = jax.eval_shape(lambda: MDL.init_cache(cfg, B, S_cache))
    cache_specs_ = cache_specs(cache_abs, mesh, cfg, B)
    cache_sh = to_shardings(cache_specs_, mesh)
    state_bytes += sharded_bytes_per_device(cache_abs, cache_specs_, mesh)
    decode = make_decode_step(cfg)

    def cell(params, tokens, cache, cache_pos, masks):
        logits, cache = decode(params, tokens, cache, cache_pos,
                               profile_masks=masks)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

    jitted = jax.jit(
        cell,
        in_shardings=(params_sh, tok_sh, cache_sh,
                      NamedSharding(mesh, P()), masks_sh),
        out_shardings=(NamedSharding(mesh, P(*tok_spec)), cache_sh),
        donate_argnums=(2,))
    return jitted.lower(params_abs, inp["tokens"], cache_abs,
                        inp["cache_pos"], inp["masks"]), state_bytes


# ----------------------------------------------------------------------------
# CLI
# ----------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--xpeft-n", type=int, default=256)
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in shapes_for(cfg)] \
            if args.shape == "all" else args.shape.split(",")
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape_name}_{'multi' if multi else 'single'}" \
                      f"_{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = lower_cell(arch, shape_name, multi, args.variant,
                                     args.xpeft_n)
                    n_ok += 1
                    print(f"OK   {tag}: compile={rec['compile_s']}s "
                          f"dom={rec['roofline']['dominant']} "
                          f"flops/dev={rec['flops_per_dev']:.3e}")
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi else "16x16",
                           "variant": args.variant, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    n_fail += 1
                    print(f"FAIL {tag}: {type(e).__name__}: {e}")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
