"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries
only gradient all-reduce (or pipeline stages) — DCN-friendly.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) devices)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
