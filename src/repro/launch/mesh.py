"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis carries
only gradient all-reduce (or pipeline stages) — DCN-friendly.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist from jax 0.5;
    on older versions every axis is Auto-typed already, so the plain call is
    equivalent. Everything in-repo that builds a mesh goes through here.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires >= prod(shape) devices)."""
    return make_mesh_compat(shape, axes)


def parse_mesh(spec: str):
    """``"4x2:data,model"`` -> Mesh (or None for ``""``).

    The one --mesh grammar every launcher shares: shape "4x2" cross axis
    names "data,model". Validated on CPU with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    if not spec:
        return None
    try:
        shape_s, axes_s = spec.split(":")
        shape = tuple(int(x) for x in shape_s.split("x"))
        axes = tuple(a for a in axes_s.split(",") if a)
    except ValueError as e:
        raise ValueError(f"bad --mesh {spec!r}; want e.g. 4x2:data,model") \
            from e
    if len(shape) != len(axes):
        raise ValueError(f"--mesh {spec!r}: {len(shape)} dims for "
                         f"{len(axes)} axis names")
    return make_mesh_compat(shape, axes)
