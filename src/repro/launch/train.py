"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --mode xpeft --steps 100 --batch 8 --seq 64 --smoke \
      --ckpt-dir /tmp/ck

--onboard switches to the profile-lifecycle flow: stream P >> S profiles
through an S-slot device-resident roster (train/roster.py), graduating
converged profiles into a serving ProfileStore:

  PYTHONPATH=src python -m repro.launch.train --onboard --smoke \
      --arch qwen1.5-0.5b --profiles 12 --roster-slots 4 \
      --store-out /tmp/profiles.npz --ckpt-dir /tmp/ck

--smoke uses the reduced config (CPU-runnable); the full config is for real
accelerators. On TPU pods also pass --mesh to enable pjit sharding, plus the
latency-hiding scheduler flags below (LIBTPU_INIT_ARGS).
"""
from __future__ import annotations

import argparse

import jax

# XLA flags a real TPU deployment ships with (documented here; harmless on CPU)
TPU_PERF_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true"
)


def run_onboarding(args):
    """--onboard: stream P >> S profiles through an S-slot roster and
    graduate converged profiles into a ProfileStore (train→serve loop)."""
    from repro import obs as OBS
    from repro.configs import get_config, reduce_for_smoke
    from repro.data import MarkovLM, ProfileClassification
    from repro.distributed.fault import PreemptionHandler
    from repro.launch.mesh import parse_mesh
    from repro.train import GraduationPolicy
    from repro.train.onboarding import build_onboarding_run

    obs = OBS.from_cli_args(args)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if args.num_labels:
        cfg = cfg.with_(num_labels=args.num_labels)
    mesh = parse_mesh(args.mesh)
    if mesh is not None:
        print(f"onboarding on mesh {dict(mesh.shape)} "
              f"({mesh.size} devices; roster slots over 'data')")

    if cfg.num_labels:
        source = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                       num_profiles=args.profiles,
                                       seed=args.seed)
    else:
        source = MarkovLM(cfg.vocab_size, args.profiles, seed=args.seed)
    policy = GraduationPolicy(
        min_steps=args.graduate_min_steps, max_steps=args.graduate_max_steps,
        ema_decay=args.ema_decay,
        target_loss=args.target_loss, target_acc=args.target_acc)
    trainer, gang = build_onboarding_run(
        cfg, source, range(args.profiles), slots=args.roster_slots,
        per_slot=args.per_slot_batch, seq_len=args.seq, policy=policy,
        lr=args.lr, seed=args.seed, mesh=mesh,
        store_path=args.store_out or None,
        ckpt_dir=args.ckpt_dir or None, ckpt_every=args.ckpt_every,
        preemption=PreemptionHandler(),
        log_every=args.log_every, obs=obs)
    scheduler, store = trainer.scheduler, trainer.scheduler.store
    if args.resume and trainer.try_resume():
        print(f"resumed onboarding from step {trainer.step}: "
              f"{scheduler.stats()}")
    trainer.run_until_drained(max_steps=args.steps)
    st = scheduler.stats()
    print(f"onboarding done at step {trainer.step}: "
          f"{st['graduated']} graduated, {st['evicted']} evicted, "
          f"{st['in_training']} in training, {st['pending']} pending, "
          f"gang-step traces {gang.trace_counter['traces']}, "
          f"host syncs/step "
          f"{trainer.host_syncs / max(trainer.step, 1):.3f}")
    if args.store_out:
        store.save(args.store_out)
        print(f"wrote {args.store_out}: {len(store.profile_ids())} profiles, "
              f"{store.bytes_per_profile()} B/profile (masks)")
    if obs is not None:
        obs.export(args.metrics_json or None, args.trace or None)
        cats = obs.tracer.category_counts()
        print(f"obs: {sum(cats.values())} trace events {cats}; "
              f"retrace watches {obs.sentinel.counts()}")
    if st["graduated"] == 0:
        raise SystemExit("onboarding graduated zero profiles")
    if not scheduler.finished():
        # the --steps backstop cut the stream short: in-slot / queued
        # profiles never reached the store — that must not look like success
        raise SystemExit(
            f"onboarding truncated by --steps {args.steps}: "
            f"{st['in_training']} profiles still in slots, "
            f"{st['pending']} pending — raise --steps (or --resume from "
            "the checkpoint) to finish the stream")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--mode", default="xpeft",
                    choices=["xpeft", "adapter", "head_only"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--profiles", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", default="",
                    help="e.g. 2x2:data,model — enable pjit sharding")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # --onboard: profile-lifecycle flow (roster/onboarding/gang-step)
    ap.add_argument("--onboard", action="store_true",
                    help="stream --profiles through a roster, graduating "
                         "converged profiles into --store-out")
    ap.add_argument("--roster-slots", type=int, default=4)
    ap.add_argument("--per-slot-batch", type=int, default=4)
    ap.add_argument("--num-labels", type=int, default=0,
                    help="add a classification head (0 = LM objective)")
    ap.add_argument("--graduate-min-steps", type=int, default=20)
    ap.add_argument("--graduate-max-steps", type=int, default=80)
    ap.add_argument("--target-loss", type=float, default=None)
    ap.add_argument("--target-acc", type=float, default=None)
    ap.add_argument("--ema-decay", type=float, default=0.9)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--store-out", default="")
    from repro import obs as OBS
    OBS.add_cli_args(ap)  # --metrics-json PATH, --trace PATH
    args = ap.parse_args()

    if args.onboard:
        run_onboarding(args)
        return

    from repro import obs as OBS
    from repro.configs import get_config, reduce_for_smoke
    from repro.data import MarkovLM
    from repro.data.loader import ShardedLoader
    from repro.distributed import ctx
    from repro.distributed.fault import PreemptionHandler
    from repro.distributed.sharding import (batch_specs, param_specs,
                                            to_shardings)
    from repro.train.steps import init_train_state, make_train_step
    from repro.train.trainer import Trainer

    obs = OBS.from_cli_args(args)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    cfg = cfg.with_xpeft(max_profiles=max(args.profiles, 2))

    key = jax.random.key(args.seed)
    state = init_train_state(key, cfg, args.mode)
    step = make_train_step(cfg, args.mode, lr=args.lr)

    if args.mesh:
        from repro.launch.mesh import parse_mesh
        mesh = parse_mesh(args.mesh)
        cm = ctx.mesh_context(mesh)
        cm.__enter__()
        st_sh = to_shardings(param_specs(state, mesh), mesh)
        step = jax.jit(step, in_shardings=(st_sh, None, None),
                       out_shardings=(st_sh, None))
    else:
        step = jax.jit(step)

    loader = ShardedLoader(
        MarkovLM(cfg.vocab_size, args.profiles, seed=args.seed),
        args.batch, args.seq)
    trainer = Trainer(step, state, loader,
                      ckpt_dir=args.ckpt_dir or None,
                      ckpt_every=args.ckpt_every,
                      preemption=PreemptionHandler(),
                      rng=jax.random.key(args.seed + 1), obs=obs)
    if args.resume and trainer.try_resume():
        print(f"resumed from step {trainer.step}")
    hist = trainer.run(args.steps)
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} "
              f"(stragglers: {trainer.watchdog.slow_steps})")
    if obs is not None:
        obs.export(args.metrics_json or None, args.trace or None)
        cats = obs.tracer.category_counts()
        print(f"obs: {sum(cats.values())} trace events {cats}; "
              f"retrace watches {obs.sentinel.counts()}")


if __name__ == "__main__":
    main()
