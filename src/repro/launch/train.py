"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --mode xpeft --steps 100 --batch 8 --seq 64 --smoke \
      --ckpt-dir /tmp/ck

--smoke uses the reduced config (CPU-runnable); the full config is for real
accelerators. On TPU pods also pass --mesh to enable pjit sharding, plus the
latency-hiding scheduler flags below (LIBTPU_INIT_ARGS).
"""
from __future__ import annotations

import argparse

import jax

# XLA flags a real TPU deployment ships with (documented here; harmless on CPU)
TPU_PERF_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true"
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--mode", default="xpeft",
                    choices=["xpeft", "adapter", "head_only"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--profiles", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--mesh", default="",
                    help="e.g. 2x2:data,model — enable pjit sharding")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config, reduce_for_smoke
    from repro.data import MarkovLM
    from repro.data.loader import ShardedLoader
    from repro.distributed import ctx
    from repro.distributed.fault import PreemptionHandler, StepWatchdog
    from repro.distributed.sharding import (batch_specs, param_specs,
                                            to_shardings)
    from repro.train.steps import init_train_state, make_train_step
    from repro.train.trainer import Trainer

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    cfg = cfg.with_xpeft(max_profiles=max(args.profiles, 2))

    key = jax.random.key(args.seed)
    state = init_train_state(key, cfg, args.mode)
    step = make_train_step(cfg, args.mode, lr=args.lr)

    if args.mesh:
        shape_s, axes_s = args.mesh.split(":")
        shape = tuple(int(x) for x in shape_s.split("x"))
        axes = tuple(axes_s.split(","))
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat(shape, axes)
        cm = ctx.mesh_context(mesh)
        cm.__enter__()
        st_sh = to_shardings(param_specs(state, mesh), mesh)
        step = jax.jit(step, in_shardings=(st_sh, None, None),
                       out_shardings=(st_sh, None))
    else:
        step = jax.jit(step)

    loader = ShardedLoader(
        MarkovLM(cfg.vocab_size, args.profiles, seed=args.seed),
        args.batch, args.seq)
    trainer = Trainer(step, state, loader,
                      ckpt_dir=args.ckpt_dir or None,
                      ckpt_every=args.ckpt_every,
                      watchdog=StepWatchdog(),
                      preemption=PreemptionHandler(),
                      rng=jax.random.key(args.seed + 1))
    if args.resume and trainer.try_resume():
        print(f"resumed from step {trainer.step}")
    hist = trainer.run(args.steps)
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} "
              f"(stragglers: {trainer.watchdog.slow_steps})")


if __name__ == "__main__":
    main()
