"""X-PEFT mask tensors: soft masks, hard (k-hot) masks with straight-through
Gumbel top-k (paper Algorithm 1), and byte-level bit packing.

A profile's trainable state is two mask tensors ``M_A, M_B`` of shape
``[L, N]`` (logits), adapter-LN affine ``[L, b]`` and optionally a task head.
Hard masks are stored packed: ``2 * ceil(N/8) * L`` bytes per profile — the
paper's 10,000x memory reduction vs storing an adapter.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def init_profile_params(key, num_layers: int, num_adapters: int,
                        bottleneck: int, dtype=jnp.float32) -> dict:
    """Per-profile trainables: 2(N+b)*L params (paper §3 Parameter efficiency)."""
    ka, kb = jax.random.split(key)
    shape = (num_layers, num_adapters)
    return {
        "mA": 0.01 * jax.random.normal(ka, shape, dtype),
        "mB": 0.01 * jax.random.normal(kb, shape, dtype),
        "ln_scale": jnp.ones((num_layers, bottleneck), dtype),
        "ln_bias": jnp.zeros((num_layers, bottleneck), dtype),
    }


def soft_mask_weights(logits):
    """Soft masks: each row is a softmax distribution over the N adapters."""
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)


def khot_from_topk(logits, k: int):
    """Deterministic k-hot (eval/serving path): top-k of the logits, /k."""
    _, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    onehots = jax.nn.one_hot(idx, logits.shape[-1], dtype=jnp.float32)
    return onehots.sum(axis=-2) / k


def hard_mask_weights(logits, k: int, *, tau: float = 1.0, nu: float = 1.0,
                      key=None, training: bool = True):
    """Paper Algorithm 1: Gumbel top-k with straight-through estimation.

    logits: [..., N]. Returns weights [..., N] that are exactly k-hot (/k) in
    the forward pass and have d(softmax)/d(logits) gradients in the backward
    pass. At eval time (training=False) no noise is added.
    """
    logits = logits.astype(jnp.float32)
    if training and key is not None and nu > 0:
        logits = logits + nu * jax.random.gumbel(key, logits.shape)
    y_soft = jax.nn.softmax(logits / tau, axis=-1)
    _, idx = jax.lax.top_k(y_soft, k)
    y_hard = jax.nn.one_hot(idx, logits.shape[-1], dtype=jnp.float32).sum(-2) / k
    # straight-through: forward = y_hard, backward = d y_soft
    return y_hard - jax.lax.stop_gradient(y_soft) + y_soft


def mask_weights(logits, cfg, *, key=None, training: bool = True):
    """Dispatch on cfg.mask_type ('soft'|'hard')."""
    if cfg.mask_type == "soft":
        return soft_mask_weights(logits)
    if training:
        return hard_mask_weights(logits, cfg.k, tau=cfg.tau, nu=cfg.nu,
                                 key=key, training=True)
    return khot_from_topk(logits, cfg.k)


# ----------------------------------------------------------------------------
# Byte-level storage (the 10,000x claim)
# ----------------------------------------------------------------------------

def binarize(logits, k: int) -> jnp.ndarray:
    """[..., N] logits -> boolean k-hot selection per row."""
    _, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    return jax.nn.one_hot(idx, logits.shape[-1], dtype=jnp.int32).sum(-2) > 0


def pack_mask(bits) -> np.ndarray:
    """Boolean [L, N] -> uint8 [L, ceil(N/8)] (host-side, byte-level)."""
    return np.packbits(np.asarray(bits, dtype=bool), axis=-1)


def unpack_mask(packed: np.ndarray, n: int) -> np.ndarray:
    return np.unpackbits(packed, axis=-1, count=n).astype(bool)


def khot_weights_from_bits(bits, k: int):
    """Packed-bit k-hot back to float weights (1/k at selected positions)."""
    return jnp.asarray(bits, jnp.float32) / k


def mask_indices(bits, k: int) -> jnp.ndarray:
    """[..., N] boolean -> [..., k] int32 selected indices (for sparse agg)."""
    # top_k over the 0/1 values returns the set bits first; ties broken by
    # index order, which is fine because exactly k bits are set.
    _, idx = jax.lax.top_k(jnp.asarray(bits, jnp.float32), k)
    return jnp.sort(idx, axis=-1)


# ----------------------------------------------------------------------------
# Memory accounting (paper Table 1)
# ----------------------------------------------------------------------------

def bytes_per_profile(num_adapters: int, num_layers: int, mask_type: str) -> int:
    if mask_type == "hard":
        return 2 * ((num_adapters + 7) // 8) * num_layers
    return 2 * num_adapters * num_layers * 4


def adapter_bytes(d: int, b: int, num_layers: int, itemsize: int = 4) -> int:
    return 2 * (d * b) * num_layers * itemsize


def trainable_params_per_profile(num_adapters: int, bottleneck: int,
                                 num_layers: int) -> int:
    return 2 * (num_adapters + bottleneck) * num_layers
