"""Shared adapter bank: N Pfeiffer bottleneck adapters per PLM block.

TPU adaptation (DESIGN.md §3.1): the bank is ONE tensor per submodule —
``bank_a: [L, N, d, b]`` (down-proj) and ``bank_b: [L, N, b, d]`` (up-proj) —
sharded over the mesh, instead of AdapterHub's N python modules. Aggregation
is a mask-bank contraction; application is two MXU matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_adapter_bank(key, num_layers: int, num_adapters: int, d: int, b: int,
                      dtype=jnp.bfloat16) -> dict:
    """Random adapter bank (the paper's LTH/supermask setting).

    Down-proj uses fan-in scaling; up-proj uses a small std so a random
    adapter perturbs the residual stream gently (matches adapter-tuning
    practice; the paper's random adapters are HF default inits).
    """
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (num_layers, num_adapters, d, b), jnp.float32)
    a = a * (1.0 / jnp.sqrt(d))
    bb = jax.random.normal(kb, (num_layers, num_adapters, b, d), jnp.float32)
    bb = bb * 0.02
    return {"bank_a": a.astype(dtype), "bank_b": bb.astype(dtype)}


def aggregate_dense(bank_l: dict, w_a, w_b):
    """Dense aggregation for one layer.

    bank_l: {"bank_a": [N, d, b], "bank_b": [N, b, d]}
    w_a, w_b: [..., N] mask weights (soft, or ST-hard during training).
    Returns (A_hat [..., d, b], B_hat [..., b, d]).
    """
    dt = bank_l["bank_a"].dtype
    a_hat = jnp.einsum("...n,ndb->...db", w_a.astype(dt), bank_l["bank_a"])
    b_hat = jnp.einsum("...n,nbd->...bd", w_b.astype(dt), bank_l["bank_b"])
    return a_hat, b_hat


def aggregate_sparse(bank_l: dict, idx_a, w_a, idx_b, w_b):
    """k-sparse aggregation: gather only the k selected adapters.

    idx_*: [..., k] int32, w_*: [..., k]. FLOPs/bytes cut by N/k vs dense —
    this is the jnp reference for kernels/mask_aggregate.py.
    """
    dt = bank_l["bank_a"].dtype
    ga = jnp.take(bank_l["bank_a"], idx_a, axis=0)   # [..., k, d, b]
    gb = jnp.take(bank_l["bank_b"], idx_b, axis=0)   # [..., k, b, d]
    a_hat = jnp.einsum("...k,...kdb->...db", w_a.astype(dt), ga)
    b_hat = jnp.einsum("...k,...kbd->...bd", w_b.astype(dt), gb)
    return a_hat, b_hat


def _ln(x, scale, bias, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_adapter(x, a_hat, b_hat, ln_scale, ln_bias, activation: str = "gelu"):
    """Bottleneck adapter with the paper's LN-after-down-proj (footnote 1).

    x: [..., T, d]; a_hat [..., d, b] or [d, b]; returns x + B̂(act(LN(Â x))).
    ``activation='identity'`` reproduces the literal paper formula.
    """
    if a_hat.ndim == 2:
        h = jnp.einsum("...td,db->...tb", x, a_hat)
    else:
        h = jnp.einsum("...td,...db->...tb", x, a_hat)
    h = _ln(h, ln_scale, ln_bias)
    if activation == "gelu":
        h = jax.nn.gelu(h)
    if b_hat.ndim == 2:
        y = jnp.einsum("...tb,bd->...td", h, b_hat)
    else:
        y = jnp.einsum("...tb,...bd->...td", h, b_hat)
    return x + y.astype(x.dtype)
