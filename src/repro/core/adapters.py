"""Shared adapter bank: N Pfeiffer bottleneck adapters per PLM block.

TPU adaptation (DESIGN.md §3.1): the bank is ONE tensor per submodule —
``bank_a: [L, N, d, b]`` (down-proj) and ``bank_b: [L, N, b, d]`` (up-proj) —
sharded over the mesh, instead of AdapterHub's N python modules. Aggregation
is a mask-bank contraction; application is two MXU matmuls.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_adapter_bank(key, num_layers: int, num_adapters: int, d: int, b: int,
                      dtype=jnp.bfloat16) -> dict:
    """Random adapter bank (the paper's LTH/supermask setting).

    Down-proj uses fan-in scaling; up-proj uses a small std so a random
    adapter perturbs the residual stream gently (matches adapter-tuning
    practice; the paper's random adapters are HF default inits).
    """
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (num_layers, num_adapters, d, b), jnp.float32)
    a = a * (1.0 / jnp.sqrt(d))
    bb = jax.random.normal(kb, (num_layers, num_adapters, b, d), jnp.float32)
    bb = bb * 0.02
    return {"bank_a": a.astype(dtype), "bank_b": bb.astype(dtype)}


def init_hetero_bank(key, num_layers: int, xp, d: int, kv_dim: int,
                     dtype=jnp.bfloat16) -> dict:
    """Typed-segment bank for a heterogeneous ``bank_spec``.

    One leaf pair/vector per family, each spanning only its segment's
    rows; the unified mask index space is the ordered concatenation of
    segments (``xp.segments()``). Per family:

    - bottleneck: ``bank_a [L, N_bn, d, b]`` / ``bank_b [L, N_bn, b, d]``
      — the historical leaves, same init statistics.
    - lora: ``lora_a [L, N_lo, d, b]`` / ``lora_b [L, N_lo, b, d]`` —
      rank r = b (shared with bottleneck so the k-sparse aggregation
      kernels are reused row-for-row), no LN, no inner activation.
    - ia3: ``ia3_v [L, N_i3, d]`` — scale DELTAS: the aggregate s is the
      mask-weighted sum and application is ``x * (1 + s)``, so an empty
      selection is exactly the identity.
    - prefix: ``prefix_k`` / ``prefix_v [L, N_pf, P, kv_dim]`` — P =
      ``xp.prefix_tokens`` learned post-RoPE KV rows per slot (consistent
      with the cache, which stores keys after rotation).
    """
    b = xp.bottleneck
    keys = iter(jax.random.split(key, 2 * len(xp.segments()) + 1))
    bank = {}
    for t, _, cnt in xp.segments():
        if t == "bottleneck":
            sub = init_adapter_bank(next(keys), num_layers, cnt, d, b, dtype)
            bank.update(sub)
        elif t == "lora":
            a = jax.random.normal(
                next(keys), (num_layers, cnt, d, b), jnp.float32)
            bb = jax.random.normal(
                next(keys), (num_layers, cnt, b, d), jnp.float32)
            bank["lora_a"] = (a * (1.0 / jnp.sqrt(d))).astype(dtype)
            bank["lora_b"] = (bb * 0.02).astype(dtype)
        elif t == "ia3":
            v = jax.random.normal(
                next(keys), (num_layers, cnt, d), jnp.float32)
            bank["ia3_v"] = (v * 0.02).astype(dtype)
        elif t == "prefix":
            P = xp.prefix_tokens
            pk = jax.random.normal(
                next(keys), (num_layers, cnt, P, kv_dim), jnp.float32)
            pv = jax.random.normal(
                next(keys), (num_layers, cnt, P, kv_dim), jnp.float32)
            bank["prefix_k"] = (pk * 0.02).astype(dtype)
            bank["prefix_v"] = (pv * 0.02).astype(dtype)
    return bank


def aggregate_dense(bank_l: dict, w_a, w_b):
    """Dense aggregation for one layer.

    bank_l: {"bank_a": [N, d, b], "bank_b": [N, b, d]}
    w_a, w_b: [..., N] mask weights (soft, or ST-hard during training).
    Returns (A_hat [..., d, b], B_hat [..., b, d]).
    """
    dt = bank_l["bank_a"].dtype
    a_hat = jnp.einsum("...n,ndb->...db", w_a.astype(dt), bank_l["bank_a"])
    b_hat = jnp.einsum("...n,nbd->...bd", w_b.astype(dt), bank_l["bank_b"])
    return a_hat, b_hat


def aggregate_sparse(bank_l: dict, idx_a, w_a, idx_b, w_b):
    """k-sparse aggregation: gather only the k selected adapters.

    idx_*: [..., k] int32, w_*: [..., k]. FLOPs/bytes cut by N/k vs dense —
    this is the jnp reference for kernels/mask_aggregate.py.
    """
    dt = bank_l["bank_a"].dtype
    ga = jnp.take(bank_l["bank_a"], idx_a, axis=0)   # [..., k, d, b]
    gb = jnp.take(bank_l["bank_b"], idx_b, axis=0)   # [..., k, b, d]
    a_hat = jnp.einsum("...k,...kdb->...db", w_a.astype(dt), ga)
    b_hat = jnp.einsum("...k,...kbd->...bd", w_b.astype(dt), gb)
    return a_hat, b_hat


def _ln(x, scale, bias, eps=1e-6):
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_adapter(x, a_hat, b_hat, ln_scale, ln_bias, activation: str = "gelu"):
    """Bottleneck adapter with the paper's LN-after-down-proj (footnote 1).

    x: [..., T, d]; a_hat [..., d, b] or [d, b]; returns x + B̂(act(LN(Â x))).
    ``activation='identity'`` reproduces the literal paper formula.
    """
    if a_hat.ndim == 2:
        h = jnp.einsum("...td,db->...tb", x, a_hat)
    else:
        h = jnp.einsum("...td,...db->...tb", x, a_hat)
    h = _ln(h, ln_scale, ln_bias)
    if activation == "gelu":
        h = jax.nn.gelu(h)
    if b_hat.ndim == 2:
        y = jnp.einsum("...tb,bd->...td", h, b_hat)
    else:
        y = jnp.einsum("...tb,...bd->...td", h, b_hat)
    return x + y.astype(x.dtype)


def apply_lora(x, a_hat, b_hat):
    """LoRA delta: x + B̂(Â x) — no LN, no inner activation. Â/B̂ share
    the bottleneck aggregate's shapes ([d, b]/[b, d], optionally batched),
    so the fused-adapter kernels serve both via ``use_ln=False`` +
    identity activation."""
    if a_hat.ndim == 2:
        h = jnp.einsum("...td,db->...tb", x, a_hat)
        y = jnp.einsum("...tb,bd->...td", h, b_hat)
    else:
        h = jnp.einsum("...td,...db->...tb", x, a_hat)
        y = jnp.einsum("...tb,...bd->...td", h, b_hat)
    return x + y.astype(x.dtype)


def apply_ia3(x, s):
    """IA3 scaling: x * (1 + s) with s the mask-weighted sum of scale
    DELTAS ([d] or batched [..., d]). Computed in fp32 (matching the
    kernel in kernels/ia3_apply.py); s == 0 (empty selection, degraded
    serving) multiplies by exactly 1.0 — bitwise the identity."""
    if s.ndim > 1:
        s = s[..., None, :]          # [..., 1, d] broadcast over T
    y = x.astype(jnp.float32) * (1.0 + s.astype(jnp.float32))
    return y.astype(x.dtype)
