"""ProfileStore: byte-level persistence of per-profile X-PEFT state.

This is the operational heart of the multi-profile scenario: thousands of
profiles, each a few hundred BYTES (hard masks bit-packed) or a few KB (soft
masks fp16). The store is host-side (numpy), cheap to snapshot, and hydrates
batch mask tensors for training/serving on demand.
"""
from __future__ import annotations

import json
import os
import tempfile
import weakref
from typing import Dict, Iterable, Optional

import numpy as np
import jax.numpy as jnp

from repro.core import masks as M
from repro.resilience.integrity import RecordIntegrityError, array_crc, \
    record_crc


class ProfileStore:
    def __init__(self, num_layers: int, num_adapters: int, bottleneck: int,
                 mask_type: str = "hard", k: int = 50,
                 quant: str = "none", quant_group: int = 32,
                 bank_spec=()):
        self.L = num_layers
        self.N = num_adapters
        self.b = bottleneck
        self.mask_type = mask_type
        self.k = k
        # Heterogeneous banks: the ((type, count), ...) segment layout of
        # the unified mask index space these records select over. A record
        # is meaningless against a bank with a different layout — the same
        # mask bits would select different adapter families — so the spec
        # is part of the store's identity (merge/save/load round-trip it).
        self.bank_spec = tuple((str(t), int(c)) for t, c in bank_spec)
        # quant != "none": graduation may attach the profile's aggregated
        # Â/B̂, persisted QUANTIZED (int8/int4 + fp16 scales) — serving then
        # admits the profile with ZERO bank reads (quant_records hydration)
        self.quant = quant
        self.quant_group = quant_group
        self._rec: Dict[int, dict] = {}
        # Integrity sidecar — parallel to _rec, NEVER inside it: the crc
        # map must not count toward record_nbytes or round-trip through
        # the npz payload keys.
        self._crc: Dict[int, Dict[str, int]] = {}
        self._quarantined: Dict[int, str] = {}
        self.corrupt_detected = 0   # total integrity violations caught
        self.agg_dropped: list = []  # pids whose corrupt agg payload was shed
        self._listeners: list = []

    # -------------------------------------------------------- invalidation
    def subscribe(self, fn) -> None:
        """Register ``fn(pid)``, called whenever a profile record is added
        or REPLACED (``add_profile`` / ``merge_from``). Serving caches
        subscribe their invalidation hook here (``ServeEngine`` does so in
        its constructor), so a re-trained profile re-graduating into the
        store can never keep serving its stale aggregated Â/B̂.

        Bound methods are held WEAKLY: a store outlives the engines serving
        from it, and a strong ref here would pin every dead engine's device
        state (params / KV cache / mask buffers) forever. Plain functions
        are held strongly (a weak ref to a local closure would die at
        once) — their owner should keep the store's lifetime in mind."""
        if hasattr(fn, "__self__"):
            self._listeners.append(weakref.WeakMethod(fn))
        else:
            self._listeners.append(lambda _fn=fn: _fn)

    def _notify(self, pid: int) -> None:
        live = []
        for ref in self._listeners:
            fn = ref()
            if fn is not None:
                fn(pid)
                live.append(ref)
        self._listeners = live

    # ------------------------------------------------------------------ add
    def add_profile(self, pid: int, profile_params: dict, *,
                    agg=None) -> None:
        """Freeze a trained profile into its byte-level record.

        `profile_params` carries mask logits mA/mB + adapter-LN affines,
        and optionally a per-profile classifier head (head_w/head_b) —
        graduated encoder profiles keep their head so serving/eval can
        reproduce classification logits, not just masks.

        `agg` (quantized stores only): the profile's aggregated
        ``(Â [L, d, b], B̂ [L, b, d])``, quantized ON WRITE with the
        store's scheme — graduation passes the masks-x-bank contraction it
        already computed so serving can admit this profile without reading
        the bank at all (`quant_records`). Training state stays bf16/fp32;
        only the persisted record is low-bit."""
        rec = {
            "ln_scale": np.asarray(profile_params["ln_scale"], np.float16),
            "ln_bias": np.asarray(profile_params["ln_bias"], np.float16),
        }
        if self.mask_type == "hard":
            rec["mA"] = M.pack_mask(np.asarray(M.binarize(profile_params["mA"], self.k)))
            rec["mB"] = M.pack_mask(np.asarray(M.binarize(profile_params["mB"], self.k)))
        else:
            rec["mA"] = np.asarray(profile_params["mA"], np.float16)
            rec["mB"] = np.asarray(profile_params["mB"], np.float16)
        if "head_w" in profile_params:
            rec["head_w"] = np.asarray(profile_params["head_w"], np.float16)
            rec["head_b"] = np.asarray(profile_params["head_b"], np.float16)
        if agg is not None:
            if self.quant == "none":
                raise ValueError("aggregated records require a quantized "
                                 "store (quant='int8'|'int4')")
            from repro.quant import schemes as QS
            a_hat, b_hat = agg
            qa = QS.quantize(a_hat, self.quant, group=self.quant_group)
            qb = QS.quantize(b_hat, self.quant, group=self.quant_group)
            rec["agg_a_q"] = np.asarray(qa["q"])
            rec["agg_a_scale"] = np.asarray(qa["scale"])
            rec["agg_b_q"] = np.asarray(qb["q"])
            rec["agg_b_scale"] = np.asarray(qb["scale"])
        self._rec[int(pid)] = rec
        self._crc[int(pid)] = record_crc(rec)
        # Re-graduating a profile replaces its record wholesale: a prior
        # quarantine no longer describes anything — the profile heals.
        self._quarantined.pop(int(pid), None)
        self._notify(int(pid))

    # ------------------------------------------------------------- integrity
    def check_record(self, pid: int) -> None:
        """Verify one record against its graduation-time checksums.

        Raises `RecordIntegrityError` if the profile is quarantined or a
        core field (masks / LN affines / head) fails its crc — such a
        record is quarantined and NEVER served. A quantized record whose
        corruption is confined to the aggregated ``agg_*`` payload is
        HEALED instead: the agg fields are shed (and subscribers notified,
        dropping any cached copy) and the call returns normally — the
        masks are intact, so the existing sparse bank-read path
        re-hydrates the profile exactly.
        """
        pid = int(pid)
        if pid in self._quarantined:
            raise RecordIntegrityError(pid, (), self._quarantined[pid])
        rec = self._rec[pid]
        want = self._crc.get(pid)
        if want is None:  # legacy record (pre-integrity snapshot): bless it
            self._crc[pid] = record_crc(rec)
            return
        bad = [k for k in sorted(set(rec) | set(want))
               if k not in rec or k not in want
               or array_crc(np.asarray(rec[k])) != want[k]]
        if not bad:
            return
        self.corrupt_detected += 1
        if all(k.startswith("agg_") for k in bad):
            for k in [k for k in rec if k.startswith("agg_")]:
                rec.pop(k, None)
                want.pop(k, None)
            self.agg_dropped.append(pid)
            self._notify(pid)
            return
        self._quarantined[pid] = \
            f"checksum mismatch ({', '.join(bad)})"
        self._notify(pid)
        raise RecordIntegrityError(pid, bad)

    def quarantined_ids(self):
        return sorted(self._quarantined)

    def integrity_stats(self) -> dict:
        return dict(corrupt_detected=self.corrupt_detected,
                    quarantined=self.quarantined_ids(),
                    agg_dropped=sorted(set(self.agg_dropped)))

    # ---------------------------------------------------------------- fetch
    def mask_weights(self, pid: int):
        """Hydrate float mask weights [L, N] x2 for one profile."""
        self.check_record(pid)
        rec = self._rec[int(pid)]
        if self.mask_type == "hard":
            wa = M.khot_weights_from_bits(M.unpack_mask(rec["mA"], self.N), self.k)
            wb = M.khot_weights_from_bits(M.unpack_mask(rec["mB"], self.N), self.k)
        else:
            wa = M.soft_mask_weights(jnp.asarray(rec["mA"], jnp.float32))
            wb = M.soft_mask_weights(jnp.asarray(rec["mB"], jnp.float32))
        return wa, wb

    def batch_mask_weights(self, pids: Iterable[int]):
        """Stacked [B, L, N] weights + [B, L, b] LN affines for a batch."""
        was, wbs, lss, lbs = [], [], [], []
        for pid in pids:
            wa, wb = self.mask_weights(pid)
            rec = self._rec[int(pid)]
            was.append(wa); wbs.append(wb)
            lss.append(jnp.asarray(rec["ln_scale"], jnp.float32))
            lbs.append(jnp.asarray(rec["ln_bias"], jnp.float32))
        return (jnp.stack(was), jnp.stack(wbs),
                jnp.stack(lss), jnp.stack(lbs))

    def sparse_indices(self, pid: int):
        """Hard-mask profiles: ([L, k] idx, [L, k] w) x2 for sparse agg."""
        assert self.mask_type == "hard"
        self.check_record(pid)
        rec = self._rec[int(pid)]
        bits_a = M.unpack_mask(rec["mA"], self.N)
        bits_b = M.unpack_mask(rec["mB"], self.N)
        ia = M.mask_indices(bits_a, self.k)
        ib = M.mask_indices(bits_b, self.k)
        w = jnp.full(ia.shape, 1.0 / self.k, jnp.float32)
        return ia, w, ib, w

    def batch_sparse_indices(self, pids: Iterable[int]):
        """Stacked ([R, L, k] idx, [R, L, k] w) x2 for a batch of hard-mask
        profiles — the vectorized hydration API serving admission uses
        (engines must not reach into ``_rec``)."""
        parts = [self.sparse_indices(pid) for pid in pids]
        ia = jnp.stack([p[0] for p in parts])
        wa = jnp.stack([p[1] for p in parts])
        ib = jnp.stack([p[2] for p in parts])
        wb = jnp.stack([p[3] for p in parts])
        return ia, wa, ib, wb

    def has_quant_record(self, pid: int) -> bool:
        """True when `pid` carries a quantized aggregated Â/B̂ record
        that passes its checksums — a record whose agg payload just got
        shed by `check_record` (or whose core fields are quarantined)
        answers False, steering admission onto the sparse bank-read
        path / the degraded fallback."""
        if "agg_a_q" not in self._rec.get(int(pid), {}):
            return False
        try:
            self.check_record(pid)
        except RecordIntegrityError:
            return False
        return "agg_a_q" in self._rec[int(pid)]

    def quant_records(self, pids: Iterable[int]):
        """Stacked quantized aggregated records for a batch of profiles:
        {"a_q" [R, L, d, b|b/2], "a_scale", "b_q", "b_scale"} as jnp
        arrays — the zero-bank-read admission hydration (the engine
        scatters these straight into its quantized slot buffers)."""
        assert self.quant != "none", "store has no quantized records"
        out = {}
        for src, dst in (("agg_a_q", "a_q"), ("agg_a_scale", "a_scale"),
                         ("agg_b_q", "b_q"), ("agg_b_scale", "b_scale")):
            out[dst] = jnp.asarray(
                np.stack([self._rec[int(pid)][src] for pid in pids]))
        return out

    def head(self, pid: int):
        """Per-profile classifier head (fp16-stored) as float32 jnp arrays,
        or None for profiles graduated without one."""
        self.check_record(pid)
        rec = self._rec[int(pid)]
        if "head_w" not in rec:
            return None
        return (jnp.asarray(rec["head_w"], jnp.float32),
                jnp.asarray(rec["head_b"], jnp.float32))

    def ln_affines(self, pids: Iterable[int]):
        """Stacked adapter-LN affines ([R, L, b] scale, [R, L, b] bias) as
        float32 — the other half of batched admission hydration."""
        pids = list(pids)
        for pid in pids:
            self.check_record(pid)
        scales = np.stack([self._rec[int(pid)]["ln_scale"] for pid in pids])
        biases = np.stack([self._rec[int(pid)]["ln_bias"] for pid in pids])
        return (jnp.asarray(scales, jnp.float32),
                jnp.asarray(biases, jnp.float32))

    # ------------------------------------------------------------- accounting
    def profile_ids(self):
        return sorted(self._rec)

    def merge_from(self, other: "ProfileStore") -> None:
        """Adopt another store's records (the onboarding resume path:
        re-hydrate already-graduated profiles from the persisted store so
        they are never re-trained). Every adopted pid is notified to
        subscribers — a record replaced here may already be cached by a
        serving engine, which must drop its aggregated copy."""
        assert (self.L, self.N, self.b, self.mask_type, self.k,
                self.quant, self.quant_group, self.bank_spec) == \
            (other.L, other.N, other.b, other.mask_type, other.k,
             other.quant, other.quant_group, other.bank_spec), \
            "store shape mismatch"
        for pid, rec in other._rec.items():
            if int(pid) in other._quarantined:
                continue  # never adopt a known-bad record
            self._rec[int(pid)] = rec
            self._crc[int(pid)] = dict(
                other._crc.get(int(pid)) or record_crc(rec))
            self._quarantined.pop(int(pid), None)
            self._notify(int(pid))

    def bytes_per_profile(self, include_ln: bool = False) -> int:
        core = M.bytes_per_profile(self.N, self.L, self.mask_type)
        if include_ln:
            core += 2 * self.b * self.L * 2  # fp16 LN affine
        return core

    def total_bytes(self, include_ln: bool = False) -> int:
        return len(self._rec) * self.bytes_per_profile(include_ln)

    def record_nbytes(self, pid: int) -> int:
        """TRUE byte size of one persisted record — packed masks, fp16
        affines/heads, and (quantized stores) the int8/int4 aggregated
        Â/B̂ plus their fp16 scales. This is what capacity planning should
        budget with; `bytes_per_profile` is the analytic mask-only
        number behind the paper's Table-1 factors."""
        return sum(v.nbytes for v in self._rec[int(pid)].values())

    # ---------------------------------------------------------------- persist
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        payload = {}
        saved = [p for p in sorted(self._rec) if p not in self._quarantined]
        for pid in saved:
            for k, v in self._rec[pid].items():
                payload[f"{pid}:{k}"] = v
        meta = dict(L=self.L, N=self.N, b=self.b, mask_type=self.mask_type,
                    k=self.k, quant=self.quant,
                    quant_group=self.quant_group,
                    bank_spec=[list(s) for s in self.bank_spec], pids=saved,
                    crc={str(pid): self._crc.get(pid)
                         or record_crc(self._rec[pid]) for pid in saved})
        # mkstemp with a .npz suffix: np.savez appends ".npz" to names that
        # lack it, which used to leave the original empty temp file behind
        fd, tmp = tempfile.mkstemp(suffix=".npz",
                                   dir=os.path.dirname(path) or ".")
        os.close(fd)
        np.savez(tmp, __meta__=json.dumps(meta), **payload)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "ProfileStore":
        z = np.load(path, allow_pickle=False)
        meta = json.loads(str(z["__meta__"]))
        store = cls(meta["L"], meta["N"], meta["b"], meta["mask_type"],
                    meta["k"], meta.get("quant", "none"),
                    meta.get("quant_group", 32),
                    bank_spec=meta.get("bank_spec", ()))
        crcs = meta.get("crc", {})
        for pid in meta["pids"]:
            # records carry a variable key set (optional per-profile heads):
            # adopt every "<pid>:<key>" entry rather than a fixed tuple
            prefix = f"{pid}:"
            store._rec[int(pid)] = {
                key[len(prefix):]: z[key] for key in z.files
                if key.startswith(prefix)}
            want = crcs.get(str(pid))
            if want is not None:
                store._crc[int(pid)] = {k: int(v) for k, v in want.items()}
        # Verify every record against its persisted checksums up front:
        # disk/transfer corruption quarantines here, never at serve time.
        for pid in list(store._rec):
            try:
                store.check_record(pid)
            except RecordIntegrityError:
                pass  # quarantined; surfaced via integrity_stats()
        return store
