"""X-PEFT layer application + the multi-profile mask table.

The framework keeps per-profile trainables as a TABLE (leading dim =
max_profiles) so that hundreds of profiles train simultaneously in one batch:
each example gathers its profile's row, and gradient scatter-add back into the
table happens automatically through the gather transpose (DESIGN.md §3.4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import adapters as A
from repro.core import masks as M


def init_xpeft_state(key, cfg) -> dict:
    """Frozen bank + per-profile trainable table for a ModelConfig."""
    xp = cfg.xpeft
    kb, kp = jax.random.split(key)
    bank = A.init_adapter_bank(kb, cfg.num_layers, xp.num_adapters,
                               cfg.d_model, xp.bottleneck,
                               dtype=jnp.dtype(cfg.dtype))
    table = init_profile_table(kp, cfg)
    return {"bank": bank, "profiles": table}


def init_profile_table(key, cfg) -> dict:
    xp = cfg.xpeft
    keys = jax.random.split(key, xp.max_profiles)
    return jax.vmap(
        lambda k: M.init_profile_params(k, cfg.num_layers, xp.num_adapters,
                                        xp.bottleneck)
    )(keys)


def gather_profiles(table: dict, profile_ids) -> dict:
    """Select rows of the profile table for a batch: [B, L, N] / [B, L, b]."""
    return jax.tree.map(lambda t: jnp.take(t, profile_ids, axis=0), table)


def profile_mask_weights(profile_params: dict, xp, *, key=None,
                         training: bool = True):
    """Logits -> (w_a, w_b) mask weights, shape [..., L, N]."""
    if key is not None:
        ka, kb = jax.random.split(key)
    else:
        ka = kb = None
    w_a = M.mask_weights(profile_params["mA"], xp, key=ka, training=training)
    w_b = M.mask_weights(profile_params["mB"], xp, key=kb, training=training)
    return w_a, w_b


def apply_xpeft_layer(x, bank_l: dict, w_a_l, w_b_l, ln_scale_l, ln_bias_l,
                      xp):
    """Apply the layer-l X-PEFT adapter to activations x [..., T, d].

    w_*_l: [N] (single profile) or [B, N] (per-example profiles).
    bank_l: {"bank_a": [N, d, b], "bank_b": [N, b, d]} — the slice the
    scan-over-layers feeds in.
    """
    a_hat, b_hat = A.aggregate_dense(bank_l, w_a_l, w_b_l)
    return A.apply_adapter(x, a_hat, b_hat, ln_scale_l, ln_bias_l,
                           activation=xp.adapter_activation)


def apply_xpeft_layer_sparse(x, bank_l: dict, idx_a_l, w_a_l, idx_b_l, w_b_l,
                             ln_scale_l, ln_bias_l, xp):
    """Hard-mask serving path: k-sparse gather aggregation (N/k cheaper)."""
    a_hat, b_hat = A.aggregate_sparse(bank_l, idx_a_l, w_a_l, idx_b_l, w_b_l)
    return A.apply_adapter(x, a_hat, b_hat, ln_scale_l, ln_bias_l,
                           activation=xp.adapter_activation)


def precompute_effective_adapters(bank: dict, profile_params: dict, xp):
    """Admission-time aggregation (beyond-paper serving optimization).

    Aggregates a profile's masks against the whole bank ONCE, producing dense
    Â/B̂ stacks [L, d, b] / [L, b, d] that the decode hot loop applies with
    two tiny matmuls — removes the per-step aggregation from the critical
    path (DESIGN.md §3, serve cache).
    """
    w_a, w_b = profile_mask_weights(profile_params, xp, training=False)
    a_hat = jnp.einsum("ln,lndb->ldb", w_a, bank["bank_a"].astype(jnp.float32))
    b_hat = jnp.einsum("ln,lnbd->lbd", w_b, bank["bank_b"].astype(jnp.float32))
    return {"a_hat": a_hat.astype(bank["bank_a"].dtype),
            "b_hat": b_hat.astype(bank["bank_b"].dtype),
            "ln_scale": profile_params["ln_scale"],
            "ln_bias": profile_params["ln_bias"]}


def apply_precomputed_layer(x, eff_l: dict, xp):
    """Apply an admission-time-aggregated adapter slice (per layer)."""
    return A.apply_adapter(x, eff_l["a_hat"], eff_l["b_hat"],
                           eff_l["ln_scale"], eff_l["ln_bias"],
                           activation=xp.adapter_activation)
