"""X-PEFT layer application + the multi-profile mask table.

The framework keeps per-profile trainables as a TABLE (leading dim =
max_profiles) so that hundreds of profiles train simultaneously in one batch:
each example gathers its profile's row, and gradient scatter-add back into the
table happens automatically through the gather transpose (DESIGN.md §3.4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import adapters as A
from repro.core import masks as M


def init_xpeft_state(key, cfg) -> dict:
    """Frozen bank + per-profile trainable table for a ModelConfig."""
    xp = cfg.xpeft
    kb, kp = jax.random.split(key)
    bank = A.init_adapter_bank(kb, cfg.num_layers, xp.num_adapters,
                               cfg.d_model, xp.bottleneck,
                               dtype=jnp.dtype(cfg.dtype))
    table = init_profile_table(kp, cfg)
    return {"bank": bank, "profiles": table}


def init_profile_table(key, cfg) -> dict:
    xp = cfg.xpeft
    keys = jax.random.split(key, xp.max_profiles)
    return jax.vmap(
        lambda k: M.init_profile_params(k, cfg.num_layers, xp.num_adapters,
                                        xp.bottleneck)
    )(keys)


def gather_profiles(table: dict, profile_ids) -> dict:
    """Select rows of the profile table for a batch: [B, L, N] / [B, L, b]."""
    return jax.tree.map(lambda t: jnp.take(t, profile_ids, axis=0), table)


def profile_mask_weights(profile_params: dict, xp, *, key=None,
                         training: bool = True):
    """Logits -> (w_a, w_b) mask weights, shape [..., L, N]."""
    if key is not None:
        ka, kb = jax.random.split(key)
    else:
        ka = kb = None
    w_a = M.mask_weights(profile_params["mA"], xp, key=ka, training=training)
    w_b = M.mask_weights(profile_params["mB"], xp, key=kb, training=training)
    return w_a, w_b


def apply_xpeft_layer(x, bank_l: dict, w_a_l, w_b_l, ln_scale_l, ln_bias_l,
                      xp):
    """Apply the layer-l X-PEFT adapter to activations x [..., T, d].

    w_*_l: [N] (single profile) or [B, N] (per-example profiles).
    bank_l: {"bank_a": [N, d, b], "bank_b": [N, b, d]} — the slice the
    scan-over-layers feeds in.
    """
    a_hat, b_hat = A.aggregate_dense(bank_l, w_a_l, w_b_l)
    return A.apply_adapter(x, a_hat, b_hat, ln_scale_l, ln_bias_l,
                           activation=xp.adapter_activation)


def apply_xpeft_layer_sparse(x, bank_l: dict, idx_a_l, w_a_l, idx_b_l, w_b_l,
                             ln_scale_l, ln_bias_l, xp):
    """Hard-mask serving path: k-sparse gather aggregation (N/k cheaper)."""
    a_hat, b_hat = A.aggregate_sparse(bank_l, idx_a_l, w_a_l, idx_b_l, w_b_l)
    return A.apply_adapter(x, a_hat, b_hat, ln_scale_l, ln_bias_l,
                           activation=xp.adapter_activation)


def precompute_effective_adapters(bank: dict, profile_params: dict, xp):
    """Admission-time aggregation (beyond-paper serving optimization).

    Aggregates a profile's masks against the whole bank ONCE, producing dense
    Â/B̂ stacks [L, d, b] / [L, b, d] that the decode hot loop applies with
    two tiny matmuls — removes the per-step aggregation from the critical
    path (DESIGN.md §3, serve cache).
    """
    w_a, w_b = profile_mask_weights(profile_params, xp, training=False)
    a_hat = jnp.einsum("ln,lndb->ldb", w_a, bank["bank_a"].astype(jnp.float32))
    b_hat = jnp.einsum("ln,lnbd->lbd", w_b, bank["bank_b"].astype(jnp.float32))
    return {"a_hat": a_hat.astype(bank["bank_a"].dtype),
            "b_hat": b_hat.astype(bank["bank_b"].dtype),
            "ln_scale": profile_params["ln_scale"],
            "ln_bias": profile_params["ln_bias"]}


def precompute_effective_adapters_dense_batched(bank: dict, w_a, w_b):
    """Dense admission aggregation for a batch of profiles (soft masks).

    w_*: [R, L, N] -> (Â [R, L, d, b], B̂ [R, L, b, d]) in bank dtype. The
    R=1 case is precompute_effective_adapters' einsum with a request axis;
    soft masks are dense by construction so there is no sparse shortcut.
    """
    a_hat = jnp.einsum("rln,lndb->rldb", w_a.astype(jnp.float32),
                       bank["bank_a"].astype(jnp.float32))
    b_hat = jnp.einsum("rln,lnbd->rlbd", w_b.astype(jnp.float32),
                       bank["bank_b"].astype(jnp.float32))
    return (a_hat.astype(bank["bank_a"].dtype),
            b_hat.astype(bank["bank_b"].dtype))


def precompute_effective_adapters_sparse(bank: dict, idx_a, w_a, idx_b, w_b,
                                         xp):
    """k-sparse admission aggregation through the kernel dispatch layer.

    idx_*/w_*: [..., L, k] (a single profile's top-k mask indices, or a
    leading request batch R for multi-request admission). Reads only
    k·L·d·b bank bytes (N/k less than the dense einsum in
    precompute_effective_adapters) by folding the layer axis into the
    bank's N axis and issuing ONE batched aggregation of P = R·L rows.
    """
    from repro.kernels import ops

    L, N = bank["bank_a"].shape[:2]
    d, b = bank["bank_a"].shape[2], bank["bank_a"].shape[3]
    batch = idx_a.shape[:-2]
    # fold layers into the bank slot axis: row (l, n) -> l*N + n
    flat_a = bank["bank_a"].reshape(L * N, d, b)
    flat_b = bank["bank_b"].reshape(L * N, b, d)
    off = (jnp.arange(L, dtype=jnp.int32) * N)[:, None]     # [L, 1]

    def flatten(idx, w):
        k = idx.shape[-1]
        fi = (idx.astype(jnp.int32) + off).reshape(-1, k)
        return fi, w.astype(jnp.float32).reshape(-1, k)

    fia, fwa = flatten(idx_a, w_a)
    fib, fwb = flatten(idx_b, w_b)
    a_hat = ops.mask_aggregate_batched(flat_a, fia, fwa, impl=xp.kernel_impl)
    b_hat = ops.mask_aggregate_batched(flat_b, fib, fwb, impl=xp.kernel_impl)
    dt = bank["bank_a"].dtype
    return (a_hat.reshape(*batch, L, d, b).astype(dt),
            b_hat.reshape(*batch, L, b, d).astype(dt))


def precompute_effective_adapters_sparse_quant(qbank: dict, idx_a, w_a,
                                               idx_b, w_b, xp):
    """k-sparse admission aggregation over a QUANTIZED bank.

    qbank: {"bank_a_q","bank_a_scale","bank_b_q","bank_b_scale"} with
    leading [L, N] dims (quant.schemes.quantize_bank). Same layer-folding
    trick as precompute_effective_adapters_sparse — ONE batched launch of
    P = R·L rows — but HBM reads are the quantized row width and the
    dequant happens in-register (kernels/mask_aggregate_quant.py).
    Returns fp32 (Â [..., L, d, b], B̂ [..., L, b, d]); the engine
    re-quantizes per row for its cache entries / slot buffers.
    """
    from repro.kernels import ops

    L, N = qbank["bank_a_q"].shape[:2]
    d = qbank["bank_a_q"].shape[2]
    b = qbank["bank_b_q"].shape[2]
    batch = idx_a.shape[:-2]
    flat = {k: v.reshape((L * N,) + v.shape[2:]) for k, v in qbank.items()}
    off = (jnp.arange(L, dtype=jnp.int32) * N)[:, None]     # [L, 1]

    def flatten(idx, w):
        k = idx.shape[-1]
        fi = (idx.astype(jnp.int32) + off).reshape(-1, k)
        return fi, w.astype(jnp.float32).reshape(-1, k)

    fia, fwa = flatten(idx_a, w_a)
    fib, fwb = flatten(idx_b, w_b)
    a_hat = ops.mask_aggregate_quant_batched(
        flat["bank_a_q"], flat["bank_a_scale"], fia, fwa,
        scheme=xp.bank_quant, impl=xp.kernel_impl)
    b_hat = ops.mask_aggregate_quant_batched(
        flat["bank_b_q"], flat["bank_b_scale"], fib, fwb,
        scheme=xp.bank_quant, impl=xp.kernel_impl)
    return (a_hat.reshape(*batch, L, d, a_hat.shape[-1]),
            b_hat.reshape(*batch, L, b, b_hat.shape[-1]))


def apply_precomputed_layer(x, eff_l: dict, xp):
    """Apply an admission-time-aggregated adapter slice (per layer)."""
    from repro.kernels import ops

    return ops.fused_adapter(x, eff_l["a_hat"], eff_l["b_hat"],
                             eff_l["ln_scale"], eff_l["ln_bias"],
                             activation=xp.adapter_activation,
                             impl=xp.kernel_impl)
