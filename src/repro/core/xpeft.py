"""X-PEFT layer application + the multi-profile mask table.

The framework keeps per-profile trainables as a TABLE (leading dim =
max_profiles) so that hundreds of profiles train simultaneously in one batch:
each example gathers its profile's row, and gradient scatter-add back into the
table happens automatically through the gather transpose (DESIGN.md §3.4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import adapters as A
from repro.core import masks as M


def init_xpeft_state(key, cfg) -> dict:
    """Frozen bank + per-profile trainable table for a ModelConfig."""
    xp = cfg.xpeft
    kb, kp = jax.random.split(key)
    if xp.is_hetero:
        bank = A.init_hetero_bank(kb, cfg.num_layers, xp, cfg.d_model,
                                  cfg.kv_dim, dtype=jnp.dtype(cfg.dtype))
    else:
        bank = A.init_adapter_bank(kb, cfg.num_layers, xp.num_adapters,
                                   cfg.d_model, xp.bottleneck,
                                   dtype=jnp.dtype(cfg.dtype))
    table = init_profile_table(kp, cfg)
    return {"bank": bank, "profiles": table}


# Entry keys each adapter family contributes to a hydrated (aggregated)
# profile entry — the typed generalization of the {a_hat, b_hat, ln_*}
# record. The unified mask still selects over ONE [0, N) index space;
# these are the per-type AGGREGATES the selection produces.
HETERO_ENTRY_KEYS = {
    "bottleneck": ("a_hat", "b_hat", "ln_scale", "ln_bias"),
    "lora": ("lora_a", "lora_b"),
    "ia3": ("ia3_s",),
    "prefix": ("prefix_k", "prefix_v"),
}


def hetero_entry_keys(xp):
    """Ordered entry keys for the families present in ``xp.bank_spec``."""
    out = []
    for t, _, _ in xp.segments():
        for k in HETERO_ENTRY_KEYS[t]:
            if k not in out:
                out.append(k)
    return tuple(out)


def _segment_slice(w, off, cnt):
    """Static slice of the unified-N weight axis for one segment."""
    return w[..., off:off + cnt]


def _safe_inv(wsum):
    """0/0-safe renorm factor: 1/wsum where wsum > 0, else 0.

    Double-where, not ``1/maximum(wsum, eps)``: the derivative of that
    form at wsum = 0 is -1/eps^2, which overflows float32 to inf, and the
    zero cotangent the unselected where-branch receives turns 0·inf into
    NaN — poisoning the whole mask-logit gradient row whenever a training
    example's masks select no prefix slot at some layer."""
    safe = jnp.where(wsum > 0, wsum, 1.0)
    return jnp.where(wsum > 0, 1.0 / safe, 0.0)


def hetero_aggregate_dense_layer(bank_l: dict, w_a_l, w_b_l, xp):
    """One layer's per-type aggregates from DENSE unified-space weights.

    bank_l holds the layer-l slices of the typed bank leaves; w_*_l are
    [..., N] over the unified index space. Per family:

    - bottleneck/lora: Â from the A-mask, B̂ from the B-mask (the paper's
      two-sided selection, per side).
    - ia3: BOTH masks contribute — s = Σ (w_a + w_b)[i] · v[i] (a scale
      delta has no A/B sidedness).
    - prefix: renormalized convex mixture — rows = Σ (w_a+w_b)[i]·rows[i]
      / Σ (w_a+w_b)[i], 0/0 -> zero rows (KV rows are not residual
      deltas; an unnormalized sum would shrink every key toward zero).

    Returns {type: aggregate(s)} for the segments present.
    """
    out = {}
    for t, off, cnt in xp.segments():
        wa = _segment_slice(w_a_l, off, cnt).astype(jnp.float32)
        wb = _segment_slice(w_b_l, off, cnt).astype(jnp.float32)
        if t == "bottleneck":
            a_hat, b_hat = A.aggregate_dense(
                {"bank_a": bank_l["bank_a"], "bank_b": bank_l["bank_b"]},
                wa, wb)
            out["bottleneck"] = (a_hat, b_hat)
        elif t == "lora":
            a_hat, b_hat = A.aggregate_dense(
                {"bank_a": bank_l["lora_a"], "bank_b": bank_l["lora_b"]},
                wa, wb)
            out["lora"] = (a_hat, b_hat)
        elif t == "ia3":
            v = bank_l["ia3_v"].astype(jnp.float32)
            out["ia3"] = jnp.einsum("...n,nd->...d", wa + wb, v)
        elif t == "prefix":
            pk = bank_l["prefix_k"].astype(jnp.float32)
            pv = bank_l["prefix_v"].astype(jnp.float32)
            wab = wa + wb
            num_k = jnp.einsum("...n,npq->...pq", wab, pk)
            num_v = jnp.einsum("...n,npq->...pq", wab, pv)
            wsum = wab.sum(-1)
            inv = _safe_inv(wsum)[..., None, None]
            out["prefix"] = (num_k * inv, num_v * inv)
    return out


def precompute_effective_adapters_hetero(bank: dict, profile_params: dict,
                                         xp):
    """Dense admission-time aggregation for a heterogeneous bank (single
    profile): the typed twin of ``precompute_effective_adapters``.
    Returns the ``hetero_entry_keys(xp)`` dict with [L, ...] leaves."""
    w_a, w_b = profile_mask_weights(profile_params, xp, training=False)
    out = {}
    for t, off, cnt in xp.segments():
        wa = w_a[..., off:off + cnt].astype(jnp.float32)
        wb = w_b[..., off:off + cnt].astype(jnp.float32)
        if t == "bottleneck":
            a32 = bank["bank_a"].astype(jnp.float32)
            b32 = bank["bank_b"].astype(jnp.float32)
            out["a_hat"] = jnp.einsum("ln,lndb->ldb", wa, a32).astype(
                bank["bank_a"].dtype)
            out["b_hat"] = jnp.einsum("ln,lnbd->lbd", wb, b32).astype(
                bank["bank_b"].dtype)
            out["ln_scale"] = profile_params["ln_scale"]
            out["ln_bias"] = profile_params["ln_bias"]
        elif t == "lora":
            a32 = bank["lora_a"].astype(jnp.float32)
            b32 = bank["lora_b"].astype(jnp.float32)
            out["lora_a"] = jnp.einsum("ln,lndb->ldb", wa, a32).astype(
                bank["lora_a"].dtype)
            out["lora_b"] = jnp.einsum("ln,lnbd->lbd", wb, b32).astype(
                bank["lora_b"].dtype)
        elif t == "ia3":
            v32 = bank["ia3_v"].astype(jnp.float32)
            out["ia3_s"] = jnp.einsum("ln,lnd->ld", wa + wb, v32).astype(
                bank["ia3_v"].dtype)
        elif t == "prefix":
            pk = bank["prefix_k"].astype(jnp.float32)
            pv = bank["prefix_v"].astype(jnp.float32)
            wab = wa + wb
            num_k = jnp.einsum("ln,lnpq->lpq", wab, pk)
            num_v = jnp.einsum("ln,lnpq->lpq", wab, pv)
            wsum = wab.sum(-1)
            inv = _safe_inv(wsum)[:, None, None]
            out["prefix_k"] = (num_k * inv).astype(bank["prefix_k"].dtype)
            out["prefix_v"] = (num_v * inv).astype(bank["prefix_v"].dtype)
    return out


def init_profile_table(key, cfg) -> dict:
    xp = cfg.xpeft
    keys = jax.random.split(key, xp.max_profiles)
    return jax.vmap(
        lambda k: M.init_profile_params(k, cfg.num_layers, xp.num_adapters,
                                        xp.bottleneck)
    )(keys)


def gather_profiles(table: dict, profile_ids) -> dict:
    """Select rows of the profile table for a batch: [B, L, N] / [B, L, b]."""
    return jax.tree.map(lambda t: jnp.take(t, profile_ids, axis=0), table)


def profile_mask_weights(profile_params: dict, xp, *, key=None,
                         training: bool = True):
    """Logits -> (w_a, w_b) mask weights, shape [..., L, N]."""
    if key is not None:
        ka, kb = jax.random.split(key)
    else:
        ka = kb = None
    w_a = M.mask_weights(profile_params["mA"], xp, key=ka, training=training)
    w_b = M.mask_weights(profile_params["mB"], xp, key=kb, training=training)
    return w_a, w_b


def apply_xpeft_layer(x, bank_l: dict, w_a_l, w_b_l, ln_scale_l, ln_bias_l,
                      xp):
    """Apply the layer-l X-PEFT adapter to activations x [..., T, d].

    w_*_l: [N] (single profile) or [B, N] (per-example profiles).
    bank_l: {"bank_a": [N, d, b], "bank_b": [N, b, d]} — the slice the
    scan-over-layers feeds in.
    """
    a_hat, b_hat = A.aggregate_dense(bank_l, w_a_l, w_b_l)
    return A.apply_adapter(x, a_hat, b_hat, ln_scale_l, ln_bias_l,
                           activation=xp.adapter_activation)


def apply_xpeft_layer_sparse(x, bank_l: dict, idx_a_l, w_a_l, idx_b_l, w_b_l,
                             ln_scale_l, ln_bias_l, xp):
    """Hard-mask serving path: k-sparse gather aggregation (N/k cheaper)."""
    a_hat, b_hat = A.aggregate_sparse(bank_l, idx_a_l, w_a_l, idx_b_l, w_b_l)
    return A.apply_adapter(x, a_hat, b_hat, ln_scale_l, ln_bias_l,
                           activation=xp.adapter_activation)


def apply_xpeft_layer_hetero(x, bank_l: dict, w_a_l, w_b_l, ln_scale_l,
                             ln_bias_l, xp):
    """Dense heterogeneous layer application (training / soft masks):
    aggregate each typed segment from the unified-space weights and apply
    in the fixed order bottleneck -> LoRA -> IA3. Prefix rows are NOT
    applied here — they are KV rows, threaded into attention by the model
    body (``prefix_rows_dense_layer``)."""
    agg = hetero_aggregate_dense_layer(bank_l, w_a_l, w_b_l, xp)
    if "bottleneck" in agg:
        a_hat, b_hat = agg["bottleneck"]
        x = A.apply_adapter(x, a_hat, b_hat, ln_scale_l, ln_bias_l,
                            activation=xp.adapter_activation)
    if "lora" in agg:
        la, lb = agg["lora"]
        x = A.apply_lora(x, la.astype(x.dtype), lb.astype(x.dtype))
    if "ia3" in agg:
        x = A.apply_ia3(x, agg["ia3"])
    return x


def prefix_rows_dense_layer(bank_l: dict, w_a_l, w_b_l, xp, kv_heads: int,
                            head_dim: int):
    """One layer's per-example prefix KV rows from dense unified-space
    weights: returns ``(pk [B, P, KV, hd], pv, pvalid [B])`` for
    attention's ``extra_kv``, or None when the spec has no prefix
    segment. pvalid is False where the example's masks select no prefix
    slot at this layer — attention then masks the rows out entirely, so
    a no-prefix selection stays bitwise the bare sequence."""
    seg = next(((off, cnt) for t, off, cnt in xp.segments()
                if t == "prefix"), None)
    if seg is None:
        return None
    off, cnt = seg
    wa = w_a_l[..., off:off + cnt].astype(jnp.float32)
    wb = w_b_l[..., off:off + cnt].astype(jnp.float32)
    wab = wa + wb                                       # [B, cnt]
    pk = bank_l["prefix_k"].astype(jnp.float32)         # [cnt, P, kv]
    pv = bank_l["prefix_v"].astype(jnp.float32)
    num_k = jnp.einsum("...n,npq->...pq", wab, pk)
    num_v = jnp.einsum("...n,npq->...pq", wab, pv)
    wsum = wab.sum(-1)                                  # [B]
    inv = _safe_inv(wsum)[..., None, None]
    shape = num_k.shape[:-1] + (kv_heads, head_dim)
    return ((num_k * inv).reshape(shape), (num_v * inv).reshape(shape),
            wsum > 0)


def precompute_effective_adapters(bank: dict, profile_params: dict, xp):
    """Admission-time aggregation (beyond-paper serving optimization).

    Aggregates a profile's masks against the whole bank ONCE, producing dense
    Â/B̂ stacks [L, d, b] / [L, b, d] that the decode hot loop applies with
    two tiny matmuls — removes the per-step aggregation from the critical
    path (DESIGN.md §3, serve cache).
    """
    w_a, w_b = profile_mask_weights(profile_params, xp, training=False)
    a_hat = jnp.einsum("ln,lndb->ldb", w_a, bank["bank_a"].astype(jnp.float32))
    b_hat = jnp.einsum("ln,lnbd->lbd", w_b, bank["bank_b"].astype(jnp.float32))
    return {"a_hat": a_hat.astype(bank["bank_a"].dtype),
            "b_hat": b_hat.astype(bank["bank_b"].dtype),
            "ln_scale": profile_params["ln_scale"],
            "ln_bias": profile_params["ln_bias"]}


def precompute_effective_adapters_dense_batched(bank: dict, w_a, w_b):
    """Dense admission aggregation for a batch of profiles (soft masks).

    w_*: [R, L, N] -> (Â [R, L, d, b], B̂ [R, L, b, d]) in bank dtype. The
    R=1 case is precompute_effective_adapters' einsum with a request axis;
    soft masks are dense by construction so there is no sparse shortcut.
    """
    a_hat = jnp.einsum("rln,lndb->rldb", w_a.astype(jnp.float32),
                       bank["bank_a"].astype(jnp.float32))
    b_hat = jnp.einsum("rln,lnbd->rlbd", w_b.astype(jnp.float32),
                       bank["bank_b"].astype(jnp.float32))
    return (a_hat.astype(bank["bank_a"].dtype),
            b_hat.astype(bank["bank_b"].dtype))


def precompute_effective_adapters_sparse(bank: dict, idx_a, w_a, idx_b, w_b,
                                         xp):
    """k-sparse admission aggregation through the kernel dispatch layer.

    idx_*/w_*: [..., L, k] (a single profile's top-k mask indices, or a
    leading request batch R for multi-request admission). Reads only
    k·L·d·b bank bytes (N/k less than the dense einsum in
    precompute_effective_adapters) by folding the layer axis into the
    bank's N axis and issuing ONE batched aggregation of P = R·L rows.
    """
    from repro.kernels import ops

    L, N = bank["bank_a"].shape[:2]
    d, b = bank["bank_a"].shape[2], bank["bank_a"].shape[3]
    batch = idx_a.shape[:-2]
    # fold layers into the bank slot axis: row (l, n) -> l*N + n
    flat_a = bank["bank_a"].reshape(L * N, d, b)
    flat_b = bank["bank_b"].reshape(L * N, b, d)
    off = (jnp.arange(L, dtype=jnp.int32) * N)[:, None]     # [L, 1]

    def flatten(idx, w):
        k = idx.shape[-1]
        fi = (idx.astype(jnp.int32) + off).reshape(-1, k)
        return fi, w.astype(jnp.float32).reshape(-1, k)

    fia, fwa = flatten(idx_a, w_a)
    fib, fwb = flatten(idx_b, w_b)
    a_hat = ops.mask_aggregate_batched(flat_a, fia, fwa, impl=xp.kernel_impl)
    b_hat = ops.mask_aggregate_batched(flat_b, fib, fwb, impl=xp.kernel_impl)
    dt = bank["bank_a"].dtype
    return (a_hat.reshape(*batch, L, d, b).astype(dt),
            b_hat.reshape(*batch, L, b, d).astype(dt))


def _sparse_fold(leaf, idx, w, xp):
    """Layer-folded k-sparse aggregation of one typed leaf.

    leaf [L, C, p, q]; idx/w [..., L, k] with idx LOCAL to the segment
    (weights of out-of-segment selections already zeroed) -> [..., L, p, q]
    fp32, via ONE batched mask_aggregate launch of R·L rows — the same
    layer-folding trick as precompute_effective_adapters_sparse."""
    from repro.kernels import ops

    L, C, p, q = leaf.shape
    batch = idx.shape[:-2]
    k = idx.shape[-1]
    flat = leaf.reshape(L * C, p, q)
    off = (jnp.arange(L, dtype=jnp.int32) * C)[:, None]
    fi = (idx.astype(jnp.int32) + off).reshape(-1, k)
    fw = w.astype(jnp.float32).reshape(-1, k)
    out = ops.mask_aggregate_batched(flat, fi, fw, impl=xp.kernel_impl)
    return out.reshape(*batch, L, p, q)


def _segment_bucket(idx, w, off, cnt):
    """Fixed-shape bucketing of unified-space indices into one segment:
    indices outside [off, off+cnt) clamp to a valid local row and their
    weights zero out (0 * finite row == exact 0 in the fp32 accumulator),
    so every segment runs at the full static k width — one trace, no
    data-dependent shapes."""
    in_seg = (idx >= off) & (idx < off + cnt)
    local = jnp.clip(idx - off, 0, cnt - 1).astype(jnp.int32)
    return local, w.astype(jnp.float32) * in_seg


def precompute_effective_adapters_sparse_hetero(bank: dict, idx_a, w_a,
                                                idx_b, w_b, xp):
    """k-sparse admission aggregation for a heterogeneous bank.

    idx_*/w_*: [..., L, k] over the UNIFIED index space. Each typed
    segment buckets the k selections with ``_segment_bucket`` and runs the
    SAME batched aggregation kernels at full k width, so a mixed-type
    k-sparse aggregation is exactly the sum of per-type dense
    aggregations (the property the fuzz test pins down). Returns the
    per-type aggregates (no ln affines — the caller attaches the
    profile's own); bottleneck/LoRA sides follow their masks, IA3 and
    prefix take contributions from BOTH masks, prefix renormalized to a
    convex mixture (0/0 -> zero rows)."""
    out = {}
    for t, off, cnt in xp.segments():
        la, wa = _segment_bucket(idx_a, w_a, off, cnt)
        lb, wb = _segment_bucket(idx_b, w_b, off, cnt)
        if t in ("bottleneck", "lora"):
            names = ("bank_a", "bank_b") if t == "bottleneck" else \
                ("lora_a", "lora_b")
            sub = {"bank_a": bank[names[0]], "bank_b": bank[names[1]]}
            a_hat, b_hat = precompute_effective_adapters_sparse(
                sub, la, wa, lb, wb, xp)
            if t == "bottleneck":
                out["a_hat"], out["b_hat"] = a_hat, b_hat
            else:
                out["lora_a"], out["lora_b"] = a_hat, b_hat
        elif t == "ia3":
            v = bank["ia3_v"][..., None]                   # [L, C, d, 1]
            s = _sparse_fold(v, la, wa, xp) + _sparse_fold(v, lb, wb, xp)
            out["ia3_s"] = s[..., 0].astype(bank["ia3_v"].dtype)
        elif t == "prefix":
            num_k = _sparse_fold(bank["prefix_k"], la, wa, xp) + \
                _sparse_fold(bank["prefix_k"], lb, wb, xp)
            num_v = _sparse_fold(bank["prefix_v"], la, wa, xp) + \
                _sparse_fold(bank["prefix_v"], lb, wb, xp)
            wsum = wa.sum(-1) + wb.sum(-1)                 # [..., L]
            inv = _safe_inv(wsum)[..., None, None]
            dt = bank["prefix_k"].dtype
            out["prefix_k"] = (num_k * inv).astype(dt)
            out["prefix_v"] = (num_v * inv).astype(dt)
    return out


def precompute_effective_adapters_sparse_quant(qbank: dict, idx_a, w_a,
                                               idx_b, w_b, xp):
    """k-sparse admission aggregation over a QUANTIZED bank.

    qbank: {"bank_a_q","bank_a_scale","bank_b_q","bank_b_scale"} with
    leading [L, N] dims (quant.schemes.quantize_bank). Same layer-folding
    trick as precompute_effective_adapters_sparse — ONE batched launch of
    P = R·L rows — but HBM reads are the quantized row width and the
    dequant happens in-register (kernels/mask_aggregate_quant.py).
    Returns fp32 (Â [..., L, d, b], B̂ [..., L, b, d]); the engine
    re-quantizes per row for its cache entries / slot buffers.
    """
    from repro.kernels import ops

    L, N = qbank["bank_a_q"].shape[:2]
    d = qbank["bank_a_q"].shape[2]
    b = qbank["bank_b_q"].shape[2]
    batch = idx_a.shape[:-2]
    flat = {k: v.reshape((L * N,) + v.shape[2:]) for k, v in qbank.items()}
    off = (jnp.arange(L, dtype=jnp.int32) * N)[:, None]     # [L, 1]

    def flatten(idx, w):
        k = idx.shape[-1]
        fi = (idx.astype(jnp.int32) + off).reshape(-1, k)
        return fi, w.astype(jnp.float32).reshape(-1, k)

    fia, fwa = flatten(idx_a, w_a)
    fib, fwb = flatten(idx_b, w_b)
    a_hat = ops.mask_aggregate_quant_batched(
        flat["bank_a_q"], flat["bank_a_scale"], fia, fwa,
        scheme=xp.bank_quant, impl=xp.kernel_impl)
    b_hat = ops.mask_aggregate_quant_batched(
        flat["bank_b_q"], flat["bank_b_scale"], fib, fwb,
        scheme=xp.bank_quant, impl=xp.kernel_impl)
    return (a_hat.reshape(*batch, L, d, a_hat.shape[-1]),
            b_hat.reshape(*batch, L, b, b_hat.shape[-1]))


def apply_precomputed_layer(x, eff_l: dict, xp):
    """Apply an admission-time-aggregated adapter slice (per layer)."""
    from repro.kernels import ops

    return ops.fused_adapter(x, eff_l["a_hat"], eff_l["b_hat"],
                             eff_l["ln_scale"], eff_l["ln_bias"],
                             activation=xp.adapter_activation,
                             impl=xp.kernel_impl)
