"""X-PEFT core: the paper's contribution as a composable JAX module."""
from repro.core import adapters, masks, profiles, xpeft  # noqa: F401
