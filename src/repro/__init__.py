"""repro: X-PEFT multi-profile training/serving framework in JAX."""
__version__ = "1.0.0"
