"""repro: X-PEFT multi-profile training/serving framework in JAX."""
import jax as _jax

# Sharding-invariant RNG, process-wide: with the legacy (non-partitionable)
# threefry lowering, a jax.random draw whose consumer is GSPMD-sharded
# produces DIFFERENT values than the same draw unsharded — the gang step's
# Gumbel mask noise would silently diverge between 1 device and a mesh.
# Partitionable threefry (the jax>=0.5 default) makes every draw a pure
# function of (key, element index) regardless of partitioning, which the
# multi-device parity gate (benchmarks/sharded_smoke.py) relies on.
#
# Deliberately set at PACKAGE import rather than per entry point: parity
# needs the single-device and mesh paths of the SAME process to share one
# RNG flavor, and a missed entry point would break bitwise parity silently.
# The cost is a global-config side effect on hosts embedding repro as a
# library on jax<0.5 — their own draws switch to the partitionable stream.
_jax.config.update("jax_threefry_partitionable", True)

__version__ = "1.0.0"
