"""Kernel dispatch layer: jit'd public wrappers for the Pallas kernels.

Every model/serve hot path that applies or aggregates adapters routes
through this module (models/model.py `_xpeft_apply`, core/xpeft.py
`apply_precomputed_layer`, serve/engine.py admission). Callers pass
``impl`` — normally ``cfg.xpeft.kernel_impl`` — and the wrapper picks the
execution backend:

- ``auto``      — compiled Pallas on TPU; jnp reference elsewhere (this CPU
                  container). The reference is the fast path off-TPU: Pallas
                  interpret mode executes the kernel body op-by-op in the
                  scheduler and is strictly a semantics check.
- ``pallas``    — force the compiled Pallas kernel (TPU).
- ``interpret`` — force Pallas interpret mode (CI/parity testing: the exact
                  kernel body, runnable on CPU).
- ``ref``       — force the jnp oracle in kernels/ref.py.

Batched (ndim-3) inputs dispatch to the single-launch batched kernels
(`fused_adapter_batched.py`, `mask_aggregate.mask_aggregate_batched`)
rather than a vmap-of-kernel: one grid `(B, ...)` launch pipelines the
per-row Â/B̂ fetches instead of serializing B independent pallas_calls.

The quantized-bank routes (`mask_aggregate_quant_batched`,
`fused_adapter_quant` — XPeftConfig.bank_quant) take int8 / packed-int4
payloads + fp16 scales and dequantize in-register inside the kernels
(`mask_aggregate_quant.py`, `fused_adapter_quant.py`); the jnp refs share
the exact dequant op sequence (`quant.schemes.dequant_block`).

TPU deployment note: `bottleneck` b of 48/64 is below the 128 lane width;
for peak MXU utilization pad Â/B̂'s b dim to 128 — LN must then mask the
padded columns (ops here keep the unpadded semantics; the pad is a
launch-config choice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fused_adapter import fused_adapter as _fused_pallas
from repro.kernels.fused_adapter_batched import (
    fused_adapter_batched as _fused_pallas_batched)
from repro.kernels.fused_adapter_quant import (
    fused_adapter_quant_batched as _fused_pallas_quant)
from repro.kernels.mask_aggregate import mask_aggregate as _agg_pallas
from repro.kernels.mask_aggregate import (
    mask_aggregate_batched as _agg_pallas_batched)
from repro.kernels.mask_aggregate_quant import (
    mask_aggregate_quant_batched as _agg_pallas_quant)
from repro.quant.schemes import check_scheme

IMPLS = ("auto", "pallas", "interpret", "ref")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str) -> str:
    """'auto' -> 'pallas' on TPU, 'ref' elsewhere; others pass through."""
    if impl not in IMPLS:
        raise ValueError(f"kernel_impl {impl!r}; expected one of {IMPLS}")
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


def mask_aggregate(bank, idx, w, *, impl: str = "auto"):
    """k-sparse bank aggregation. bank [N,d,b], idx [k], w [k] -> [d,b]."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref.mask_aggregate_ref(bank, idx, w)
    return _agg_pallas(bank, idx, w, interpret=impl == "interpret")


def mask_aggregate_batched(bank, idx, w, *, impl: str = "auto"):
    """bank [N,d,b], idx [P,k], w [P,k] -> [P,d,b] (single batched launch)."""
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref.mask_aggregate_batched_ref(bank, idx, w)
    return _agg_pallas_batched(bank, idx, w, interpret=impl == "interpret")


def fused_adapter(x, a_hat, b_hat, ln_scale, ln_bias, *,
                  activation: str = "gelu", impl: str = "auto",
                  use_ln: bool = True):
    """Fused bottleneck adapter: y = x + B̂(act(LN(Â x))).

    x [T,d] with a_hat [d,b], or x [B,T,d] with per-row a_hat [B,d,b]
    (b_hat/ln_* likewise; 2-D adapter args broadcast across the batch).
    ``use_ln=False`` + ``activation="identity"`` is the LoRA route
    (y = x + B̂Âx) — same kernels, the LN block compiled out.
    """
    impl = resolve_impl(impl)
    if x.ndim == 3:
        if impl == "ref":
            return ref.fused_adapter_batched_ref(
                x, a_hat, b_hat, ln_scale, ln_bias, activation=activation,
                use_ln=use_ln)
        return _fused_pallas_batched(x, a_hat, b_hat, ln_scale, ln_bias,
                                     activation=activation, use_ln=use_ln,
                                     interpret=impl == "interpret")
    if impl == "ref":
        return ref.fused_adapter_ref(x, a_hat, b_hat, ln_scale, ln_bias,
                                     activation=activation, use_ln=use_ln)
    return _fused_pallas(x, a_hat, b_hat, ln_scale, ln_bias,
                         activation=activation, use_ln=use_ln,
                         interpret=impl == "interpret")


def lora_adapter(x, a_hat, b_hat, *, impl: str = "auto"):
    """LoRA route: y = x + B̂Âx — the fused bottleneck kernels with the
    LN skipped and identity activation. Â/B̂ share the bottleneck
    aggregate shapes (rank r = b), so aggregation AND application reuse
    the same kernels row-for-row. ln args are dummies the kernel never
    reads (shapes must still tile)."""
    b = a_hat.shape[-1]
    lead = a_hat.shape[:-2]
    ones = jnp.ones(lead + (b,), x.dtype)
    zeros = jnp.zeros(lead + (b,), x.dtype)
    return fused_adapter(x, a_hat, b_hat, ones, zeros,
                         activation="identity", impl=impl, use_ln=False)


def ia3_apply(x, s, *, impl: str = "auto"):
    """IA3 fused scaling: y = x * (1 + s), s the aggregated scale-delta
    vector ([d] shared or [B, d] per-row); x [B,T,d] or [T,d]."""
    from repro.kernels.ia3_apply import ia3_apply_batched as _ia3_pallas

    impl = resolve_impl(impl)
    squeeze = x.ndim == 2
    if squeeze:
        x = x[None]
    if impl == "ref":
        out = ref.ia3_apply_batched_ref(x, s)
    else:
        out = _ia3_pallas(x, s, interpret=impl == "interpret")
    return out[0] if squeeze else out


def decode_block_fused(x, pos, block, k_cache, v_cache, masks_l, *,
                       norm: str, qkv_bias: bool, use_rope: bool,
                       theta: float, cap: float, mlp_type: str,
                       act_name: str, adapter: str, adapter_act: str,
                       impl: str = "auto"):
    """Decode megakernel (ModelConfig.decode_fused): one program per layer
    applying norm/attention/MLP AND the X-PEFT adapter over the resident
    [B, 1, d] activations. `adapter` picks the fused route ("none", "bf16",
    "int8", "int4"); returns (y, k_rows, v_rows) — the caller scatters the
    K/V rows into the cache (paged writeback stays outside the kernel)."""
    from repro.kernels.decode_fused import decode_block_pallas

    impl = resolve_impl(impl)
    kw = dict(norm=norm, qkv_bias=qkv_bias, use_rope=use_rope, theta=theta,
              cap=cap, mlp_type=mlp_type, act_name=act_name, adapter=adapter,
              adapter_act=adapter_act)
    if impl == "ref":
        return ref.decode_block_ref(x, pos, block, k_cache, v_cache,
                                    masks_l, **kw)
    return decode_block_pallas(x, pos, block, k_cache, v_cache, masks_l,
                               interpret=impl == "interpret", **kw)


# ----------------------------------------------------------------------------
# Quantized-bank routes (XPeftConfig.bank_quant != "none"). Pure additions:
# with bank_quant "none" nothing below is reachable and the unquantized
# dispatch above stays bitwise-identical.
# ----------------------------------------------------------------------------

def mask_aggregate_quant_batched(q, scale, idx, w, *, scheme: str,
                                 impl: str = "auto"):
    """k-sparse aggregation over a quantized bank: q [N,d,b|b/2] int8/uint8,
    scale [N,d|d,b/g] fp16, idx [P,k], w [P,k] -> [P,d,b] f32 (dequantized
    in-register; HBM reads stay at the quantized row width)."""
    check_scheme(scheme)
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref.mask_aggregate_quant_batched_ref(q, scale, idx, w,
                                                    scheme=scheme)
    return _agg_pallas_quant(q, scale, idx, w, scheme=scheme,
                             interpret=impl == "interpret")


def fused_adapter_quant(x, a_q, a_scale, b_q, b_scale, ln_scale, ln_bias, *,
                        scheme: str, activation: str = "gelu",
                        impl: str = "auto"):
    """Dequant-fused bottleneck adapter (decode/prefill hot path): x
    [B,T,d] with per-row quantized Â/B̂ records. Batched-only — quantized
    records always arrive per-slot from the profile cache / mask buffers."""
    check_scheme(scheme)
    if x.ndim != 3:
        raise ValueError("fused_adapter_quant is batched-only: x must be "
                         f"[B, T, d], got ndim={x.ndim}")
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref.fused_adapter_quant_batched_ref(
            x, a_q, a_scale, b_q, b_scale, ln_scale, ln_bias,
            scheme=scheme, activation=activation)
    return _fused_pallas_quant(x, a_q, a_scale, b_q, b_scale, ln_scale,
                               ln_bias, scheme=scheme, activation=activation,
                               interpret=impl == "interpret")
