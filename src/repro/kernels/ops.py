"""Jit'd public wrappers for the Pallas kernels with backend dispatch.

On TPU the compiled kernels run; elsewhere (this CPU container) they run in
interpret mode (the kernel body executed in Python — semantics identical) or
fall back to the jnp oracle. Batched variants vmap over profiles/slots.

TPU deployment note: `bottleneck` b of 48/64 is below the 128 lane width; for
peak MXU utilization pad Â/B̂'s b dim to 128 — LN must then mask the padded
columns (ops here keep the unpadded semantics; the pad is a launch-config
choice).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.fused_adapter import fused_adapter as _fused_pallas
from repro.kernels.mask_aggregate import mask_aggregate as _agg_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def mask_aggregate(bank, idx, w, *, impl: str = "auto"):
    """k-sparse bank aggregation. impl: auto|pallas|interpret|ref."""
    if impl == "ref" or (impl == "auto" and not _on_tpu() and bank.shape[1] > 4096):
        return ref.mask_aggregate_ref(bank, idx, w)
    if impl == "pallas" or (impl == "auto" and _on_tpu()):
        return _agg_pallas(bank, idx, w, interpret=False)
    return _agg_pallas(bank, idx, w, interpret=True)


def mask_aggregate_batched(bank, idx, w, *, impl: str = "auto"):
    """bank [N,d,b], idx [P,k], w [P,k] -> [P,d,b] (vmap over profiles)."""
    return jax.vmap(lambda i, ww: mask_aggregate(bank, i, ww, impl=impl))(
        idx, w)


def fused_adapter(x, a_hat, b_hat, ln_scale, ln_bias, *,
                  activation: str = "gelu", impl: str = "auto"):
    """Fused bottleneck adapter. x [T,d] (or [B,T,d] -> vmapped)."""
    if x.ndim == 3:
        return jax.vmap(
            lambda xx, aa, bb, ls, lb: fused_adapter(
                xx, aa, bb, ls, lb, activation=activation, impl=impl)
        )(x, a_hat, b_hat, ln_scale, ln_bias)
    if impl == "ref" or (impl == "auto" and not _on_tpu() and x.shape[0] > 4096):
        return ref.fused_adapter_ref(x, a_hat, b_hat, ln_scale, ln_bias,
                                     activation=activation)
    interpret = not (impl == "pallas" or (impl == "auto" and _on_tpu()))
    return _fused_pallas(x, a_hat, b_hat, ln_scale, ln_bias,
                         activation=activation, interpret=interpret)
