"""Pallas TPU megakernel: one whole transformer block per layer at decode
shapes (T=1, B slots) — norm1, QKV projection, RoPE, cached attention,
output projection, norm2, MLP AND the X-PEFT adapter (bf16 Â/B̂ or the
int8/int4 dequant routes) in a SINGLE ``pallas_call``.

The composed decode path launches attention, the MLP and the fused-adapter
kernel as separate programs per layer; at T=1 every one of those re-reads
the [1, d] residual from HBM. Here the residual stream lives in registers
for the whole block: grid ``(B,)``, one program per slot, and the only HBM
traffic is the weights (read once per slot), the slot's KV rows and the
[1, d] input/output.

The kernel does NOT scatter into the KV cache — it returns the new K/V
rows (already in cache dtype) and ``models/model.py`` scatters them at the
slot's position outside the kernel, so the paged continuous-batching
engine keeps its sentinel-drop writeback semantics unchanged.

``decode_block_row`` is the per-slot math, shared verbatim between the
kernel body (reading Refs) and ``ref.decode_block_ref`` (a python loop
over slots) — interpret-vs-ref parity is therefore bitwise by
construction on all three adapter routes, the same contract the quant
kernels make via ``dequant_block``.

VMEM note: the per-slot blocks load the full [S, KV, hd] cache rows and
the full weight set; at smoke/CI shapes that is KBs, at real decode
shapes (32k context) the S axis must be tiled with an online softmax —
a launch-config evolution, not a semantics change (the row math is the
oracle either way).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.schemes import dequant_block

NEG_INF = -2.0e38

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "sqrelu": lambda t: jnp.square(jax.nn.relu(t)),
    "identity": lambda t: t,
}

# adapter route -> the masks_l leaves the kernel streams per slot
ADAPTER_LEAVES = {
    "none": (),
    "bf16": ("a_hat", "b_hat", "ln_scale", "ln_bias"),
    "int8": ("a_q", "a_scale", "b_q", "b_scale", "ln_scale", "ln_bias"),
    "int4": ("a_q", "a_scale", "b_q", "b_scale", "ln_scale", "ln_bias"),
}


def _norm_row(t, scale, bias, kind: str, eps: float = 1e-6):
    """Row twin of models.common.norm_apply (same op order -> bitwise)."""
    t32 = t.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(t32 * t32, axis=-1, keepdims=True)
        y = t32 * jax.lax.rsqrt(var + eps)
        return (y * (1.0 + scale.astype(jnp.float32))).astype(t.dtype)
    mu = jnp.mean(t32, axis=-1, keepdims=True)
    var = jnp.var(t32, axis=-1, keepdims=True)
    y = (t32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(t.dtype)


def decode_block_row(x, pos, n1, n2, attn, mlp, kc, vc, ad, *, norm: str,
                     qkv_bias: bool, use_rope: bool, theta: float,
                     cap: float, mlp_type: str, act_name: str,
                     adapter: str, adapter_act: str):
    """One slot's whole decode block: x [1, d], pos scalar int32,
    kc/vc [S, KV, hd] cache rows, ad the slot's adapter leaves (or {}).

    Returns (y [1, d], k_row [KV, hd], v_row [KV, hd]) with the K/V rows
    already in cache dtype. Pure jnp on plain arrays — the Pallas kernel
    body and the ref oracle both call THIS, so their parity is bitwise.
    """
    dt = x.dtype
    d = x.shape[-1]
    S, KV, hd = kc.shape
    H = attn["wq"].shape[1]
    G = H // KV
    act = _ACTS[act_name]

    # --- norm1 + QKV (mirrors attention.attention at T=1) ----------------
    h = _norm_row(x, n1["scale"], n1.get("bias"), norm)
    q = jnp.dot(h, attn["wq"].reshape(d, H * hd)).reshape(1, H, hd)
    k = jnp.dot(h, attn["wk"].reshape(d, KV * hd)).reshape(1, KV, hd)
    v = jnp.dot(h, attn["wv"].reshape(d, KV * hd)).reshape(1, KV, hd)
    if qkv_bias:
        q = q + attn["bq"].astype(q.dtype)
        k = k + attn["bk"].astype(k.dtype)
        v = v + attn["bv"].astype(v.dtype)
    if use_rope:
        half = hd // 2
        # == models.common.rope_freqs: iota*2.0 is exactly arange(0,hd,2)
        freqs = 1.0 / (theta ** (jax.lax.broadcasted_iota(
            jnp.float32, (1, half), 1) * 2.0 / hd))
        ang = pos.astype(jnp.float32) * freqs            # [1, hd/2]
        cos = jnp.cos(ang)[:, None, :]
        sin = jnp.sin(ang)[:, None, :]

        def rope(t):
            t1, t2 = jnp.split(t.astype(jnp.float32), 2, axis=-1)
            return jnp.concatenate(
                [t1 * cos - t2 * sin, t1 * sin + t2 * cos],
                axis=-1).astype(t.dtype)

        q, k = rope(q), rope(k)

    # --- cached attention ------------------------------------------------
    # the composed path writes K/V into the cache and reads them BACK
    # (quantized caches round-trip through cache dtype); mirror that by
    # substituting the round-tripped new row at position `pos`
    k_row = k[0].astype(kc.dtype)                        # [KV, hd]
    v_row = v[0].astype(vc.dtype)
    s_iota = jax.lax.broadcasted_iota(jnp.int32, (S, 1, 1), 0)
    keys = jnp.where(s_iota == pos, k_row.astype(dt)[None], kc.astype(dt))
    vals = jnp.where(s_iota == pos, v_row.astype(dt)[None], vc.astype(dt))
    keys = keys.transpose(1, 0, 2)                       # [KV, S, hd]
    vals = vals.transpose(1, 0, 2)
    qg = q.reshape(1, KV, G, hd).transpose(1, 2, 0, 3)   # [KV, G, 1, hd]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    logits = jnp.einsum("kgth,ksh->kgts", qg, keys,
                        preferred_element_type=jnp.float32) * scale
    if cap and cap > 0:
        logits = jnp.tanh(logits / cap) * cap
    kp = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, S), 3)
    # causal+valid at T=1 collapse to k_pos <= pos
    logits = jnp.where(kp <= pos, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("kgts,ksh->kgth", w.astype(dt), vals)
    o = o.transpose(2, 0, 1, 3).reshape(1, H * hd)
    x = x + jnp.dot(o, attn["wo"].reshape(H * hd, d))

    # --- norm2 + MLP ------------------------------------------------------
    h = _norm_row(x, n2["scale"], n2.get("bias"), norm)
    if mlp_type == "glu":
        g = jnp.dot(h, mlp["wg"])
        u = jnp.dot(h, mlp["wu"])
        x = x + jnp.dot(act(g) * u, mlp["wd"])
    else:
        m = act(jnp.dot(h, mlp["w1"]) + mlp["b1"].astype(h.dtype))
        x = x + (jnp.dot(m, mlp["w2"]) + mlp["b2"].astype(h.dtype))

    # --- X-PEFT adapter (same op order as the fused-adapter kernels) -----
    if adapter == "bf16":
        hh = jnp.dot(x, ad["a_hat"], preferred_element_type=jnp.float32)
        mu = jnp.mean(hh, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(hh - mu), axis=-1, keepdims=True)
        hh = (hh - mu) * jax.lax.rsqrt(var + 1e-6)
        hh = hh * ad["ln_scale"].astype(jnp.float32) \
            + ad["ln_bias"].astype(jnp.float32)
        if adapter_act == "gelu":
            hh = jax.nn.gelu(hh)
        y = jnp.dot(hh.astype(dt), ad["b_hat"],
                    preferred_element_type=jnp.float32)
        x = x + y.astype(dt)
    elif adapter in ("int8", "int4"):
        x32 = x.astype(jnp.float32)
        a = dequant_block(ad["a_q"], ad["a_scale"], adapter)
        hh = jnp.dot(x32, a, preferred_element_type=jnp.float32)
        mu = jnp.mean(hh, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(hh - mu), axis=-1, keepdims=True)
        hh = (hh - mu) * jax.lax.rsqrt(var + 1e-6)
        hh = hh * ad["ln_scale"].astype(jnp.float32) \
            + ad["ln_bias"].astype(jnp.float32)
        if adapter_act == "gelu":
            hh = jax.nn.gelu(hh)
        y = jnp.dot(hh, dequant_block(ad["b_q"], ad["b_scale"], adapter),
                    preferred_element_type=jnp.float32)
        x = (x32 + y).astype(dt)

    return x, k_row, v_row


def _weight_names(norm: str, qkv_bias: bool, mlp_type: str):
    names = ["n1.scale"]
    if norm == "layernorm":
        names.append("n1.bias")
    names += ["attn.wq", "attn.wk", "attn.wv", "attn.wo"]
    if qkv_bias:
        names += ["attn.bq", "attn.bk", "attn.bv"]
    names.append("n2.scale")
    if norm == "layernorm":
        names.append("n2.bias")
    if mlp_type == "glu":
        names += ["mlp.wg", "mlp.wu", "mlp.wd"]
    else:
        names += ["mlp.w1", "mlp.b1", "mlp.w2", "mlp.b2"]
    return names


def _lookup(block, path):
    o = block
    for p in path.split("."):
        o = o[p]
    return o


def _regroup(names, values):
    """names like 'attn.wq' / 'ad.a_hat' -> {"n1": {...}, "attn": {...}, ...}"""
    out = {}
    for nm, v in zip(names, values):
        grp, leaf = nm.split(".")
        out.setdefault(grp, {})[leaf] = v
    return out


@functools.partial(jax.jit, static_argnames=(
    "norm", "qkv_bias", "use_rope", "theta", "cap", "mlp_type", "act_name",
    "adapter", "adapter_act", "interpret"))
def decode_block_pallas(x, pos, block, k_cache, v_cache, masks_l, *,
                        norm: str, qkv_bias: bool, use_rope: bool,
                        theta: float, cap: float, mlp_type: str,
                        act_name: str, adapter: str, adapter_act: str,
                        interpret: bool = False):
    """x [B, 1, d], pos [B] int32, block the layer's param dict, k/v_cache
    [B, S, KV, hd], masks_l the per-slot adapter leaves (route `adapter`).

    -> (y [B, 1, d], k_rows [B, KV, hd], v_rows [B, KV, hd]).
    """
    B, T, d = x.shape
    assert T == 1, "decode megakernel is a T=1 path"
    S, KV, hd = k_cache.shape[1:]
    cdt = k_cache.dtype

    def full(arr):
        nd = arr.ndim
        return pl.BlockSpec(arr.shape, lambda bi, _n=nd: (0,) * _n)

    def row(arr):
        nd = arr.ndim
        return pl.BlockSpec((1,) + arr.shape[1:],
                            lambda bi, _n=nd: (bi,) + (0,) * (_n - 1))

    # (name, array, spec, leading-slot-dim?) in the kernel's fixed order
    ins = [("x", x, row(x), True), ("pos", pos, row(pos), True)]
    for nm in _weight_names(norm, qkv_bias, mlp_type):
        arr = _lookup(block, nm)
        ins.append((nm, arr, full(arr), False))
    ins.append(("kc", k_cache, row(k_cache), True))
    ins.append(("vc", v_cache, row(v_cache), True))
    for nm in ADAPTER_LEAVES[adapter]:
        arr = masks_l[nm]
        ins.append(("ad." + nm, arr, row(arr), True))

    names = tuple(nm for nm, _, _, _ in ins)
    rowset = tuple(is_row for _, _, _, is_row in ins)

    def kernel(*refs):
        o_ref, k_ref, v_ref = refs[-3:]
        vals = {}
        for nm, is_row, ref in zip(names, rowset, refs[:-3]):
            vals[nm] = ref[0] if is_row else ref[...]
        w = _regroup([n for n in names if "." in n],
                     [vals[n] for n in names if "." in n])
        y, k_row, v_row = decode_block_row(
            vals["x"], vals["pos"], w["n1"], w["n2"], w["attn"], w["mlp"],
            vals["kc"], vals["vc"], w.get("ad", {}), norm=norm,
            qkv_bias=qkv_bias, use_rope=use_rope, theta=theta, cap=cap,
            mlp_type=mlp_type, act_name=act_name, adapter=adapter,
            adapter_act=adapter_act)
        o_ref[0] = y
        k_ref[0] = k_row
        v_ref[0] = v_row

    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[spec for _, _, spec, _ in ins],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, KV, hd), lambda bi: (bi, 0, 0)),
            pl.BlockSpec((1, KV, hd), lambda bi: (bi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1, d), x.dtype),
            jax.ShapeDtypeStruct((B, KV, hd), cdt),
            jax.ShapeDtypeStruct((B, KV, hd), cdt),
        ],
        interpret=interpret,
    )(*[arr for _, arr, _, _ in ins])
