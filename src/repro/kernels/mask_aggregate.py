"""Pallas TPU kernel: k-sparse adapter-bank aggregation.

Hard X-PEFT masks select k of N adapters; aggregating via the dense
mask-bank einsum reads the WHOLE bank from HBM (N·d·b bytes) and spends
N·d·b MACs. This kernel streams only the k selected slices HBM->VMEM using
scalar-prefetched indices (the mask's k-hot index list lives in SMEM before
the grid starts, so the DMA pipeline knows which bank rows to fetch), and
accumulates in fp32 VMEM:

    bytes:  k·d·b   (N/k fewer, = 5.1x at N=256, k=50)
    flops:  k·d·b   MACs

Grid: (d/block_d, k) — the output tile stays resident in VMEM across the
minor k steps (revisiting accumulation), one bank tile per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, w_ref, bank_ref, out_ref):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += w_ref[ki] * bank_ref[0].astype(jnp.float32)


def _kernel_batched(idx_ref, w_ref, bank_ref, out_ref):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += w_ref[0, ki] * bank_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def mask_aggregate(bank, idx, w, *, block_d: int = 256,
                   interpret: bool = False):
    """bank [N, d, b], idx [k] int32, w [k] f32 -> [d, b] f32."""
    N, d, b = bank.shape
    k = idx.shape[0]
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(d // block_d, k),
        in_specs=[
            pl.BlockSpec((k,), lambda di, ki, idx_ref: (0,)),
            pl.BlockSpec((1, block_d, b),
                         lambda di, ki, idx_ref: (idx_ref[ki], di, 0)),
        ],
        out_specs=pl.BlockSpec((block_d, b), lambda di, ki, idx_ref: (di, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((d, b), jnp.float32),
        interpret=interpret,
    )(idx, w, bank)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def mask_aggregate_batched(bank, idx, w, *, block_d: int = 256,
                           interpret: bool = False):
    """bank [N, d, b], idx [P, k] int32, w [P, k] f32 -> [P, d, b] f32.

    One pallas_call for P profiles (serve admission batches the per-layer
    aggregations of every admitted request into one P = R·L launch; the
    layer axis is folded into the bank's N axis by the caller, see
    core.xpeft.precompute_effective_adapters_sparse). Grid (P, d/block_d, k):
    the output tile stays VMEM-resident across the minor k steps
    (revisiting accumulation) while scalar-prefetched indices steer the
    bank-row DMAs — HBM reads stay P·k·d·b, never N·d·b.
    """
    N, d, b = bank.shape
    P, k = idx.shape
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(P, d // block_d, k),
        in_specs=[
            pl.BlockSpec((1, k), lambda pi, di, ki, idx_ref: (pi, 0)),
            pl.BlockSpec((1, block_d, b),
                         lambda pi, di, ki, idx_ref: (idx_ref[pi, ki], di, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_d, b),
                               lambda pi, di, ki, idx_ref: (pi, di, 0)),
    )
    return pl.pallas_call(
        _kernel_batched,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, d, b), jnp.float32),
        interpret=interpret,
    )(idx, w, bank)
