"""Pallas TPU kernel: batched fused IA3 scaling.

IA3 rescales the residual stream elementwise: ``y = x * (1 + s)`` with
``s [B, d]`` the mask-weighted sum of the profile's selected scale DELTAS
(one aggregate vector per batch row, hydrated at admission exactly like
Â/B̂). The op is pure VPU work and trivially HBM-bound — the kernel's only
job is to stream the ``[block_t, d]`` activation tile through VMEM once
with the row's scale vector held resident, instead of letting XLA
materialize the broadcast ``[B, T, d]`` scale:

    HBM traffic: read x once + write y once (2·B·T·d) + s once (B·d).

``s == 0`` (empty selection / degraded serving) multiplies by exactly 1.0,
so the zero entry stays bitwise the bare PLM — the same identity contract
the bottleneck/LoRA zero aggregates satisfy additively.

Shared broadcast: pass 1-D ``s [d]`` to apply one profile's scale to the
whole batch (index map pins the fetch to row 0, mirroring the
fused-adapter kernels' shared-Â/B̂ path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, s_ref, o_ref):
    x = x_ref[0]                                            # [block_t, d]
    s = s_ref[0].astype(jnp.float32)                        # [d]
    y = x.astype(jnp.float32) * (1.0 + s)
    o_ref[0] = y.astype(x.dtype)


def _pick_block_t(T: int, block_t: int) -> int:
    block_t = min(block_t, T)
    while T % block_t:
        block_t -= 1
    return block_t


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def ia3_apply_batched(x, s, *, block_t: int = 256, interpret: bool = False):
    """x [B, T, d]; s [B, d] or [d] (shared) -> x * (1 + s)."""
    B, T, d = x.shape
    block_t = _pick_block_t(T, block_t)

    shared = s.ndim == 1
    if shared:
        s = s[None]
    row = (lambda bi, ti: (0, 0)) if shared else (lambda bi, ti: (bi, 0))

    return pl.pallas_call(
        _kernel,
        grid=(B, T // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, d), row),
        ],
        out_specs=pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, d), x.dtype),
        interpret=interpret,
    )(x, s)
