"""Pallas TPU kernel: fused bottleneck-adapter application.

The X-PEFT adapter d->b->d (b ≈ 48..64) has arithmetic intensity ~b, i.e. it
is HBM-bound on TPU. Unfused, XLA writes the [T,b] intermediate and re-reads
the [T,d] activations for the residual add. This kernel keeps a [block_t, d]
activation tile plus both projection matrices in VMEM and performs
down-proj -> LN -> GeLU -> up-proj -> residual in one pass:

    HBM traffic: read x once + write y once (2·T·d) vs ≥ 4·T·d unfused.

VMEM budget at defaults (block_t=256, d=8192, b=128, bf16):
x tile 4 MiB + Â 2 MiB + B̂ 2 MiB + out 4 MiB ≈ 12 MiB < 16 MiB v5e VMEM.
On real TPUs b should be zero-padded to a lane multiple (128) — the wrapper
in ops.py documents the LN-masking caveat.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, ls_ref, lb_ref, o_ref, *, activation, eps,
            use_ln):
    x = x_ref[...]
    h = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    if use_ln:
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
        h = (h - mu) * jax.lax.rsqrt(var + eps)
        h = h * ls_ref[...].astype(jnp.float32) + \
            lb_ref[...].astype(jnp.float32)
    if activation == "gelu":
        h = jax.nn.gelu(h)
    y = jnp.dot(h.astype(x.dtype), b_ref[...],
                preferred_element_type=jnp.float32)
    o_ref[...] = x + y.astype(x.dtype)


@functools.partial(jax.jit,
                   static_argnames=("activation", "block_t", "interpret",
                                    "use_ln"))
def fused_adapter(x, a_hat, b_hat, ln_scale, ln_bias, *,
                  activation: str = "gelu", block_t: int = 256,
                  interpret: bool = False, use_ln: bool = True):
    """x [T, d], a_hat [d, b], b_hat [b, d], ln_* [b] -> [T, d].
    ``use_ln=False`` skips LN-after-down-proj (the LoRA route)."""
    T, d = x.shape
    b = a_hat.shape[1]
    block_t = min(block_t, T)
    assert T % block_t == 0, (T, block_t)

    kernel = functools.partial(_kernel, activation=activation, eps=1e-6,
                               use_ln=use_ln)
    return pl.pallas_call(
        kernel,
        grid=(T // block_t,),
        in_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((d, b), lambda i: (0, 0)),
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=interpret,
    )(x, a_hat, b_hat, ln_scale, ln_bias)
