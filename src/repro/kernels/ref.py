"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mask_aggregate_ref(bank, idx, w):
    """bank [N, d, b], idx [k] int32, w [k] -> [d, b] fp32.

    The k-sparse hard-mask aggregation: Â = Σ_j w_j · bank[idx_j].
    """
    g = jnp.take(bank, idx, axis=0).astype(jnp.float32)      # [k, d, b]
    return jnp.einsum("k,kdb->db", w.astype(jnp.float32), g)


def fused_adapter_ref(x, a_hat, b_hat, ln_scale, ln_bias, *,
                      activation: str = "gelu", eps: float = 1e-6,
                      use_ln: bool = True):
    """x [T, d], a_hat [d, b], b_hat [b, d] -> [T, d].

    y = x + B̂(act(LN(Â x)))  — the X-PEFT bottleneck with the paper's
    LN-after-down-proj, fp32 internals. ``use_ln=False`` + identity
    activation is the LoRA route: y = x + B̂Âx.
    """
    h = jnp.dot(x.astype(jnp.float32), a_hat.astype(jnp.float32))
    if use_ln:
        mu = h.mean(-1, keepdims=True)
        var = h.var(-1, keepdims=True)
        h = (h - mu) * jax.lax.rsqrt(var + eps)
        h = h * ln_scale.astype(jnp.float32) + ln_bias.astype(jnp.float32)
    if activation == "gelu":
        h = jax.nn.gelu(h)
    y = jnp.dot(h, b_hat.astype(jnp.float32))
    return (x.astype(jnp.float32) + y).astype(x.dtype)


def ia3_apply_batched_ref(x, s):
    """x [B, T, d]; s [B, d] or [d] (shared) -> x * (1 + s), fp32 compute
    — the oracle twin of kernels/ia3_apply.py. s == 0 is bitwise x."""
    if s.ndim == 2:
        s = s[:, None, :]
    y = x.astype(jnp.float32) * (1.0 + s.astype(jnp.float32))
    return y.astype(x.dtype)


def mask_aggregate_quant_batched_ref(q, scale, idx, w, *, scheme: str):
    """Quantized twin of mask_aggregate_batched_ref, BIT-identical to the
    Pallas kernel: dequant via the shared quant.schemes.dequant_block and
    fp32 accumulation in the kernel's k-minor order (a python loop over the
    static k, not an einsum — einsum reduction order is XLA's choice)."""
    from repro.quant.schemes import dequant_block

    P, k = idx.shape
    rows_q = jnp.take(q, idx.reshape(-1), axis=0)
    rows_q = rows_q.reshape((P, k) + rows_q.shape[1:])
    rows_s = jnp.take(scale, idx.reshape(-1), axis=0)
    rows_s = rows_s.reshape((P, k) + rows_s.shape[1:])
    out = None
    for ki in range(k):
        term = w[:, ki, None, None].astype(jnp.float32) * \
            dequant_block(rows_q[:, ki], rows_s[:, ki], scheme)
        out = term if out is None else out + term
    return out


def fused_adapter_quant_batched_ref(x, a_q, a_scale, b_q, b_scale, ln_scale,
                                    ln_bias, *, scheme: str,
                                    activation: str = "gelu",
                                    eps: float = 1e-6):
    """Quantized twin of fused_adapter_batched_ref, mirroring the Pallas
    kernel's exact op sequence (fp32 x, dequant_block, mean/rsqrt LN) so
    interpret-mode parity is bitwise, not allclose."""
    from repro.quant.schemes import dequant_block

    B = x.shape[0]
    rows = []
    for i in range(B):
        xi = x[i].astype(jnp.float32)
        a = dequant_block(a_q[i], a_scale[i], scheme)
        h = jnp.dot(xi, a, preferred_element_type=jnp.float32)
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
        h = (h - mu) * jax.lax.rsqrt(var + eps)
        h = h * ln_scale[i].astype(jnp.float32) + \
            ln_bias[i].astype(jnp.float32)
        if activation == "gelu":
            h = jax.nn.gelu(h)
        y = jnp.dot(h, dequant_block(b_q[i], b_scale[i], scheme),
                    preferred_element_type=jnp.float32)
        rows.append((xi + y).astype(x.dtype))
    return jnp.stack(rows)


def decode_block_ref(x, pos, block, k_cache, v_cache, masks_l, *, norm: str,
                     qkv_bias: bool, use_rope: bool, theta: float,
                     cap: float, mlp_type: str, act_name: str,
                     adapter: str, adapter_act: str):
    """Oracle twin of the decode megakernel: a python loop over slots
    calling the SAME per-row math (`decode_fused.decode_block_row`) the
    kernel body runs — interpret-vs-ref parity is bitwise by construction
    on all three adapter routes (none/bf16, int8, int4)."""
    from repro.kernels.decode_fused import ADAPTER_LEAVES, decode_block_row

    B = x.shape[0]
    leaves = ADAPTER_LEAVES[adapter]
    ys, krs, vrs = [], [], []
    for i in range(B):
        ad_i = {nm: masks_l[nm][i] for nm in leaves}
        y, kr, vr = decode_block_row(
            x[i], pos[i], block["n1"], block["n2"], block["attn"],
            block["mlp"], k_cache[i], v_cache[i], ad_i, norm=norm,
            qkv_bias=qkv_bias, use_rope=use_rope, theta=theta, cap=cap,
            mlp_type=mlp_type, act_name=act_name, adapter=adapter,
            adapter_act=adapter_act)
        ys.append(y)
        krs.append(kr)
        vrs.append(vr)
    return jnp.stack(ys), jnp.stack(krs), jnp.stack(vrs)


def mask_aggregate_batched_ref(bank, idx, w):
    """bank [N, d, b], idx [P, k], w [P, k] -> [P, d, b] fp32."""
    g = jnp.take(bank, idx, axis=0).astype(jnp.float32)      # [P, k, d, b]
    return jnp.einsum("pk,pkdb->pdb", w.astype(jnp.float32), g)


def fused_adapter_batched_ref(x, a_hat, b_hat, ln_scale, ln_bias, *,
                              activation: str = "gelu", eps: float = 1e-6,
                              use_ln: bool = True):
    """x [B, T, d]; a_hat [B, d, b] or [d, b] (shared across the batch);
    ln_* [B, b] or [b] -> [B, T, d]. Batched twin of fused_adapter_ref;
    ``use_ln=False`` is the LoRA route."""
    x32 = x.astype(jnp.float32)
    a32 = a_hat.astype(jnp.float32)
    b32 = b_hat.astype(jnp.float32)
    if a_hat.ndim == 2:
        h = x32 @ a32
    else:
        h = jnp.einsum("btd,bdc->btc", x32, a32)
    if use_ln:
        mu = h.mean(-1, keepdims=True)
        var = h.var(-1, keepdims=True)
        h = (h - mu) * jax.lax.rsqrt(var + eps)
        ls = ln_scale.astype(jnp.float32)
        lb = ln_bias.astype(jnp.float32)
        if ls.ndim == 2:
            ls, lb = ls[:, None, :], lb[:, None, :]
        h = h * ls + lb
    if activation == "gelu":
        h = jax.nn.gelu(h)
    if b_hat.ndim == 2:
        y = h @ b32
    else:
        y = jnp.einsum("btc,bcd->btd", h, b32)
    return (x32 + y).astype(x.dtype)
