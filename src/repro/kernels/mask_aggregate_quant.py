"""Pallas TPU kernel: k-sparse aggregation over a QUANTIZED adapter bank.

Same revisiting-accumulation structure as `mask_aggregate_batched`
(grid (P, d/block_d, k), scalar-prefetched indices steering the bank-row
DMAs), but the bank rows arrive int8 (or packed int4) with fp16 scales and
are dequantized IN-REGISTER — the HBM traffic per aggregated profile drops
from 2·k·L·d·b bank-dtype bytes to the quantized row bytes:

    int8:  k·d·b bytes + k·d fp16 scales    (~2x under bf16, 4x under fp32)
    int4:  k·d·b/2 bytes + group scales     (~3.6x under bf16)

The dequant epilogue is `quant.schemes.dequant_block` — the SAME function
the jnp reference backend uses, so each dequantized term is BIT-identical
across compiled / interpret / ref (asserted in tests/test_kernels_quant.py
with one-hot weights). The k-term fp32 accumulation runs in the same
k-minor order in all three backends, but its final bits can differ by a
few ulps between backends: XLA contracts `w*deq + acc` into an FMA at
LLVM codegen inside whatever fusion each program structure produces, and
no HLO-level construct (optimization_barrier, bitcast round-trips) pins
that choice. Parity tests therefore assert terms bitwise and reductions
at <= 5e-7 absolute — quantization steps are ~1e-3, four orders larger.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.schemes import check_scheme, dequant_block


def _kernel(idx_ref, w_ref, q_ref, s_ref, out_ref, *, scheme):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    deq = dequant_block(q_ref[0], s_ref[0], scheme)     # [block_d, b] f32
    out_ref[...] += w_ref[0, ki] * deq


@functools.partial(jax.jit,
                   static_argnames=("scheme", "block_d", "interpret"))
def mask_aggregate_quant_batched(q, scale, idx, w, *, scheme: str,
                                 block_d: int = 256,
                                 interpret: bool = False):
    """Quantized bank rows q [N, d, b] int8 (or [N, d, b/2] uint8 packed
    int4) + scale [N, d] / [N, d, b/g] fp16, idx [P, k] int32, w [P, k]
    f32 -> [P, d, b] f32 (single batched launch, layer axis pre-folded into
    N by the caller exactly as in the unquantized path)."""
    check_scheme(scheme)
    N, d = q.shape[:2]
    b = q.shape[2] * (2 if scheme == "int4" else 1)
    P, k = idx.shape
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)

    scale_spec = (
        pl.BlockSpec((1, block_d),
                     lambda pi, di, ki, idx_ref: (idx_ref[pi, ki], di))
        if scheme == "int8" else
        pl.BlockSpec((1, block_d, scale.shape[-1]),
                     lambda pi, di, ki, idx_ref: (idx_ref[pi, ki], di, 0)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(P, d // block_d, k),
        in_specs=[
            pl.BlockSpec((1, k), lambda pi, di, ki, idx_ref: (pi, 0)),
            pl.BlockSpec((1, block_d, q.shape[-1]),
                         lambda pi, di, ki, idx_ref: (idx_ref[pi, ki], di, 0)),
            scale_spec,
        ],
        out_specs=pl.BlockSpec((1, block_d, b),
                               lambda pi, di, ki, idx_ref: (pi, di, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, scheme=scheme),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((P, d, b), jnp.float32),
        interpret=interpret,
    )(idx, w, q, scale)
