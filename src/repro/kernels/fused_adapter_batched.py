"""Pallas TPU kernel: batched fused bottleneck-adapter application.

The serve decode step and per-example-profile training both present the
adapter with a BATCH of activations and a batch of (already aggregated)
projection pairs: ``x [B, T, d]``, ``Â [B, d, b]``, ``B̂ [B, b, d]`` — a
grouped matmul (one adapter per batch row). The unbatched kernel in
``fused_adapter.py`` covers one row; vmapping it launches B independent
pallas_calls and loses the chance to pipeline Â/B̂ fetches across rows.

This kernel is ONE ``pallas_call`` with grid ``(B, T // block_t)``: the
per-row projections are fetched once per row (the t-minor grid order keeps
them resident in VMEM across the row's T/block_t steps) and the activation
tile streams HBM->VMEM exactly once:

    HBM traffic: read x once + write y once (2·B·T·d)
                 + the projections once     (2·B·d·b)
    vs unfused ≥ 4·B·T·d plus the [B, T, b] intermediate round-trip.

Shared-adapter broadcast: when every row uses the SAME Â/B̂ (e.g. an
admission-time aggregated single profile applied to a whole batch), pass
2-D ``a_hat [d, b]`` / ``b_hat [b, d]`` — the index map pins the fetch to
block 0 and no [B, d, b] materialization happens.

VMEM budget at decode defaults (block_t<=256, d=8192, b=128, bf16):
x tile 4 MiB + Â 2 MiB + B̂ 2 MiB + out 4 MiB ≈ 12 MiB < 16 MiB v5e VMEM.
As with the unbatched kernel, pad b to the 128 lane width on real TPUs
(LN then masks the padded columns — see ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, ls_ref, lb_ref, o_ref, *, activation, eps,
            use_ln):
    x = x_ref[0]                                            # [block_t, d]
    h = jnp.dot(x, a_ref[0], preferred_element_type=jnp.float32)
    if use_ln:
        mu = jnp.mean(h, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
        h = (h - mu) * jax.lax.rsqrt(var + eps)
        h = h * ls_ref[0].astype(jnp.float32) + \
            lb_ref[0].astype(jnp.float32)
    if activation == "gelu":
        h = jax.nn.gelu(h)
    y = jnp.dot(h.astype(x.dtype), b_ref[0],
                preferred_element_type=jnp.float32)
    o_ref[0] = x + y.astype(x.dtype)


def _pick_block_t(T: int, block_t: int) -> int:
    block_t = min(block_t, T)
    while T % block_t:          # fall back to a divisor (decode T is 1 or pow2)
        block_t -= 1
    return block_t


@functools.partial(jax.jit,
                   static_argnames=("activation", "block_t", "interpret",
                                    "use_ln"))
def fused_adapter_batched(x, a_hat, b_hat, ln_scale, ln_bias, *,
                          activation: str = "gelu", block_t: int = 256,
                          interpret: bool = False, use_ln: bool = True):
    """x [B, T, d]; a_hat [B, d, b] or [d, b] (shared); b_hat [B, b, d] or
    [b, d]; ln_* [B, b] or [b] -> [B, T, d]. ``use_ln=False`` skips the
    LN-after-down-proj (the LoRA route: identity activation + no LN turns
    the bottleneck kernel into x + B̂Âx)."""
    B, T, d = x.shape
    b = a_hat.shape[-1]
    block_t = _pick_block_t(T, block_t)

    shared_proj = a_hat.ndim == 2
    shared_ln = ln_scale.ndim == 1
    if shared_proj:
        a_hat, b_hat = a_hat[None], b_hat[None]
    if shared_ln:
        ln_scale, ln_bias = ln_scale[None], ln_bias[None]
    row_p = (lambda bi, ti: (0, 0, 0)) if shared_proj else \
        (lambda bi, ti: (bi, 0, 0))
    row_l = (lambda bi, ti: (0, 0)) if shared_ln else \
        (lambda bi, ti: (bi, 0))

    kernel = functools.partial(_kernel, activation=activation, eps=1e-6,
                               use_ln=use_ln)
    return pl.pallas_call(
        kernel,
        grid=(B, T // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, d, b), row_p),
            pl.BlockSpec((1, b, d), row_p),
            pl.BlockSpec((1, b), row_l),
            pl.BlockSpec((1, b), row_l),
        ],
        out_specs=pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, d), x.dtype),
        interpret=interpret,
    )(x, a_hat, b_hat, ln_scale, ln_bias)
