"""Pallas TPU kernel: batched fused adapter with in-register dequant.

The decode hot path applies per-slot aggregated Â/B̂ every layer; with
`bank_quant` enabled those records live in HBM as int8 / packed int4 +
fp16 scales (profile cache entries and the per-slot mask buffers). This
kernel is `fused_adapter_batched` with a dequant prologue: the quantized
projection rows stream HBM->VMEM at their quantized width and widen to
fp32 registers right before the MXU dots, so the adapter's HBM traffic
shrinks by the storage factor with ZERO extra latency from a separate
dequantize pass (no fp32/bf16 Â/B̂ ever materializes in HBM).

Grid (B, T/block_t), per-row records only (every slot serves its own
profile); compute is fp32 end-to-end (dequant output is fp32), matching
`ref.fused_adapter_quant_batched_ref` bit-for-bit — both call
`quant.schemes.dequant_block` and run the same LN/activation op sequence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.schemes import check_scheme, dequant_block


def _kernel(x_ref, aq_ref, as_ref, bq_ref, bs_ref, ls_ref, lb_ref, o_ref, *,
            scheme, activation, eps):
    x = x_ref[0].astype(jnp.float32)                        # [block_t, d]
    a = dequant_block(aq_ref[0], as_ref[0], scheme)         # [d, b] f32
    h = jnp.dot(x, a, preferred_element_type=jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    h = h * ls_ref[0].astype(jnp.float32) + lb_ref[0].astype(jnp.float32)
    if activation == "gelu":
        h = jax.nn.gelu(h)
    b_hat = dequant_block(bq_ref[0], bs_ref[0], scheme)     # [b, d] f32
    y = jnp.dot(h, b_hat, preferred_element_type=jnp.float32)
    o_ref[0] = (x + y).astype(o_ref.dtype)


def _pick_block_t(T: int, block_t: int) -> int:
    block_t = min(block_t, T)
    while T % block_t:
        block_t -= 1
    return block_t


@functools.partial(jax.jit, static_argnames=("scheme", "activation",
                                             "block_t", "interpret"))
def fused_adapter_quant_batched(x, a_q, a_scale, b_q, b_scale, ln_scale,
                                ln_bias, *, scheme: str,
                                activation: str = "gelu", block_t: int = 256,
                                interpret: bool = False):
    """x [B, T, d]; a_q [B, d, b] int8 (or [B, d, b/2] packed int4) with
    a_scale [B, d] / [B, d, b/g]; b_q [B, b, d] (or [B, b, d/2]) with
    b_scale [B, b] / [B, b, d/g]; ln_* [B, b] -> [B, T, d]."""
    check_scheme(scheme)
    B, T, d = x.shape
    b = b_q.shape[1]
    block_t = _pick_block_t(T, block_t)

    def row3(bi, ti):
        return (bi, 0, 0)

    def row2(bi, ti):
        return (bi, 0)

    scale_rank3 = scheme == "int4"
    a_s_spec = (pl.BlockSpec((1, d, a_scale.shape[-1]), row3) if scale_rank3
                else pl.BlockSpec((1, d), row2))
    b_s_spec = (pl.BlockSpec((1, b, b_scale.shape[-1]), row3) if scale_rank3
                else pl.BlockSpec((1, b), row2))
    kernel = functools.partial(_kernel, scheme=scheme, activation=activation,
                               eps=1e-6)
    return pl.pallas_call(
        kernel,
        grid=(B, T // block_t),
        in_specs=[
            pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, d, a_q.shape[-1]), row3),
            a_s_spec,
            pl.BlockSpec((1, b, b_q.shape[-1]), row3),
            b_s_spec,
            pl.BlockSpec((1, b), row2),
            pl.BlockSpec((1, b), row2),
        ],
        out_specs=pl.BlockSpec((1, block_t, d), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, d), x.dtype),
        interpret=interpret,
    )(x, a_q, a_scale, b_q, b_scale, ln_scale, ln_bias)
