"""`FaultPlan`: seeded, declarative fault injection for the chaos harness.

One plan object describes every fault a run injects; the hot paths carry
thin seams that consult it (`fault_plan=None` everywhere in production —
the seams cost nothing when no plan is installed):

- hydration faults  — `ServeEngine` admission probes call `on_hydration`
                      per (pid, attempt): persistent failures exhaust the
                      retry budget (the request degrades to the bare PLM),
                      flaky ones fail only the first attempt (the retry
                      succeeds), delays inject latency spikes.
- store corruption  — `corrupt_store` flips payload bytes of chosen
                      records WITHOUT updating their checksums, exactly
                      like disk/transfer corruption; the store's crc
                      verification must catch it at load/hydration.
- gang poisoning    — `gang_poison_mask` marks roster slots whose grads
                      the step overwrites with non-finite values
                      (in-trace, deterministic per slot_step), exercising
                      the per-slot finite guard.
- checkpoint faults — `truncate_checkpoint(step)` truncates the written
                      payload after its manifest checksum was computed,
                      the torn-write case resume must survive.

Every stochastic decision hashes (seed, kind, id) through crc32, so the
SAME plan replayed gives the SAME faults — benches compute the expected
degraded set from the plan itself and gate equality.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Callable, Iterable, List, Optional, Tuple

import numpy as np


class InjectedFault(Exception):
    """Base class for faults raised by a FaultPlan."""


class InjectedHydrationError(InjectedFault):
    """A plan-injected profile hydration failure."""

    def __init__(self, pid: int, attempt: int, persistent: bool):
        self.pid = int(pid)
        self.attempt = int(attempt)
        self.persistent = persistent
        kind = "persistent" if persistent else "transient"
        super().__init__(f"injected {kind} hydration failure: "
                         f"profile {pid}, attempt {attempt}")


@dataclass
class FaultPlan:
    seed: int = 0
    # -- hydration (serve admission) --------------------------------------
    hydration_fail_rate: float = 0.0    # persistent: every attempt fails
    hydration_flaky_rate: float = 0.0   # transient: only attempt 0 fails
    hydration_delay_rate: float = 0.0   # latency spike, then success
    hydration_delay_s: float = 0.0
    fail_pids: Tuple[int, ...] = ()     # explicit persistent failures
    flaky_pids: Tuple[int, ...] = ()    # explicit transient failures
    # -- store record corruption ------------------------------------------
    corrupt_pids: Tuple[int, ...] = ()  # records whose payload bytes flip
    corrupt_agg_only: bool = False      # flip only agg_* (quantized) fields
    # -- gang-step grad poisoning -----------------------------------------
    poison_slots: Tuple[int, ...] = ()  # roster slots with non-finite grads
    poison_from_step: int = 0           # ...once slot_step reaches this
    poison_steps: Optional[int] = None  # ...for this many steps (None=always)
    # -- checkpoint truncation --------------------------------------------
    truncate_ckpt_steps: Tuple[int, ...] = ()
    sleep: Callable[[float], None] = field(default=None, repr=False)

    # ------------------------------------------------------------- decisions
    def _u(self, kind: str, ident: int) -> float:
        """Deterministic uniform in [0, 1) for one (kind, id) decision."""
        h = zlib.crc32(f"{self.seed}:{kind}:{int(ident)}".encode())
        return (h & 0xFFFFFFFF) / 2.0 ** 32

    def hydration_mode(self, pid: int) -> Optional[str]:
        """"fail" | "flaky" | "delay" | None for one profile — stable
        across attempts and waves (what makes failures persistent)."""
        pid = int(pid)
        if pid in self.fail_pids:
            return "fail"
        if pid in self.flaky_pids:
            return "flaky"
        u = self._u("hydration", pid)
        edge = self.hydration_fail_rate
        if u < edge:
            return "fail"
        edge += self.hydration_flaky_rate
        if u < edge:
            return "flaky"
        edge += self.hydration_delay_rate
        if u < edge:
            return "delay"
        return None

    def on_hydration(self, pid: int, attempt: int) -> None:
        """Seam called before each hydration attempt; raises or delays."""
        mode = self.hydration_mode(pid)
        if mode == "fail":
            raise InjectedHydrationError(pid, attempt, persistent=True)
        if mode == "flaky" and attempt == 0:
            raise InjectedHydrationError(pid, attempt, persistent=False)
        if mode == "delay" and attempt == 0 and self.hydration_delay_s > 0:
            (self.sleep or __import__("time").sleep)(self.hydration_delay_s)

    def persistent_fail_pids(self, pids: Iterable[int]) -> List[int]:
        """The subset of `pids` whose hydration can never succeed — the
        bench's expected-degraded set (corrupt records add to it)."""
        return [int(p) for p in pids
                if self.hydration_mode(p) == "fail"]

    def flaky_hydration_pids(self, pids: Iterable[int]) -> List[int]:
        return [int(p) for p in pids
                if self.hydration_mode(p) == "flaky"]

    # ------------------------------------------------------------ corruption
    def corrupt_store(self, store) -> List[dict]:
        """Flip payload bytes of each `corrupt_pids` record IN the store,
        leaving its recorded checksums stale — the disk-corruption model.
        Returns [{"pid", "key"}] of what was corrupted. Deterministic:
        the flipped offset comes from the plan seed."""
        events = []
        for pid in self.corrupt_pids:
            rec = store._rec.get(int(pid))
            if not rec:
                continue
            keys = [k for k in sorted(rec)
                    if not self.corrupt_agg_only or k.startswith("agg_")]
            if not keys:
                continue
            key = keys[int(self._u("corrupt_key", pid) * len(keys))
                       % len(keys)]
            arr = np.array(rec[key], copy=True)
            flat = arr.view(np.uint8).reshape(-1)
            off = int(self._u("corrupt_off", pid) * flat.size) % flat.size
            flat[off] ^= 0xFF
            rec[key] = arr
            events.append({"pid": int(pid), "key": key})
        return events

    # --------------------------------------------------------- gang poisoning
    def poisons_gang(self) -> bool:
        return bool(self.poison_slots)

    def gang_poison_mask(self, slot_step, capacity: int):
        """[S] bool (traced): slots whose grads this step poisons, decided
        from the device-resident per-slot step counter so the injection is
        deterministic under jit and across resumes."""
        import jax.numpy as jnp

        sel = np.zeros((capacity,), bool)
        for s in self.poison_slots:
            if 0 <= int(s) < capacity:
                sel[int(s)] = True
        window = slot_step >= self.poison_from_step
        if self.poison_steps is not None:
            window &= slot_step < self.poison_from_step + self.poison_steps
        return jnp.asarray(sel) & window

    # ------------------------------------------------------------ checkpoints
    def truncate_checkpoint(self, step: int) -> bool:
        return int(step) in self.truncate_ckpt_steps
