"""Record / checkpoint integrity: crc32 checksums + the error types.

Checksums cover dtype, shape, AND payload bytes, so a bit flip, a
truncation, and a silent dtype change are all detected. crc32 on the
byte-scale profile records costs microseconds per hydration; on multi-MB
checkpoint files it runs once per save/restore.
"""
from __future__ import annotations

import zlib
from typing import Dict

import numpy as np


class RecordIntegrityError(Exception):
    """A ProfileStore record failed its checksum (or is quarantined)."""

    def __init__(self, pid: int, keys, reason: str = "checksum mismatch"):
        self.pid = int(pid)
        self.keys = tuple(keys)
        super().__init__(f"profile {pid}: {reason} ({', '.join(self.keys)})")


class CheckpointCorruptError(Exception):
    """A checkpoint payload failed its manifest checksum / size check."""


def array_crc(arr: np.ndarray) -> int:
    """crc32 of one array's dtype + shape + contiguous payload bytes."""
    a = np.ascontiguousarray(arr)
    head = f"{a.dtype.str}:{a.shape}".encode()
    return zlib.crc32(a.tobytes(), zlib.crc32(head)) & 0xFFFFFFFF


def record_crc(rec: Dict[str, np.ndarray]) -> Dict[str, int]:
    """Per-field checksums for one profile record."""
    return {k: array_crc(np.asarray(v)) for k, v in rec.items()}


def file_crc(path: str, chunk: int = 1 << 20):
    """(crc32, nbytes) of a file, streamed — checkpoint payloads."""
    crc, n = 0, 0
    with open(path, "rb") as f:
        while True:
            buf = f.read(chunk)
            if not buf:
                break
            crc = zlib.crc32(buf, crc)
            n += len(buf)
    return crc & 0xFFFFFFFF, n
