"""Resilience layer: seeded fault injection, retry/degrade primitives, and
record/checkpoint integrity.

Three pieces the serve/train hot paths run through (DESIGN: ISSUE 6):

- `faults.py`     — `FaultPlan`: a seeded, declarative chaos plan injected
                    behind thin seams (ProfileStore hydration, ServeEngine
                    admission, the gang step, CheckpointManager writes).
                    `None` everywhere = production behavior, zero overhead.
- `retry.py`      — `retry_with_backoff` + `RetryPolicy`: deadline-bounded
                    jittered exponential backoff (admission hydration).
- `integrity.py`  — crc32 checksums over store records / checkpoint
                    payloads and the error types the hot paths catch
                    (`RecordIntegrityError`, `CheckpointCorruptError`).

The invariant the whole layer leans on is X-PEFT's structure: every
profile is a tiny mask over ONE shared frozen PLM, so the bare PLM (a
zero-adapter mask) is always resident and always valid — hydration
failures degrade a request to it instead of failing the wave
(cf. arXiv:2305.16742, where the unadapted backbone is a first-class
inference path).
"""
from repro.resilience.faults import (FaultPlan, InjectedFault,
                                     InjectedHydrationError)
from repro.resilience.integrity import (CheckpointCorruptError,
                                        RecordIntegrityError, array_crc,
                                        file_crc, record_crc)
from repro.resilience.retry import RetryPolicy, retry_with_backoff

__all__ = [
    "FaultPlan", "InjectedFault", "InjectedHydrationError",
    "RecordIntegrityError", "CheckpointCorruptError",
    "array_crc", "record_crc", "file_crc",
    "RetryPolicy", "retry_with_backoff",
]
