"""Deadline-bounded retry with jittered exponential backoff.

The one retry primitive the hot paths share (admission hydration uses it
per profile). Deterministic: jitter comes from a seeded PRNG, and the
clock/sleep are injectable, so tests and the chaos bench replay exactly.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple, Type


@dataclass(frozen=True)
class RetryPolicy:
    """attempts total tries; delay_s * backoff**attempt between tries,
    capped at max_delay_s, each jittered by up to +jitter fraction;
    deadline_s bounds the WHOLE call (a retry that would start past the
    deadline is abandoned instead — serving latency stays bounded)."""
    attempts: int = 3
    delay_s: float = 0.005
    backoff: float = 2.0
    max_delay_s: float = 0.25
    jitter: float = 0.5
    deadline_s: float = 2.0


def retry_with_backoff(fn: Callable, *, policy: RetryPolicy = RetryPolicy(),
                       retry_on: Tuple[Type[BaseException], ...] = (Exception,),
                       seed: int = 0,
                       sleep: Callable[[float], None] = time.sleep,
                       clock: Callable[[], float] = time.monotonic,
                       on_retry: Optional[Callable] = None):
    """Call `fn()` up to `policy.attempts` times within `policy.deadline_s`.

    Retries only on `retry_on` exceptions; anything else propagates at
    once. `on_retry(exc, attempt, delay)` is invoked before each sleep
    (callers count retries through it). Raises the last error when the
    attempts or the deadline run out.
    """
    if policy.attempts < 1:
        raise ValueError("RetryPolicy.attempts must be >= 1")
    rng = random.Random(seed)
    t0 = clock()
    last: Optional[BaseException] = None
    for attempt in range(policy.attempts):
        try:
            return fn()
        except retry_on as exc:
            last = exc
            if attempt == policy.attempts - 1:
                break
            delay = min(policy.delay_s * policy.backoff ** attempt,
                        policy.max_delay_s)
            delay *= 1.0 + policy.jitter * rng.random()
            if clock() - t0 + delay > policy.deadline_s:
                break
            if on_retry is not None:
                on_retry(exc, attempt, delay)
            sleep(delay)
    assert last is not None
    raise last
