"""Checkpointing: atomic, async, keep-last-k, exact resume, cross-mesh
restore (elastic).

Layout: <dir>/step_<n>/state.npz + MANIFEST.json, written to a tmp dir and
os.replace'd into place (a partially-written checkpoint is never visible).
Async saves run on a daemon thread; `wait()` joins before the next save or
exit. Restore takes optional shardings so a checkpoint saved on one mesh
restores onto another (elastic shrink/grow) — jax.device_put reshards.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import numpy as np
import jax

from repro.resilience.integrity import CheckpointCorruptError, file_crc
from repro.utils import tree_paths


def _jsonify(obj):
    """Manifest extras must survive a JSON round-trip: lifecycle state
    (pending-queue positions, slot→profile assignments, per-slot step
    counts) arrives as numpy scalars/arrays from device fetches, which
    ``json.dump`` rejects — convert recursively to native Python types."""
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 fault_plan=None):
        self.dir = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        # Chaos seam: a FaultPlan may truncate a payload AFTER its manifest
        # checksum was computed — the torn-write case verify_step catches.
        self.fault_plan = fault_plan
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, *, blocking: bool = True,
             extra: Optional[dict] = None):
        """Snapshot to host memory synchronously, write to disk (optionally
        async). The device->host copy is the only blocking part."""
        host_flat = {k: np.asarray(v) for k, v in tree_paths(state).items()}
        meta = {"step": int(step), "time": time.time(),
                "extra": _jsonify(extra or {})}
        if blocking:
            self._write(step, host_flat, meta)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_flat, meta), daemon=True)
            self._thread.start()

    def _write(self, step: int, host_flat: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        state_path = os.path.join(tmp, "state.npz")
        np.savez(state_path,
                 **{k.replace("/", "__"): v for k, v in host_flat.items()})
        crc, nbytes = file_crc(state_path)
        meta = dict(meta, state_crc32=crc, state_nbytes=nbytes)
        if self.fault_plan is not None and \
                self.fault_plan.truncate_checkpoint(step):
            with open(state_path, "r+b") as f:  # torn write: drop the tail
                f.truncate(max(nbytes // 2, 1))
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "MANIFEST.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def verify_step(self, step: int) -> None:
        """Check a checkpoint's payload against its manifest checksum.

        Raises `CheckpointCorruptError` on a size or crc32 mismatch (torn
        write, disk corruption). Manifests predating the checksum field
        pass — there is nothing to verify them against."""
        path = os.path.join(self.dir, f"step_{step:010d}", "state.npz")
        if not os.path.exists(path):
            raise CheckpointCorruptError(
                f"step {step}: state.npz missing")
        meta = self.manifest(step)
        if "state_crc32" not in meta:
            return
        crc, nbytes = file_crc(path)
        if nbytes != meta["state_nbytes"]:
            raise CheckpointCorruptError(
                f"step {step}: payload {nbytes}B != "
                f"manifest {meta['state_nbytes']}B (truncated write)")
        if crc != meta["state_crc32"]:
            raise CheckpointCorruptError(
                f"step {step}: payload crc32 {crc:#010x} != "
                f"manifest {meta['state_crc32']:#010x}")

    def latest_good_step(self) -> Optional[int]:
        """Newest step whose payload verifies — the resume fallback walks
        backward past torn/corrupt checkpoints to the last good one."""
        for step in reversed(self.all_steps()):
            try:
                self.verify_step(step)
                return step
            except CheckpointCorruptError:
                continue
        return None

    def restore(self, step: int, abstract_state, shardings=None):
        """Rebuild the state pytree (shaped like abstract_state) from disk.
        shardings: optional matching pytree of NamedSharding for placement
        on a (possibly different) mesh. Verifies the payload checksum
        before deserializing."""
        self.verify_step(step)
        z = np.load(os.path.join(self.dir, f"step_{step:010d}", "state.npz"))
        flat = {k.replace("__", "/"): z[k] for k in z.files}
        paths = tree_paths(abstract_state)
        assert set(paths) == set(flat), (
            f"checkpoint/state mismatch: {set(paths) ^ set(flat)}")

        leaves_by_path = {}
        shard_paths = tree_paths(shardings) if shardings is not None else {}
        for p, ref in paths.items():
            arr = flat[p].astype(ref.dtype) if hasattr(ref, "dtype") else flat[p]
            if p in shard_paths:
                leaves_by_path[p] = jax.device_put(arr, shard_paths[p])
            else:
                leaves_by_path[p] = jax.numpy.asarray(arr)
        # rebuild tree in abstract_state's structure
        from repro.utils.tree import _key_str
        flat_ref, tdef = jax.tree_util.tree_flatten_with_path(abstract_state)
        ordered = [leaves_by_path["/".join(_key_str(k) for k in path)]
                   for path, _ in flat_ref]
        return jax.tree_util.tree_unflatten(tdef, ordered)

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:010d}",
                               "MANIFEST.json")) as f:
            return json.load(f)
