"""Distribution layer: sharding rules, compressed collectives, pipeline,
small-mesh pjit — multi-device pieces run in a subprocess with 8 host
devices (never set device-count flags in this process)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (leading_axis_specs,
                                        sharded_bytes_per_device, spec_for)

MESH_AXES = {"data": 4, "model": 2}


def test_rules_attention_heads_tp():
    s = spec_for("blocks/attn/wq", (12, 64, 8, 16), MESH_AXES, fsdp=True)
    assert s == P(None, "data", "model", None)


def test_rules_mqa_kv_replicated():
    # kv_heads=1 not divisible by model=2 -> replicated, FSDP elsewhere
    s = spec_for("blocks/attn/wk", (12, 512, 1, 64), MESH_AXES, fsdp=True)
    assert s == P(None, "data", None, None)


def test_rules_small_tensors_skip_fsdp():
    s = spec_for("blocks/attn/wk", (12, 64, 1, 16), MESH_AXES, fsdp=True)
    assert s == P(None, None, None, None)  # < FSDP_MIN_SIZE


def test_rules_divisibility_guard():
    s = spec_for("blocks/mlp/wg", (10, 64, 31), MESH_AXES, fsdp=True)
    assert s[2] is None  # 31 % 2 != 0


def test_rules_bank_tp_and_fsdp():
    s = spec_for("xpeft_bank/bank_a", (12, 256, 64, 8), MESH_AXES, fsdp=True)
    assert s == P(None, "data", "model", None)


def test_rules_small_params_not_fsdp():
    s = spec_for("final_norm/scale", (64,), MESH_AXES, fsdp=True)
    assert s == P(None)


def test_rules_expert_pinned_fsdp():
    s = spec_for("blocks/moe/ew_g", (4, 8, 64, 32), MESH_AXES, fsdp=True)
    assert s == P(None, "model", None, "data")


def test_rules_mqa_kv_replicated_wide_model_axis():
    """kv_heads=1 stays replicated however wide the model axis gets (no
    FSDP axis in a TP-only mesh -> fully replicated)."""
    s = spec_for("blocks/attn/wk", (12, 512, 1, 64), {"model": 16},
                 fsdp=True)
    assert s == P(None, None, None, None)


def test_rules_bank_a_and_bank_b_tp_assignment():
    """Both banks TP-shard their d_model dim over "model": bank_a [L,N,d,b]
    on dim 2, bank_b [L,N,b,d] on dim 3; the N dim is never sharded (the
    k-sparse gather indexes it) and FSDP claims the largest leftover."""
    sa = spec_for("xpeft_bank/bank_a", (12, 256, 64, 8), MESH_AXES,
                  fsdp=False)
    assert sa == P(None, None, "model", None)
    sb = spec_for("xpeft_bank/bank_b", (12, 256, 8, 64), MESH_AXES,
                  fsdp=True)
    assert sb == P(None, "data", None, "model")


def test_rules_quantized_bank_leaves_follow_tp():
    """Quantized bank payloads keep the bf16 bank's d_model TP layout and
    the fp16 scale arrays ride along: int8 scales (ndim 3) drop the
    quantized axis, int4 group scales (ndim 4) keep a trailing group
    axis. Dims here are the full config's (L=24, N=256, d=1024, b=64)."""
    sa = spec_for("qbank/bank_a_q", (24, 256, 1024, 64), MESH_AXES,
                  fsdp=False)
    assert sa == P(None, None, "model", None)
    # int4 packs bank_b's LAST axis (d/2) — still TP-divisible
    sb = spec_for("qbank/bank_b_q", (24, 256, 64, 512), MESH_AXES,
                  fsdp=False)
    assert sb == P(None, None, None, "model")
    s8 = spec_for("qbank/bank_a_scale", (24, 256, 1024), MESH_AXES,
                  fsdp=False)
    assert s8 == P(None, None, "model")
    s4 = spec_for("qbank/bank_a_scale", (24, 256, 1024, 2), MESH_AXES,
                  fsdp=False)
    assert s4 == P(None, None, "model", None)
    sb8 = spec_for("qbank/bank_b_scale", (24, 256, 64), MESH_AXES,
                   fsdp=False)
    assert sb8 == P(None, None, None)
    sb4 = spec_for("qbank/bank_b_scale", (24, 256, 64, 32), MESH_AXES,
                   fsdp=False)
    assert sb4 == P(None, None, None, "model")


def test_quant_engine_qbank_and_buffers_shard():
    """A quantized ServeEngine under an 8-device mesh: qbank leaves and
    quantized slot buffers get valid specs, decode runs, and per-device
    bytes land strictly below the single-device footprint (subprocess:
    forces host devices)."""
    _run_sub("""
    from repro.configs import get_config, reduce_for_smoke
    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.models import init_lm
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import Request
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b")).with_xpeft(
        bank_quant="int8")
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    xp = cfg.xpeft
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k, quant="int8")
    table = XP.init_profile_table(key, cfg)
    for pid in range(4):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    eng = ServeEngine(cfg, params, store, max_slots=8, max_seq=32,
                      mesh=mesh)
    reqs = [Request(uid=i, prompt=np.arange(5) % cfg.vocab_size,
                    profile_id=i % 4, max_new_tokens=6) for i in range(8)]
    eng.run_until_drained(reqs)
    assert all(len(r.generated) for r in reqs)
    per_dev = eng.resident_bytes_per_device()
    assert "qbank" in per_dev and per_dev["qbank"] > 0
    single = ServeEngine(cfg, init_lm(key, cfg), store, max_slots=8,
                         max_seq=32).resident_bytes_per_device()
    assert per_dev["total"] < single["total"], (per_dev, single)
    print("quant shard ok")
    """)


def test_rules_fsdp_largest_dim_tie_break():
    """Equal largest candidate dims: FSDP takes the LATER one (max over
    (dim, index) tuples) — pinned so resharding stays deterministic
    across processes."""
    s = spec_for("frozen/unmatched_w", (256, 256), {"data": 4}, fsdp=True)
    assert s == P(None, "data")


def test_overrides_pattern_matching():
    """A substring-matched override replaces the name rule (first match
    wins); non-matching patterns fall through to the built-in rule."""
    s = spec_for("blocks/attn/wq", (12, 64, 8, 16), MESH_AXES, fsdp=False,
                 overrides={"attn/wq": ("tp_d", None, None)})
    assert s == P(None, "model", None, None)
    s2 = spec_for("blocks/attn/wq", (12, 64, 8, 16), MESH_AXES, fsdp=False,
                  overrides={"mlp/wg": ("tp_d", None, None)})
    assert s2 == P(None, None, "model", None)  # built-in heads rule


# ------------------------------------------- per-device memory accounting

def _abs(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_sharded_bytes_per_device_counts_axes():
    tree = {"a": _abs((8, 64)), "b": _abs((3,))}
    specs = {"a": P("data", "model"), "b": P(None)}
    got = sharded_bytes_per_device(tree, specs, MESH_AXES)
    assert got == (8 * 64 * 4) // 8 + 3 * 4


def test_sharded_bytes_per_device_rejects_missing_spec():
    tree = {"a": _abs((8, 64)), "b": _abs((3,))}
    with pytest.raises(ValueError, match="exactly one spec"):
        sharded_bytes_per_device(tree, {"a": P("data", None)}, MESH_AXES)


def test_sharded_bytes_per_device_rejects_short_spec():
    with pytest.raises(ValueError, match="full rank"):
        sharded_bytes_per_device({"a": _abs((8, 64))}, {"a": P("data")},
                                 MESH_AXES)


def test_sharded_bytes_per_device_rejects_unknown_axis():
    with pytest.raises(ValueError, match="mesh axis"):
        sharded_bytes_per_device({"a": _abs((8, 64))},
                                 {"a": P("pod", None)}, MESH_AXES)


def test_leading_axis_specs_divisibility():
    class _Mesh:  # only .shape is consulted
        shape = MESH_AXES
    specs = leading_axis_specs(
        {"x": _abs((8, 3)), "odd": _abs((5,)), "s": _abs(())}, _Mesh())
    assert specs["x"] == P("data", None)
    assert specs["odd"] == P(None)      # 5 % 4 != 0 -> replicated
    assert specs["s"] == P()


_SUB_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh_compat
"""


def _run_sub(body: str):
    code = _SUB_PRELUDE + textwrap.dedent(body)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))),
                       timeout=600)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    return r.stdout


def test_compressed_psum_numerics():
    _run_sub("""
    from functools import partial
    from jax.experimental.shard_map import shard_map
    from repro.distributed.collectives import compressed_psum, compressed_psum_ef
    mesh = make_mesh_compat((8,), ("d",))
    x = jax.random.normal(jax.random.key(0), (8, 64))

    @partial(shard_map, mesh=mesh, in_specs=P("d", None), out_specs=P("d", None))
    def f(xl):
        return compressed_psum(xl, "d")
    got = f(x)[0]
    want = x.sum(0)
    err = float(jnp.abs(got - want).max()) / float(jnp.abs(want).max())
    assert err < 0.05, err

    # error feedback: mean of quantized psums over repeated steps converges
    @partial(shard_map, mesh=mesh, in_specs=(P("d", None), P("d", None)),
             out_specs=(P("d", None), P("d", None)))
    def g(xl, el):
        y, e = compressed_psum_ef(xl, el, "d")
        return y, e
    err_buf = jnp.zeros_like(x)
    acc = 0.0
    for i in range(20):
        y, err_buf = g(x, err_buf)
        acc = acc + y[0]
    rel = float(jnp.abs(acc / 20 - want).max()) / float(jnp.abs(want).max())
    assert rel < 0.01, rel
    print("compressed psum ok")
    """)


def test_pipeline_matches_single_device():
    _run_sub("""
    from repro.distributed.pipeline import pipeline_apply, stack_stages
    mesh = make_mesh_compat((4, 2), ("pod", "model"))
    L, d = 8, 16
    ks = jax.random.split(jax.random.key(0), L)
    layers = jax.vmap(lambda k: {"w": jax.random.normal(k, (d, d)) / np.sqrt(d)})(ks)

    def stage_fn(stage_params, x):
        def body(c, lp):
            return jnp.tanh(c @ lp["w"]), None
        y, _ = jax.lax.scan(body, x, stage_params)
        return y

    x_micro = jax.random.normal(jax.random.key(1), (6, 4, d))  # M=6 microbatches
    stacked = stack_stages(layers, 4)
    y_pipe = pipeline_apply(stage_fn, stacked, x_micro, mesh, axis="pod")

    # reference: run all layers sequentially
    def ref_one(x):
        def body(c, lp):
            return jnp.tanh(c @ lp["w"]), None
        y, _ = jax.lax.scan(body, x, layers)
        return y
    y_ref = jax.vmap(ref_one)(x_micro)
    err = float(jnp.abs(y_pipe - y_ref).max())
    assert err < 1e-4, err
    print("pipeline ok", err)
    """)


def test_small_mesh_train_step_and_moe_parity():
    """pjit xpeft train step on a 4x2 mesh == single-device result; also
    checks the shard_map MoE path against the local path."""
    _run_sub("""
    from repro.configs import get_config, reduce_for_smoke
    from repro.train.steps import init_train_state, make_train_step
    from repro.distributed import ctx
    from repro.distributed.sharding import param_specs, batch_specs, to_shardings
    from repro.models.moe import init_moe, moe_apply

    cfg = reduce_for_smoke(get_config("qwen3-moe-30b-a3b")).with_(
        num_experts=8, top_k=2, capacity_factor=8.0)
    key = jax.random.key(0)
    state = init_train_state(key, cfg, "xpeft")
    B, T = 8, 16
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
             "profile_ids": jnp.arange(B) % 4}
    step = make_train_step(cfg, "xpeft", lr=1e-3)
    s1, m1 = jax.jit(step)(state, batch, jax.random.key(7))

    mesh = make_mesh_compat((4, 2), ("data", "model"))
    with ctx.mesh_context(mesh):
        st_sh = to_shardings(param_specs(state, mesh, fsdp=True), mesh)
        b_sh = to_shardings(batch_specs(batch, mesh, B), mesh)
        stepd = jax.jit(step, in_shardings=(st_sh, b_sh, None),
                        out_shardings=(st_sh, None))
        s2, m2 = stepd(state, batch, jax.random.key(7))
    l1, l2 = float(m1["loss"]), float(m2["loss"])
    assert abs(l1 - l2) / max(abs(l1), 1e-6) < 2e-2, (l1, l2)

    # MoE parity: shard_map path vs local path on identical inputs
    p = init_moe(jax.random.key(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(4), (B, T, cfg.d_model))
    y_local, _ = moe_apply(p, x, cfg)          # no mesh ctx -> local
    with ctx.mesh_context(mesh):
        xs = jax.device_put(x, jax.sharding.NamedSharding(mesh, P(("data",), None, None)))
        y_dist, _ = jax.jit(lambda pp, xx: moe_apply(pp, xx, cfg))(p, xs)
    err = float(jnp.abs(y_local - y_dist).max())
    assert err < 1e-3, err
    print("mesh train + moe parity ok", l1, l2, err)
    """)


def test_eight_device_serve_onboard_bitwise_parity():
    """End-to-end tentpole gate: the 8-fake-device mesh onboards and serves
    BIT-identically to the 1-device path — graduated store bytes, admission
    Â/B̂ cache entries, and decoded token ids all equal, with the gang step
    tracing exactly once on both paths. Runs benchmarks/sharded_smoke.py
    (the same vehicle serve_bench embeds into BENCH_serve.json) through its
    shared subprocess entry point."""
    from benchmarks.sharded_smoke import run_subprocess

    rec = run_subprocess(check=True)
    assert rec["onboard_store_bitwise_equal"]
    assert rec["serve_entries_bitwise_equal"]
    assert rec["decode_tokens_equal"]
    assert rec["gang_traces"] == {"single": 1, "sharded": 1}
    single = rec["single"]["resident_bytes_per_device"]["total"]
    sharded = rec["sharded"]["resident_bytes_per_device"]["total"]
    assert 0 < sharded < single  # the mesh actually shards device state


def test_elastic_reshard_smaller_mesh():
    _run_sub("""
    from repro.distributed.fault import reshard_state, surviving_mesh
    from jax.sharding import NamedSharding
    mesh8 = make_mesh_compat((8,), ("data",))
    x = jax.device_put(jnp.arange(64.0).reshape(8, 8),
                       NamedSharding(mesh8, P("data", None)))
    mesh4 = surviving_mesh(("data",), (8,), "data", 4)
    y = reshard_state({"x": x}, {"x": NamedSharding(mesh4, P("data", None))})
    np.testing.assert_array_equal(np.asarray(y["x"]), np.asarray(x))
    print("elastic ok")
    """)
