import os
import sys

# Tests run on the single real CPU device (the dry-run subprocesses set their
# own XLA_FLAGS; never set device-count flags globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# repo root too: tests import the benchmarks package (e.g. the shared
# sharded_smoke subprocess runner) without requiring `python -m pytest`
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))
