"""Fault tolerance: watchdog, preemption, trainer integration."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed.fault import (PreemptionHandler, StepWatchdog,
                                     rebalance_assignment)


def test_watchdog_flags_slow_steps():
    t = [0.0]

    def clock():
        return t[0]

    wd = StepWatchdog(deadline_factor=2.0, clock=clock)
    for dur in [1.0] * 8:
        wd.step_start()
        t[0] += dur
        assert not wd.step_end()
    wd.step_start()
    t[0] += 5.0  # straggler
    assert wd.step_end()
    assert wd.slow_steps == 1
    assert abs(wd.median - 1.0) < 1e-6


def test_watchdog_window_attribution():
    """Buffered-metrics trainers observe device time only at sync
    boundaries: window_end spreads a window's wall time over its steps and
    flags the whole window against the trailing median."""
    wd = StepWatchdog(deadline_factor=2.0)
    assert not wd.window_end(4, 4.0)   # no history yet -> baseline 1.0/step
    assert wd.slow_steps == 0 and abs(wd.median - 1.0) < 1e-9
    assert wd.window_end(2, 10.0)      # 5.0/step > 2x median 1.0
    assert wd.slow_steps == 2
    assert not wd.window_end(0, 1.0)   # empty window is a no-op
    assert wd.slow_steps == 2


def test_preemption_checkpoints_and_stops(tmp_path):
    from repro.configs import get_config, reduce_for_smoke
    from repro.data import MarkovLM
    from repro.data.loader import ShardedLoader
    from repro.train.steps import init_train_state, make_train_step
    from repro.train.trainer import Trainer

    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    step = jax.jit(make_train_step(cfg, "xpeft", lr=1e-3))
    loader = ShardedLoader(MarkovLM(cfg.vocab_size, 4, seed=0), 2, 8)
    pre = PreemptionHandler.__new__(PreemptionHandler)  # no signal handler
    import threading
    pre._flag = threading.Event()
    tr = Trainer(step, init_train_state(jax.random.key(0), cfg, "xpeft"),
                 loader, ckpt_dir=str(tmp_path), preemption=pre,
                 log_every=1000)
    tr.run(2)
    pre.trigger()
    tr.run(10)  # should stop immediately and checkpoint
    assert tr.step == 2
    assert tr.mgr.latest_step() == 2


def test_rebalance_total_preserved_and_monotone():
    for n in (7, 64, 100):
        asg = rebalance_assignment(n, [0, 1, 2], {1: 0.25})
        assert sum(len(r) for r in asg.values()) == n
        ranges = [asg[h] for h in (0, 1, 2)]
        # contiguous, ordered partition
        assert ranges[0].start == 0
        assert ranges[0].stop == ranges[1].start
        assert ranges[1].stop == ranges[2].start
        assert ranges[2].stop == n
