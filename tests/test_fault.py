"""Fault tolerance: watchdog, preemption, trainer integration, elastic
resize (8 -> 4 devices mid-onboarding, in a subprocess)."""
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.distributed.fault import (PreemptionHandler, StepWatchdog,
                                     rebalance_assignment)


def test_watchdog_flags_slow_steps():
    t = [0.0]

    def clock():
        return t[0]

    wd = StepWatchdog(deadline_factor=2.0, clock=clock)
    for dur in [1.0] * 8:
        wd.step_start()
        t[0] += dur
        assert not wd.step_end()
    wd.step_start()
    t[0] += 5.0  # straggler
    assert wd.step_end()
    assert wd.slow_steps == 1
    assert abs(wd.median - 1.0) < 1e-6


def test_watchdog_window_attribution():
    """Buffered-metrics trainers observe device time only at sync
    boundaries: window_end spreads a window's wall time over its steps and
    flags the whole window against the trailing median."""
    wd = StepWatchdog(deadline_factor=2.0)
    assert not wd.window_end(4, 4.0)   # no history yet -> baseline 1.0/step
    assert wd.slow_steps == 0 and abs(wd.median - 1.0) < 1e-9
    assert wd.window_end(2, 10.0)      # 5.0/step > 2x median 1.0
    assert wd.slow_steps == 2
    assert not wd.window_end(0, 1.0)   # empty window is a no-op
    assert wd.slow_steps == 2


def test_preemption_checkpoints_and_stops(tmp_path):
    from repro.configs import get_config, reduce_for_smoke
    from repro.data import MarkovLM
    from repro.data.loader import ShardedLoader
    from repro.train.steps import init_train_state, make_train_step
    from repro.train.trainer import Trainer

    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    step = jax.jit(make_train_step(cfg, "xpeft", lr=1e-3))
    loader = ShardedLoader(MarkovLM(cfg.vocab_size, 4, seed=0), 2, 8)
    pre = PreemptionHandler.__new__(PreemptionHandler)  # no signal handler
    import threading
    pre._flag = threading.Event()
    tr = Trainer(step, init_train_state(jax.random.key(0), cfg, "xpeft"),
                 loader, ckpt_dir=str(tmp_path), preemption=pre,
                 log_every=1000)
    tr.run(2)
    pre.trigger()
    tr.run(10)  # should stop immediately and checkpoint
    assert tr.step == 2
    assert tr.mgr.latest_step() == 2


def test_watchdog_step_end_without_start_is_noop():
    """step_end before any step_start must not crash (the trainer can hit
    this on a resume path) — it returns False and records nothing."""
    wd = StepWatchdog()
    assert wd.step_end() is False
    assert wd.slow_steps == 0 and wd.median == 0.0
    # a consumed step_start does not leak into a second step_end
    t = [0.0]
    wd = StepWatchdog(clock=lambda: t[0])
    wd.step_start()
    t[0] += 1.0
    assert wd.step_end() is False
    assert wd.step_end() is False      # no start since -> no-op
    assert len(wd._durations) == 1


def test_rebalance_zero_speeds_and_empty_hosts():
    # every host at speed 0: clamped to a positive floor -> even split,
    # full coverage, no NaN ranges
    asg = rebalance_assignment(90, [0, 1, 2], {0: 0.0, 1: 0.0, 2: 0.0})
    assert sum(len(r) for r in asg.values()) == 90
    assert all(len(r) == 30 for r in asg.values())
    # one dead host among live ones: gets (almost) nothing, total preserved
    asg = rebalance_assignment(100, [0, 1], {0: 0.0})
    assert sum(len(r) for r in asg.values()) == 100
    assert len(asg[0]) < len(asg[1])
    with pytest.raises(ValueError):
        rebalance_assignment(10, [], {})


def test_preemption_chains_previous_handler():
    """Installing a PreemptionHandler must not silently replace a
    previously-installed handler — both fire on the signal."""
    sig = signal.SIGUSR1
    calls = []
    original = signal.getsignal(sig)
    try:
        signal.signal(sig, lambda s, f: calls.append(s))
        pre = PreemptionHandler(sigs=(sig,))
        os.kill(os.getpid(), sig)
        assert pre.preempted()
        assert calls == [sig]
    finally:
        signal.signal(sig, original)


def test_preemption_does_not_chain_default_sigint():
    """SIGINT's default KeyboardInterrupt handler is NOT chained: raising
    it would defeat the graceful checkpoint the handler exists for."""
    sig = signal.SIGINT
    original = signal.getsignal(sig)
    try:
        signal.signal(sig, signal.default_int_handler)
        pre = PreemptionHandler(sigs=(sig,))
        os.kill(os.getpid(), sig)   # must NOT raise KeyboardInterrupt
        assert pre.preempted()
    finally:
        signal.signal(sig, original)


def test_preemption_accepts_multiple_signals():
    sig1, sig2 = signal.SIGUSR1, signal.SIGUSR2
    orig = {s: signal.getsignal(s) for s in (sig1, sig2)}
    try:
        pre = PreemptionHandler(sigs=(sig1, sig2))
        assert not pre.preempted()
        os.kill(os.getpid(), sig2)
        assert pre.preempted()
    finally:
        for s, h in orig.items():
            signal.signal(s, h)


def test_rebalance_total_preserved_and_monotone():
    for n in (7, 64, 100):
        asg = rebalance_assignment(n, [0, 1, 2], {1: 0.25})
        assert sum(len(r) for r in asg.values()) == n
        ranges = [asg[h] for h in (0, 1, 2)]
        # contiguous, ordered partition
        assert ranges[0].start == 0
        assert ranges[0].stop == ranges[1].start
        assert ranges[1].stop == ranges[2].start
        assert ranges[2].stop == n


# --------------------------------------------------------------- elastic

def test_elastic_shrink_resumes_onboarding(tmp_path):
    """Node-failure drill on 8 fake devices: onboard on a (4,2) mesh,
    checkpoint mid-run, 'lose' half the data axis, resume on the surviving
    (2,2) mesh with an explicit reshard — the final graduated store must be
    byte-identical to an unfailed straight-through run."""
    body = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from jax.sharding import NamedSharding, PartitionSpec

    from repro.configs import get_config, reduce_for_smoke
    from repro.data import MarkovLM
    from repro.distributed import sharding as SH
    from repro.distributed.fault import reshard_state, surviving_mesh
    from repro.launch.mesh import make_mesh_compat
    from repro.train import GraduationPolicy
    from repro.train.onboarding import build_onboarding_run

    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    n_prof, slots = 4, 4
    ckpt = {str(tmp_path)!r}

    def build(mesh, ckpt_dir=None):
        data = MarkovLM(cfg.vocab_size, n_prof, seed=1)
        policy = GraduationPolicy(min_steps=3, max_steps=5, target_acc=2.0)
        trainer, _ = build_onboarding_run(
            cfg, data, range(n_prof), slots=slots, per_slot=2, seq_len=8,
            policy=policy, lr=5e-2, seed=0, rng=jax.random.key(1),
            log_every=2, mesh=mesh, ckpt_dir=ckpt_dir, ckpt_every=4,
            store_path=(os.path.join(ckpt_dir, "store.npz")
                        if ckpt_dir else None))
        return trainer

    # reference: unfailed straight-through run on the full mesh
    mesh8 = make_mesh_compat((4, 2), ("data", "model"))
    ref = build(mesh8)
    ref.run_until_drained(max_steps=200)
    assert len(ref.scheduler.graduated) == n_prof

    # failed run: same mesh, checkpoint at step 4, die at step 6
    t1 = build(mesh8, ckpt_dir=os.path.join(ckpt, "ckpt"))
    t1.run(6)
    assert t1.mgr.latest_step() is not None

    # half the data axis is gone: resume on the surviving (2,2) mesh
    mesh4 = surviving_mesh(("data", "model"), (4, 2), "data", 2)
    t2 = build(mesh4, ckpt_dir=os.path.join(ckpt, "ckpt"))
    assert t2.try_resume()
    rsh = SH.to_shardings(
        SH.leading_axis_specs(t2.state["roster"], mesh4), mesh4)
    fsh = jax.tree.map(
        lambda _: NamedSharding(mesh4, PartitionSpec()),
        t2.state["frozen"])
    t2.state = {{
        "frozen": reshard_state(t2.state["frozen"], fsh),
        "roster": reshard_state(t2.state["roster"], rsh),
    }}
    t2.run_until_drained(max_steps=200)

    ref_store, new_store = ref.scheduler.store, t2.scheduler.store
    assert ref_store.profile_ids() == new_store.profile_ids() == \\
        list(range(n_prof))
    for pid in ref_store.profile_ids():
        ra, rb = ref_store._rec[pid], new_store._rec[pid]
        assert sorted(ra) == sorted(rb), pid
        for key in ra:
            assert ra[key].dtype == rb[key].dtype
            assert np.array_equal(ra[key], rb[key]), (pid, key)
    print("elastic resume ok")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, env=env, cwd=root, timeout=900)
    assert r.returncode == 0, \
        f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    assert "elastic resume ok" in r.stdout
