"""HLO cost analyzer: loop trip-count multiplication correctness."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo_cost import analyze


def _hlo(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_plain_matmul():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = analyze(_hlo(lambda a, b: a @ b, x, x))
    expect = 2 * 128 ** 3
    assert abs(r["flops"] - expect) / expect < 0.05


def test_scan_multiplies_body():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(a, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, a, None, length=9)
        return y

    r = analyze(_hlo(f, x, x))
    expect = 9 * 2 * 128 ** 3
    assert abs(r["flops"] - expect) / expect < 0.05


def test_nested_scans():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, a, None, length=5)
        return y

    r = analyze(_hlo(f, x, x))
    expect = 15 * 2 * 64 ** 3
    assert abs(r["flops"] - expect) / expect < 0.06


def test_xla_cost_analysis_undercounts_scans():
    """The reason this module exists: XLA visits while bodies once."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(a, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, a, None, length=9)
        return y

    c = jax.jit(f).lower(x, x).compile()
    ca = c.cost_analysis()
    if isinstance(ca, list):  # older jax returns [per-device dict]
        ca = ca[0]
    xla_flops = ca["flops"]
    ours = analyze(c.as_text())["flops"]
    assert ours > 5 * xla_flops  # XLA reports ~1 body; we report 9


def test_collectives_counted(tmp_path):
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.analysis.hlo_cost import analyze
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((8,), ("d",))
        w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        sh_w = NamedSharding(mesh, P("d", None))
        sh_x = NamedSharding(mesh, P(None, None))
        def f(a, b):
            return jnp.sum(a @ b)  # contract sharded dim -> all-reduce
        c = jax.jit(f, in_shardings=(sh_x, sh_w)).lower(x, w).compile()
        r = analyze(c.as_text())
        assert r["collectives"]["total"] > 0, r["collectives"]
        print("colls ok", r["collectives"]["total"])
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, r.stderr[-2000:]
