"""X-PEFT mask invariants (property-based where it matters)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import masks as M


@given(st.integers(2, 12), st.integers(8, 64), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_soft_rows_sum_to_one(L, N, seed):
    logits = jax.random.normal(jax.random.key(seed), (L, N)) * 3
    w = M.soft_mask_weights(logits)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert (np.asarray(w) >= 0).all()


@given(st.integers(2, 8), st.integers(8, 64), st.data())
@settings(max_examples=20, deadline=None)
def test_khot_exactly_k(L, N, data):
    k = data.draw(st.integers(1, N))
    logits = jax.random.normal(jax.random.key(0), (L, N))
    w = M.khot_from_topk(logits, k)
    nz = np.count_nonzero(np.asarray(w), axis=-1)
    assert (nz == k).all()
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)


def test_hard_mask_straight_through_forward_is_khot():
    logits = jax.random.normal(jax.random.key(1), (4, 32))
    w = M.hard_mask_weights(logits, k=5, key=jax.random.key(2), training=True)
    nz = np.count_nonzero(np.asarray(w) > 1e-9, axis=-1)
    # forward value: exactly k entries at 1/k (+ small soft cancellation)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert (nz >= 5).all()  # ST adds (y_soft - sg(y_soft)) = 0 numerically


def test_hard_mask_gradients_flow():
    logits = jax.random.normal(jax.random.key(1), (4, 32))

    def f(lg):
        w = M.hard_mask_weights(lg, k=5, key=jax.random.key(2))
        return jnp.sum(w * jnp.arange(32.0))

    g = jax.grad(f)(logits)
    assert float(jnp.abs(g).sum()) > 0  # softmax gradient passes through


@given(st.integers(1, 8), st.integers(1, 200), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_pack_unpack_roundtrip(L, N, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random((L, N)) > 0.5
    packed = M.pack_mask(bits)
    assert packed.dtype == np.uint8
    assert packed.shape == (L, (N + 7) // 8)
    out = M.unpack_mask(packed, N)
    np.testing.assert_array_equal(out, bits)


def test_binarize_matches_topk_weights():
    logits = jax.random.normal(jax.random.key(3), (6, 64))
    k = 10
    bits = np.asarray(M.binarize(logits, k))
    w = np.asarray(M.khot_from_topk(logits, k))
    np.testing.assert_array_equal(bits, w > 0)


def test_paper_table1_memory_numbers():
    # Paper Table 1 (L=12, b=64, d=768): hard N=100 -> 0.3KB, N=200 -> 0.6KB,
    # N=400 -> 1.2KB; soft N=100 -> 10K(ish, 2*100*12*4=9.6KB); sa -> 3.5MB
    assert M.bytes_per_profile(100, 12, "hard") == 2 * 13 * 12  # 312 B
    assert M.bytes_per_profile(200, 12, "hard") == 2 * 25 * 12  # 600 B
    assert M.bytes_per_profile(400, 12, "hard") == 2 * 50 * 12  # 1200 B
    assert M.bytes_per_profile(100, 12, "soft") == 2 * 100 * 12 * 4  # 9.6 KB
    assert M.adapter_bytes(768, 64, 12) == 2 * 768 * 64 * 12 * 4  # ~4.7MB(b=64)
    # trainable params 2(N+b)L — paper: N=100,b=64,L=12 -> 3.9K ("3.5K" row)
    assert M.trainable_params_per_profile(100, 64, 12) == 2 * 164 * 12


def test_mask_indices_sparse_equiv():
    logits = jax.random.normal(jax.random.key(4), (5, 40))
    k = 7
    bits = M.binarize(logits, k)
    idx = np.asarray(M.mask_indices(bits, k))
    for row_bits, row_idx in zip(np.asarray(bits), idx):
        np.testing.assert_array_equal(np.sort(np.where(row_bits)[0]),
                                      np.sort(row_idx))
