"""Adapter bank aggregation: dense == sparse for hard masks; apply math."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import adapters as A
from repro.core import masks as M


def _bank(key, N=16, d=32, b=8, L=1):
    bk = A.init_adapter_bank(key, L, N, d, b, jnp.float32)
    return {"bank_a": bk["bank_a"][0], "bank_b": bk["bank_b"][0]}


def test_dense_vs_sparse_aggregation():
    key = jax.random.key(0)
    bank = _bank(key)
    logits = jax.random.normal(jax.random.key(1), (16,))
    k = 5
    bits = M.binarize(logits, k)
    w_dense = M.khot_weights_from_bits(bits, k)
    a1, b1 = A.aggregate_dense(bank, w_dense, w_dense)
    idx = M.mask_indices(bits, k)
    w_sp = jnp.full((k,), 1.0 / k)
    a2, b2 = A.aggregate_sparse(bank, idx, w_sp, idx, w_sp)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-5)


def test_apply_adapter_residual_identity_when_b_zero():
    key = jax.random.key(0)
    x = jax.random.normal(key, (4, 10, 32))
    a_hat = jax.random.normal(jax.random.key(1), (32, 8)) * 0.1
    b_hat = jnp.zeros((8, 32))
    y = A.apply_adapter(x, a_hat, b_hat, jnp.ones(8), jnp.zeros(8))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_apply_adapter_batched_profiles_differ():
    key = jax.random.key(0)
    x = jnp.ones((2, 6, 16))
    a_hat = jax.random.normal(key, (2, 16, 4)) * 0.5
    b_hat = jax.random.normal(jax.random.key(1), (2, 4, 16)) * 0.5
    y = A.apply_adapter(x, a_hat, b_hat, jnp.ones(4), jnp.zeros(4))
    assert not np.allclose(np.asarray(y[0]), np.asarray(y[1]))
