"""k-sparse admission: parity with the dense precompute path + end-to-end
engine runs under every CPU kernel_impl."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(4):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, params, store


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_sparse_admission_matches_dense_precompute(setup, impl):
    """k-sparse aggregation of a top-k hard mask == the dense full-bank
    einsum in precompute_effective_adapters (it reads N/k more bytes to
    multiply N-k of them by zero)."""
    cfg, params, store = setup
    bank = params["xpeft_bank"]
    xp = cfg.with_xpeft(kernel_impl=impl).xpeft
    for pid in (0, 1):
        wa, wb = store.mask_weights(pid)
        a_dense = jnp.einsum("ln,lndb->ldb", wa,
                             bank["bank_a"].astype(jnp.float32))
        b_dense = jnp.einsum("ln,lnbd->lbd", wb,
                             bank["bank_b"].astype(jnp.float32))
        ia, wia, ib, wib = store.sparse_indices(pid)
        a_sp, b_sp = XP.precompute_effective_adapters_sparse(
            bank, ia, wia, ib, wib, xp)
        dt = bank["bank_a"].dtype
        np.testing.assert_allclose(np.asarray(a_sp, np.float32),
                                   np.asarray(a_dense.astype(dt), np.float32),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(b_sp, np.float32),
                                   np.asarray(b_dense.astype(dt), np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_sparse_admission_batched_requests(setup):
    """Multi-request admission: stacked [R, L, k] indices aggregate to the
    same Â/B̂ as per-request calls."""
    cfg, params, store = setup
    bank = params["xpeft_bank"]
    xp = cfg.with_xpeft(kernel_impl="ref").xpeft
    parts = [store.sparse_indices(pid) for pid in (0, 1, 2)]
    ia = jnp.stack([p[0] for p in parts])
    wa = jnp.stack([p[1] for p in parts])
    ib = jnp.stack([p[2] for p in parts])
    wb = jnp.stack([p[3] for p in parts])
    a_all, b_all = XP.precompute_effective_adapters_sparse(
        bank, ia, wa, ib, wb, xp)
    assert a_all.shape[0] == 3
    for r, (pia, pwa, pib, pwb) in enumerate(parts):
        a_one, b_one = XP.precompute_effective_adapters_sparse(
            bank, pia, pwa, pib, pwb, xp)
        np.testing.assert_allclose(np.asarray(a_all[r], np.float32),
                                   np.asarray(a_one, np.float32),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(b_all[r], np.float32),
                                   np.asarray(b_one, np.float32),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("impl", ["auto", "ref", "interpret"])
def test_engine_end_to_end_kernel_impls(setup, impl):
    """ServeEngine(precompute=True) drains under each CPU-runnable backend
    and greedy tokens agree across backends (same admission math)."""
    cfg, params, store = setup
    cfg = cfg.with_xpeft(kernel_impl=impl)
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                    profile_id=i % 3, max_new_tokens=4) for i in range(3)]
    eng.run_until_drained(list(reqs))
    for r in reqs:
        assert r.done and len(r.generated) >= 4
    # cross-impl token parity vs the ref backend
    ref_cfg = cfg.with_xpeft(kernel_impl="ref")
    eng2 = ServeEngine(ref_cfg, params, store, max_slots=2, max_seq=64)
    reqs2 = [Request(uid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                     profile_id=i % 3, max_new_tokens=4) for i in range(3)]
    eng2.run_until_drained(list(reqs2))
    for a, b in zip(reqs, reqs2):
        assert a.generated == b.generated


def test_admit_many_respects_free_slots(setup):
    cfg, params, store = setup
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(4) % cfg.vocab_size,
                    profile_id=0, max_new_tokens=64) for i in range(4)]
    assert eng.admit_many(reqs) == 2          # only 2 slots
    assert eng.admit_many(reqs[2:]) == 0      # engine full
    assert eng.free_slots() == []


def test_sparse_admission_tokens_match_dense_admission(setup):
    """The k-sparse jitted admission produces the same generation as an
    engine fed the dense per-step mask path (precompute=False)."""
    cfg, params, store = setup
    prompt = np.asarray([3, 1, 4, 1, 5, 9]) % cfg.vocab_size
    gens = []
    for precompute in (True, False):
        eng = ServeEngine(cfg, params, store, max_slots=1, max_seq=64,
                          precompute=precompute)
        req = Request(uid=0, prompt=prompt, profile_id=1, max_new_tokens=5)
        eng.admit(req)
        for _ in range(4):
            eng.step()
        gens.append(req.generated)
    assert gens[0] == gens[1]


def test_cached_admission_reads_zero_bank_bytes(setup):
    """R requests sharing ONE already-cached profile must admit without
    touching the bank: path == "cached", zero bank bytes, zero store
    hydration calls."""
    cfg, params, store = setup
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64)
    # first wave aggregates profile 0 (cold)
    eng.admit_many([Request(uid=0, prompt=np.arange(4) % cfg.vocab_size,
                            profile_id=0, max_new_tokens=2)])
    assert eng.last_admission["path"] == "sparse"
    assert eng.last_admission["bank_bytes_per_request"] > 0
    eng.abort_all()
    # count store hydration calls during the warm wave
    calls = {"n": 0}
    orig = store.batch_sparse_indices
    store.batch_sparse_indices = \
        lambda pids: calls.__setitem__("n", calls["n"] + 1) or orig(pids)
    try:
        n = eng.admit_many(
            [Request(uid=10 + i, prompt=np.arange(4) % cfg.vocab_size,
                     profile_id=0, max_new_tokens=2) for i in range(2)])
    finally:
        store.batch_sparse_indices = orig
    assert n == 2
    adm = eng.last_admission
    assert adm["path"] == "cached"
    assert adm["cache_hits"] == 2 and adm["cache_misses"] == 0
    assert adm["bank_bytes_per_request"] == 0
    assert calls["n"] == 0  # the bank-reading hydration never ran


def test_invalidate_profile_forces_reaggregation(setup):
    """After a profile's masks are updated in the store, invalidate_profile
    must make the next admission re-aggregate (sparse path), not serve the
    stale cached adapters."""
    cfg, params, store = setup
    eng = ServeEngine(cfg, params, store, max_slots=1, max_seq=64)

    def admit_one(uid):
        n = eng.admit_many([Request(uid=uid,
                                    prompt=np.arange(4) % cfg.vocab_size,
                                    profile_id=0, max_new_tokens=2)])
        assert n == 1
        eng.abort_all()
        return eng.last_admission["path"]

    assert admit_one(0) == "sparse"   # cold
    assert admit_one(1) == "cached"   # warm
    assert eng.invalidate_profile(0)
    assert admit_one(2) == "sparse"   # re-aggregated after invalidation


class _PublicOnlyStore:
    """Proxy exposing ONLY ProfileStore's public API — any engine reach
    into ``_rec`` (or other privates) raises AttributeError."""

    _PUBLIC = ("mask_weights", "batch_mask_weights", "sparse_indices",
               "batch_sparse_indices", "ln_affines", "profile_ids",
               "bytes_per_profile", "total_bytes", "mask_type", "k",
               "L", "N", "b", "subscribe", "check_record",
               "quarantined_ids", "integrity_stats")

    def subscribe(self, fn):
        # engines register their invalidation hook at construction; the
        # proxy forwards it so re-graduation notifications still flow
        self._store.subscribe(fn)

    def __init__(self, store):
        object.__setattr__(self, "_store", store)

    def __getattr__(self, name):
        if name not in self._PUBLIC:
            raise AttributeError(
                f"engine accessed non-public ProfileStore attr {name!r}")
        return getattr(self._store, name)


def test_engine_uses_only_public_store_api(setup):
    cfg, params, store = setup
    eng = ServeEngine(cfg, params, _PublicOnlyStore(store), max_slots=2,
                      max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                    profile_id=i % 3, max_new_tokens=3) for i in range(3)]
    eng.run_until_drained(list(reqs))
    assert all(r.done for r in reqs)
    # and the paper-faithful per-step path stays public-API-only too
    eng2 = ServeEngine(cfg, params, _PublicOnlyStore(store), max_slots=2,
                       max_seq=64, precompute=False)
    reqs2 = [Request(uid=9, prompt=np.arange(5) % cfg.vocab_size,
                     profile_id=1, max_new_tokens=3)]
    eng2.run_until_drained(list(reqs2))
    assert reqs2[0].done


def test_apply_precomputed_layer_routes_through_ops(setup):
    """The per-layer public API for precomputed adapters matches the core
    apply_adapter semantics under both CPU backends, for 2-D and batched x."""
    from repro.core.adapters import apply_adapter
    cfg, params, store = setup
    bank = params["xpeft_bank"]
    wa, wb = store.mask_weights(0)
    ln_s, ln_b = store.ln_affines([0])
    prof = {"ln_scale": ln_s[0], "ln_bias": ln_b[0]}
    a_hat = jnp.einsum("ln,lndb->ldb", wa, bank["bank_a"].astype(jnp.float32))
    b_hat = jnp.einsum("ln,lnbd->lbd", wb, bank["bank_b"].astype(jnp.float32))
    eff_l = {"a_hat": a_hat[0].astype(bank["bank_a"].dtype),
             "b_hat": b_hat[0].astype(bank["bank_b"].dtype),
             "ln_scale": prof["ln_scale"][0], "ln_bias": prof["ln_bias"][0]}
    x2 = jax.random.normal(jax.random.key(7), (16, cfg.d_model), jnp.float32)
    x3 = jax.random.normal(jax.random.key(8), (2, 16, cfg.d_model))
    for impl in ("ref", "interpret"):
        xp = cfg.with_xpeft(kernel_impl=impl).xpeft
        want2 = apply_adapter(x2, eff_l["a_hat"], eff_l["b_hat"],
                              eff_l["ln_scale"], eff_l["ln_bias"])
        got2 = XP.apply_precomputed_layer(x2, eff_l, xp)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                                   rtol=1e-4, atol=1e-4)
        got3 = XP.apply_precomputed_layer(x3, eff_l, xp)  # shared broadcast
        want3 = jnp.stack([apply_adapter(x3[i], eff_l["a_hat"],
                                         eff_l["b_hat"], eff_l["ln_scale"],
                                         eff_l["ln_bias"]) for i in range(2)])
        np.testing.assert_allclose(np.asarray(got3), np.asarray(want3),
                                   rtol=1e-4, atol=1e-4)
