"""k-sparse admission: parity with the dense precompute path + end-to-end
engine runs under every CPU kernel_impl."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(4):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, params, store


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_sparse_admission_matches_dense_precompute(setup, impl):
    """k-sparse aggregation of a top-k hard mask == the dense full-bank
    einsum in precompute_effective_adapters (it reads N/k more bytes to
    multiply N-k of them by zero)."""
    cfg, params, store = setup
    bank = params["xpeft_bank"]
    xp = cfg.with_xpeft(kernel_impl=impl).xpeft
    for pid in (0, 1):
        wa, wb = store.mask_weights(pid)
        a_dense = jnp.einsum("ln,lndb->ldb", wa,
                             bank["bank_a"].astype(jnp.float32))
        b_dense = jnp.einsum("ln,lnbd->lbd", wb,
                             bank["bank_b"].astype(jnp.float32))
        ia, wia, ib, wib = store.sparse_indices(pid)
        a_sp, b_sp = XP.precompute_effective_adapters_sparse(
            bank, ia, wia, ib, wib, xp)
        dt = bank["bank_a"].dtype
        np.testing.assert_allclose(np.asarray(a_sp, np.float32),
                                   np.asarray(a_dense.astype(dt), np.float32),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(b_sp, np.float32),
                                   np.asarray(b_dense.astype(dt), np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_sparse_admission_batched_requests(setup):
    """Multi-request admission: stacked [R, L, k] indices aggregate to the
    same Â/B̂ as per-request calls."""
    cfg, params, store = setup
    bank = params["xpeft_bank"]
    xp = cfg.with_xpeft(kernel_impl="ref").xpeft
    parts = [store.sparse_indices(pid) for pid in (0, 1, 2)]
    ia = jnp.stack([p[0] for p in parts])
    wa = jnp.stack([p[1] for p in parts])
    ib = jnp.stack([p[2] for p in parts])
    wb = jnp.stack([p[3] for p in parts])
    a_all, b_all = XP.precompute_effective_adapters_sparse(
        bank, ia, wa, ib, wb, xp)
    assert a_all.shape[0] == 3
    for r, (pia, pwa, pib, pwb) in enumerate(parts):
        a_one, b_one = XP.precompute_effective_adapters_sparse(
            bank, pia, pwa, pib, pwb, xp)
        np.testing.assert_allclose(np.asarray(a_all[r], np.float32),
                                   np.asarray(a_one, np.float32),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(b_all[r], np.float32),
                                   np.asarray(b_one, np.float32),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("impl", ["auto", "ref", "interpret"])
def test_engine_end_to_end_kernel_impls(setup, impl):
    """ServeEngine(precompute=True) drains under each CPU-runnable backend
    and greedy tokens agree across backends (same admission math)."""
    cfg, params, store = setup
    cfg = cfg.with_xpeft(kernel_impl=impl)
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                    profile_id=i % 3, max_new_tokens=4) for i in range(3)]
    eng.run_until_drained(list(reqs))
    for r in reqs:
        assert r.done and len(r.generated) >= 4
    # cross-impl token parity vs the ref backend
    ref_cfg = cfg.with_xpeft(kernel_impl="ref")
    eng2 = ServeEngine(ref_cfg, params, store, max_slots=2, max_seq=64)
    reqs2 = [Request(uid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                     profile_id=i % 3, max_new_tokens=4) for i in range(3)]
    eng2.run_until_drained(list(reqs2))
    for a, b in zip(reqs, reqs2):
        assert a.generated == b.generated


def test_admit_many_respects_free_slots(setup):
    cfg, params, store = setup
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(4) % cfg.vocab_size,
                    profile_id=0, max_new_tokens=64) for i in range(4)]
    assert eng.admit_many(reqs) == 2          # only 2 slots
    assert eng.admit_many(reqs[2:]) == 0      # engine full
    assert eng.free_slots() == []


def test_sparse_admission_tokens_match_dense_admission(setup):
    """The k-sparse jitted admission produces the same generation as an
    engine fed the dense per-step mask path (precompute=False)."""
    cfg, params, store = setup
    prompt = np.asarray([3, 1, 4, 1, 5, 9]) % cfg.vocab_size
    gens = []
    for precompute in (True, False):
        eng = ServeEngine(cfg, params, store, max_slots=1, max_seq=64,
                          precompute=precompute)
        req = Request(uid=0, prompt=prompt, profile_id=1, max_new_tokens=5)
        eng.admit(req)
        for _ in range(4):
            eng.step()
        gens.append(req.generated)
    assert gens[0] == gens[1]


def test_apply_precomputed_layer_routes_through_ops(setup):
    """The per-layer public API for precomputed adapters matches the core
    apply_adapter semantics under both CPU backends, for 2-D and batched x."""
    from repro.core.adapters import apply_adapter
    cfg, params, store = setup
    bank = params["xpeft_bank"]
    wa, wb = store.mask_weights(0)
    rec = store._rec[0]
    prof = {"ln_scale": jnp.asarray(rec["ln_scale"], jnp.float32),
            "ln_bias": jnp.asarray(rec["ln_bias"], jnp.float32)}
    a_hat = jnp.einsum("ln,lndb->ldb", wa, bank["bank_a"].astype(jnp.float32))
    b_hat = jnp.einsum("ln,lnbd->lbd", wb, bank["bank_b"].astype(jnp.float32))
    eff_l = {"a_hat": a_hat[0].astype(bank["bank_a"].dtype),
             "b_hat": b_hat[0].astype(bank["bank_b"].dtype),
             "ln_scale": prof["ln_scale"][0], "ln_bias": prof["ln_bias"][0]}
    x2 = jax.random.normal(jax.random.key(7), (16, cfg.d_model), jnp.float32)
    x3 = jax.random.normal(jax.random.key(8), (2, 16, cfg.d_model))
    for impl in ("ref", "interpret"):
        xp = cfg.with_xpeft(kernel_impl=impl).xpeft
        want2 = apply_adapter(x2, eff_l["a_hat"], eff_l["b_hat"],
                              eff_l["ln_scale"], eff_l["ln_bias"])
        got2 = XP.apply_precomputed_layer(x2, eff_l, xp)
        np.testing.assert_allclose(np.asarray(got2), np.asarray(want2),
                                   rtol=1e-4, atol=1e-4)
        got3 = XP.apply_precomputed_layer(x3, eff_l, xp)  # shared broadcast
        want3 = jnp.stack([apply_adapter(x3[i], eff_l["a_hat"],
                                         eff_l["b_hat"], eff_l["ln_scale"],
                                         eff_l["ln_bias"]) for i in range(2)])
        np.testing.assert_allclose(np.asarray(got3), np.asarray(want3),
                                   rtol=1e-4, atol=1e-4)
