"""The device-resident training roster: slot-packed gang step, bitwise slot
isolation under admission/eviction, untouched optimizer state for parked
slots, and single-trace guarantees across admission waves."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.data import ProfileClassification
from repro.models import init_lm
from repro.train import Roster, init_roster_state, make_gang_step


S, M_PER_SLOT, SEQ = 2, 4, 12


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("bert-base-xpeft")).with_(
        num_labels=4, vocab_size=64).with_xpeft(num_adapters=8, k=2)
    frozen = init_lm(jax.random.key(0), cfg)
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=8, seed=5)
    return cfg, frozen, data


def _batch(data, step, slot_pids):
    pids = np.repeat([0 if p is None else p for p in slot_pids], M_PER_SLOT)
    b = data.sample(step, S * M_PER_SLOT, SEQ, profile_ids=pids)
    return {k: jnp.asarray(np.asarray(v).reshape((S, M_PER_SLOT)
                                                 + v.shape[1:]))
            for k, v in b.items()}


def _run(cfg, frozen, data, schedule, n_steps, gang=None):
    """Drive the gang step manually with a fixed rng sequence; `schedule`
    maps step -> list of (op, slot, pid) lifecycle actions."""
    roster = Roster(cfg, jax.random.key(7), S)
    state = {"frozen": frozen,
             "roster": init_roster_state(jax.random.key(1), cfg, S)}
    gang = gang or make_gang_step(cfg, lr=5e-2)
    step = jax.jit(gang)
    slot_pids = [None] * S
    for op, slot, pid in schedule.get(-1, []):
        state["roster"] = roster.admit(state["roster"], slot, pid)
        slot_pids[slot] = pid
    for i in range(n_steps):
        state, _ = step(state, _batch(data, i, slot_pids), jax.random.key(i))
        for op, slot, pid in schedule.get(i, []):
            if op == "evict":
                state["roster"] = roster.evict(state["roster"], slot)
                slot_pids[slot] = None
            else:
                state["roster"] = roster.admit(state["roster"], slot, pid)
                slot_pids[slot] = pid
    return roster, state["roster"], gang


def _slot_leaves(rstate, slot):
    """Every per-slot array (trainable + moments + EMAs) for one slot."""
    rows = jax.tree.map(lambda t: t[slot],
                        {"trainable": rstate["trainable"],
                         "m": rstate["opt"]["m"], "v": rstate["opt"]["v"]})
    leaves = jax.tree.leaves(rows)
    leaves += [rstate["opt"]["step"][slot], rstate["slot_step"][slot],
               rstate["ema_loss"][slot], rstate["ema_acc"][slot]]
    return [np.asarray(x) for x in jax.device_get(leaves)]


def test_slot_isolation_bitwise_under_evict_readmit(setup):
    """Evicting/re-admitting slot 0 mid-run leaves slot 1's parameter AND
    Adam-moment trajectory bit-identical to an uninterrupted run."""
    cfg, frozen, data = setup
    base = {-1: [("admit", 0, 0), ("admit", 1, 1)]}
    churn = {-1: [("admit", 0, 0), ("admit", 1, 1)],
             3: [("evict", 0, None)],
             5: [("admit", 0, 2)]}
    _, r_base, _ = _run(cfg, frozen, data, base, 10)
    _, r_churn, _ = _run(cfg, frozen, data, churn, 10)
    for a, b in zip(_slot_leaves(r_base, 1), _slot_leaves(r_churn, 1)):
        np.testing.assert_array_equal(a, b)


def test_gang_step_traces_once_across_admission_waves(setup):
    """>= 3 admission/eviction waves; the jitted gang step traces ONCE."""
    cfg, frozen, data = setup
    gang = make_gang_step(cfg, lr=5e-2)
    schedule = {-1: [("admit", 0, 0), ("admit", 1, 1)],
                2: [("evict", 0, None)],
                3: [("admit", 0, 2)],
                5: [("evict", 1, None), ("admit", 1, 3)],
                7: [("evict", 0, None), ("admit", 0, 4)]}
    _run(cfg, frozen, data, schedule, 10, gang=gang)
    assert gang.trace_counter["traces"] == 1


def test_inactive_slots_fully_untouched(setup):
    """A never-admitted slot's params, moments, and counters are
    bit-identical to init after training steps on other slots."""
    cfg, frozen, data = setup
    init = init_roster_state(jax.random.key(1), cfg, S)
    _, rstate, _ = _run(cfg, frozen, data,
                        {-1: [("admit", 0, 0)]}, 6)
    for a, b in zip(_slot_leaves(init, 1), _slot_leaves(rstate, 1)):
        np.testing.assert_array_equal(a, b)
    assert not bool(np.asarray(rstate["active"])[1])


def test_readmission_resets_to_fresh_deterministic_init(setup):
    """Re-admitting a slot restores a from-scratch state for the new
    profile: params re-derived from fold_in(base_key, pid), moments and
    per-slot Adam step zeroed."""
    cfg, frozen, data = setup
    roster, rstate, _ = _run(
        cfg, frozen, data,
        {-1: [("admit", 0, 0), ("admit", 1, 1)],
         4: [("evict", 0, None), ("admit", 0, 5)]}, 5)
    # the step-4 lifecycle runs AFTER the last training step, so slot 0 is
    # exactly its freshly-admitted state here
    fresh = jax.device_get(roster._fresh(roster.profile_key(5)))
    got = jax.device_get(jax.tree.map(lambda t: t[0], rstate["trainable"]))
    for a, b in zip(jax.tree.leaves(fresh), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for leaf in jax.tree.leaves(jax.tree.map(lambda t: t[0],
                                             rstate["opt"]["m"])):
        assert not np.asarray(leaf).any()
    assert int(rstate["opt"]["step"][0]) == 0
    assert int(rstate["slot_step"][0]) == 0


def test_per_slot_adam_step_advances_only_when_active(setup):
    cfg, frozen, data = setup
    _, rstate, _ = _run(cfg, frozen, data, {-1: [("admit", 0, 0)]}, 4)
    steps = np.asarray(rstate["opt"]["step"])
    assert steps[0] == 4 and steps[1] == 0
    assert np.asarray(rstate["slot_step"])[0] == 4
    assert np.asarray(rstate["ema_count"])[1] == 0
