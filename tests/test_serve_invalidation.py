"""Stale-profile serving regression (the PR's headline bugfix).

`OnboardingScheduler` graduation writes through `ProfileStore.add_profile`
(and resume merges through `merge_from`), but `ServeEngine`'s ProfileCache
keys aggregated Â/B̂ by pid alone — before the invalidation hook, a
re-trained profile kept serving its STALE aggregate forever. The engine now
subscribes `invalidate_profile` to the store's change notifications.

Semantics under test:
- re-graduation (full onboarding round into the SAME store) invalidates,
  and the next admission's aggregate matches the fresh store, not the cache;
- `merge_from` (the resume path) invalidates every adopted pid;
- in-flight slots FINISH on their scattered copy of the old masks — only
  the next admission re-aggregates.
"""
import gc

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.data import MarkovLM
from repro.models import init_lm
from repro.serve.engine import Request, ServeEngine
from repro.train import GraduationPolicy
from repro.train.onboarding import build_onboarding_run


def _cfg():
    return reduce_for_smoke(get_config("qwen1.5-0.5b"))


def _onboard(cfg, store, seed, frozen=None):
    """One onboarding round graduating profile 0 into `store`. Different
    seeds give different roster base keys, so re-training the same pid
    lands on different mask logits (a genuine re-graduation)."""
    data = MarkovLM(cfg.vocab_size, 2, seed=seed)
    policy = GraduationPolicy(min_steps=3, max_steps=5, target_acc=2.0)
    trainer, _ = build_onboarding_run(
        cfg, data, [0], slots=1, per_slot=2, seq_len=8, policy=policy,
        lr=5e-2, seed=seed, rng=jax.random.key(seed), log_every=50,
        frozen=frozen, store=store)
    trainer.run_until_drained(max_steps=100)
    assert len(trainer.scheduler.graduated) == 1
    return trainer


def _fresh_aggregate(eng, store, pid):
    """What admission SHOULD produce for `pid` given the store's current
    record (the k-sparse path the engine runs on a cache miss)."""
    ia, wa, ib, wb = store.batch_sparse_indices([pid])
    a_hat, b_hat = eng._aggregate_sparse(eng.params["xpeft_bank"],
                                         ia, wa, ib, wb)
    return np.asarray(a_hat[0]), np.asarray(b_hat[0])


def _req(uid, pid, max_new=3):
    return Request(uid=uid, prompt=np.arange(5, dtype=np.int64) % 31,
                   profile_id=pid, max_new_tokens=max_new)


def test_regraduation_invalidates_and_next_admission_reaggregates():
    """graduate -> serve -> re-train -> re-graduate -> the next admission
    matches the FRESH store aggregation, not the cached entry. (Fails on
    the pre-hook engine: the cache kept the round-1 aggregate.)"""
    cfg = _cfg()
    xp = cfg.xpeft
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k)
    t1 = _onboard(cfg, store, seed=0)
    frozen = t1.state["frozen"]

    eng = ServeEngine(cfg, frozen, store, max_slots=1, max_seq=32,
                      sync_every=2)
    assert eng.admit(_req(0, 0))
    eng.run_until_drained()
    stale = {k: np.asarray(v)
             for k, v in eng.profile_cache.peek(0).items()}

    # re-train profile 0 into the SAME store the engine serves from
    _onboard(cfg, store, seed=7, frozen=frozen)
    fresh_a, fresh_b = _fresh_aggregate(eng, store, 0)
    assert not np.array_equal(fresh_a, stale["a_hat"]), \
        "re-training produced identical masks; test can't discriminate"

    # the hook dropped the stale entry at graduation time...
    assert eng.profile_cache.peek(0) is None
    # ...and the next admission aggregates from the updated store
    assert eng.admit(_req(1, 0))
    eng.run_until_drained()
    entry = eng.profile_cache.peek(0)
    np.testing.assert_array_equal(np.asarray(entry["a_hat"]), fresh_a)
    np.testing.assert_array_equal(np.asarray(entry["b_hat"]), fresh_b)
    ls, lb = store.ln_affines([0])
    np.testing.assert_array_equal(np.asarray(entry["ln_scale"]),
                                  np.asarray(ls[0]))
    assert eng.profile_cache.stats()["invalidations"] == 1


def _table_store(cfg, n=2, key=0):
    xp = cfg.xpeft
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k)
    table = XP.init_profile_table(jax.random.key(key), cfg)
    for pid in range(n):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return store


def test_merge_from_invalidates_adopted_pids_only():
    """The resume path: merging a store notifies every ADOPTED pid; other
    cached profiles stay hot."""
    cfg = _cfg()
    params = init_lm(jax.random.key(0), cfg)
    store = _table_store(cfg, n=2, key=1)
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=32,
                      sync_every=2)
    eng.admit_many([_req(0, 0), _req(1, 1)])
    eng.run_until_drained()
    assert eng.profile_cache.peek(0) is not None
    assert eng.profile_cache.peek(1) is not None

    other = _table_store(cfg, n=1, key=9)  # different masks for pid 0 only
    store.merge_from(other)
    assert eng.profile_cache.peek(0) is None, \
        "merge_from must invalidate the adopted pid's cached aggregate"
    assert eng.profile_cache.peek(1) is not None, \
        "untouched profiles must stay cached"


def test_store_does_not_pin_dead_engines():
    """The store holds engine hooks WEAKLY: a store outlives the engines
    serving from it, and a strong ref would pin every dead engine's device
    state forever. Dropped engines are pruned at the next notification."""
    cfg = _cfg()
    params = init_lm(jax.random.key(0), cfg)
    store = _table_store(cfg, n=1, key=1)
    eng = ServeEngine(cfg, params, store, max_slots=1, max_seq=32)
    assert len(store._listeners) == 1
    ref = store._listeners[0]
    del eng
    gc.collect()
    assert ref() is None, "dead engine's hook must not be kept alive"
    table = XP.init_profile_table(jax.random.key(9), cfg)
    store.add_profile(0, jax.tree.map(lambda t: t[0], table))  # prunes
    assert store._listeners == []


def test_inflight_slot_finishes_on_old_masks():
    """Invalidating mid-flight only drops the CACHE entry: the slot's
    scattered Â/B̂ copy keeps serving the in-flight request; the next
    admission re-aggregates."""
    cfg = _cfg()
    params = init_lm(jax.random.key(0), cfg)
    store = _table_store(cfg, n=1, key=1)
    eng = ServeEngine(cfg, params, store, max_slots=1, max_seq=64,
                      sync_every=4)
    assert eng.admit(_req(0, 0, max_new=16))
    old = {k: np.asarray(v) for k, v in eng.profile_cache.peek(0).items()}
    eng.step()  # in flight, not drained

    table = XP.init_profile_table(jax.random.key(9), cfg)
    store.add_profile(0, jax.tree.map(lambda t: t[0], table))  # re-graduate
    assert eng.profile_cache.peek(0) is None
    # the slot buffer still carries the OLD aggregate (documented behavior)
    np.testing.assert_array_equal(
        np.asarray(eng.masks["a_hat"][0]),
        old["a_hat"].astype(np.asarray(eng.masks["a_hat"]).dtype))
    eng.run_until_drained()

    # next admission of the pid aggregates the NEW record
    fresh_a, _ = _fresh_aggregate(eng, store, 0)
    assert eng.admit(_req(1, 0))
    eng.run_until_drained()
    np.testing.assert_array_equal(
        np.asarray(eng.profile_cache.peek(0)["a_hat"]), fresh_a)
