"""ProfileStore precision round-trips: fp16 head/ln hydration, quantized
aggregated-record save→load bit-exactness, dequant error bounds, and the
checkpoint manager round-tripping quantized trees bit-exactly."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.quant import schemes as QS


def _cfg(scheme="int8"):
    return reduce_for_smoke(get_config("qwen1.5-0.5b")).with_xpeft(
        bank_quant=scheme)


def _store_with_agg(cfg, n=3, key=0):
    """Store with quantized aggregated records built from a real bank."""
    xp = cfg.xpeft
    k = jax.random.key(key)
    bank = XP.init_xpeft_state(k, cfg)["bank"]
    table = XP.init_profile_table(k, cfg)
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k, quant=xp.bank_quant,
                         quant_group=xp.quant_group)
    effs = {}
    for pid in range(n):
        prof = jax.tree.map(lambda t: t[pid], table)
        # a per-profile head rides along to exercise the fp16 head path
        prof["head_w"] = 0.02 * jax.random.normal(
            jax.random.fold_in(k, pid), (cfg.d_model, 4))
        prof["head_b"] = jnp.arange(4, dtype=jnp.float32) * 0.5
        eff = XP.precompute_effective_adapters(bank, prof, xp)
        store.add_profile(pid, prof, agg=(eff["a_hat"], eff["b_hat"]))
        effs[pid] = eff
    return store, effs


def test_fp16_head_and_ln_roundtrip(tmp_path):
    cfg = _cfg()
    store, _ = _store_with_agg(cfg)
    path = str(tmp_path / "s.npz")
    store.save(path)
    loaded = ProfileStore.load(path)
    assert (loaded.quant, loaded.quant_group) == (store.quant,
                                                 store.quant_group)
    for pid in store.profile_ids():
        hw, hb = store.head(pid)
        hw2, hb2 = loaded.head(pid)
        np.testing.assert_array_equal(np.asarray(hw), np.asarray(hw2))
        np.testing.assert_array_equal(np.asarray(hb), np.asarray(hb2))
        ls, lb = store.ln_affines([pid])
        ls2, lb2 = loaded.ln_affines([pid])
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(ls2))
        np.testing.assert_array_equal(np.asarray(lb), np.asarray(lb2))
    # fp16 storage is exact for values representable in fp16 (the bias
    # ramp above), and hydration returns float32
    assert store.head(0)[1].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(store.head(0)[1]),
                                  np.arange(4) * 0.5)


@pytest.mark.parametrize("scheme", ["int8", "int4"])
def test_quant_record_save_load_bit_exact(tmp_path, scheme):
    cfg = _cfg(scheme)
    store, _ = _store_with_agg(cfg)
    path = str(tmp_path / "q.npz")
    store.save(path)
    loaded = ProfileStore.load(path)
    pids = store.profile_ids()
    a = store.quant_records(pids)
    b = loaded.quant_records(pids)
    for key in ("a_q", "a_scale", "b_q", "b_scale"):
        assert a[key].dtype == b[key].dtype
        np.testing.assert_array_equal(np.asarray(a[key]), np.asarray(b[key]))
    for pid in pids:
        assert loaded.has_quant_record(pid)
        assert loaded.record_nbytes(pid) == store.record_nbytes(pid)


@pytest.mark.parametrize("scheme", ["int8", "int4"])
def test_quant_record_dequant_error_bound(scheme):
    """Dequantizing a stored record recovers the exact aggregated Â/B̂ to
    within the scheme's per-row quantization step."""
    cfg = _cfg(scheme)
    store, effs = _store_with_agg(cfg)
    recs = store.quant_records(store.profile_ids())
    step = {"int8": 1 / 127, "int4": 1 / 7}[scheme]
    for i, pid in enumerate(store.profile_ids()):
        for qk, sk, ref in (("a_q", "a_scale", effs[pid]["a_hat"]),
                            ("b_q", "b_scale", effs[pid]["b_hat"])):
            deq = QS.dequant_block(recs[qk][i], recs[sk][i], scheme)
            ref32 = np.asarray(ref, np.float32)
            bound = 0.6 * step * np.abs(ref32).max() + 1e-7
            assert np.abs(np.asarray(deq) - ref32).max() <= bound


def test_quant_store_merge_requires_matching_scheme():
    cfg = _cfg("int8")
    a, _ = _store_with_agg(cfg)
    xp = cfg.xpeft
    other = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k, quant="int4")
    with pytest.raises(AssertionError):
        other.merge_from(a)


def test_unquantized_store_rejects_agg_records():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    xp = cfg.xpeft
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k)
    table = XP.init_profile_table(jax.random.key(0), cfg)
    prof = jax.tree.map(lambda t: t[0], table)
    with pytest.raises(ValueError, match="quantized store"):
        store.add_profile(0, prof,
                          agg=(jnp.zeros((2, 4, 2)), jnp.zeros((2, 2, 4))))


def test_checkpoint_manager_roundtrips_quantized_tree(tmp_path):
    """CheckpointManager save→restore preserves int8/uint8 payloads and
    fp16 scales bit-exactly (the quantized-store-in-training-state path)."""
    from repro.checkpoint.manager import CheckpointManager

    bank = {"bank_a": 0.05 * jax.random.normal(jax.random.key(0),
                                               (2, 4, 16, 8)),
            "bank_b": 0.05 * jax.random.normal(jax.random.key(1),
                                               (2, 4, 8, 16))}
    state = {"q8": QS.quantize_bank(bank, "int8"),
             "q4": QS.quantize_bank(bank, "int4", group=8),
             "step": jnp.int32(7)}
    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep_last=2)
    mgr.save(1, state, blocking=True)
    restored = mgr.restore(1, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), state))

    def check(got, want):
        got, want = jnp.asarray(got), jnp.asarray(want)
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    jax.tree.map(check, restored, state)
