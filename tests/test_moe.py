"""MoE dispatch: sort-based vs dense reference, capacity behaviour."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduce_for_smoke
from repro.models.moe import capacity, init_moe, moe_apply


def _cfg(E=8, k=2, cf=8.0):
    return reduce_for_smoke(get_config("qwen3-moe-30b-a3b")).with_(
        num_experts=E, top_k=k, capacity_factor=cf)


def test_sort_matches_dense_high_capacity():
    cfg = _cfg(cf=8.0)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y_sort, aux1 = moe_apply(p, x, cfg)
    y_dense, aux2 = moe_apply(p, x, cfg.with_(moe_impl="dense"))
    np.testing.assert_allclose(np.asarray(y_sort), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-5)


def test_low_capacity_drops_but_stays_finite():
    cfg = _cfg(cf=0.25)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, _ = moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    # dropped tokens pass through as zeros (residual handles identity)
    y_hi, _ = moe_apply(p, x, cfg.with_(capacity_factor=8.0))
    assert float(jnp.abs(y).sum()) < float(jnp.abs(y_hi).sum())


@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_capacity_formula_bounds(seed):
    cfg = _cfg()
    rng = np.random.default_rng(seed)
    n = int(rng.integers(cfg.num_experts, 4096))
    C = capacity(n, cfg)
    assert cfg.top_k <= C <= n


def test_grad_flows_through_router():
    cfg = _cfg(cf=4.0)
    p = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model))

    def loss(params):
        y, aux = moe_apply(params, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["ew_g"]).sum()) > 0
