"""Checkpoint manager: atomicity, resume, keep-last-k, async."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.key(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.arange(4.0)},
            "opt": {"m": jnp.zeros((8, 8)), "step": jnp.int32(7)}}


def test_save_restore_bitwise(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    st = _state()
    mgr.save(3, st, extra={"loader": {"step": 3}})
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                            st)
    out = mgr.restore(3, abstract)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.manifest(3)["extra"]["loader"]["step"] == 3


def test_keep_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state())
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=3)
    mgr.save(1, _state(), blocking=False)
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_manifest_roundtrips_lifecycle_state(tmp_path):
    """Onboarding lifecycle state (numpy ints/arrays from device fetches:
    pending-queue positions, slot→profile maps, per-slot step counts) must
    survive the JSON manifest — json.dump rejects raw numpy types."""
    mgr = CheckpointManager(str(tmp_path))
    extra = {"onboarding": {
        "pending": np.arange(3, dtype=np.int64),
        "slot_pid": [np.int32(7), None],
        "slot_steps": [np.int32(12), np.int32(0)],
        "waves": np.int64(2)}}
    mgr.save(5, _state(), extra=extra)
    man = mgr.manifest(5)["extra"]["onboarding"]
    assert man["pending"] == [0, 1, 2]
    assert man["slot_pid"] == [7, None]
    assert man["slot_steps"] == [12, 0]
    assert man["waves"] == 2


def test_partial_write_invisible(tmp_path):
    """A .tmp dir from a crashed writer is never listed as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "step_0000000009.tmp"))
    assert mgr.all_steps() == []


def test_trainer_resume_bitwise(tmp_path):
    """Train 6 steps w/ checkpoint at 3; crash; resume; states match a
    straight 6-step run exactly (data position included)."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.data import MarkovLM
    from repro.data.loader import ShardedLoader
    from repro.train.steps import init_train_state, make_train_step
    from repro.train.trainer import Trainer

    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    step = jax.jit(make_train_step(cfg, "xpeft", lr=1e-2))

    def mk_loader():
        return ShardedLoader(MarkovLM(cfg.vocab_size, 4, seed=1), 4, 16)

    # continuous run
    t1 = Trainer(step, init_train_state(key, cfg, "xpeft"), mk_loader(),
                 rng=jax.random.key(42), log_every=1000)
    t1.run(6)

    # checkpointed + resumed run
    ck = str(tmp_path / "ck")
    t2 = Trainer(step, init_train_state(key, cfg, "xpeft"), mk_loader(),
                 ckpt_dir=ck, ckpt_every=3, rng=jax.random.key(42),
                 log_every=1000)
    t2.run(3)
    t2.checkpoint(blocking=True)

    t3 = Trainer(step, init_train_state(key, cfg, "xpeft"), mk_loader(),
                 ckpt_dir=ck, rng=jax.random.key(0), log_every=1000)
    assert t3.try_resume()  # restores state, step, data position AND rng
    assert t3.step == 3
    t3.run(3)

    a = jax.tree.leaves(t1.state["trainable"])
    b = jax.tree.leaves(t3.state["trainable"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
