"""Onboarding lifecycle: P >> S streaming, train→serve graduation parity
(bit-for-bit masks through ServeEngine admission, classifier logits from
the persisted store), resume mid-onboarding, and the trainer's buffered
host-sync cadence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import masks as M
from repro.core.profiles import ProfileStore
from repro.data import MarkovLM, ProfileClassification
from repro.models import model as MDL
from repro.train import (GraduationPolicy, Trainer, init_train_state,
                         make_train_step)
from repro.train.onboarding import build_onboarding_run


def _cls_cfg(vocab=64):
    return reduce_for_smoke(get_config("bert-base-xpeft")).with_(
        num_labels=4, vocab_size=vocab).with_xpeft(num_adapters=8, k=2)


def _build(cfg, source, n_profiles, *, S=2, m=2, seq=12, policy=None,
           log_every=5, **trainer_kw):
    policy = policy or GraduationPolicy(min_steps=4, max_steps=8,
                                        target_acc=2.0)  # force max_steps
    trainer, gang = build_onboarding_run(
        cfg, source, range(n_profiles), slots=S, per_slot=m, seq_len=seq,
        policy=policy, lr=5e-2, log_every=log_every,
        rng=jax.random.key(1), **trainer_kw)
    return (trainer, gang, trainer.scheduler.roster, trainer.scheduler.store,
            trainer.state["frozen"])


# ----------------------------------------------------------- streaming P>>S

def test_stream_profiles_through_roster():
    cfg = _cls_cfg()
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=5, seed=5)
    trainer, gang, _, store, _ = _build(cfg, data, 5)
    trainer.run_until_drained(max_steps=500)
    st = trainer.scheduler.stats()
    assert st["graduated"] == 5 and st["evicted"] == 0
    assert store.profile_ids() == [0, 1, 2, 3, 4]
    assert st["admission_waves"] >= 3          # 5 profiles through 2 slots
    assert gang.trace_counter["traces"] == 1   # zero retraces across waves
    assert trainer.host_syncs < trainer.step   # metrics buffered on device


def test_evict_at_max_drops_unconverged_profiles():
    """With evict_at_max, profiles that never hit the target are dropped
    (recorded, not graduated) and every streamed profile is accounted for."""
    cfg = _cls_cfg()
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=3, seed=5)
    policy = GraduationPolicy(min_steps=4, max_steps=6, target_acc=2.0,
                              evict_at_max=True)  # unreachable target
    trainer, _, _, store, _ = _build(cfg, data, 3, policy=policy)
    trainer.run_until_drained(max_steps=300)
    st = trainer.scheduler.stats()
    assert st["graduated"] == 0 and st["evicted"] == 3
    assert store.profile_ids() == []
    assert {e["pid"] for e in trainer.scheduler.evicted} == {0, 1, 2}


# --------------------------------------------------- train→serve graduation

@pytest.fixture(scope="module")
def lm_graduated():
    """Roster-train 2 profiles on an LM arch and graduate them."""
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    data = MarkovLM(cfg.vocab_size, 2, seed=1)
    trainer, gang, roster, store, frozen = _build(cfg, data, 2, seq=16)
    trainer.run_until_drained(max_steps=100)
    assert len(trainer.scheduler.graduated) == 2
    return cfg, frozen, roster, trainer, store


def test_graduated_masks_roundtrip_bit_for_bit(lm_graduated, tmp_path):
    """Trained slot -> binarize -> store -> save/load: k-sparse indices and
    hydrated weights identical at every stage."""
    cfg, frozen, roster, trainer, store = lm_graduated
    store.save(str(tmp_path / "store.npz"))
    loaded = ProfileStore.load(str(tmp_path / "store.npz"))
    k = cfg.xpeft.k
    for g in trainer.scheduler.graduated:
        row = roster.slot_params(trainer.state["roster"], g["slot"])
        bits_a = np.asarray(M.binarize(row["mA"], k))
        ia_t = np.asarray(M.mask_indices(bits_a, k))
        for st in (store, loaded):
            ia, wa, ib, wb = st.sparse_indices(g["pid"])
            np.testing.assert_array_equal(np.asarray(ia), ia_t)
            np.testing.assert_array_equal(
                np.asarray(ib),
                np.asarray(M.mask_indices(M.binarize(row["mB"], k), k)))
            assert np.all(np.asarray(wa) == 1.0 / k)
        wa_t, _ = store.mask_weights(g["pid"])
        np.testing.assert_array_equal(
            np.asarray(wa_t), np.asarray(M.khot_weights_from_bits(bits_a, k)))


def test_graduated_profile_admits_through_serve_engine(lm_graduated,
                                                       tmp_path):
    """The full loop: persisted store -> ServeEngine.admit -> the engine's
    aggregated Â/B̂ equal the aggregation of the IN-TRAINING masks, and the
    scattered LN affines equal the trained row's (fp16 store precision)."""
    from repro.serve.engine import Request, ServeEngine

    cfg, frozen, roster, trainer, store = lm_graduated
    store.save(str(tmp_path / "store.npz"))
    loaded = ProfileStore.load(str(tmp_path / "store.npz"))
    eng = ServeEngine(cfg, frozen, loaded, max_slots=2, max_seq=32,
                      sync_every=2)
    k = cfg.xpeft.k
    for g in trainer.scheduler.graduated:
        pid = g["pid"]
        req = Request(uid=pid, prompt=np.arange(5, dtype=np.int64) % 31,
                      profile_id=pid, max_new_tokens=2)
        assert eng.admit(req)
        entry = eng.profile_cache.get(pid)
        row = roster.slot_params(trainer.state["roster"], g["slot"])
        ia = jnp.asarray(M.mask_indices(M.binarize(row["mA"], k), k))[None]
        ib = jnp.asarray(M.mask_indices(M.binarize(row["mB"], k), k))[None]
        w = jnp.full(ia.shape, 1.0 / k, jnp.float32)
        a_hat, b_hat = eng._aggregate_sparse(frozen["xpeft_bank"],
                                             ia, w, ib, w)
        np.testing.assert_array_equal(np.asarray(entry["a_hat"]),
                                      np.asarray(a_hat[0]))
        np.testing.assert_array_equal(np.asarray(entry["b_hat"]),
                                      np.asarray(b_hat[0]))
        np.testing.assert_array_equal(
            np.asarray(entry["ln_scale"]),
            row["ln_scale"].astype(np.float16).astype(np.float32))
    eng.run_until_drained()


def test_graduated_classifier_logits_parity(tmp_path):
    """Classification parity: logits from the PERSISTED store (masks + LN +
    per-profile head, fp16 records) match the in-training eval forward
    bit-for-bit on a fixed batch."""
    cfg = _cls_cfg()
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=2, seed=5)
    trainer, _, roster, store, frozen = _build(cfg, data, 2)
    trainer.run_until_drained(max_steps=100)
    store.save(str(tmp_path / "store.npz"))
    loaded = ProfileStore.load(str(tmp_path / "store.npz"))
    k = cfg.xpeft.k
    B = 8

    def logits_with(masks, head_w, head_b, toks):
        hidden, _, _ = MDL.forward(frozen, toks, cfg, profile_masks=masks)
        head = {"head_w": jnp.broadcast_to(head_w, (B,) + head_w.shape),
                "head_b": jnp.broadcast_to(head_b, (B,) + head_b.shape)}
        return np.asarray(MDL.cls_logits(frozen, hidden, cfg, head))

    for g in trainer.scheduler.graduated:
        pid = g["pid"]
        toks = jnp.asarray(data.sample(777, B, 12,
                                       profile_ids=[pid] * B)["tokens"])
        # in-training eval path: deterministic k-hot of the trained logits,
        # affines/head at the store's fp16 persistence precision
        row = roster.slot_params(trainer.state["roster"], g["slot"])
        f16 = lambda x: jnp.asarray(x.astype(np.float16).astype(np.float32))
        wa = jnp.asarray(M.khot_weights_from_bits(M.binarize(row["mA"], k), k))
        wb = jnp.asarray(M.khot_weights_from_bits(M.binarize(row["mB"], k), k))
        train_masks = {
            "w_a": jnp.broadcast_to(wa, (B,) + wa.shape),
            "w_b": jnp.broadcast_to(wb, (B,) + wb.shape),
            "ln_scale": jnp.broadcast_to(f16(row["ln_scale"]),
                                         (B,) + row["ln_scale"].shape),
            "ln_bias": jnp.broadcast_to(f16(row["ln_bias"]),
                                        (B,) + row["ln_bias"].shape)}
        lt = logits_with(train_masks, f16(row["head_w"]), f16(row["head_b"]),
                         toks)
        # persisted-store path
        swa, swb, sls, slb = loaded.batch_mask_weights([pid] * B)
        hw, hb = loaded.head(pid)
        ls = logits_with({"w_a": swa, "w_b": swb, "ln_scale": sls,
                          "ln_bias": slb}, hw, hb, toks)
        np.testing.assert_array_equal(lt, ls)


# ------------------------------------------------------------------- resume

def test_resume_mid_onboarding_matches_uninterrupted(tmp_path):
    """Checkpoint mid-onboarding, resume in a fresh process state: the
    final store is bit-identical to an uninterrupted run, and graduated
    profiles are not re-trained."""
    cfg = _cls_cfg()

    def make(ckpt_dir=None, store_path=None):
        data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                     num_profiles=4, seed=5)
        return _build(cfg, data, 4, log_every=5,
                      ckpt_dir=ckpt_dir, ckpt_every=5,
                      store_path=store_path)

    # uninterrupted
    t1, _, _, store1, _ = make()
    t1.run_until_drained(max_steps=500)

    # checkpointed at step 10, then resumed
    ck = str(tmp_path / "ck")
    sp = str(tmp_path / "store.npz")
    t2, _, _, _, _ = make(ckpt_dir=ck, store_path=sp)
    t2.run(10)
    graduated_at_ckpt = [g["pid"] for g in t2.scheduler.graduated]

    t3, _, _, store3, _ = make(ckpt_dir=ck, store_path=sp)
    assert t3.try_resume()
    assert t3.step == 10
    assert [g["pid"] for g in t3.scheduler.graduated] == graduated_at_ckpt
    t3.run_until_drained(max_steps=500)

    assert t3.step == t1.step
    assert store3.profile_ids() == store1.profile_ids() == [0, 1, 2, 3]
    # graduated-before-checkpoint profiles were not re-trained after resume
    for g, h in zip(t1.scheduler.graduated, t3.scheduler.graduated):
        assert g == h
    for pid in store1.profile_ids():
        for a, b in zip(store1.sparse_indices(pid),
                        store3.sparse_indices(pid)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(store1.head(pid)[0]),
                                      np.asarray(store3.head(pid)[0]))


# -------------------------------------------------- trainer metric buffering

def test_trainer_buffers_metrics_until_log_boundary():
    """The classic Trainer path: history contents are preserved while host
    syncs happen only at log/end boundaries, not per step."""
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    from repro.data.loader import ShardedLoader
    loader = ShardedLoader(MarkovLM(cfg.vocab_size, 4, seed=1), 4, 16)
    state = init_train_state(jax.random.key(0), cfg, "xpeft")
    step = jax.jit(make_train_step(cfg, "xpeft", lr=1e-2))
    tr = Trainer(step, state, loader, rng=jax.random.key(42), log_every=5)
    hist = tr.run(7)
    assert [r["step"] for r in hist] == list(range(1, 8))
    for r in hist:
        assert {"loss", "aux_loss", "grad_norm", "step",
                "straggler"} <= set(r)
        assert isinstance(r["loss"], float)
    assert tr.host_syncs == 2  # step 5 boundary + end-of-run flush
