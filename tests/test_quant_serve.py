"""Quantized-bank serving end to end: bytes, decode parity, store-record
admission, graduation quantize-on-write, and the bank_quant=none
bitwise-no-change guarantee."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.serve.engine import ServeEngine
from repro.serve.profile_cache import entry_nbytes
from repro.serve.scheduler import Request


def _build(scheme, *, n_prof=5, max_slots=4, seed=0, store=None):
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b")).with_xpeft(
        bank_quant=scheme)
    key = jax.random.key(seed)
    params = init_lm(key, cfg)
    if store is None:
        xp = cfg.xpeft
        store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                             xp.mask_type, xp.k, quant=scheme,
                             quant_group=xp.quant_group)
        table = XP.init_profile_table(key, cfg)
        for pid in range(n_prof):
            store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    eng = ServeEngine(cfg, params, store, max_slots=max_slots, max_seq=64,
                      sync_every=4)
    return cfg, eng, store


def _decode(cfg, eng, *, n=4, max_new=16, base=0):
    reqs = [Request(uid=base + i, prompt=np.arange(5 + i) % cfg.vocab_size,
                    profile_id=i % 3, max_new_tokens=max_new)
            for i in range(n)]
    eng.run_until_drained(reqs)
    return [list(r.generated) for r in reqs]


def test_none_engine_is_unchanged():
    """bank_quant='none' keeps the bf16/fp32 bank resident, the fp mask
    buffers, and the k-sparse admission path — the pre-quant engine."""
    cfg, eng, _ = _build("none")
    assert eng.qbank is None and "xpeft_bank" in eng.params
    assert "a_hat" in eng.masks and "a_q" not in eng.masks
    _decode(cfg, eng, n=2, max_new=4)
    assert eng.last_admission["path"] == "sparse"
    assert "scheme" not in eng.last_admission


def test_bank_quant_rejects_per_step_serving():
    """precompute=False + bank_quant must REFUSE (the per-step path reads
    the fp bank every step — none of the quant savings would exist)."""
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b")).with_xpeft(
        bank_quant="int8")
    xp = cfg.xpeft
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k, quant="int8")
    with pytest.raises(ValueError, match="precompute"):
        ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                    precompute=False)


@pytest.mark.parametrize("scheme", ["int8", "int4"])
def test_quant_engine_drops_bank_and_reads_fewer_bytes(scheme):
    cfg0, eng0, _ = _build("none")
    cfg, eng, _ = _build(scheme)
    assert "xpeft_bank" not in eng.params and eng.qbank is not None
    assert eng.masks["a_q"].dtype == (jnp.int8 if scheme == "int8"
                                      else jnp.uint8)
    _decode(cfg0, eng0, n=4, max_new=2)
    _decode(cfg, eng, n=4, max_new=2)
    assert eng.last_admission["path"] == "quant_sparse"
    assert eng.last_admission["scheme"] == scheme
    got = eng.last_admission["bank_bytes_per_request"]
    ref = eng0.last_admission["bank_bytes_per_request"]
    ceiling = {"int8": 0.55, "int4": 0.35}[scheme]
    assert 0 < got <= ceiling * ref, (got, ref)
    # quantized engine is strictly lighter per device (bank + buffers)
    assert eng.resident_bytes_per_device()["total"] < \
        eng0.resident_bytes_per_device()["total"]


def test_int8_greedy_decode_matches_bf16_path():
    """End-to-end greedy decode under int8 agrees with the unquantized
    path on >= 99%% of tokens (the acceptance bar; measured exact here)."""
    cfg0, eng0, _ = _build("none")
    cfg, eng, _ = _build("int8")
    base = _decode(cfg0, eng0, n=6, max_new=16)
    got = _decode(cfg, eng, n=6, max_new=16)
    flat = [(t, u) for s, su in zip(got, base) for t, u in zip(s, su)]
    agree = sum(t == u for t, u in flat) / len(flat)
    assert agree >= 0.99, agree


def test_int4_prefill_step_agreement():
    """int4's per-step choices track the bf16 path closely; full greedy
    sequences may diverge after a flip (autoregressive compounding), so
    the per-step metric is the honest one for the coarser scheme."""
    cfg0, eng0, _ = _build("none")
    cfg, eng, _ = _build("int4")
    # first generated token of each request = one independent trial
    base = [s[0] for s in _decode(cfg0, eng0, n=8, max_new=1)]
    got = [s[0] for s in _decode(cfg, eng, n=8, max_new=1)]
    agree = np.mean([t == u for t, u in zip(got, base)])
    assert agree >= 0.75, agree


@pytest.mark.parametrize("scheme", ["int8", "int4"])
def test_store_record_admission_reads_zero_bank_bytes(scheme):
    """Profiles graduated WITH quantized Â/B̂ records admit via store
    hydration: zero bank reads, and the decode uses the stored record."""
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b")).with_xpeft(
        bank_quant=scheme)
    xp = cfg.xpeft
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    bank = params["xpeft_bank"]
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         xp.mask_type, xp.k, quant=scheme,
                         quant_group=xp.quant_group)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        prof = jax.tree.map(lambda t: t[pid], table)
        eff = XP.precompute_effective_adapters(bank, prof, xp)
        store.add_profile(pid, prof, agg=(eff["a_hat"], eff["b_hat"]))
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      sync_every=4)
    _decode(cfg, eng, n=2, max_new=4)
    adm = eng.last_admission
    assert adm["path"] == "quant_store"
    assert adm["bank_bytes_per_request"] == 0
    assert adm["store_hydrated_profiles"] == 2


def test_quant_cache_entries_use_true_quantized_bytes():
    """ProfileCache capacity accounting sees the TRUE quantized record
    bytes — int4 entries are smaller than int8, both far under fp32."""
    sizes = {}
    for scheme in ("none", "int8", "int4"):
        cfg, eng, _ = _build(scheme)
        _decode(cfg, eng, n=3, max_new=2)
        entry = eng.profile_cache.peek(0)
        sizes[scheme] = entry_nbytes(entry)
    assert sizes["int4"] < sizes["int8"] < sizes["none"]


def test_regraduation_invalidates_quant_cache():
    """Store notifications drop quantized cache entries too: re-graduating
    a profile with NEW masks changes the next admission's record."""
    cfg, eng, store = _build("int8")
    _decode(cfg, eng, n=2, max_new=2)
    assert 0 in eng.profile_cache
    before = np.asarray(eng.profile_cache.peek(0)["a_q"]).copy()
    table2 = XP.init_profile_table(jax.random.key(42), cfg)
    store.add_profile(0, jax.tree.map(lambda t: t[0], table2))
    assert 0 not in eng.profile_cache  # invalidated via subscription
    _decode(cfg, eng, n=2, max_new=2, base=100)
    after = np.asarray(eng.profile_cache.peek(0)["a_q"])
    assert (before != after).any()


def test_onboarding_graduation_writes_quant_records():
    """build_onboarding_run under bank_quant: graduated profiles carry
    quantized Â/B̂ records that a ServeEngine admits with zero bank reads
    (train→serve loop closed for the quantized path)."""
    from repro.data import ProfileClassification
    from repro.train import GraduationPolicy
    from repro.train.onboarding import build_onboarding_run

    cfg = reduce_for_smoke(get_config("bert-base-xpeft")).with_(
        num_labels=4, vocab_size=64).with_xpeft(num_adapters=8, k=2,
                                                bank_quant="int8")
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=3, seed=5)
    policy = GraduationPolicy(min_steps=4, max_steps=6, target_acc=2.0)
    trainer, _ = build_onboarding_run(cfg, data, range(3), slots=2,
                                      per_slot=2, seq_len=12, policy=policy,
                                      lr=5e-2, log_every=5,
                                      rng=jax.random.key(1))
    trainer.run_until_drained(max_steps=300)
    store = trainer.scheduler.store
    assert store.quant == "int8"
    assert store.profile_ids() == [0, 1, 2]
    for pid in store.profile_ids():
        assert store.has_quant_record(pid)
        # record carries masks AND the quantized aggregate
        assert store.record_nbytes(pid) > store.bytes_per_profile()
    recs = store.quant_records([0, 1, 2])
    assert recs["a_q"].dtype == jnp.int8
    assert recs["a_q"].shape[:2] == (3, cfg.num_layers)
