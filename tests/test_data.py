"""Data pipeline: determinism, profile-conditioning, shard re-balance."""
import numpy as np

from repro.data import MarkovLM, ProfileClassification, ShardedLoader
from repro.distributed.fault import rebalance_assignment


def test_markov_deterministic():
    d1 = MarkovLM(256, 8, seed=3).sample(5, 4, 16)
    d2 = MarkovLM(256, 8, seed=3).sample(5, 4, 16)
    for k in d1:
        np.testing.assert_array_equal(d1[k], d2[k])


def test_markov_profile_dependent():
    src = MarkovLM(256, 8, seed=0)
    a = src.sample(0, 2, 64, profile_ids=[0, 0])
    b = src.sample(0, 2, 64, profile_ids=[3, 3])
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_classification_teacher_consistency():
    src = ProfileClassification(64, 5, 4, seed=1)
    batch = src.sample(0, 8, 32)
    assert batch["labels"].min() >= 0 and batch["labels"].max() < 5
    # same tokens + same profile => same label
    b2 = src.sample(0, 8, 32)
    np.testing.assert_array_equal(batch["labels"], b2["labels"])


def test_sharded_loader_partition_and_resume():
    src = MarkovLM(128, 4, seed=0)
    full = ShardedLoader(src, global_batch=8, seq_len=16)
    h0 = ShardedLoader(src, 8, 16, host_id=0, num_hosts=2)
    h1 = ShardedLoader(src, 8, 16, host_id=1, num_hosts=2)
    b_full, b0, b1 = full.next(), h0.next(), h1.next()
    np.testing.assert_array_equal(
        np.concatenate([b0["tokens"], b1["tokens"]]), b_full["tokens"])
    # resume: loader at step 1 == fresh loader fast-forwarded
    h0b = ShardedLoader(src, 8, 16, host_id=0, num_hosts=2)
    h0b.load_state_dict(h0.state_dict())
    np.testing.assert_array_equal(h0.next()["tokens"],
                                  h0b.next()["tokens"])


def test_rebalance_downweights_straggler():
    asg = rebalance_assignment(100, [0, 1, 2, 3], {2: 0.5})
    sizes = {h: len(r) for h, r in asg.items()}
    assert sum(sizes.values()) == 100
    assert sizes[2] < sizes[0]
    # deterministic
    asg2 = rebalance_assignment(100, [0, 1, 2, 3], {2: 0.5})
    assert all(asg[h] == asg2[h] for h in asg)
