"""Per-arch smoke tests (reduced configs): one forward + one train step on
CPU, asserting output shapes and no NaNs; plus decode==full consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.models import cls_logits, forward, init_cache, init_lm, lm_logits
from repro.train.steps import init_train_state, make_train_step

ALL_ARCHS = ASSIGNED_ARCHS + ("bert-base-xpeft",)


def _masks(cfg, key, B):
    table = XP.init_profile_table(key, cfg)
    prof = XP.gather_profiles(table, jnp.arange(B) % cfg.xpeft.max_profiles)
    wa, wb = XP.profile_mask_weights(prof, cfg.xpeft, key=key, training=False)
    return {"w_a": wa, "w_b": wb, "ln_scale": prof["ln_scale"],
            "ln_bias": prof["ln_bias"]}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    B, T = 2, 16
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    prefix = None
    if cfg.num_prefix_tokens:
        prefix = jax.random.normal(key, (B, cfg.num_prefix_tokens,
                                         cfg.d_model))
    masks = _masks(cfg, key, B)
    h, _, aux = forward(params, toks, cfg, prefix_embeds=prefix,
                        profile_masks=masks)
    assert h.shape == (B, T + (cfg.num_prefix_tokens or 0), cfg.d_model)
    assert not bool(jnp.isnan(h).any())
    if cfg.family == "encoder":
        logits = cls_logits(params, h, cfg)
        assert logits.shape == (B, cfg.num_labels)
    else:
        logits = lm_logits(params, h[:, -2:, :], cfg)
        assert logits.shape == (B, 2, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ["gemma-2b", "gemma3-27b", "rwkv6-7b",
                                  "zamba2-1.2b", "qwen3-moe-30b-a3b"])
def test_train_step_runs(arch):
    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.key(0)
    state = init_train_state(key, cfg, "xpeft")
    step = jax.jit(make_train_step(cfg, "xpeft", lr=1e-3))
    B, T = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, T), 0, cfg.vocab_size),
             "profile_ids": jnp.array([0, 1])}
    if cfg.num_prefix_tokens:
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.num_prefix_tokens, cfg.d_model))
    if cfg.num_labels:
        batch["labels"] = jnp.array([0, 1])
    state2, metrics = step(state, batch, key)
    assert np.isfinite(float(metrics["loss"]))
    # masks actually received gradient
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     state["trainable"], state2["trainable"])
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma-2b", "gemma3-27b",
                                  "rwkv6-7b", "zamba2-1.2b",
                                  "musicgen-medium"])
def test_decode_matches_full_forward(arch):
    """Incremental prefill+decode logits == full forward logits."""
    cfg = reduce_for_smoke(get_config(arch))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    B, T = 2, 12
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    prefix = None
    P = cfg.num_prefix_tokens or 0
    if P:
        prefix = jax.random.normal(key, (B, P, cfg.d_model))
    masks = _masks(cfg, key, B)

    h_full, _, _ = forward(params, toks, cfg, prefix_embeds=prefix,
                           profile_masks=masks)
    full_logits = lm_logits(params, h_full[:, -1:, :], cfg)

    cache = init_cache(cfg, B, 32)
    h_pre, cache, _ = forward(params, toks[:, :-1], cfg, prefix_embeds=prefix,
                              profile_masks=masks, cache=cache, cache_pos=0)
    h_dec, cache, _ = forward(params, toks[:, -1:], cfg, profile_masks=masks,
                              cache=cache, cache_pos=T - 1 + P)
    dec_logits = lm_logits(params, h_dec, cfg)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-3, atol=2e-3)


def test_gemma3_local_layers_have_window():
    """Sliding-window mask changes outputs when context exceeds the window."""
    cfg = reduce_for_smoke(get_config("gemma3-27b")).with_(
        sliding_window=4, global_every=2)
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size)
    h1, _, _ = forward(params, toks, cfg)
    # same tokens but distant past perturbed: only global layers may see it
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    h2, _, _ = forward(params, toks2, cfg)
    assert not np.allclose(np.asarray(h1[:, -1]), np.asarray(h2[:, -1]))


def test_moe_local_sort_matches_dense():
    cfg = reduce_for_smoke(get_config("qwen3-moe-30b-a3b")).with_(
        capacity_factor=8.0)  # high capacity -> no drops
    key = jax.random.key(0)
    from repro.models.moe import init_moe, moe_apply
    p = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    y1, _ = moe_apply(p, x, cfg)
    y2, _ = moe_apply(p, x, cfg.with_(moe_impl="dense"))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_sparse_mask_path_matches_dense():
    """forward() with k-sparse hard masks == dense k-hot weights."""
    from repro.core import masks as M
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    table = XP.init_profile_table(key, cfg)
    prof = XP.gather_profiles(table, jnp.array([0, 1]))
    xp = cfg.xpeft
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    wa, wb = XP.profile_mask_weights(prof, xp, training=False)
    dense = {"w_a": wa, "w_b": wb, "ln_scale": prof["ln_scale"],
             "ln_bias": prof["ln_bias"]}
    h1, _, _ = forward(params, toks, cfg, profile_masks=dense)
    ia = M.mask_indices(M.binarize(prof["mA"], xp.k), xp.k)
    ib = M.mask_indices(M.binarize(prof["mB"], xp.k), xp.k)
    w = jnp.full(ia.shape, 1.0 / xp.k, jnp.float32)
    sparse = {"idx_a": ia, "w_a": w, "idx_b": ib, "w_b": w,
              "ln_scale": prof["ln_scale"], "ln_bias": prof["ln_bias"]}
    h2, _, _ = forward(params, toks, cfg, profile_masks=sparse)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)
