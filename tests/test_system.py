"""End-to-end behaviour of the paper's system.

The paper's central claims, validated on the synthetic multi-profile tasks
(DESIGN.md §9): (1) X-PEFT mask training improves over head_only with the
same budget; (2) profiles specialize (a profile's masks beat another
profile's masks on its own data); (3) hard masks freeze to byte-level
records that reproduce the trained behaviour.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import masks as M
from repro.core.profiles import ProfileStore
from repro.data import ProfileClassification
from repro.train.steps import (init_train_state, loss_for_batch,
                               make_train_step)


@pytest.fixture(scope="module")
def trained():
    cfg = reduce_for_smoke(get_config("bert-base-xpeft")).with_(
        num_labels=4, vocab_size=128).with_xpeft(
        num_adapters=16, k=4, max_profiles=4)
    key = jax.random.key(0)
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=2, seed=7)
    state = init_train_state(key, cfg, "xpeft")
    step = jax.jit(make_train_step(cfg, "xpeft", lr=5e-2))
    losses = []
    for i in range(60):
        b = data.sample(i, 16, 24)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, m = step(state, batch, jax.random.key(i))
        losses.append(float(m["loss"]))
    return cfg, data, state, losses


def test_loss_decreases_multi_profile(trained):
    cfg, data, state, losses = trained
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first * 0.9, (first, last)


def test_profiles_specialize(trained):
    """Evaluating profile-0 data with profile-1's masks must be worse."""
    cfg, data, state, _ = trained
    b = data.sample(999, 32, 24, profile_ids=[0] * 32)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    _, own = loss_for_batch(state["frozen"], state["trainable"], batch, cfg,
                            "xpeft", jax.random.key(0), training=False)
    swapped = dict(batch)
    swapped["profile_ids"] = jnp.ones(32, jnp.int32)  # wrong profile's masks
    _, other = loss_for_batch(state["frozen"], state["trainable"], swapped,
                              cfg, "xpeft", jax.random.key(0),
                              training=False)
    assert float(own["accuracy"]) > float(other["accuracy"]), \
        (float(own["accuracy"]), float(other["accuracy"]))


def test_hard_masks_freeze_to_bytes_and_reproduce(trained):
    cfg, data, state, _ = trained
    xp = cfg.xpeft
    store = ProfileStore(cfg.num_layers, xp.num_adapters, xp.bottleneck,
                         "hard", xp.k)
    prof0 = jax.tree.map(lambda t: t[0], state["trainable"]["table"])
    store.add_profile(0, prof0)
    assert store.bytes_per_profile() == 2 * ((xp.num_adapters + 7) // 8) \
        * cfg.num_layers
    wa, wb = store.mask_weights(0)
    want = M.khot_from_topk(prof0["mA"], xp.k)
    np.testing.assert_allclose(np.asarray(wa), np.asarray(want), atol=1e-6)


def test_xpeft_beats_head_only(trained):
    """Paper Table 2 ordering: x_peft >= head_only under equal budgets."""
    cfg, data, _, xp_losses = trained
    key = jax.random.key(1)
    state = init_train_state(key, cfg, "head_only")
    step = jax.jit(make_train_step(cfg, "head_only", lr=5e-2))
    ho_losses = []
    for i in range(60):
        b = data.sample(i, 16, 24)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state, m = step(state, batch, jax.random.key(i))
        ho_losses.append(float(m["loss"]))
    assert np.mean(xp_losses[-10:]) < np.mean(ho_losses[-10:]) * 1.05
