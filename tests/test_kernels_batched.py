"""Batched Pallas kernels vs jnp oracles + the ops dispatch layer."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.fused_adapter_batched import fused_adapter_batched
from repro.kernels.mask_aggregate import mask_aggregate_batched


@pytest.mark.parametrize("B,T,d,b,dt", [
    (2, 128, 256, 64, jnp.bfloat16),
    (4, 1, 512, 48, jnp.float32),       # decode step: T=1, one row per slot
    (3, 64, 256, 32, jnp.float32),
    (8, 1, 256, 64, jnp.bfloat16),      # decode step, bf16
])
def test_fused_adapter_batched_sweep(B, T, d, b, dt):
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (B, T, d), dt)
    a = jax.random.normal(ks[1], (B, d, b), dt) / np.sqrt(d)
    bb = jax.random.normal(ks[2], (B, b, d), dt) * 0.02
    ls = 1 + 0.1 * jax.random.normal(ks[3], (B, b), jnp.float32)
    lb = 0.1 * jax.random.normal(ks[4], (B, b), jnp.float32)
    got = fused_adapter_batched(x, a, bb, ls, lb, block_t=64, interpret=True)
    want = ref.fused_adapter_batched_ref(x, a, bb, ls, lb)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=4e-2, atol=4e-2)


def test_fused_adapter_batched_shared_broadcast():
    """2-D Â/B̂ broadcast to every batch row without materializing [B,d,b]."""
    ks = jax.random.split(jax.random.key(1), 5)
    B, T, d, b = 3, 16, 64, 16
    x = jax.random.normal(ks[0], (B, T, d), jnp.float32)
    a = jax.random.normal(ks[1], (d, b)) * 0.1
    bb = jax.random.normal(ks[2], (b, d)) * 0.1
    ls, lb = jnp.ones(b), jnp.zeros(b)
    got = fused_adapter_batched(x, a, bb, ls, lb, interpret=True)
    want = jnp.stack([ref.fused_adapter_ref(x[i], a, bb, ls, lb)
                      for i in range(B)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_fused_adapter_batched_matches_per_row_kernel():
    """Batched launch == B unbatched launches (vmap-replacement parity)."""
    from repro.kernels.fused_adapter import fused_adapter
    ks = jax.random.split(jax.random.key(2), 5)
    B, T, d, b = 2, 32, 64, 16
    x = jax.random.normal(ks[0], (B, T, d), jnp.float32)
    a = jax.random.normal(ks[1], (B, d, b)) * 0.1
    bb = jax.random.normal(ks[2], (B, b, d)) * 0.1
    ls = jnp.ones((B, b)).at[1].mul(1.1)
    lb = jnp.zeros((B, b)).at[0].add(0.05)
    got = fused_adapter_batched(x, a, bb, ls, lb, block_t=16, interpret=True)
    want = jnp.stack([fused_adapter(x[i], a[i], bb[i], ls[i], lb[i],
                                    block_t=16, interpret=True)
                      for i in range(B)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("N,d,b,k,P,dt", [
    (32, 256, 64, 8, 2, jnp.bfloat16),
    (64, 512, 48, 16, 3, jnp.float32),
    (100, 768, 48, 50, 2, jnp.bfloat16),   # paper dims
])
def test_mask_aggregate_batched_sweep(N, d, b, k, P, dt):
    ks = jax.random.split(jax.random.key(3), 2 + P)
    bank = jax.random.normal(ks[0], (N, d, b), dt)
    idx = jnp.stack([jax.random.permutation(ks[2 + p], N)[:k]
                     for p in range(P)]).astype(jnp.int32)
    w = jax.random.uniform(ks[1], (P, k), jnp.float32)
    got = mask_aggregate_batched(bank, idx, w, block_d=128, interpret=True)
    want = ref.mask_aggregate_batched_ref(bank, idx, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_ops_ndim3_dispatch():
    """ops picks the batched kernels for ndim-3 inputs (no vmap-of-kernel)."""
    ks = jax.random.split(jax.random.key(4), 5)
    B, T, d, b = 2, 8, 32, 8
    x = jax.random.normal(ks[0], (B, T, d), jnp.float32)
    a = jax.random.normal(ks[1], (B, d, b)) * 0.1
    bb = jax.random.normal(ks[2], (B, b, d)) * 0.1
    ls, lb = jnp.ones((B, b)), jnp.zeros((B, b))
    for impl in ("ref", "interpret"):
        got = ops.fused_adapter(x, a, bb, ls, lb, impl=impl)
        want = ref.fused_adapter_batched_ref(x, a, bb, ls, lb)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
    bank = jax.random.normal(ks[3], (16, 32, 8))
    idx = jnp.stack([jnp.arange(4), jnp.arange(4, 8)]).astype(jnp.int32)
    w = jnp.ones((2, 4)) / 4
    for impl in ("ref", "interpret"):
        got = ops.mask_aggregate_batched(bank, idx, w, impl=impl)
        want = ref.mask_aggregate_batched_ref(bank, idx, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


def test_resolve_impl_rules():
    assert ops.resolve_impl("pallas") == "pallas"
    assert ops.resolve_impl("interpret") == "interpret"
    assert ops.resolve_impl("ref") == "ref"
    # this container has no TPU: auto must pick the jnp reference
    assert ops.resolve_impl("auto") == ("pallas" if jax.default_backend()
                                        == "tpu" else "ref")
    with pytest.raises(ValueError):
        ops.resolve_impl("cuda")
