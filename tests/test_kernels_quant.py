"""Dequant-fused Pallas kernels: parity with their jnp refs + dispatch.

The quant kernels and refs share quant.schemes.dequant_block and the same
fp32 op order, so every dequantized TERM is asserted BITWISE
(assert_array_equal via one-hot weights / k=1 grids). Multi-term fp32
reductions are asserted at <= 5e-7 absolute instead: XLA CPU contracts
mul+add chains into FMAs at LLVM codegen per fusion, and the fusion
layout necessarily differs between a pallas program and a jnp program
(verified empirically — optimization_barrier and bitcast round-trips are
both simplified through), so the last ulp of an accumulation is backend
scheduling, not kernel semantics. 5e-7 is ~4 orders below the ~1e-3
quantization step the schemes introduce.
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.fused_adapter_quant import fused_adapter_quant_batched
from repro.kernels.mask_aggregate_quant import mask_aggregate_quant_batched
from repro.quant import schemes as QS


def _qbank(N, d, b, scheme, key=0, group=32):
    bank = 0.05 * jax.random.normal(jax.random.key(key), (N, d, b),
                                    jnp.float32)
    rec = QS.quantize(bank, scheme, group=group)
    return bank, rec["q"], rec["scale"]


@pytest.mark.parametrize("scheme,N,d,b,k,P,block_d", [
    ("int8", 32, 256, 64, 8, 2, 128),
    ("int8", 16, 64, 4, 2, 3, 64),       # smoke-config dims
    ("int4", 32, 256, 64, 8, 2, 128),
    ("int4", 16, 64, 4, 2, 3, 32),
    ("int4", 24, 128, 48, 50, 2, 128),   # paper-ish dims, k > N/2 repeats
])
def test_mask_aggregate_quant_term_bitwise_sum_tight(scheme, N, d, b, k, P,
                                                     block_d):
    _, q, s = _qbank(N, d, b, scheme, key=1)
    ks = jax.random.split(jax.random.key(2), P + 1)
    idx = jnp.stack([jax.random.randint(ks[p], (k,), 0, N)
                     for p in range(P)]).astype(jnp.int32)
    w = jax.random.uniform(ks[-1], (P, k), jnp.float32)
    # every individual dequantized term is BITWISE equal to the ref's
    # (one-hot weights make the accumulation a pure select)
    for ki in (0, k - 1):
        onehot = jnp.zeros_like(w).at[:, ki].set(w[:, ki])
        got = mask_aggregate_quant_batched(q, s, idx, onehot, scheme=scheme,
                                           block_d=block_d, interpret=True)
        want = ref.mask_aggregate_quant_batched_ref(q, s, idx, onehot,
                                                    scheme=scheme)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # the full k-term fp32 reduction: identical order, FMA-contraction ulps
    got = mask_aggregate_quant_batched(q, s, idx, w, scheme=scheme,
                                       block_d=block_d, interpret=True)
    want = ref.mask_aggregate_quant_batched_ref(q, s, idx, w, scheme=scheme)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=5e-7)


@pytest.mark.parametrize("scheme", ["int8", "int4"])
def test_mask_aggregate_quant_close_to_fp32(scheme):
    """Dequantized aggregation stays within the quantization error budget
    of the exact fp32 aggregation (the admission-quality bound)."""
    N, d, b, k, P = 32, 128, 32, 8, 2
    bank, q, s = _qbank(N, d, b, scheme, key=3)
    idx = jnp.stack([jnp.arange(k), jnp.arange(k, 2 * k)]).astype(jnp.int32)
    w = jnp.full((P, k), 1.0 / k, jnp.float32)
    got = ref.mask_aggregate_quant_batched_ref(q, s, idx, w, scheme=scheme)
    want = ref.mask_aggregate_batched_ref(bank, idx, w)
    # elementwise bound: each of the k averaged rows errs <= ~step/2
    step = {"int8": 1 / 127, "int4": 1 / 7}[scheme]
    bound = 0.6 * step * float(jnp.abs(bank).max())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=bound)


@pytest.mark.parametrize("scheme,B,T,d,b,block_t", [
    ("int8", 2, 1, 64, 4, 256),          # decode step, smoke dims
    ("int8", 3, 32, 128, 16, 32),
    ("int4", 4, 1, 256, 64, 256),
    ("int4", 2, 16, 64, 8, 16),
])
def test_fused_adapter_quant_parity(scheme, B, T, d, b, block_t):
    ks = jax.random.split(jax.random.key(4), 5)
    x = jax.random.normal(ks[0], (B, T, d), jnp.float32)
    a = jax.random.normal(ks[1], (B, d, b)) / np.sqrt(d)
    bb = jax.random.normal(ks[2], (B, b, d)) * 0.02
    qa = QS.quantize(a, scheme)
    qb = QS.quantize(bb, scheme)
    ls = 1 + 0.1 * jax.random.normal(ks[3], (B, b), jnp.float32)
    lb = 0.1 * jax.random.normal(ks[4], (B, b), jnp.float32)
    got = fused_adapter_quant_batched(
        x, qa["q"], qa["scale"], qb["q"], qb["scale"], ls, lb,
        scheme=scheme, block_t=block_t, interpret=True)
    want = jax.jit(functools.partial(ref.fused_adapter_quant_batched_ref,
                                     scheme=scheme))(
        x, qa["q"], qa["scale"], qb["q"], qb["scale"], ls, lb)
    # both backends run the same fp32 op sequence; the dots are gemm-call
    # boundaries so only elementwise fusion ulps can differ
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=0, atol=5e-6)


def test_fused_adapter_quant_close_to_unquantized():
    """Dequant-fused output tracks the bf16/fp32 fused adapter closely —
    the decode-quality bound behind the >= 99%% token-agreement criterion."""
    B, T, d, b = 2, 8, 64, 16
    ks = jax.random.split(jax.random.key(5), 3)
    x = jax.random.normal(ks[0], (B, T, d), jnp.float32)
    a = jax.random.normal(ks[1], (B, d, b)) / np.sqrt(d)
    bb = jax.random.normal(ks[2], (B, b, d)) * 0.02
    ls, lb = jnp.ones((B, b)), jnp.zeros((B, b))
    want = ref.fused_adapter_batched_ref(x, a, bb, ls, lb)
    for scheme in ("int8", "int4"):
        qa, qb = QS.quantize(a, scheme), QS.quantize(bb, scheme)
        got = ref.fused_adapter_quant_batched_ref(
            x, qa["q"], qa["scale"], qb["q"], qb["scale"], ls, lb,
            scheme=scheme)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=0.1, atol=0.05)


# ----------------------------------------------------------------------------
# ops dispatch table (satellite): quant routes + strict impl validation
# ----------------------------------------------------------------------------

def test_ops_quant_dispatch_interpret_matches_ref():
    N, d, b, k, P = 16, 64, 8, 4, 2
    _, q, s = _qbank(N, d, b, "int8", key=6)
    idx = jnp.stack([jnp.arange(k), jnp.arange(k, 2 * k)]).astype(jnp.int32)
    w = jnp.ones((P, k)) / k
    outs = {impl: ops.mask_aggregate_quant_batched(q, s, idx, w,
                                                   scheme="int8", impl=impl)
            for impl in ("ref", "interpret")}
    np.testing.assert_allclose(np.asarray(outs["ref"]),
                               np.asarray(outs["interpret"]),
                               rtol=0, atol=5e-7)

    ks = jax.random.split(jax.random.key(7), 3)
    x = jax.random.normal(ks[0], (P, 4, d), jnp.float32)
    a = jax.random.normal(ks[1], (P, d, b)) * 0.1
    bb = jax.random.normal(ks[2], (P, b, d)) * 0.1
    qa, qb = QS.quantize(a, "int4"), QS.quantize(bb, "int4")
    ls, lb = jnp.ones((P, b)), jnp.zeros((P, b))
    outs = {impl: jax.jit(functools.partial(
        ops.fused_adapter_quant, scheme="int4", impl=impl))(
        x, qa["q"], qa["scale"], qb["q"], qb["scale"], ls, lb)
        for impl in ("ref", "interpret")}
    np.testing.assert_allclose(np.asarray(outs["ref"]),
                               np.asarray(outs["interpret"]),
                               rtol=0, atol=5e-6)


def test_ops_quant_rejects_bad_scheme_and_shape():
    z3 = jnp.zeros((2, 2, 2))
    with pytest.raises(ValueError, match="int4"):
        ops.mask_aggregate_quant_batched(
            jnp.zeros((2, 2, 2), jnp.int8), jnp.zeros((2, 2), jnp.float16),
            jnp.zeros((1, 1), jnp.int32), jnp.zeros((1, 1)), scheme="fp8")
    with pytest.raises(ValueError, match="batched-only"):
        ops.fused_adapter_quant(
            jnp.zeros((2, 2)), z3, jnp.zeros((2, 2)), z3, jnp.zeros((2, 2)),
            jnp.zeros((2, 2)), jnp.zeros((2, 2)), scheme="int8")


def test_resolve_impl_error_lists_valid_impls():
    """Unrecognized impl strings must raise (never silently fall back) and
    the message must name every valid impl."""
    with pytest.raises(ValueError) as e:
        ops.resolve_impl("cuda")
    for impl in ops.IMPLS:
        assert impl in str(e.value)
