"""Optimizer substrate: AdamW math, clipping, schedule."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         linear_decay_schedule)


def test_adamw_first_step_matches_reference():
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.5])}
    st = adamw_init(p)
    new_p, st2 = adamw_update(g, st, p, lr=0.1, b1=0.9, b2=0.999, eps=1e-8)
    # bias-corrected first step: mhat = g, vhat = g^2 -> delta ~ sign(g)
    expected = np.array([1.0, -2.0]) - 0.1 * np.array([0.5, 0.5]) / (
        np.abs([0.5, 0.5]) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expected, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_weight_decay_shrinks_params():
    p = {"w": jnp.array([10.0])}
    g = {"w": jnp.array([0.0])}
    st = adamw_init(p)
    new_p, _ = adamw_update(g, st, p, lr=0.1, weight_decay=0.1)
    assert float(new_p["w"][0]) < 10.0


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 5.0, rtol=1e-6)
    total = np.sqrt(float(clipped["a"][0]) ** 2 + float(clipped["b"][0]) ** 2)
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_linear_decay_schedule():
    s = linear_decay_schedule(1.0, 100, warmup_steps=10)
    assert float(s(0)) == 0.0
    np.testing.assert_allclose(float(s(10)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(s(55)), 0.5, rtol=1e-5)
    assert float(s(100)) == 0.0


def test_convergence_on_quadratic():
    target = jnp.array([3.0, -1.0])
    p = {"w": jnp.zeros(2)}
    st = adamw_init(p)
    for _ in range(300):
        g = {"w": 2 * (p["w"] - target)}
        p, st = adamw_update(g, st, p, lr=0.05)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target),
                               atol=0.05)
