"""Resilience layer: retry/backoff, FaultPlan determinism, store record
integrity + quarantine, degraded bare-PLM serving, gang-step finite guard,
poisoned-profile quarantine, checkpoint checksum fallback."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.resilience import (CheckpointCorruptError, FaultPlan,
                              InjectedHydrationError, RecordIntegrityError,
                              RetryPolicy, array_crc, retry_with_backoff)
from repro.serve.engine import Request, ServeEngine

FAST_RETRY = RetryPolicy(attempts=3, delay_s=1e-4, max_delay_s=1e-3,
                         deadline_s=5.0)


# ------------------------------------------------------------------- retry

def test_retry_succeeds_after_transient_failures():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return "ok"

    sleeps = []
    assert retry_with_backoff(flaky, policy=FAST_RETRY,
                              retry_on=(RuntimeError,),
                              sleep=sleeps.append) == "ok"
    assert len(calls) == 3 and len(sleeps) == 2
    assert sleeps[1] > sleeps[0]  # exponential backoff


def test_retry_raises_last_error_and_is_deterministic():
    def always():
        raise ValueError("nope")

    sleeps_a, sleeps_b = [], []
    for sleeps in (sleeps_a, sleeps_b):
        with pytest.raises(ValueError):
            retry_with_backoff(always, policy=FAST_RETRY, seed=7,
                               retry_on=(ValueError,), sleep=sleeps.append)
    assert sleeps_a == sleeps_b  # seeded jitter replays exactly


def test_retry_non_matching_exception_propagates_at_once():
    calls = []

    def boom():
        calls.append(1)
        raise KeyError("not retryable")

    with pytest.raises(KeyError):
        retry_with_backoff(boom, policy=FAST_RETRY, retry_on=(ValueError,))
    assert len(calls) == 1


def test_retry_respects_deadline():
    """A retry whose backoff would start past the deadline is abandoned."""
    t = [0.0]
    policy = RetryPolicy(attempts=10, delay_s=0.5, backoff=1.0,
                         max_delay_s=0.5, jitter=0.0, deadline_s=1.0)
    calls = []

    def always():
        calls.append(1)
        raise RuntimeError("down")

    def sleep(d):
        t[0] += d

    with pytest.raises(RuntimeError):
        retry_with_backoff(always, policy=policy, retry_on=(RuntimeError,),
                           sleep=sleep, clock=lambda: t[0])
    # deadline 1.0 / delay 0.5 -> attempts at t=0, 0.5, 1.0; the 4th would
    # start at 1.5 > deadline
    assert len(calls) == 3


# --------------------------------------------------------------- FaultPlan

def test_fault_plan_is_deterministic_and_rate_accurate():
    plan = FaultPlan(seed=11, hydration_fail_rate=0.25,
                     hydration_flaky_rate=0.25)
    pids = list(range(400))
    fails = plan.persistent_fail_pids(pids)
    flaky = plan.flaky_hydration_pids(pids)
    assert fails == FaultPlan(seed=11, hydration_fail_rate=0.25,
                              hydration_flaky_rate=0.25) \
        .persistent_fail_pids(pids)
    assert not set(fails) & set(flaky)
    assert 0.15 < len(fails) / len(pids) < 0.35
    assert 0.15 < len(flaky) / len(pids) < 0.35
    # a different seed draws a different fault set
    assert fails != FaultPlan(seed=12, hydration_fail_rate=0.25,
                              hydration_flaky_rate=0.25) \
        .persistent_fail_pids(pids)


def test_fault_plan_hydration_modes():
    plan = FaultPlan(fail_pids=(1,), flaky_pids=(2,))
    with pytest.raises(InjectedHydrationError):
        plan.on_hydration(1, attempt=0)
    with pytest.raises(InjectedHydrationError):
        plan.on_hydration(1, attempt=5)   # persistent: every attempt
    with pytest.raises(InjectedHydrationError):
        plan.on_hydration(2, attempt=0)
    plan.on_hydration(2, attempt=1)       # flaky: retry succeeds
    plan.on_hydration(3, attempt=0)       # healthy pid: no-op


# ------------------------------------------------------------ store records

def _store(quant="none", n=4, L=2, N=16, b=4, k=4):
    st = ProfileStore(L, N, b, "hard", k, quant=quant)
    rng = np.random.default_rng(0)
    for pid in range(n):
        prof = dict(mA=rng.normal(size=(L, N)), mB=rng.normal(size=(L, N)),
                    ln_scale=np.ones((L, b)), ln_bias=np.zeros((L, b)))
        agg = None
        if quant != "none":
            agg = (rng.normal(size=(L, 8, b)).astype(np.float32),
                   rng.normal(size=(L, b, 8)).astype(np.float32))
        st.add_profile(pid, prof, agg=agg)
    return st


def test_store_checksums_catch_corruption_and_quarantine():
    st = _store()
    ev = FaultPlan(seed=5, corrupt_pids=(1,)).corrupt_store(st)
    assert len(ev) == 1 and ev[0]["pid"] == 1
    with pytest.raises(RecordIntegrityError):
        st.mask_weights(1)
    assert st.quarantined_ids() == [1]
    # quarantined stays quarantined on every later hydration attempt
    with pytest.raises(RecordIntegrityError):
        st.sparse_indices(1)
    # healthy records unaffected
    st.mask_weights(0)
    assert st.integrity_stats()["corrupt_detected"] == 1


def test_store_heals_on_regraduation():
    st = _store()
    FaultPlan(seed=5, corrupt_pids=(2,)).corrupt_store(st)
    with pytest.raises(RecordIntegrityError):
        st.mask_weights(2)
    rng = np.random.default_rng(9)
    st.add_profile(2, dict(mA=rng.normal(size=(2, 16)),
                           mB=rng.normal(size=(2, 16)),
                           ln_scale=np.ones((2, 4)),
                           ln_bias=np.zeros((2, 4))))
    st.mask_weights(2)  # re-graduation replaces the record: healed
    assert st.quarantined_ids() == []


def test_store_quant_agg_corruption_sheds_payload_not_profile():
    """Corruption confined to the quantized agg payload drops the agg
    fields but keeps the profile servable via the sparse bank-read path."""
    st = _store(quant="int8")
    assert st.has_quant_record(1)
    FaultPlan(seed=5, corrupt_pids=(1,),
              corrupt_agg_only=True).corrupt_store(st)
    assert not st.has_quant_record(1)   # shed, not quarantined
    assert st.quarantined_ids() == []
    st.mask_weights(1)                  # masks intact -> still hydrates
    assert st.integrity_stats()["agg_dropped"] == [1]
    assert "agg_a_q" not in st._rec[1]


def test_store_save_load_roundtrip_verifies_checksums(tmp_path):
    st = _store()
    FaultPlan(seed=5, corrupt_pids=(3,)).corrupt_store(st)
    with pytest.raises(RecordIntegrityError):
        st.ln_affines([3])
    path = str(tmp_path / "store.npz")
    st.save(path)   # quarantined pid 3 is never persisted
    st2 = ProfileStore.load(path)
    assert st2.profile_ids() == [0, 1, 2]
    assert st2.quarantined_ids() == []
    for pid in st2.profile_ids():  # crcs round-trip and verify clean
        st2.check_record(pid)
    # on-disk corruption after load is still caught at hydration
    st2._rec[0]["mB"] = st2._rec[0]["mB"].copy()
    st2._rec[0]["mB"][-1] ^= 0x55
    with pytest.raises(RecordIntegrityError):
        st2.batch_mask_weights([0])


def test_array_crc_covers_dtype_and_shape():
    a = np.arange(8, dtype=np.int32)
    assert array_crc(a) != array_crc(a.astype(np.int64))
    assert array_crc(a) != array_crc(a.reshape(2, 4))
    assert array_crc(a) == array_crc(a.copy())


# -------------------------------------------------------- degraded serving

@pytest.fixture(scope="module")
def serve_setup():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    table = XP.init_profile_table(key, cfg)
    return cfg, params, table


def _serve_store(cfg, table, n=4):
    st = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                      cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    for pid in range(n):
        st.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return st


def _serve(cfg, params, store, plan):
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      fault_plan=plan, retry_policy=FAST_RETRY)
    reqs = [Request(uid=i, prompt=np.arange(4 + i % 3) % cfg.vocab_size,
                    profile_id=i % 4, max_new_tokens=5) for i in range(8)]
    eng.run_until_drained(list(reqs))
    assert all(r.done for r in reqs)
    return eng, reqs


def test_degraded_wave_completes_and_peers_are_bitwise_equal(serve_setup):
    cfg, params, table = serve_setup
    ref_eng, ref = _serve(cfg, params, _serve_store(cfg, table), None)
    plan = FaultPlan(fail_pids=(1,), flaky_pids=(2,))
    eng, reqs = _serve(cfg, params, _serve_store(cfg, table), plan)
    stats = eng.serve_stats()
    # every pid-1 request degraded; nothing else did
    assert [r.uid for r in reqs if r.degraded] == [1, 5]
    assert stats["degraded_requests"] == 2
    # flaky pid 2 recovered via retry (never degraded)
    assert stats["hydration_retries"] > 0
    assert not any(r.degraded for r in reqs if r.profile_id == 2)
    # unaffected requests decode bitwise-identically to the no-fault run
    for r, rr in zip(reqs, ref):
        if not r.degraded:
            assert r.generated == rr.generated, r.uid
    # degraded entries must not poison the cache for later recovery
    assert eng.profile_cache.peek(1) is None


def test_corrupt_record_is_never_served(serve_setup):
    cfg, params, table = serve_setup
    store = _serve_store(cfg, table)
    FaultPlan(seed=5, corrupt_pids=(3,)).corrupt_store(store)
    eng, reqs = _serve(cfg, params, store, None)
    assert all(r.done for r in reqs)
    assert all(r.degraded for r in reqs if r.profile_id == 3)
    assert eng.serve_stats()["quarantined_profiles"] == 1
    assert eng.profile_cache.peek(3) is None


def test_zero_adapter_entry_matches_bare_plm(serve_setup):
    """A degraded request's decode equals X-PEFT disabled entirely —
    the zero-adapter mask IS the bare PLM, bitwise."""
    cfg, params, table = serve_setup
    store = _serve_store(cfg, table)
    prompt = np.arange(6) % cfg.vocab_size

    eng = ServeEngine(cfg, params, store, max_slots=1, max_seq=64,
                      fault_plan=FaultPlan(fail_pids=(0,)),
                      retry_policy=FAST_RETRY)
    r_deg = Request(uid=0, prompt=prompt, profile_id=0, max_new_tokens=6)
    eng.run_until_drained([r_deg])
    assert r_deg.degraded

    bare_cfg = cfg.with_xpeft(enabled=False)
    bare = ServeEngine(bare_cfg, params, store, max_slots=1, max_seq=64)
    r_bare = Request(uid=0, prompt=prompt, profile_id=0, max_new_tokens=6)
    bare.run_until_drained([r_bare])
    assert r_deg.generated == r_bare.generated


def test_missing_profile_degrades_instead_of_crashing(serve_setup):
    cfg, params, table = serve_setup
    store = _serve_store(cfg, table)
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      retry_policy=FAST_RETRY)
    reqs = [Request(uid=0, prompt=np.arange(5), profile_id=0,
                    max_new_tokens=4),
            Request(uid=1, prompt=np.arange(5), profile_id=999,  # unknown
                    max_new_tokens=4)]
    eng.run_until_drained(list(reqs))
    assert reqs[1].degraded and not reqs[0].degraded
    assert eng.serve_stats()["degraded_requests"] == 1


# ------------------------------------------------------- gang finite guard

def test_gang_finite_guard_isolates_poisoned_slot():
    """A NaN-poisoned slot's params and Adam moments stay bitwise-frozen
    while healthy slots update bitwise-identically to a no-fault step.

    The bitwise reference is the SAME plan with a never-firing poison
    window: injection on vs off within one compiled program. (A plan-free
    step compiles to different HLO — without the seam's where-ops XLA
    fuses the EMA multiply-adds differently, a 1-ulp compiler artifact
    that has nothing to do with the guard.)"""
    from repro.data import ProfileClassification
    from repro.train.roster import Roster, init_roster_state
    from repro.train.steps import make_gang_step

    cfg = reduce_for_smoke(get_config("bert-base-xpeft")).with_(
        num_labels=4, vocab_size=64).with_xpeft(num_adapters=8, k=2)
    S, m = 3, 2
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=S, seed=5)

    def build(plan):
        key = jax.random.key(0)
        frozen = init_lm(key, cfg)
        roster = Roster(cfg, jax.random.key(2), S)
        rstate = init_roster_state(jax.random.key(1), cfg, S)
        for s in range(S):
            rstate = roster.admit(rstate, s, s)
        step = jax.jit(make_gang_step(cfg, lr=5e-2, fault_plan=plan))
        state = {"frozen": frozen, "roster": rstate}
        pids = np.repeat(np.arange(S), m)
        b = data.sample(0, S * m, 12, profile_ids=pids)
        batch = {k: jnp.asarray(np.asarray(v).reshape((S, m) + v.shape[1:]))
                 for k, v in b.items()}
        for _ in range(3):
            state, met = step(state, batch, jax.random.key(3))
        return jax.device_get(state["roster"]), jax.device_get(met)

    clean, met0 = build(FaultPlan(poison_slots=(1,),
                                  poison_from_step=10 ** 9))
    faulty, met1 = build(FaultPlan(poison_slots=(1,)))

    assert met0["nonfinite_slots"] == 0
    assert met1["nonfinite_slots"] == 1
    assert np.isfinite(met1["loss"])  # NaN never leaks into metrics
    assert faulty["nonfinite"].tolist() == [0, 3, 0]
    assert faulty["slot_step"].tolist() == [3, 0, 3]

    def rows(tree, s):
        return [np.asarray(leaf[s]) for leaf in jax.tree.leaves(tree)]

    for s in (0, 2):  # healthy slots: bitwise-identical to the clean run
        for a, b in zip(rows(clean, s), rows(faulty, s)):
            assert np.array_equal(a, b)
    # poisoned slot: params and moments bitwise-frozen at admission values
    for key in ("trainable",):
        for a0, a1 in zip(rows(clean[key], 1), rows(faulty[key], 1)):
            assert not np.array_equal(a0, a1)  # clean DID train slot 1
    for leaf in jax.tree.leaves(faulty["opt"]["m"]) + \
            jax.tree.leaves(faulty["opt"]["v"]):
        assert np.all(np.asarray(leaf)[1] == 0.0)
    assert faulty["opt"]["step"][1] == 0


def test_poisoned_profile_quarantined_without_graduation():
    from repro.data import ProfileClassification
    from repro.train import GraduationPolicy
    from repro.train.onboarding import build_onboarding_run

    cfg = reduce_for_smoke(get_config("bert-base-xpeft")).with_(
        num_labels=4, vocab_size=64).with_xpeft(num_adapters=8, k=2)
    data = ProfileClassification(cfg.vocab_size, cfg.num_labels,
                                 num_profiles=4, seed=5)
    pol = GraduationPolicy(min_steps=3, max_steps=5, target_acc=2.0,
                           max_poison_strikes=2)
    trainer, _ = build_onboarding_run(
        cfg, data, range(4), slots=2, per_slot=2, seq_len=12, policy=pol,
        lr=5e-2, log_every=3, rng=jax.random.key(1),
        fault_plan=FaultPlan(poison_slots=(0,)))
    trainer.run_until_drained(max_steps=300)
    st = trainer.scheduler.stats()
    assert st["quarantined"] >= 1
    assert st["graduated"] + st["evicted"] + st["quarantined"] == 4
    quarantined_pids = {r["pid"] for r in trainer.scheduler.quarantined}
    # nothing of a quarantined profile reached the store
    assert not quarantined_pids & set(trainer.scheduler.store.profile_ids())


# ------------------------------------------------------ checkpoint fallback

def test_checkpoint_truncation_falls_back_to_last_good(tmp_path):
    from repro.checkpoint import CheckpointManager

    state = {"w": jnp.arange(8.0), "b": jnp.zeros((3,))}
    plan = FaultPlan(truncate_ckpt_steps=(20,))
    mgr = CheckpointManager(str(tmp_path), keep_last=5, fault_plan=plan)
    mgr.save(10, state)
    mgr.save(20, jax.tree.map(lambda x: x + 1, state))  # torn write
    with pytest.raises(CheckpointCorruptError):
        mgr.verify_step(20)
    assert mgr.latest_step() == 20          # newest on disk...
    assert mgr.latest_good_step() == 10     # ...but not restorable
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(20, abstract)
    got = mgr.restore(10, abstract)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8.0))


def test_trainer_resume_skips_corrupt_checkpoint(tmp_path):
    from repro.data import MarkovLM
    from repro.data.loader import ShardedLoader
    from repro.train.steps import init_train_state, make_train_step
    from repro.train.trainer import Trainer

    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    step = jax.jit(make_train_step(cfg, "xpeft", lr=1e-3))

    def build(plan=None):
        loader = ShardedLoader(MarkovLM(cfg.vocab_size, 4, seed=0), 2, 8)
        return Trainer(step,
                       init_train_state(jax.random.key(0), cfg, "xpeft"),
                       loader, ckpt_dir=str(tmp_path), ckpt_every=2,
                       log_every=1000, fault_plan=plan)

    t1 = build(FaultPlan(truncate_ckpt_steps=(4,)))
    t1.run(4)   # checkpoints at 2 (good) and 4 (truncated)
    t1.mgr.wait()
    assert t1.mgr.latest_step() == 4
    t2 = build()
    assert t2.try_resume()
    assert t2.step == 2  # fell back past the torn step-4 checkpoint
