"""quant/schemes.py: int8/int4 quantize/dequantize roundtrips and bounds."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.quant import schemes as QS


def _rand(shape, key=0, scale=0.05):
    return scale * jax.random.normal(jax.random.key(key), shape, jnp.float32)


def test_check_scheme_rejects_unknown():
    with pytest.raises(ValueError, match="int8"):
        QS.check_scheme("fp8")
    for s in QS.SCHEMES:
        assert QS.check_scheme(s) == s


@pytest.mark.parametrize("n,group,want", [(64, 32, 32), (4, 32, 4),
                                          (48, 32, 24), (6, 4, 3),
                                          (1024, 32, 32)])
def test_group_for(n, group, want):
    assert QS.group_for(n, group) == want


def test_group_for_odd_axis_raises():
    with pytest.raises(ValueError, match="even"):
        QS.group_for(7)


def test_int8_roundtrip_error_bound():
    x = _rand((5, 12, 64), key=1)
    rec = QS.quantize(x, "int8")
    assert rec["q"].dtype == jnp.int8 and rec["scale"].dtype == jnp.float16
    assert rec["scale"].shape == (5, 12)
    deq = QS.dequantize(rec, "int8")
    err = np.abs(np.asarray(deq - x))
    # per-row bound: half a quantization step (+ slack for the clip tail
    # when the fp16 scale rounds down)
    bound = 0.6 * np.asarray(rec["scale"], np.float32)[..., None] + 1e-8
    assert (err <= bound).all(), err.max()


@pytest.mark.parametrize("n,group", [(64, 32), (4, 32), (48, 16)])
def test_int4_roundtrip_error_bound(n, group):
    x = _rand((3, 8, n), key=2)
    rec = QS.quantize(x, "int4", group=group)
    g = QS.group_for(n, group)
    assert rec["q"].dtype == jnp.uint8 and rec["q"].shape == (3, 8, n // 2)
    assert rec["scale"].shape == (3, 8, n // g)
    deq = QS.dequantize(rec, "int4")
    assert deq.shape == x.shape
    sc = np.repeat(np.asarray(rec["scale"], np.float32), g, axis=-1)
    err = np.abs(np.asarray(deq - x))
    assert (err <= 0.6 * sc + 1e-8).all(), err.max()


def test_int4_pack_unpack_exact():
    q = jnp.arange(-8, 8, dtype=jnp.int32).reshape(2, 8)
    np.testing.assert_array_equal(np.asarray(QS.unpack_int4(QS.pack_int4(q))),
                                  np.asarray(q))


def test_zero_rows_quantize_to_zero():
    x = jnp.zeros((2, 16))
    for scheme in ("int8", "int4"):
        rec = QS.quantize(x, scheme, group=8)
        deq = QS.dequantize(rec, scheme)
        assert not np.isnan(np.asarray(deq)).any()
        np.testing.assert_array_equal(np.asarray(deq), 0.0)


def test_quant_spec_matches_quantize_shapes():
    x = _rand((6, 10), key=3)
    for scheme in ("int8", "int4"):
        qs, qdt, ss = QS.quant_spec(x.shape, scheme, group=4)
        rec = QS.quantize(x, scheme, group=4)
        assert rec["q"].shape == qs and rec["q"].dtype == qdt
        assert rec["scale"].shape == ss


def test_quantize_bank_names_and_bytes():
    bank = {"bank_a": _rand((2, 4, 16, 8), key=4),
            "bank_b": _rand((2, 4, 8, 16), key=5)}
    q8 = QS.quantize_bank(bank, "int8")
    assert set(q8) == {"bank_a_q", "bank_a_scale", "bank_b_q", "bank_b_scale"}
    assert q8["bank_a_q"].shape == (2, 4, 16, 8)
    assert q8["bank_a_scale"].shape == (2, 4, 16)
    q4 = QS.quantize_bank(bank, "int4", group=8)
    assert q4["bank_a_q"].shape == (2, 4, 16, 4)       # b=8 packed
    assert q4["bank_b_q"].shape == (2, 4, 8, 8)        # d=16 packed
    # true byte counts: int8 ~= half of bf16, int4 ~= a quarter + scales
    bf16 = sum(v.size * 2 for v in bank.values())
    n8 = sum(np.asarray(v).nbytes for v in q8.values())
    n4 = sum(np.asarray(v).nbytes for v in q4.values())
    assert n4 < n8 < bf16


def test_dequantize_is_jit_safe():
    x = _rand((4, 32), key=6)
    for scheme in ("int8", "int4"):
        rec = QS.quantize(x, scheme, group=16)
        eager = QS.dequantize(rec, scheme)
        jitted = jax.jit(lambda r, s=scheme: QS.dequantize(r, s))(rec)
        np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))
