"""Self-speculative decoding (ISSUE 8): the bare PLM (zero-adapter view)
drafts, the adapted model verifies in one batched step, the accepted
prefix commits. The contract under test is BITWISE: greedy speculative
output equals non-speculative greedy per request — through admission
churn, forced preemption/resume, and an 8-fake-device mesh — while the
decode step still traces exactly once and commits > 1 token per device
step."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request


def skewed(cfg, n, *, long_new=20, seed=0):
    from benchmarks.cb_smoke import skewed_requests
    return skewed_requests(cfg, n, seed=seed, long_new=long_new)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, params, store


def drain(setup, *, gamma=0, n=6, long_new=20, quant="none", **kw):
    cfg, params, store = setup
    cfg = cfg.with_(spec_enable=gamma > 0, spec_gamma=max(gamma, 1))
    if quant != "none":
        cfg = cfg.with_xpeft(bank_quant=quant)
        store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                             cfg.xpeft.bottleneck, "hard", cfg.xpeft.k,
                             quant=quant)
        key = jax.random.key(0)
        table = XP.init_profile_table(key, cfg)
        for pid in range(3):
            store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      sync_every=4, continuous=True, page_size=16, **kw)
    reqs = skewed(cfg, n, long_new=long_new)
    eng.run_until_drained(reqs)
    assert all(r.done for r in reqs)
    return eng, {r.uid: list(map(int, r.generated)) for r in reqs}


@pytest.fixture(scope="module")
def plain_ref(setup):
    eng, toks = drain(setup, gamma=0)
    return {"tokens": toks, "device_steps": eng.slots.device_steps}


@pytest.mark.parametrize("gamma", [1, 3])
def test_spec_greedy_bitwise_parity(setup, plain_ref, gamma):
    eng, toks = drain(setup, gamma=gamma)
    assert toks == plain_ref["tokens"]            # bitwise per request
    st = eng.serve_stats()
    assert st["step_traces"] == 1                 # one compiled program
    # the perf claim: the same tokens in fewer device steps
    assert eng.slots.device_steps < plain_ref["device_steps"]
    assert st["committed_per_device_step"] > 1.0
    assert st["committed_tokens"] == st["decode_tokens"]
    spec = st["spec"]
    assert spec["gamma"] == gamma
    assert spec["drafted"] > 0
    assert 0.0 <= spec["acceptance_rate"] <= 1.0
    assert spec["per_request_acceptance"]          # per-slot/uid stats
    eng.page_alloc.check()


def test_spec_bitwise_through_preempt_resume(setup):
    """5-page pool + long budgets force preempt-to-pending swaps mid-
    generation; resumed requests must still commit bitwise the plain
    tokens (stale speculative KV beyond the commit point must never
    survive a swap), all through the one compiled step."""
    _, ref = drain(setup, gamma=0, n=6, long_new=50)
    eng, toks = drain(setup, gamma=3, n=6, long_new=50, max_pages=5)
    st = eng.serve_stats()
    assert st["preemptions"] > 0 and st["resumes"] > 0
    assert toks == ref
    assert st["step_traces"] == 1
    eng.page_alloc.check()


@pytest.mark.parametrize("quant", ["int8", "int4"])
def test_spec_parity_quantized_bank(setup, quant):
    """Speculation over the quantized adapter bank: drafts use the zero
    quantized record (dequantizes to the exact bare PLM), verify uses the
    slot's int8/int4 record — tokens still match that engine's own
    non-speculative greedy bitwise."""
    _, ref = drain(setup, gamma=0, quant=quant)
    eng, toks = drain(setup, gamma=2, quant=quant)
    assert toks == ref
    assert eng.serve_stats()["step_traces"] == 1


def test_spec_config_gates(setup):
    cfg, params, store = setup
    with pytest.raises(ValueError, match="continuous"):
        ServeEngine(cfg.with_(spec_enable=True, spec_gamma=2), params,
                    store, continuous=False)
    with pytest.raises(ValueError, match="exclusive"):
        ServeEngine(cfg.with_(spec_enable=True, spec_gamma=2,
                              decode_fused=True), params, store,
                    continuous=True)
    with pytest.raises(ValueError, match="spec_gamma"):
        ServeEngine(cfg.with_(spec_enable=True, spec_gamma=0), params,
                    store, continuous=True)


def test_spec_recurrent_arch_rejected():
    cfg = reduce_for_smoke(get_config("rwkv6-7b")).with_(
        spec_enable=True, spec_gamma=2)
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    with pytest.raises(ValueError, match="attention"):
        ServeEngine(cfg, params, store, continuous=True)


def test_spec_mesh_bitwise_parity():
    """Speculative vs plain greedy on an 8-fake-device (4 data x 2 model)
    mesh: token ids bitwise equal, one trace each, tokens-per-step > 1.
    Subprocess: never set device-count flags in this process."""
    code = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    import jax
    from repro.configs import get_config, reduce_for_smoke
    from repro.core import xpeft as XP
    from repro.core.profiles import ProfileStore
    from repro.launch.mesh import make_mesh_compat
    from repro.models import init_lm
    from repro.serve.engine import ServeEngine
    from benchmarks.cb_smoke import skewed_requests

    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    mesh = make_mesh_compat((4, 2), ("data", "model"))
    out = {}
    for gamma in (0, 3):
        c = cfg.with_(spec_enable=gamma > 0, spec_gamma=max(gamma, 1))
        eng = ServeEngine(c, params, store, max_slots=4, max_seq=64,
                          sync_every=4, continuous=True, page_size=16,
                          mesh=mesh)
        reqs = skewed_requests(c, 6, seed=0, long_new=20)
        eng.run_until_drained(reqs)
        out[gamma] = {r.uid: list(map(int, r.generated)) for r in reqs}
        st = eng.serve_stats()
        assert st["step_traces"] == 1, st["step_traces"]
        if gamma:
            assert st["committed_per_device_step"] > 1.0
    assert out[3] == out[0], "mesh spec tokens diverge"
    print("mesh spec parity ok")
    """)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=600)
    assert r.returncode == 0, f"STDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    assert "mesh spec parity ok" in r.stdout
