"""The layered serving subsystem: scheduler bucketing, device-resident slot
state + windowed host syncs, profile-cache LRU accounting, pow2 helpers,
and a recurrent-arch (exact-length prefill) engine smoke."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.serve.engine import Request, ServeEngine
from repro.serve.profile_cache import ProfileCache, entry_nbytes
from repro.serve.scheduler import Scheduler
from repro.utils import pow2_bucket, pow2_count


# ---------------------------------------------------------------- pow2 utils

def test_pow2_helpers():
    assert [pow2_bucket(n) for n in (1, 8, 9, 17)] == [8, 8, 16, 32]
    assert pow2_bucket(3, floor=2) == 4
    assert [pow2_count(n) for n in (1, 2, 3, 5)] == [1, 2, 4, 8]


# ----------------------------------------------------------------- scheduler

def _req(uid, T, pid=0, max_new=4):
    return Request(uid=uid, prompt=np.arange(T, dtype=np.int64) % 97,
                   profile_id=pid, max_new_tokens=max_new)


def test_scheduler_fifo_and_bucket_grouping():
    s = Scheduler("attn")
    # head is length-5 (bucket 8); lengths 20/21 share bucket 32
    s.submit([_req(0, 5), _req(1, 20), _req(2, 6), _req(3, 21)])
    wave = s.next_batch(3)
    # FIFO: uid 0 first; its bucket-8 peer uid 2 rides along before uid 1
    assert [r.uid for r in wave] == [0, 2, 1]
    assert s.pending() == 1
    groups = s.group_by_bucket(wave)
    assert sorted(groups) == [8, 32]
    assert [r.uid for r in groups[8]] == [0, 2]


def test_scheduler_exact_length_for_recurrent():
    s = Scheduler("rwkv")
    s.submit([_req(0, 5), _req(1, 5), _req(2, 6)])
    wave = s.next_batch(3)
    groups = s.group_by_bucket(wave)
    assert sorted(groups) == [5, 6]  # exact lengths, no pow2 padding
    assert len(groups[5]) == 2


# ------------------------------------------------------------- profile cache

def _entry(scale=1):
    return {"a_hat": jnp.zeros((2, 8, 4 * scale), jnp.float32),
            "b_hat": jnp.zeros((2, 4 * scale, 8), jnp.float32),
            "ln_scale": jnp.ones((2, 4), jnp.float32),
            "ln_bias": jnp.zeros((2, 4), jnp.float32)}


def test_profile_cache_lru_eviction_by_bytes():
    one = entry_nbytes(_entry())
    cache = ProfileCache(capacity_bytes=2 * one)
    cache.put(0, _entry())
    cache.put(1, _entry())
    assert cache.get(0) is not None      # 0 is now most-recent
    cache.put(2, _entry())               # evicts 1 (LRU), not 0
    assert 1 not in cache and 0 in cache and 2 in cache
    assert cache.evictions == 1
    assert cache.bytes_used == 2 * one


def test_profile_cache_zero_capacity_disables():
    cache = ProfileCache(capacity_bytes=0)
    cache.put(0, _entry())
    assert cache.get(0) is None
    assert cache.misses == 1


def test_profile_cache_invalidate_and_stats():
    cache = ProfileCache()
    cache.put(7, _entry())
    assert cache.get(7) is not None
    assert cache.invalidate(7) and not cache.invalidate(7)
    assert cache.get(7) is None
    st = cache.stats()
    assert st["hits"] == 1 and st["misses"] == 1 and st["bytes"] == 0
    # invalidations are visible (only the successful drop counts)
    assert st["invalidations"] == 1


def test_profile_cache_rejects_oversized_put_and_counts_it():
    one = entry_nbytes(_entry())
    cache = ProfileCache(capacity_bytes=one)
    cache.put(0, _entry(scale=4))        # larger than the whole budget
    assert 0 not in cache and len(cache) == 0
    st = cache.stats()
    assert st["rejects"] == 1 and st["evictions"] == 0
    cache.put(1, _entry())               # a fitting entry still caches
    assert 1 in cache and cache.stats()["rejects"] == 1


def test_profile_cache_clear_resets_counters():
    """clear() starts a fresh measurement window: entries AND counters go
    to zero, so BENCH_serve hit-rates are comparable across runs."""
    cache = ProfileCache(capacity_bytes=entry_nbytes(_entry()))
    cache.get(0)                          # miss
    cache.put(0, _entry())
    cache.get(0)                          # hit
    cache.put(1, _entry())                # evicts 0
    cache.put(2, _entry(scale=4))         # reject
    cache.invalidate(1)
    st = cache.stats()
    assert (st["hits"], st["misses"], st["evictions"], st["rejects"],
            st["invalidations"]) == (1, 1, 1, 1, 1)
    cache.clear()
    st = cache.stats()
    assert st["entries"] == 0 and st["bytes"] == 0
    assert (st["hits"], st["misses"], st["evictions"], st["rejects"],
            st["invalidations"]) == (0, 0, 0, 0, 0)
    assert st["hit_rate"] == 0.0


# ------------------------------------------------------- engine on rwkv/ssm

@pytest.fixture(scope="module", params=["rwkv6-7b", "zamba2-1.2b"])
def recurrent_setup(request):
    cfg = reduce_for_smoke(get_config(request.param))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, params, store


def test_recurrent_engine_exact_length_prefill(recurrent_setup):
    """block_pattern != "attn": prompts prefill at EXACT length (recurrent
    state can't mask pad tokens); same-length prompts still share one
    batched prefill launch, and the engine drains correctly."""
    cfg, params, store = recurrent_setup
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      sync_every=4)
    # two length-5 prompts (one exact-length batch) + a length-7 straggler
    reqs = [_req(0, 5, pid=0), _req(1, 5, pid=1), _req(2, 7, pid=2)]
    eng.run_until_drained(list(reqs))
    for r in reqs:
        assert r.done and len(r.generated) >= 4, (r.uid, r.generated)
    st = eng.serve_stats()
    assert st["prefill_occupancy"] == 1.0  # exact batches: no pad rows
    assert st["syncs_per_token"] < 1.0


def test_recurrent_tokens_invariant_to_sync_cadence(recurrent_setup):
    """sync_every only changes WHEN the host learns tokens, never WHICH
    tokens are generated."""
    cfg, params, store = recurrent_setup
    gens = []
    for sync_every in (1, 4):
        eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                          sync_every=sync_every)
        reqs = [_req(0, 5, pid=0, max_new=6), _req(1, 6, pid=1, max_new=6)]
        eng.run_until_drained(list(reqs))
        gens.append([tuple(r.generated) for r in reqs])
    assert gens[0] == gens[1]
