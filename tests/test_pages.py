"""Page allocator + device paging helpers (serve/pages.py).

The allocator properties are checked with hypothesis when it is installed
(CI installs it); without it the same property body runs over seeded
numpy-random op sequences, so the invariants are exercised either way.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.serve.pages import (PageAllocator, PageOOM, apply_remap,
                               dense_view, pages_needed, writeback)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------- properties
def _snapshot(alloc):
    return (dict(alloc._owner),
            {o: list(ps) for o, ps in alloc._pages_of.items()},
            [list(s) for s in alloc._free])


def run_op_sequence(ops, n_pages=16, n_colors=2):
    """Interpret coded (op, a, b) triples against the allocator and a
    mirror model; after EVERY op the allocator's own audit must pass, no
    page may be double-booked, and a failed alloc must leave the state
    bitwise-untouched."""
    alloc = PageAllocator(n_pages, n_colors=n_colors)
    mirror = {}  # owner -> [pages]
    for code, a, b in ops:
        op = code % 4
        if op == 0:                                   # alloc
            owner, n = a % 6, b % (n_pages + 2)       # may exceed the pool
            before = _snapshot(alloc)
            try:
                got = alloc.alloc(n, owner, color=a % n_colors)
            except PageOOM:
                assert n > alloc.free_count()
                assert _snapshot(alloc) == before, \
                    "OOM mutated allocator state"
            else:
                booked = {p for ps in mirror.values() for p in ps}
                assert not (set(got) & booked), f"double-booked {got}"
                assert len(set(got)) == len(got) == n
                mirror.setdefault(owner, []).extend(got)
        elif op == 1:                                 # partial free
            owner = a % 6
            if mirror.get(owner):
                k = 1 + b % len(mirror[owner])
                alloc.free(mirror[owner][:k], owner)
                del mirror[owner][:k]
                if not mirror[owner]:
                    del mirror[owner]
        elif op == 2:                                 # free_owner
            owner = a % 6
            freed = alloc.free_owner(owner)
            assert sorted(freed) == sorted(mirror.pop(owner, []))
        else:                                         # compact
            remap = alloc.compact()
            mirror = {o: [remap[p] for p in ps]
                      for o, ps in mirror.items()}
        alloc.check()
        for owner, ps in mirror.items():
            assert alloc.pages_of(owner) == ps, "owner pages drifted"
    # every owner's pages are reusable after a full teardown
    for owner in list(mirror):
        alloc.free(mirror.pop(owner), owner)
    alloc.check()
    assert alloc.free_count() == n_pages
    alloc.alloc(n_pages, "reuser")                    # pool fully reusable
    alloc.check()


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 63),
                              st.integers(0, 63)), max_size=60))
    def test_allocator_properties(ops):
        run_op_sequence(ops)
else:
    def test_allocator_properties():
        rng = np.random.default_rng(0)
        for _ in range(200):
            n = int(rng.integers(0, 61))
            ops = rng.integers(0, 64, size=(n, 3))
            ops[:, 0] %= 4
            run_op_sequence([tuple(map(int, row)) for row in ops])


# ------------------------------------------------------------------- directed
def test_oom_raises_before_any_mutation():
    alloc = PageAllocator(4)
    alloc.alloc(3, "a")
    before = _snapshot(alloc)
    with pytest.raises(PageOOM):
        alloc.alloc(2, "b")
    assert _snapshot(alloc) == before
    assert alloc.stats()["oom_events"] == 1
    # the remaining page is still cleanly allocatable
    assert len(alloc.alloc(1, "b")) == 1
    alloc.check()


def test_foreign_and_double_free_raise():
    alloc = PageAllocator(4)
    pages = alloc.alloc(2, "a")
    with pytest.raises(ValueError):
        alloc.free(pages, "b")                  # foreign free
    alloc.free(pages, "a")
    with pytest.raises(ValueError):
        alloc.free(pages, "a")                  # double free
    alloc.check()


def test_freed_pages_reusable():
    alloc = PageAllocator(8)
    alloc.alloc(8, "a")
    with pytest.raises(PageOOM):
        alloc.alloc(1, "b")
    alloc.free_owner("a")
    assert sorted(alloc.alloc(8, "b")) == list(range(8))
    alloc.check()


def test_color_affinity_prefers_own_shard():
    alloc = PageAllocator(8, n_colors=2)       # colors: pages 0-3 / 4-7
    got = alloc.alloc(2, "a", color=1)
    assert all(alloc.color_of(p) == 1 for p in got)
    # exhausting the preferred color falls back without failing
    got2 = alloc.alloc(4, "b", color=1)
    assert any(alloc.color_of(p) == 0 for p in got2)
    alloc.check()


def test_compact_packs_low_and_preserves_order():
    alloc = PageAllocator(8)
    a = alloc.alloc(3, "a")
    b = alloc.alloc(3, "b")
    alloc.free_owner("a")
    remap = alloc.compact()
    assert sorted(remap) == sorted(b)
    assert alloc.pages_of("b") == [remap[p] for p in b]  # order preserved
    assert set(alloc.pages_of("b")) == set(range(3))     # packed low
    alloc.check()
    assert alloc.compact() == {0: 0, 1: 1, 2: 2}         # now identity


def test_apply_remap_preserves_dense_view():
    """compact() + apply_remap move page CONTENTS and table entries
    together: the dense view through the table is bitwise unchanged."""
    n_pages, page = 6, 4
    alloc = PageAllocator(n_pages)
    a = alloc.alloc(2, "a")
    b = alloc.alloc(2, "b")
    alloc.free_owner("a")
    pool = {"k": jnp.arange(n_pages * page, dtype=jnp.float32)
            .reshape(1, n_pages, page)}
    table_h = np.full((2, 2), n_pages, np.int32)
    table_h[0] = b                             # slot 0 owns b's pages
    before = np.asarray(dense_view(pool, jnp.asarray(table_h), page)["k"])
    remap = alloc.compact()
    pool2, table2 = apply_remap(pool, table_h, remap, n_pages)
    after = np.asarray(dense_view(pool2, jnp.asarray(table2), page)["k"])
    np.testing.assert_array_equal(before, after)
    assert (table2[1] == n_pages).all()        # sentinels stay sentinel


def test_writeback_drops_inactive_and_sentinel():
    """An inactive slot's pad-compute write and a sentinel table entry must
    both be DROPPED — a freed slot can never touch a re-owned page."""
    n_pages, page, B, S = 2, 4, 2, 8
    pool = {"k": jnp.zeros((1, n_pages, page))}
    table = jnp.full((B, S // page), n_pages, jnp.int32)
    table = table.at[0, 0].set(0)              # slot 0 owns page 0 only
    dense = {"k": jnp.ones((1, B, S))}
    lengths = jnp.array([1, 1], jnp.int32)
    out = writeback(pool, dense, table, lengths,
                    jnp.array([True, False]), page)
    got = np.asarray(out["k"])
    assert got[0, 0, 1] == 1.0                 # active slot's write landed
    assert got.sum() == 1.0                    # nothing else was touched


def test_pages_needed():
    assert pages_needed(0, 16) == 0
    assert pages_needed(1, 16) == 1
    assert pages_needed(16, 16) == 1
    assert pages_needed(17, 16) == 2
