"""Chunked gated linear attention vs the naive recurrence (the rwkv6/mamba2
engine — long-context correctness hinges on this)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.linear_attn import (clamp_lw, gla_chunked, gla_decode_step)


def naive(q, k, v, lw, bonus=None, state=None):
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    lw = clamp_lw(lw.astype(jnp.float32))
    S = jnp.zeros((B, H, dk, dv)) if state is None else state
    outs = []
    for t in range(T):
        kv = (k[:, :, t, :, None].astype(jnp.float32)
              * v[:, :, t, None, :].astype(jnp.float32))
        if bonus is None:
            S = S * jnp.exp(lw[:, :, t])[..., None] + kv
            o = jnp.einsum("bhk,bhkv->bhv", q[:, :, t].astype(jnp.float32), S)
        else:
            o = jnp.einsum("bhk,bhkv->bhv", q[:, :, t].astype(jnp.float32),
                           S + bonus[None, :, :, None] * kv)
            S = S * jnp.exp(lw[:, :, t])[..., None] + kv
        outs.append(o)
    return jnp.stack(outs, 2), S


def _inputs(seed, B=2, H=2, T=32, dk=8, dv=8, strong=False):
    ks = jax.random.split(jax.random.key(seed), 5)
    q = jax.random.normal(ks[0], (B, H, T, dk))
    k = jax.random.normal(ks[1], (B, H, T, dk))
    v = jax.random.normal(ks[2], (B, H, T, dv))
    scale = 3.0 if strong else 0.3
    lw = -scale * jnp.exp(jax.random.normal(ks[3], (B, H, T, dk)))
    u = 0.5 * jax.random.normal(ks[4], (H, dk))
    return q, k, v, lw, u


@pytest.mark.parametrize("chunk", [8, 16, 32])
@pytest.mark.parametrize("mode", ["gla", "rwkv"])
def test_chunked_matches_naive(chunk, mode):
    q, k, v, lw, u = _inputs(0)
    bonus = u if mode == "rwkv" else None
    o1, s1 = gla_chunked(q, k, v, lw, chunk=chunk, bonus=bonus)
    o2, s2 = naive(q, k, v, lw, bonus=bonus)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_strong_decay_no_overflow():
    """Secondary chunking keeps fp32 finite even for near-total decay."""
    q, k, v, lw, u = _inputs(1, T=64, strong=True)
    o, s = gla_chunked(q, k, v, lw, chunk=32, bonus=u)
    assert np.isfinite(np.asarray(o)).all()
    o2, _ = naive(q, k, v, lw, bonus=u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2),
                               rtol=1e-3, atol=1e-3)


def test_decode_step_continues_chunked():
    q, k, v, lw, u = _inputs(2, T=33)
    o_full, s_full = naive(q, k, v, lw, bonus=u)
    o_pre, s_pre = gla_chunked(q[:, :, :32], k[:, :, :32], v[:, :, :32],
                               lw[:, :, :32], chunk=16, bonus=u)
    o_dec, s_dec = gla_decode_step(q[:, :, 32], k[:, :, 32], v[:, :, 32],
                                   lw[:, :, 32], s_pre, bonus=u)
    np.testing.assert_allclose(np.asarray(o_dec),
                               np.asarray(o_full[:, :, -1]),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_dec), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(0, 10_000), st.sampled_from([8, 16]),
       st.sampled_from([16, 32]))
@settings(max_examples=8, deadline=None)
def test_property_state_carry_composition(seed, chunk, T):
    """Processing [0:T] in one call == two calls with carried state."""
    q, k, v, lw, _ = _inputs(seed, T=T)
    o_all, s_all = gla_chunked(q, k, v, lw, chunk=chunk)
    h = T // 2
    o1, s1 = gla_chunked(q[:, :, :h], k[:, :, :h], v[:, :, :h],
                         lw[:, :, :h], chunk=chunk)
    o2, s2 = gla_chunked(q[:, :, h:], k[:, :, h:], v[:, :, h:],
                         lw[:, :, h:], chunk=chunk, state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 2)),
                               np.asarray(o_all), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                               rtol=1e-4, atol=1e-4)
