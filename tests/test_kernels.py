"""Pallas kernels vs jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.fused_adapter import fused_adapter
from repro.kernels.mask_aggregate import mask_aggregate
from repro.kernels import ops


@pytest.mark.parametrize("N,d,b,k,dt", [
    (32, 256, 64, 8, jnp.bfloat16),
    (64, 512, 48, 16, jnp.float32),
    (100, 768, 48, 50, jnp.bfloat16),   # paper dims (bert-base, r=16)
    (256, 1024, 64, 50, jnp.bfloat16),  # framework defaults
    (16, 128, 128, 1, jnp.float32),     # k=1 edge
])
def test_mask_aggregate_sweep(N, d, b, k, dt):
    ks = jax.random.split(jax.random.key(0), 3)
    bank = jax.random.normal(ks[0], (N, d, b), dt)
    idx = jax.random.permutation(ks[1], N)[:k].astype(jnp.int32)
    w = jax.random.uniform(ks[2], (k,), jnp.float32)
    got = mask_aggregate(bank, idx, w, block_d=128, interpret=True)
    want = ref.mask_aggregate_ref(bank, idx, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_mask_aggregate_repeated_indices():
    """k-hot with repeated index == weight doubling (scatter semantics)."""
    bank = jnp.eye(4)[:, :, None] * jnp.ones((4, 4, 2))
    idx = jnp.array([1, 1], jnp.int32)
    w = jnp.array([0.5, 0.25], jnp.float32)
    got = mask_aggregate(bank, idx, w, block_d=4, interpret=True)
    want = ref.mask_aggregate_ref(bank, idx, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("T,d,b,dt,act", [
    (128, 256, 64, jnp.bfloat16, "gelu"),
    (512, 512, 48, jnp.float32, "gelu"),
    (256, 768, 48, jnp.bfloat16, "identity"),  # literal paper formula
    (64, 1024, 128, jnp.float32, "gelu"),
])
def test_fused_adapter_sweep(T, d, b, dt, act):
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (T, d), dt)
    a = jax.random.normal(ks[1], (d, b), dt) / np.sqrt(d)
    bb = jax.random.normal(ks[2], (b, d), dt) * 0.02
    ls = 1 + 0.1 * jax.random.normal(ks[3], (b,), jnp.float32)
    lb = 0.1 * jax.random.normal(ks[4], (b,), jnp.float32)
    got = fused_adapter(x, a, bb, ls, lb, activation=act, block_t=64,
                        interpret=True)
    want = ref.fused_adapter_ref(x, a, bb, ls, lb, activation=act)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=4e-2, atol=4e-2)


def test_ops_dispatch_and_batched():
    bank = jax.random.normal(jax.random.key(0), (16, 64, 8))
    idx = jnp.stack([jnp.arange(4), jnp.arange(4, 8)]).astype(jnp.int32)
    w = jnp.ones((2, 4)) / 4
    out = ops.mask_aggregate_batched(bank, idx, w, impl="interpret")
    assert out.shape == (2, 64, 8)
    want0 = ref.mask_aggregate_ref(bank, idx[0], w[0])
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want0),
                               rtol=1e-4, atol=1e-5)


def test_fused_adapter_matches_core_apply():
    """Kernel semantics == core.adapters.apply_adapter (the model path)."""
    from repro.core.adapters import apply_adapter
    ks = jax.random.split(jax.random.key(2), 3)
    x = jax.random.normal(ks[0], (32, 64), jnp.float32)
    a = jax.random.normal(ks[1], (64, 16)) * 0.1
    b = jax.random.normal(ks[2], (16, 64)) * 0.1
    ls, lb = jnp.ones(16), jnp.zeros(16)
    got = fused_adapter(x, a, b, ls, lb, block_t=32, interpret=True)
    want = apply_adapter(x, a, b, ls, lb, activation="gelu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
