"""Serving engine: continuous batching, per-request profiles, precompute
parity, ragged slot lengths."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import forward, init_lm, lm_logits
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, params, store


def test_engine_drains_and_generates(setup):
    cfg, params, store = setup
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64)
    reqs = [Request(uid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                    profile_id=i % 3, max_new_tokens=5) for i in range(5)]
    eng.run_until_drained(list(reqs))
    for r in reqs:
        assert r.done and len(r.generated) >= 5


def test_precompute_parity(setup):
    """Admission-time aggregated adapters produce (numerically) the same
    decode logits as per-step mask aggregation — compared at the logit
    level because argmax of an untrained model can flip on fp ties."""
    cfg, params, store = setup
    from repro.core import xpeft as XPC
    wa, wb = store.mask_weights(0)
    ln_s, ln_b = store.ln_affines([0])
    prof = {"ln_scale": ln_s[0], "ln_bias": ln_b[0]}
    toks = jnp.arange(8)[None] % cfg.vocab_size
    dense = {"w_a": wa[None], "w_b": wb[None],
             "ln_scale": ln_s, "ln_bias": ln_b}
    h1, _, _ = forward(params, toks, cfg, profile_masks=dense)
    bank = params["xpeft_bank"]
    a_hat = jnp.einsum("ln,lndb->ldb", wa, bank["bank_a"].astype(jnp.float32))
    b_hat = jnp.einsum("ln,lnbd->lbd", wb, bank["bank_b"].astype(jnp.float32))
    pre = {"a_hat": a_hat[None].astype(bank["bank_a"].dtype),
           "b_hat": b_hat[None].astype(bank["bank_b"].dtype),
           "ln_scale": prof["ln_scale"][None],
           "ln_bias": prof["ln_bias"][None]}
    h2, _, _ = forward(params, toks, cfg, profile_masks=pre)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-3, atol=2e-3)


def test_profiles_change_generation(setup):
    """Different profiles (different masks) must produce different logits."""
    cfg, params, store = setup
    wa0, wb0 = store.mask_weights(0)
    wa1, wb1 = store.mask_weights(1)
    assert not np.allclose(np.asarray(wa0), np.asarray(wa1))
    toks = jnp.arange(8)[None, :] % cfg.vocab_size
    outs = []
    for pid in (0, 1):
        wa, wb = store.mask_weights(pid)
        ln_s, ln_b = store.ln_affines([pid])
        masks = {"w_a": wa[None], "w_b": wb[None],
                 "ln_scale": ln_s, "ln_bias": ln_b}
        h, _, _ = forward(params, toks, cfg, profile_masks=masks)
        outs.append(np.asarray(lm_logits(params, h[:, -1:], cfg)))
    assert not np.allclose(outs[0], outs[1], atol=1e-5)


def test_engine_decode_matches_full_forward(setup):
    """Greedy engine tokens == argmax of a from-scratch full forward at each
    step (KV-cache/ragged-slot correctness)."""
    cfg, params, store = setup
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      precompute=False)
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6]) % cfg.vocab_size
    req = Request(uid=0, prompt=prompt, profile_id=0, max_new_tokens=4)
    eng.admit(req)
    for _ in range(3):
        eng.step()
    wa, wb = store.mask_weights(0)
    ln_s, ln_b = store.ln_affines([0])
    masks = {"w_a": wa[None], "w_b": wb[None],
             "ln_scale": ln_s, "ln_bias": ln_b}
    seq = list(prompt)
    for t, expect in enumerate(req.generated):
        h, _, _ = forward(params, jnp.asarray([seq]), cfg,
                          profile_masks=masks)
        nxt = int(jnp.argmax(lm_logits(params, h[:, -1:], cfg)[0, -1]))
        assert nxt == expect, (t, nxt, expect)
        seq.append(nxt)
