"""Continuous-batching serving engine (paged KV + adapter-slot memory).

Contracts: per-request token ids BITWISE equal to the windowed engine on a
skewed-length workload (including under forced preempt/resume), strictly
less slot stranding, ONE decode trace across admissions/preemptions/
resumes, and the scheduler's age-promotion valve for exact-length buckets.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import xpeft as XP
from repro.core.profiles import ProfileStore
from repro.models import init_lm
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request, Scheduler


def skewed(cfg, n, *, long_new=20, seed=0):
    from benchmarks.cb_smoke import skewed_requests
    return skewed_requests(cfg, n, seed=seed, long_new=long_new)


@pytest.fixture(scope="module")
def setup():
    cfg = reduce_for_smoke(get_config("qwen1.5-0.5b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    return cfg, params, store


def drain(setup, *, continuous, n=6, long_new=20, **kw):
    cfg, params, store = setup
    eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                      sync_every=4, continuous=continuous, page_size=16,
                      **kw)
    reqs = skewed(cfg, n, long_new=long_new)
    eng.run_until_drained(reqs)
    return eng, {r.uid: list(map(int, r.generated)) for r in reqs}


@pytest.fixture(scope="module")
def windowed_ref(setup):
    eng, toks = drain(setup, continuous=False)
    return {"tokens": toks, "stranded": eng.stranded_slot_steps,
            "device_steps": eng.slots.device_steps}


def test_cb_bitwise_parity_and_less_stranding(setup, windowed_ref):
    eng, toks = drain(setup, continuous=True)
    assert toks == windowed_ref["tokens"]          # bitwise token parity
    st = eng.serve_stats()
    assert st["step_traces"] == 1
    # the whole point: short requests stop waiting out the wave straggler
    assert eng.stranded_slot_steps < windowed_ref["stranded"]
    assert eng.slots.device_steps < windowed_ref["device_steps"]
    assert "stranded_slot_steps" in st
    eng.page_alloc.check()
    eng.mask_alloc.check()


def test_preempt_resume_bitwise(setup):
    """A starved page pool (5 pages < 4 + 2 a long plus a short request
    want) forces preempt-to-pending swaps; resumed requests must still
    produce bitwise the windowed tokens, through the SAME compiled step.
    long_new=50 pushes the long requests to ~4 pages of the 64-seq cache."""
    _, ref = drain(setup, continuous=False, n=6, long_new=50)
    eng, toks = drain(setup, continuous=True, n=6, long_new=50, max_pages=5)
    st = eng.serve_stats()
    assert st["preemptions"] > 0 and st["resumes"] > 0
    assert toks == ref
    assert st["step_traces"] == 1
    eng.page_alloc.check()


def test_recurrent_arch_continuous_parity():
    """Pure-recurrent archs have no paged leaves (O(1) state per slot):
    the continuous engine must still run — mid-stream admission + pooled
    mask entries — and match the windowed tokens bitwise."""
    cfg = reduce_for_smoke(get_config("rwkv6-7b"))
    key = jax.random.key(0)
    params = init_lm(key, cfg)
    store = ProfileStore(cfg.num_layers, cfg.xpeft.num_adapters,
                         cfg.xpeft.bottleneck, "hard", cfg.xpeft.k)
    table = XP.init_profile_table(key, cfg)
    for pid in range(3):
        store.add_profile(pid, jax.tree.map(lambda t: t[pid], table))
    out = {}
    for cont in (False, True):
        eng = ServeEngine(cfg, params, store, max_slots=2, max_seq=64,
                          sync_every=4, continuous=cont, page_size=16)
        reqs = skewed(cfg, 4, long_new=10)
        eng.run_until_drained(reqs)
        out[cont] = {r.uid: list(map(int, r.generated)) for r in reqs}
        if cont:
            assert eng.serve_stats()["step_traces"] == 1
    assert out[True] == out[False]


# ------------------------------------------------------------------ scheduler
def _flood(n, length=5, base=100, max_new=2):
    rng = np.random.default_rng(0)
    return [Request(uid=base + i,
                    prompt=rng.integers(0, 50, size=length),
                    profile_id=0, max_new_tokens=max_new)
            for i in range(n)]


def test_exact_length_starvation_without_promotion():
    """Under efficiency policy + exact-length buckets (recurrent archs), a
    one-off prompt length is a bucket of size 1 that largest-first never
    picks while the common length keeps flowing."""
    sched = Scheduler("mamba", policy="efficiency", max_wait_waves=None)
    rare = Request(uid=0, prompt=np.arange(9), profile_id=0)
    sched.submit(_flood(2))
    sched.submit(rare)
    for wave in range(10):
        sched.submit(_flood(2, base=200 + 10 * wave))   # steady flood
        picked = sched.next_batch(2)
        assert rare not in picked
    assert rare.waits >= 10


def test_max_wait_waves_promotes_starved_bucket():
    """The same flood with max_wait_waves=2: the rare length leads a wave
    as soon as its age hits the valve — the anti-starvation guarantee the
    exact-length archs (rwkv/mamba/zamba) rely on."""
    sched = Scheduler("mamba", policy="efficiency", max_wait_waves=2)
    rare = Request(uid=0, prompt=np.arange(9), profile_id=0)
    sched.submit(_flood(2))
    sched.submit(rare)
    admitted_at = None
    for wave in range(10):
        sched.submit(_flood(2, base=200 + 10 * wave))
        if rare in sched.next_batch(2):
            admitted_at = wave
            break
    assert admitted_at is not None and admitted_at <= 3
    assert sched.stats()["promoted"] >= 1


def test_requeue_front_preserves_order():
    """Requests the page pool declined go back to the HEAD of the queue in
    their original order — a declined admission never loses its place."""
    sched = Scheduler("attn")
    reqs = _flood(6, length=5)
    sched.submit(reqs)
    first = sched.next_batch(2)
    assert [r.uid for r in first] == [reqs[0].uid, reqs[1].uid]
    sched.requeue_front(first)
    assert sched.stats()["requeued"] == 2
    again = sched.next_batch(2)
    assert [r.uid for r in again] == [r.uid for r in first]
